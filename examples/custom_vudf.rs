//! Extending the engine with a user-registered VUDF (paper §III-D:
//! "FlashMatrix allows programmers to extend the framework by registering
//! new VUDFs"). A soft-threshold (shrinkage) operator is registered and
//! used through `fm.sapply` like any built-in — it participates in lazy
//! fusion and parallel execution automatically.
//!
//! Run: `cargo run --release --example custom_vudf`

use std::sync::Arc;

use flashmatrix::dtype::DType;
use flashmatrix::fmr::{Engine, EngineExt};
use flashmatrix::vudf::{Buf, CustomVudf};
use flashmatrix::EngineConfig;

/// Soft-threshold: sign(x) * max(|x| - lambda, 0) — LASSO's prox operator.
struct SoftThreshold {
    lambda: f64,
}

impl CustomVudf for SoftThreshold {
    fn name(&self) -> &str {
        "soft_threshold"
    }

    fn out_dtype(&self, input: DType) -> DType {
        input
    }

    // The vectorized (uVUDF) form: one call per CPU-partition strip.
    fn unary(&self, a: &Buf) -> flashmatrix::Result<Buf> {
        let l = self.lambda;
        match a {
            Buf::F64(v) => Ok(Buf::F64(
                v.iter()
                    .map(|&x| x.signum() * (x.abs() - l).max(0.0))
                    .collect(),
            )),
            other => {
                let v: Vec<f64> = other
                    .to_f64_vec()
                    .iter()
                    .map(|&x| x.signum() * (x.abs() - l).max(0.0))
                    .collect();
                Buf::F64(v).cast(other.dtype())
            }
        }
    }
}

fn main() -> flashmatrix::Result<()> {
    let eng = Engine::new(EngineConfig::default())?;

    // register once; usable from any matrix bound to this engine
    eng.registry.register(Arc::new(SoftThreshold { lambda: 0.5 }));
    println!("registered VUDFs: {:?}", eng.registry.names());

    let x = eng.runif_matrix(2_000_000, 8, -1.0, 1.0, 7);

    // shrunk = sapply(x, soft_threshold); fuses with downstream ops
    let shrunk = x.sapply_custom("soft_threshold")?;
    let sparsity = {
        let nz = shrunk.sapply(flashmatrix::vudf::UnOp::NotZero)?;
        nz.agg(flashmatrix::vudf::AggOp::Sum)?.as_f64() / (2_000_000.0 * 8.0)
    };
    println!("non-zero fraction after soft-threshold(0.5): {sparsity:.4} (expect ~0.5)");

    // the custom node composes with built-ins in one fused pass
    let energy_kept = shrunk.sq()?.sum()? / x.sq()?.sum()?;
    println!("energy kept: {:.1}%", energy_kept * 100.0);
    assert!(sparsity > 0.45 && sparsity < 0.55);
    Ok(())
}
