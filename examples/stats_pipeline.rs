//! Out-of-core statistics pipeline: summary -> correlation -> SVD on a
//! matrix that lives on the (simulated) SSD array, never fully in memory.
//! Demonstrates the paper's §IV-C scenario: constant-pass algorithms whose
//! EM execution approaches IM performance as columns grow.
//!
//! Run: `cargo run --release --example stats_pipeline -- [--n 400000] [--p 64]`

use flashmatrix::algs;
use flashmatrix::datasets;
use flashmatrix::harness::{engine_for, Mode, Scale};
use flashmatrix::util::cli::Args;

fn main() -> flashmatrix::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let mut s = Scale::default();
    s.n = args.u64_or("n", 400_000);
    let p = args.u64_or("p", 64);

    let eng = engine_for(&s, Mode::FmEm, s.threads)?;
    println!(
        "== out-of-core stats pipeline: {}x{} ({:.2} GB) on simulated SSDs ({} MB/s) ==",
        s.n,
        p,
        (s.n * p * 8) as f64 / 1e9,
        s.ssd_bps >> 20
    );
    let t0 = std::time::Instant::now();
    let x = datasets::uniform(&eng, s.n, p, -1.0, 1.0, 99, Some("stats_demo.mat"))?;
    println!("dataset written to SSD in {:.2}s", t0.elapsed().as_secs_f64());
    eng.metrics.reset();

    // 1. multivariate summary — ONE pass for all seven statistics
    let t0 = std::time::Instant::now();
    let sm = algs::summary(&x)?;
    let m1 = eng.metrics.snapshot();
    println!(
        "summary     : {:6.2}s  {:.2} GB read  (mean[0]={:+.4} var[0]={:.4} nnz[0]={})",
        t0.elapsed().as_secs_f64(),
        m1.io_read_bytes as f64 / 1e9,
        sm.mean[0],
        sm.var[0],
        sm.nnz[0]
    );

    // 2. correlation — the paper's two passes (means, centered Gramian)
    let t0 = std::time::Instant::now();
    let corr = algs::correlation(&x)?;
    let m2 = eng.metrics.snapshot().delta_since(&m1);
    let max_off = (0..p as usize)
        .flat_map(|i| (0..p as usize).map(move |j| (i, j)))
        .filter(|(i, j)| i != j)
        .map(|(i, j)| corr.corr[i * p as usize + j].abs())
        .fold(0.0, f64::max);
    println!(
        "correlation : {:6.2}s  {:.2} GB read  (max |off-diag| = {max_off:.4})",
        t0.elapsed().as_secs_f64(),
        m2.io_read_bytes as f64 / 1e9
    );

    // 3. SVD — Gramian pass + host eigensolve; top 10 singular values
    let t0 = std::time::Instant::now();
    let svd = algs::svd(&x, 10)?;
    let m3 = eng.metrics.snapshot().delta_since(&m1);
    println!(
        "svd (top 10): {:6.2}s  sigma = {:?}",
        t0.elapsed().as_secs_f64(),
        svd.sigma.iter().map(|v| (v * 100.0).round() / 100.0).collect::<Vec<_>>()
    );
    let _ = m3;

    let mt = eng.metrics.snapshot();
    println!(
        "\ntotal I/O: {:.2} GB read, {:.2} GB written; peak tracked memory {:.3} GB \
         — the pipeline never held the matrix in RAM",
        mt.io_read_bytes as f64 / 1e9,
        mt.io_write_bytes as f64 / 1e9,
        mt.mem_peak as f64 / 1e9
    );
    Ok(())
}
