//! End-to-end driver (DESIGN.md headline experiment): k-means and GMM on a
//! MixGaussian dataset across the three execution modes the paper
//! compares — FM-IM (in-memory), FM-EM (out-of-core on the simulated SSD
//! array) and the eager MLlib-like baseline — reporting runtime,
//! throughput, peak memory and clustering quality (centroid recovery).
//!
//! Run: `cargo run --release --example kmeans_clustering -- [--n 500000] [--k 10]`

use flashmatrix::algs;
use flashmatrix::datasets;
use flashmatrix::harness::{engine_for, Mode, Scale};
use flashmatrix::util::cli::Args;

fn main() -> flashmatrix::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let mut s = Scale::default();
    s.n = args.u64_or("n", 500_000);
    s.iters = args.usize_or("iters", 5);
    let k = args.usize_or("k", 10);
    let p = 32u64;

    println!("== FlashMatrix end-to-end: k-means + GMM on MixGaussian {}x{p}, k={k} ==", s.n);

    let mut im_kmeans_secs = 0.0;
    for mode in [Mode::FmIm, Mode::FmEm, Mode::MllibLike] {
        // the eager baseline gets a 10x smaller input; times are
        // normalized per row for comparability (see harness::fig6a)
        let n = if mode == Mode::MllibLike { s.n / 10 } else { s.n };
        let eng = engine_for(&s, mode, s.threads)?;
        let t0 = std::time::Instant::now();
        let (x, true_means) = datasets::mix_gaussian(&eng, n, p, k as u64, 8.0, 42, None)?;
        let gen_secs = t0.elapsed().as_secs_f64();
        eng.metrics.reset();

        // ---- k-means
        let t0 = std::time::Instant::now();
        let km = algs::kmeans(&x, k, s.iters, 1)?;
        let km_secs = t0.elapsed().as_secs_f64() * (s.n as f64 / n as f64);
        if mode == Mode::FmIm {
            im_kmeans_secs = km_secs;
        }

        // quality: every fitted centroid close to a true component mean
        let mut worst = 0.0f64;
        for ci in 0..k {
            let best = (0..k)
                .map(|t| {
                    (0..p as usize)
                        .map(|j| {
                            let d = km.centroids.get(ci, j).as_f64() - true_means.get(t, j).as_f64();
                            d * d
                        })
                        .sum::<f64>()
                        .sqrt()
                })
                .fold(f64::INFINITY, f64::min);
            worst = worst.max(best);
        }

        // ---- GMM (fewer iterations; it is ~k x heavier per pass)
        let t0 = std::time::Instant::now();
        let gm = algs::gmm(&x, k, 2, 1)?;
        let gmm_secs = t0.elapsed().as_secs_f64() * (s.n as f64 / n as f64);

        let m = eng.metrics.snapshot();
        let gb = (s.n * p * 8) as f64 / 1e9;
        println!("\n-- {} (dataset {:.1}s) --", mode.label(), gen_secs);
        println!(
            "  kmeans : {km_secs:7.2}s  ({:5.2} GB/s/iter)  wcss {:.3e} -> {:.3e}  worst-centroid-err {worst:.3}",
            gb * s.iters as f64 / km_secs,
            km.wcss.first().unwrap(),
            km.wcss.last().unwrap(),
        );
        println!(
            "  gmm    : {gmm_secs:7.2}s  loglik {:.4e} -> {:.4e}",
            gm.loglik.first().unwrap(),
            gm.loglik.last().unwrap()
        );
        println!(
            "  io read {:.2} GB in {} reqs; peak tracked mem {:.2} GB; xla/native partitions {}/{}",
            m.io_read_bytes as f64 / 1e9,
            m.io_read_reqs,
            m.mem_peak as f64 / 1e9,
            m.xla_dispatches,
            m.native_partitions
        );
        if mode == Mode::FmEm && im_kmeans_secs > 0.0 {
            println!(
                "  headline: EM kmeans at {:.0}% of IM performance",
                100.0 * im_kmeans_secs / km_secs
            );
        }
    }
    Ok(())
}
