//! Perf probe: sensitivity of the fused pipeline to the CPU-level strip
//! size (the paper's CPU-partition size, §III-B1). Used during the §Perf
//! pass (EXPERIMENTS.md) to verify the 64 KiB default sits on the flat
//! part of the curve.
//!
//! Run: `cargo run --release --example strip_probe`

use flashmatrix::config::EngineConfig;
use flashmatrix::datasets;
use flashmatrix::fmr::Engine;

fn main() {
    for kb in [16usize, 64, 128, 256, 512, 1024] {
        let eng = Engine::new(EngineConfig {
            cpu_part_bytes: kb << 10,
            xla_dispatch: false,
            ..Default::default()
        })
        .unwrap();
        let x = datasets::uniform(&eng, 800_000, 32, -1.0, 1.0, 3, None).unwrap();
        let t0 = std::time::Instant::now();
        for _ in 0..3 {
            flashmatrix::algs::summary(&x).unwrap();
        }
        let su = t0.elapsed().as_secs_f64() / 3.0;
        let t0 = std::time::Instant::now();
        flashmatrix::algs::kmeans(&x, 10, 2, 1).unwrap();
        let km = t0.elapsed().as_secs_f64();
        println!(
            "strip {kb:4} KiB: summary {su:.3}s ({:.2} GB/s)  kmeans(2 iter) {km:.3}s",
            (800_000.0 * 32.0 * 8.0) / su / 1e9
        );
    }
}
