//! Quickstart: the R-like `fmr` API in 60 lines.
//!
//! Mirrors the paper's programming model: build matrices with `fm.*`
//! constructors, chain GenOp-backed operations lazily, and let the engine
//! run everything in one fused, parallel pass when a result is needed.
//!
//! Run: `cargo run --release --example quickstart`

use flashmatrix::dtype::Scalar;
use flashmatrix::fmr::{Engine, EngineExt};
use flashmatrix::vudf::AggOp;
use flashmatrix::EngineConfig;

fn main() -> flashmatrix::Result<()> {
    // An in-memory engine with default (fully-optimized) configuration.
    let eng = Engine::new(EngineConfig::default())?;

    // fm.runif.matrix(1e6, 4): a million-row random matrix. Nothing is
    // computed yet — this is a virtual matrix.
    let x = eng.runif_matrix(1_000_000, 4, -1.0, 1.0, 42);

    // R: y <- abs(x) + x^2 * 0.5       (still virtual: a 4-node DAG)
    let y = x.abs()?.add(&x.sq()?.mul_scalar(0.5)?)?;

    // R: sum(y) — a sink; the whole DAG fuses into ONE parallel pass.
    let total = y.sum()?;
    println!("sum(|x| + 0.5 x^2)  = {total:.3}");

    // R: colSums(x^2) — another single fused pass.
    let l2 = x.sq()?.col_sums()?;
    println!("colSums(x^2)        = {:?}", l2.buf.to_f64_vec());

    // Row reductions stay lazy (they keep the long dimension): chain them.
    let row_norm = x.sq()?.row_sums()?.sqrt()?;
    println!("max row norm        = {:.4}", row_norm.max()?);

    // Generalized operators: count rows whose norm exceeds 1.
    let big = row_norm.mapply_scalar(Scalar::F64(1.0), flashmatrix::vudf::BinOp::Gt, true)?;
    let count = big.agg(AggOp::Sum)?.as_i64();
    println!("rows with norm > 1  = {count}");

    // Transpose is a zero-copy view; t(X) %*% X is the Gramian sink.
    let g = x.crossprod(&x)?;
    println!("gramian diag        = {:?}", (0..4).map(|i| g.get(i, i).as_f64()).collect::<Vec<_>>());

    // Matrices are immutable; every op returned a new (virtual) matrix and
    // dropped intermediates were garbage-collected automatically.
    println!("engine peak memory  = {:.1} MB", eng.metrics.snapshot().mem_peak as f64 / 1e6);
    Ok(())
}
