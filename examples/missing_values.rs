//! The paper's Fig 5, verbatim: standard deviation of a dataset with
//! missing values, written exactly as the R code of §III-D —
//!
//! ```R
//! isna.X <- fm.sapply(X, isna)
//! X0     <- fm.mapply(X,   isna.X, ifelse0)   # NAs -> 0
//! X2     <- fm.mapply(X^2, isna.X, ifelse0)
//! n      <- sum(!isna.X);  s <- sum(X0);  ss <- sum(X2)
//! sd     <- sqrt((ss - s^2/n) / (n-1))
//! ```
//!
//! All three sums (the paper's three sink matrices) are materialized
//! TOGETHER in one fused streaming pass over X — the exact DAG of Fig 5.
//!
//! Run: `cargo run --release --example missing_values`

use flashmatrix::fmr::{Engine, EngineExt};
use flashmatrix::vudf::{AggOp, BinOp, UnOp};
use flashmatrix::EngineConfig;

fn main() -> flashmatrix::Result<()> {
    let eng = Engine::new(EngineConfig::default())?;
    let n_rows = 2_000_000u64;

    // X ~ N(3, 2) with ~5% NaN entries (NaN injected through an expression:
    // where u < 0.05, 0/0 = NaN, else x)
    let x_clean = eng.rnorm_matrix(n_rows, 1, 3.0, 2.0, 11);
    let u = eng.runif_matrix(n_rows, 1, 0.0, 1.0, 12);
    let mask = u
        .mapply_scalar(flashmatrix::dtype::Scalar::F64(0.05), BinOp::Lt, true)?
        .cast(flashmatrix::dtype::DType::F64)?;
    let notmask = mask.mapply_scalar(flashmatrix::dtype::Scalar::F64(1.0), BinOp::Sub, false)?; // 1-mask
    // x = ifelse0(x_clean, mask) + ifelse0(NaN, !mask):
    //   unmasked rows keep x_clean (+0); masked rows get 0 + NaN = NaN
    let nan = eng.fill(flashmatrix::dtype::Scalar::F64(f64::NAN), n_rows, 1);
    let x = x_clean
        .mapply(&mask, BinOp::IfElse0)?
        .add(&nan.mapply(&notmask, BinOp::IfElse0)?)?;

    // ---- Fig 5's DAG --------------------------------------------------
    let isna = x.sapply(UnOp::IsNa)?; // fm.sapply(X, isna)
    let isna_f = isna.cast(flashmatrix::dtype::DType::F64)?;
    let x0 = x.mapply(&isna_f, BinOp::IfElse0)?; // replace NAs with 0
    let x2 = x.sq()?.mapply(&isna_f, BinOp::IfElse0)?;

    // the three sink matrices of Fig 5, one fused pass (fm.materialize)
    let sinks = vec![
        isna.agg_sink(AggOp::Sum), // number of NAs
        x0.agg_sink(AggOp::Sum),
        x2.agg_sink(AggOp::Sum),
    ];
    let rs = eng.materialize_sinks(&sinks)?;
    let n_na = rs[0].scalar().as_f64();
    let s = rs[1].scalar().as_f64();
    let ss = rs[2].scalar().as_f64();

    let n = n_rows as f64 - n_na;
    let mean = s / n;
    let sd = ((ss - n * mean * mean) / (n - 1.0)).sqrt();
    println!("rows             = {n_rows}");
    println!("missing values   = {n_na} ({:.2}%)", 100.0 * n_na / n_rows as f64);
    println!("mean (excl. NA)  = {mean:.4}   (truth 3.0)");
    println!("sd   (excl. NA)  = {sd:.4}   (truth 2.0)");
    assert!((mean - 3.0).abs() < 0.01);
    assert!((sd - 2.0).abs() < 0.01);
    assert!(n_na > 0.0);
    println!("Fig 5 pipeline reproduced: one pass, three fused sinks.");
    Ok(())
}
