#!/usr/bin/env python3
"""Benchmark regression gate.

Compares the machine-readable ``BENCH_<name>.json`` reports emitted by the
bench binaries (schema: ``rust/src/harness.rs::BenchReport``) against the
committed ``rust/benches/baseline.json`` and exits non-zero when:

* a baselined bench emitted no report at all;
* a baselined row's wall-time exceeds its baseline by more than
  ``max_regression`` (default 25%);
* a counter listed for a row is missing from the emitted row (renamed or
  dropped counters fail CI exactly like a slowdown);
* a baselined acceptance check is missing or reported ``"pass": false``;
* a report's ``schema_version`` is one this gate does not know.

Rows are matched by their ``label`` across all tables of a report (labels
are unique within a bench). Only rows named in the baseline are gated —
benches may add rows freely without touching the baseline.

Usage:
    python3 python/bench_gate.py rust/benches/baseline.json <json-dir>

The refresh procedure for baseline numbers is documented in the header of
``rust/benches/baseline.json`` itself.
"""

import json
import os
import sys

KNOWN_SCHEMA_VERSIONS = {1}


def flatten_rows(report):
    """{label: row-object} across every table of one bench report."""
    rows = {}
    for table in report.get("tables", []):
        for row in table.get("rows", []):
            rows.setdefault(row.get("label"), row)
    return rows


def gate_bench(name, spec, report, max_regression, failures):
    version = report.get("schema_version")
    if version not in KNOWN_SCHEMA_VERSIONS:
        failures.append(f"{name}: unknown schema_version {version!r}")
        return

    rows = flatten_rows(report)
    for label, row_spec in spec.get("rows", {}).items():
        row = rows.get(label)
        if row is None:
            failures.append(f"{name}: baselined row '{label}' missing from report")
            continue
        base_secs = row_spec.get("secs")
        if base_secs is not None:
            limit = base_secs * (1.0 + max_regression)
            got = row.get("value")
            if not isinstance(got, (int, float)):
                failures.append(f"{name}/{label}: wall-time value missing")
            elif row.get("unit") != "s":
                failures.append(
                    f"{name}/{label}: expected a seconds row, got unit "
                    f"{row.get('unit')!r}"
                )
            elif got > limit:
                failures.append(
                    f"{name}/{label}: wall-time regression — {got:.3f}s > "
                    f"{base_secs:.3f}s +{max_regression:.0%} ({limit:.3f}s)"
                )
            else:
                print(f"ok   {name}/{label}: {got:.3f}s <= {limit:.3f}s")
        for counter in row_spec.get("counters", []):
            if counter not in row:
                failures.append(f"{name}/{label}: counter '{counter}' missing")

    checks = {c.get("name"): c.get("pass") for c in report.get("checks", [])}
    for check in spec.get("checks", []):
        if check not in checks:
            failures.append(f"{name}: acceptance check '{check}' missing")
        elif checks[check] is not True:
            failures.append(f"{name}: acceptance check '{check}' FAILED")
        else:
            print(f"ok   {name}: check '{check}' passed")


def main(argv):
    if len(argv) != 3:
        print(__doc__)
        return 2
    baseline_path, json_dir = argv[1], argv[2]
    with open(baseline_path) as f:
        baseline = json.load(f)
    max_regression = baseline.get("max_regression", 0.25)

    failures = []
    for name, spec in baseline["benches"].items():
        path = os.path.join(json_dir, f"BENCH_{name}.json")
        if not os.path.exists(path):
            failures.append(f"{name}: {path} was not emitted")
            continue
        with open(path) as f:
            report = json.load(f)
        gate_bench(name, spec, report, max_regression, failures)

    if failures:
        print(f"\nbench gate: {len(failures)} failure(s)", file=sys.stderr)
        for f_ in failures:
            print(f"  FAIL {f_}", file=sys.stderr)
        return 1
    print("\nbench gate: all baselined rows, counters and checks green")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
