"""L1 Pallas kernel: fused multivariate-summary pass (column statistics).

One streaming pass over a (rows, p) partition producing, per column:
min, max, sum, sum of squares, sum of |x| and non-zero count — the six
accumulators from which the paper's "multivariate statistical summary"
(min / max / mean / L1 / L2 / nnz / variance) derives.

FlashMatrix computes these with six fused `fm.agg.col` GenOps sharing one
scan of X (cache-fuse). Here the same fusion is a single Pallas kernel:
the grid walks row tiles; every grid step loads one tile into VMEM and
folds it into a (6, p) accumulator block that lives at the same output
offset for all steps — the standard Pallas cross-step accumulation
pattern (sequential grid), mirroring the per-thread partial aggregation
+ merge of §III-F.

VMEM per step (tile=4096, p≤512, f64): tile 16 MiB (p=512 uses 2048-row partitions, 8 MiB) + acc 24 KiB — fits.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 4096


def _colstats_kernel(x_ref, acc_ref):
    """Fold one (tile, p) block into the (6, p) accumulator."""
    i = pl.program_id(0)
    x = x_ref[...]

    @pl.when(i == 0)
    def _init():
        acc_ref[0, :] = jnp.full_like(x[0], jnp.inf)
        acc_ref[1, :] = jnp.full_like(x[0], -jnp.inf)
        acc_ref[2, :] = jnp.zeros_like(x[0])
        acc_ref[3, :] = jnp.zeros_like(x[0])
        acc_ref[4, :] = jnp.zeros_like(x[0])
        acc_ref[5, :] = jnp.zeros_like(x[0])

    acc_ref[0, :] = jnp.minimum(acc_ref[0, :], jnp.min(x, axis=0))
    acc_ref[1, :] = jnp.maximum(acc_ref[1, :], jnp.max(x, axis=0))
    acc_ref[2, :] = acc_ref[2, :] + jnp.sum(x, axis=0)
    acc_ref[3, :] = acc_ref[3, :] + jnp.sum(x * x, axis=0)
    acc_ref[4, :] = acc_ref[4, :] + jnp.sum(jnp.abs(x), axis=0)
    acc_ref[5, :] = acc_ref[5, :] + jnp.sum((x != 0).astype(x.dtype), axis=0)


@functools.partial(jax.jit, static_argnames=("tile",))
def colstats(x: jnp.ndarray, tile: int = DEFAULT_TILE) -> jnp.ndarray:
    """Fused column statistics of a (rows, p) partition; rows % tile == 0.

    Returns a (6, p) matrix: [min, max, sum, sumsq, sumabs, nnz].
    """
    rows, p = x.shape
    if rows % tile != 0:
        raise ValueError(f"rows ({rows}) must be a multiple of tile ({tile})")
    return pl.pallas_call(
        _colstats_kernel,
        grid=(rows // tile,),
        in_specs=[pl.BlockSpec((tile, p), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((6, p), lambda i: (0, 0)),  # same block ∀ steps
        out_shape=jax.ShapeDtypeStruct((6, p), x.dtype),
        interpret=True,
    )(x)
