"""L1 Pallas kernel: fused pairwise-distance + argmin (k-means assignment).

This is the paper's hottest loop — for every data point, the squared
Euclidean distance to every centroid and the index of the nearest one
(FlashMatrix expresses it as `fm.inner.prod` with (sub, sq-add) VUDFs
followed by `fm.agg.row(min)`; the engine fuses them in CPU cache).

Hardware adaptation (paper: SSD->DRAM->L1 streaming; here: HBM->VMEM tiles):
  * the grid walks row tiles of X — one tile ≙ one CPU-level partition.
    Each tile is resident in VMEM while *all* fused work (matmul, +norms,
    min, argmin) completes, exactly the cache-fuse schedule of §III-F.
  * the centroid matrix C (k×p, tiny) is mapped whole into VMEM and
    revisited by every grid step — the analogue of the paper keeping the
    per-iteration state matrices in CPU cache.
  * distances are computed as ||x||² - 2·X@Cᵀ + ||c||² so the dominant
    FLOPs are a (tile × p) @ (p × k) matmul that targets the MXU systolic
    array; the elementwise epilogue (adds, min, argmin) is VPU work on an
    already-resident tile.

VMEM footprint per grid step (defaults tile=4096, p=32, k≤64, f64):
  x tile 1 MiB + C ≤16 KiB + d tile ≤512 KiB + outputs ≤40 KiB ≈ 0.8 MiB,
  comfortably under a 16 MiB VMEM budget; documented for DESIGN.md §Perf.

`interpret=True` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; correctness is validated through the interpret path and
TPU efficiency is argued structurally (see DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 4096


def _assign_kernel(x_ref, c_ref, assign_ref, mind_ref):
    """One grid step: assignment for a (tile, p) row block of X.

    x_ref: (tile, p) data tile; c_ref: (k, p) full centroid matrix;
    assign_ref: (tile,) int32 out; mind_ref: (tile,) out.
    """
    x = x_ref[...]
    c = c_ref[...]
    # MXU path: the matmul dominates; norms + broadcast adds are epilogue.
    x2 = jnp.sum(x * x, axis=1, keepdims=True)  # (tile, 1)
    c2 = jnp.sum(c * c, axis=1)  # (k,)
    d = x2 - 2.0 * jnp.dot(x, c.T) + c2[None, :]  # (tile, k)
    assign_ref[...] = jnp.argmin(d, axis=1).astype(jnp.int32)
    mind_ref[...] = jnp.min(d, axis=1)


@functools.partial(jax.jit, static_argnames=("tile",))
def kmeans_assign(x: jnp.ndarray, c: jnp.ndarray, tile: int = DEFAULT_TILE):
    """Fused assignment over a (rows, p) partition; rows % tile == 0.

    Returns (assign (rows,) int32, mindist (rows,) x.dtype).
    """
    rows, p = x.shape
    k = c.shape[0]
    if rows % tile != 0:
        raise ValueError(f"rows ({rows}) must be a multiple of tile ({tile})")
    grid = (rows // tile,)
    return pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, p), lambda i: (i, 0)),
            pl.BlockSpec((k, p), lambda i: (0, 0)),  # whole C every step
        ],
        out_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows,), jnp.int32),
            jax.ShapeDtypeStruct((rows,), x.dtype),
        ],
        interpret=True,
    )(x, c)
