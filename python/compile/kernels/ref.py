"""Pure-jnp reference oracles for the Pallas kernels and the L2 models.

Everything here is the *specification*: simple, obviously-correct jnp code.
`python/tests/` asserts the Pallas kernels and the jitted model functions
match these within tolerance, and the Rust engine's native GenOp path is
cross-checked against the same numbers through golden fixtures
(tests/test_golden.py dumps vectors consumed by `rust/tests/`).
"""

from __future__ import annotations

import jax.numpy as jnp
import jax.scipy.special


def pairwise_sqdist(x: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Squared Euclidean distance matrix.

    x: (n, p) data points, c: (k, p) centroids -> (n, k).
    Uses the expanded form ||x||^2 - 2 x.c + ||c||^2, the same formulation
    the Pallas kernel uses so that the dominant FLOPs are a matmul.
    """
    x2 = jnp.sum(x * x, axis=1, keepdims=True)  # (n, 1)
    c2 = jnp.sum(c * c, axis=1)  # (k,)
    return x2 - 2.0 * (x @ c.T) + c2[None, :]


def kmeans_assign(x: jnp.ndarray, c: jnp.ndarray):
    """Assignment step: nearest centroid index and its squared distance."""
    d = pairwise_sqdist(x, c)
    assign = jnp.argmin(d, axis=1).astype(jnp.int32)
    mind = jnp.min(d, axis=1)
    return assign, mind


def kmeans_step(x: jnp.ndarray, c: jnp.ndarray):
    """Full k-means partition step: per-cluster sums, counts, WCSS, assign.

    Returns (sums (k,p), counts (k,), wcss scalar, assign (n,) i32).
    The caller (one call per I/O-level partition) merges sums/counts/wcss
    additively across partitions, then divides — the paper's sink-matrix
    partial-aggregation merge.
    """
    assign, mind = kmeans_assign(x, c)
    k = c.shape[0]
    onehot = (assign[:, None] == jnp.arange(k)[None, :]).astype(x.dtype)
    sums = onehot.T @ x  # (k, p)
    counts = jnp.sum(onehot, axis=0)  # (k,)
    wcss = jnp.sum(mind)
    return sums, counts, wcss, assign


def colstats(x: jnp.ndarray) -> jnp.ndarray:
    """Fused multivariate summary pass.

    Returns a (6, p) matrix with rows
      0: column min        1: column max      2: column sum
      3: column sum x^2    4: column sum |x|  5: column non-zero count
    mean / variance / L1 / L2 norms derive from these plus the row count.
    """
    return jnp.stack(
        [
            jnp.min(x, axis=0),
            jnp.max(x, axis=0),
            jnp.sum(x, axis=0),
            jnp.sum(x * x, axis=0),
            jnp.sum(jnp.abs(x), axis=0),
            jnp.sum((x != 0).astype(x.dtype), axis=0),
        ]
    )


def gramian(x: jnp.ndarray):
    """One-pass Gramian: (X^T X, column sums)."""
    return x.T @ x, jnp.sum(x, axis=0)


def gramian_centered(x: jnp.ndarray, mu: jnp.ndarray) -> jnp.ndarray:
    """Second (centered) pass of the paper's two-pass correlation."""
    xc = x - mu[None, :]
    return xc.T @ xc


def gmm_estep(x, means, prec, logdet, logw):
    """GMM E-step sufficient statistics for one partition.

    x: (n, p); means: (k, p); prec: (k, p, p) precision matrices;
    logdet: (k,) log-determinants of the precisions; logw: (k,) log weights.

    Returns (Nk (k,), Sk (k,p), SSk (k,p,p), loglik scalar):
      resp_nk = softmax_k [ logw_k + 0.5 logdet_k - 0.5 maha_nk - p/2 log 2pi ]
      Nk = sum_n resp, Sk = resp^T X, SSk_k = sum_n resp_nk x_n x_n^T,
      loglik = sum_n logsumexp_k(...)
    """
    p = x.shape[1]
    diff = x[:, None, :] - means[None, :, :]  # (n, k, p)
    maha = jnp.einsum("nkp,kpq,nkq->nk", diff, prec, diff)
    logp = logw[None, :] + 0.5 * logdet[None, :] - 0.5 * maha
    logp = logp - 0.5 * p * jnp.log(jnp.asarray(2.0 * jnp.pi, dtype=x.dtype))
    lse = jax.scipy.special.logsumexp(logp, axis=1)  # (n,)
    resp = jnp.exp(logp - lse[:, None])  # (n, k)
    nk = jnp.sum(resp, axis=0)
    sk = resp.T @ x
    ssk = jnp.einsum("nk,np,nq->kpq", resp, x, x)
    return nk, sk, ssk, jnp.sum(lse)
