"""AOT compile path: lower every L2 model variant to HLO *text* artifacts.

Emits artifacts/<name>.hlo.txt plus artifacts/manifest.json describing each
artifact's input/output shapes and dtypes. The Rust runtime
(rust/src/runtime/) reads the manifest, compiles each module on the PJRT CPU
client on first use, and dispatches per-partition algorithm steps whose
shapes match. Python never runs after this script.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate binds) rejects (`proto.id() <=
INT_MAX`); the text parser reassigns ids and round-trips cleanly. Lowering
goes through stablehlo -> XlaComputation with return_tuple=True, so the Rust
side always unwraps a tuple (Literal::to_tuple).

Usage: (cd python && python -m compile.aot --out-dir ../artifacts)
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

jax.config.update("jax_enable_x64", True)

# Column widths the benches sweep (Fig 9) and cluster counts (Fig 10).
P_SWEEP = [8, 16, 32, 64, 128, 256, 512]
K_SWEEP = [2, 4, 8, 10, 16, 32, 64]
DTYPE = jnp.float64


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=DTYPE):
    return jax.ShapeDtypeStruct(shape, dtype)


def _dtype_name(dt) -> str:
    return jnp.dtype(dt).name


def _shapes(tree):
    return [
        {"shape": list(x.shape), "dtype": _dtype_name(x.dtype)}
        for x in jax.tree_util.tree_leaves(tree)
    ]


def build_variants():
    """Yield (name, fn, input_specs, meta) for every artifact to emit."""
    for p in P_SWEEP:
        rows = model.io_rows_for(p)
        x = _spec((rows, p))
        yield (f"summary_p{p}", model.summary_step, (x,),
               {"kind": "summary", "rows": rows, "p": p})
        yield (f"gramian_p{p}", model.gramian_step, (x,),
               {"kind": "gramian", "rows": rows, "p": p})
        yield (f"gramian_centered_p{p}", model.gramian_centered_step,
               (x, _spec((p,))),
               {"kind": "gramian_centered", "rows": rows, "p": p})
    p = 32
    rows = model.io_rows_for(p)
    x = _spec((rows, p))
    for k in K_SWEEP:
        yield (f"kmeans_p{p}_k{k}", model.kmeans_step, (x, _spec((k, p))),
               {"kind": "kmeans", "rows": rows, "p": p, "k": k})
        yield (f"gmm_p{p}_k{k}", model.gmm_estep,
               (x, _spec((k, p)), _spec((k, p, p)), _spec((k,)), _spec((k,))),
               {"kind": "gmm", "rows": rows, "p": p, "k": k})


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact-name prefixes to emit")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    only = args.only.split(",") if args.only else None
    manifest = []
    for name, fn, specs, meta in build_variants():
        if only and not any(name.startswith(o) for o in only):
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        out_tree = jax.eval_shape(fn, *specs)
        manifest.append({
            "name": name,
            "file": fname,
            "inputs": _shapes(specs),
            "outputs": _shapes(out_tree),
            **meta,
        })
        print(f"  {name}: {len(text)} chars, "
              f"{len(manifest[-1]['inputs'])} in -> "
              f"{len(manifest[-1]['outputs'])} out")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump({"elem_bytes": 8,
                   "target_part_bytes": model.TARGET_PART_BYTES,
                   "min_io_rows": model.MIN_IO_ROWS,
                   "max_io_rows": model.MAX_IO_ROWS,
                   "artifacts": manifest}, f, indent=1)
    print(f"wrote {len(manifest)} artifacts + manifest.json to {args.out_dir}")


if __name__ == "__main__":
    main()
