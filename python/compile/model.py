"""L2: the JAX compute graphs for the FlashMatrix algorithm hot spots.

Each function here is the dense-FLOP inner step of one of the paper's five
evaluation algorithms, expressed over ONE I/O-level partition (a row block
of the tall-and-skinny data matrix). The Rust engine streams partitions and
merges the returned partial aggregates — the exact split of work the paper
describes in §III-F (per-thread partial aggregation + final merge).

These functions play the role BLAS plays in the paper: `fm.inner.prod` and
the fused per-partition pipelines dispatch to the AOT-compiled XLA
executables of these graphs when an artifact with a matching shape exists
(rust/src/runtime/); otherwise the engine's native VUDF path runs.

Everything is jit-lowered once by aot.py; python never runs at request time.
The Pallas kernels (kernels/) are called from here so they lower into the
same HLO module.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import colstats as colstats_kernel
from .kernels import distance


def _tile_for(rows: int) -> int:
    """Largest kernel row-tile dividing `rows` (artifact rows are powers of
    two >= 2048, so this is DEFAULT_TILE there; small test blocks get one
    tile)."""
    return distance.DEFAULT_TILE if rows % distance.DEFAULT_TILE == 0 else rows


def kmeans_step(x: jnp.ndarray, c: jnp.ndarray):
    """k-means partition step on one row block.

    x: (rows, p), c: (k, p) ->
      sums (k, p), counts (k,), wcss (), assign (rows,) int32.
    Assignment runs in the L1 Pallas kernel; the per-cluster accumulation
    is a one-hot matmul so the whole step is MXU-dominated.
    """
    assign, mind = distance.kmeans_assign(x, c, tile=_tile_for(x.shape[0]))
    k = c.shape[0]
    onehot = (assign[:, None] == jnp.arange(k, dtype=jnp.int32)[None, :])
    onehot = onehot.astype(x.dtype)
    sums = onehot.T @ x
    counts = jnp.sum(onehot, axis=0)
    wcss = jnp.sum(mind)
    return sums, counts, wcss, assign


def summary_step(x: jnp.ndarray) -> jnp.ndarray:
    """Multivariate-summary partition step: (6, p) accumulator block.

    Runs entirely in the L1 Pallas colstats kernel.
    """
    return colstats_kernel.colstats(x, tile=_tile_for(x.shape[0]))


def gramian_step(x: jnp.ndarray):
    """One-pass Gramian partition step: (X^T X, colsums)."""
    return x.T @ x, jnp.sum(x, axis=0)


def gramian_centered_step(x: jnp.ndarray, mu: jnp.ndarray):
    """Centered Gramian partition step (pass 2 of two-pass correlation)."""
    xc = x - mu[None, :]
    return (xc.T @ xc,)


def gmm_estep(x, means, prec, logdet, logw):
    """GMM E-step partition stats: (Nk, Sk, SSk, loglik).

    Mahalanobis terms are expanded so the dominant work is matmuls:
      maha_nk = x P_k x^T - 2 x (P_k mu_k) + mu_k P_k mu_k
    (P_k symmetric), giving k (rows,p)@(p,p) products on the MXU instead
    of an (n,k,p) broadcast subtract.
    """
    p = x.shape[1]
    # (k, p, p) @ (k, p) -> (k, p)
    pmu = jnp.einsum("kpq,kq->kp", prec, means)
    # x P_k x^T diagonal: rows of (x @ P_k) * x summed — batched matmul.
    xp = jnp.einsum("np,kpq->knq", x, prec)  # (k, n, p)
    xpx = jnp.sum(xp * x[None, :, :], axis=2).T  # (n, k)
    xpmu = x @ pmu.T  # (n, k)
    mupmu = jnp.sum(pmu * means, axis=1)  # (k,)
    maha = xpx - 2.0 * xpmu + mupmu[None, :]
    logp = logw[None, :] + 0.5 * logdet[None, :] - 0.5 * maha
    logp = logp - 0.5 * p * jnp.log(jnp.asarray(2.0 * jnp.pi, dtype=x.dtype))
    mx = jnp.max(logp, axis=1, keepdims=True)
    lse = (mx[:, 0] + jnp.log(jnp.sum(jnp.exp(logp - mx), axis=1)))
    resp = jnp.exp(logp - lse[:, None])  # (n, k)
    nk = jnp.sum(resp, axis=0)
    sk = resp.T @ x
    ssk = jnp.einsum("nk,np,nq->kpq", resp, x, x)
    return nk, sk, ssk, jnp.sum(lse)


# ---------------------------------------------------------------------------
# Shared partition-shape formula.
#
# The Rust engine picks the I/O-level partition row count for a p-column f64
# matrix as the largest power of two with rows*p*8 <= 8 MiB, clamped to
# [1024, 65536] (matrix/partition.rs `io_rows_for`). aot.py uses this same
# formula so every emitted artifact's input shape matches the partitions the
# engine will feed it. Keep the two in sync (cross-checked by
# rust/tests/manifest.rs against artifacts/manifest.json).
# ---------------------------------------------------------------------------

TARGET_PART_BYTES = 8 * 1024 * 1024
MIN_IO_ROWS = 1024
MAX_IO_ROWS = 65536


def io_rows_for(p: int, elem_bytes: int = 8) -> int:
    """Rows per I/O-level partition for a p-column matrix (power of two)."""
    rows = TARGET_PART_BYTES // (elem_bytes * p)
    pow2 = 1 << (rows.bit_length() - 1)
    return max(MIN_IO_ROWS, min(MAX_IO_ROWS, pow2))
