"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes and dtypes; every case asserts allclose against
the reference. This is the core correctness signal for the kernels that
end up inside the AOT artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import colstats, distance, ref

jax.config.update("jax_enable_x64", True)

DTYPES = [jnp.float32, jnp.float64]


def _tol(dtype):
    return dict(rtol=1e-4, atol=1e-4) if dtype == jnp.float32 else dict(
        rtol=1e-10, atol=1e-10)


@st.composite
def assign_case(draw):
    tile = draw(st.sampled_from([4, 8, 16]))
    ntiles = draw(st.integers(1, 6))
    p = draw(st.integers(1, 24))
    k = draw(st.integers(1, 12))
    dtype = draw(st.sampled_from(DTYPES))
    seed = draw(st.integers(0, 2**31 - 1))
    return tile, ntiles * tile, p, k, dtype, seed


@given(assign_case())
@settings(max_examples=60, deadline=None)
def test_kmeans_assign_matches_ref(case):
    tile, rows, p, k, dtype, seed = case
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((rows, p)), dtype=dtype)
    c = jnp.asarray(rng.standard_normal((k, p)), dtype=dtype)
    a, d = distance.kmeans_assign(x, c, tile=tile)
    a_ref, d_ref = ref.kmeans_assign(x, c)
    # distances must match tightly; assignment may differ only on exact ties
    np.testing.assert_allclose(d, d_ref, **_tol(dtype))
    dist_full = ref.pairwise_sqdist(x, c)
    picked = np.take_along_axis(
        np.asarray(dist_full), np.asarray(a)[:, None], axis=1)[:, 0]
    np.testing.assert_allclose(picked, np.asarray(d_ref), **_tol(dtype))


@given(assign_case())
@settings(max_examples=60, deadline=None)
def test_colstats_matches_ref(case):
    tile, rows, p, _k, dtype, seed = case
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((rows, p))
    # inject exact zeros so the nnz accumulator is exercised
    x[rng.random((rows, p)) < 0.1] = 0.0
    x = jnp.asarray(x, dtype=dtype)
    got = colstats.colstats(x, tile=tile)
    want = ref.colstats(x)
    np.testing.assert_allclose(got, want, **_tol(dtype))


def test_assign_rejects_ragged_rows():
    x = jnp.zeros((10, 3))
    c = jnp.zeros((2, 3))
    with pytest.raises(ValueError):
        distance.kmeans_assign(x, c, tile=4)


def test_colstats_constant_matrix():
    x = jnp.full((32, 5), 3.5, dtype=jnp.float64)
    got = np.asarray(colstats.colstats(x, tile=8))
    np.testing.assert_allclose(got[0], 3.5)  # min
    np.testing.assert_allclose(got[1], 3.5)  # max
    np.testing.assert_allclose(got[2], 32 * 3.5)  # sum
    np.testing.assert_allclose(got[3], 32 * 3.5**2)  # sumsq
    np.testing.assert_allclose(got[5], 32.0)  # nnz


def test_assign_exact_centroid_hit():
    # points placed exactly on centroids must be assigned to them
    c = jnp.asarray([[0.0, 0.0], [10.0, 10.0], [-5.0, 5.0]], jnp.float64)
    x = jnp.tile(c, (4, 1))  # 12 rows
    a, d = distance.kmeans_assign(x, c, tile=4)
    np.testing.assert_array_equal(np.asarray(a), np.tile([0, 1, 2], 4))
    np.testing.assert_allclose(np.asarray(d), 0.0, atol=1e-12)
