"""L2 correctness: the jitted model step functions vs the oracle (ref.py).

These are the exact functions aot.py lowers to artifacts, so passing here
plus an HLO round-trip (rust/tests/) validates the whole compile path.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)

TOL = dict(rtol=1e-9, atol=1e-9)


def _rand_psd(rng, k, p):
    """Random well-conditioned precision matrices + their logdets."""
    a = rng.standard_normal((k, p, p)) * 0.3
    prec = np.einsum("kpq,krq->kpr", a, a) + np.eye(p)[None] * 1.5
    sign, logdet = np.linalg.slogdet(prec)
    assert (sign > 0).all()
    return jnp.asarray(prec), jnp.asarray(logdet)


@st.composite
def block_case(draw):
    rows = draw(st.sampled_from([16, 64, 128]))
    p = draw(st.integers(2, 16))
    k = draw(st.integers(1, 8))
    seed = draw(st.integers(0, 2**31 - 1))
    return rows, p, k, seed


@given(block_case())
@settings(max_examples=40, deadline=None)
def test_kmeans_step_matches_ref(case):
    rows, p, k, seed = case
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((rows, p)))
    c = jnp.asarray(rng.standard_normal((k, p)))
    sums, counts, wcss, assign = model.kmeans_step(x, c)
    rsums, rcounts, rwcss, rassign = ref.kmeans_step(x, c)
    np.testing.assert_allclose(sums, rsums, **TOL)
    np.testing.assert_allclose(counts, rcounts, **TOL)
    np.testing.assert_allclose(wcss, rwcss, **TOL)
    np.testing.assert_array_equal(np.asarray(assign), np.asarray(rassign))
    # invariants: counts sum to rows; sums consistent with assignment
    assert float(jnp.sum(counts)) == rows


@given(block_case())
@settings(max_examples=30, deadline=None)
def test_gmm_estep_matches_ref(case):
    rows, p, k, seed = case
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((rows, p)))
    means = jnp.asarray(rng.standard_normal((k, p)))
    prec, logdet = _rand_psd(rng, k, p)
    w = rng.random(k) + 0.1
    logw = jnp.asarray(np.log(w / w.sum()))
    nk, sk, ssk, ll = model.gmm_estep(x, means, prec, logdet, logw)
    rnk, rsk, rssk, rll = ref.gmm_estep(x, means, prec, logdet, logw)
    np.testing.assert_allclose(nk, rnk, **TOL)
    np.testing.assert_allclose(sk, rsk, **TOL)
    np.testing.assert_allclose(ssk, rssk, **TOL)
    np.testing.assert_allclose(ll, rll, **TOL)
    # responsibilities sum to 1 per row => Nk sums to rows
    np.testing.assert_allclose(float(jnp.sum(nk)), rows, **TOL)


@given(block_case())
@settings(max_examples=30, deadline=None)
def test_gramian_steps_match_ref(case):
    rows, p, _k, seed = case
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((rows, p)))
    xtx, cs = model.gramian_step(x)
    rxtx, rcs = ref.gramian(x)
    np.testing.assert_allclose(xtx, rxtx, **TOL)
    np.testing.assert_allclose(cs, rcs, **TOL)
    mu = cs / rows
    (xtxc,) = model.gramian_centered_step(x, mu)
    np.testing.assert_allclose(xtxc, ref.gramian_centered(x, mu), **TOL)
    # centered Gramian == gramian - n * mu mu^T  (merge identity the Rust
    # one-pass correlation relies on)
    np.testing.assert_allclose(
        xtxc, xtx - rows * jnp.outer(mu, mu), rtol=1e-8, atol=1e-8)


def test_summary_step_uses_kernel_and_matches_ref():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((2048, 8))
    x[rng.random(x.shape) < 0.05] = 0.0
    x = jnp.asarray(x)
    np.testing.assert_allclose(model.summary_step(x), ref.colstats(x), **TOL)


def test_io_rows_formula():
    # pinned values the Rust engine's partition.rs mirrors
    assert model.io_rows_for(8) == 65536
    assert model.io_rows_for(16) == 65536
    assert model.io_rows_for(32) == 32768
    assert model.io_rows_for(64) == 16384
    assert model.io_rows_for(128) == 8192
    assert model.io_rows_for(256) == 4096
    assert model.io_rows_for(512) == 2048
    for p in range(1, 600):
        r = model.io_rows_for(p)
        assert r & (r - 1) == 0  # power of two
        assert 1024 <= r <= 65536
