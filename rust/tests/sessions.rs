//! Multi-tenant serving end to end: N concurrent [`Session`]s over one
//! shared engine must be **invisible to results** — every workload's
//! output is bit-identical to a serialized run on the root engine — while
//! the cache stays shared (one §III-B3 hierarchy) and per-tenant
//! accounting holds (fair-share eviction, private hit/miss metrics).
//!
//! Every engine here runs `threads = 1` so fold order inside a workload
//! is deterministic and "bit-identical" is a meaningful claim; the
//! concurrency under test is *between* sessions, not inside a pass. The
//! suite runs in both storage modes (IM and tiny-cache EM) and is the
//! body of the `concurrent-tests` CI job (`FLASHR_TEST_EM=1`).

use std::sync::Arc;

use flashmatrix::algs;
use flashmatrix::config::EngineConfig;
use flashmatrix::datasets;
use flashmatrix::fmr::Engine;
use flashmatrix::testutil::{out_of_core_config, TempDir};
use flashmatrix::{JobQueue, Session};

// -- the three tenant workloads (kmeans / PageRank / IRLS) ------------------

fn kmeans_fp(eng: &Arc<Engine>) -> Vec<f64> {
    let (x, _) = datasets::mix_gaussian(eng, 60_000, 6, 3, 8.0, 3, None).unwrap();
    let km = algs::kmeans(&x, 3, 3, 1).unwrap();
    let mut fp = km.wcss.clone();
    fp.extend(km.centroids.buf.to_f64_vec());
    fp.extend(km.sizes.clone());
    fp
}

fn pagerank_fp(eng: &Arc<Engine>) -> Vec<f64> {
    let (g, dangling) = datasets::pagerank_graph(eng, 1 << 13, 6, 17, None).unwrap();
    let pr = algs::pagerank(&g, &dangling, 0.85, 5, 0.0).unwrap();
    let mut fp = pr.ranks.clone();
    fp.extend(pr.deltas);
    fp
}

fn irls_fp(eng: &Arc<Engine>) -> Vec<f64> {
    let x = datasets::uniform(eng, 60_000, 4, -1.0, 1.0, 21, None).unwrap();
    let y = datasets::logistic_labels(&x, &[1.0, -0.5, 0.25, -1.5], 22).unwrap();
    let fit = algs::logistic(&x, &y, 3, 1e-8).unwrap();
    let mut fp = fit.beta.clone();
    fp.extend(fit.deviances);
    fp
}

const WORKLOADS: [(&str, fn(&Arc<Engine>) -> Vec<f64>); 3] =
    [("kmeans", kmeans_fp), ("pagerank", pagerank_fp), ("irls", irls_fp)];

fn im_config() -> EngineConfig {
    EngineConfig {
        threads: 1,
        xla_dispatch: false,
        chunk_bytes: 4 << 20,
        target_part_bytes: 1 << 20,
        ..EngineConfig::default()
    }
}

fn em_config(dir: &std::path::Path) -> EngineConfig {
    let mut cfg = out_of_core_config(dir);
    cfg.threads = 1;
    cfg
}

/// Serialized baseline: the three workloads one after another on the
/// root engine itself (the pre-PR-9 one-pass-at-a-time regime).
fn serialized(root: &Arc<Engine>) -> Vec<Vec<f64>> {
    WORKLOADS.iter().map(|(_, f)| f(root)).collect()
}

/// Interleaved: one session per workload, all three running at once on
/// their own OS threads against the shared cache.
fn interleaved(root: &Arc<Engine>, session_cfg: &EngineConfig) -> Vec<Vec<f64>> {
    let sessions: Vec<Session> = WORKLOADS
        .iter()
        .map(|_| Session::open(root, session_cfg.clone()).unwrap())
        .collect();
    let mut out: Vec<Option<Vec<f64>>> = vec![None; WORKLOADS.len()];
    std::thread::scope(|s| {
        let handles: Vec<_> = WORKLOADS
            .iter()
            .zip(&sessions)
            .map(|((_, f), sess)| {
                let eng = Arc::clone(sess.engine());
                s.spawn(move || f(&eng))
            })
            .collect();
        for (slot, h) in out.iter_mut().zip(handles) {
            *slot = Some(h.join().expect("tenant workload panicked"));
        }
    });
    out.into_iter().map(Option::unwrap).collect()
}

fn assert_bitwise(serial: &[Vec<f64>], inter: &[Vec<f64>], mode: &str) {
    for (((label, _), a), b) in WORKLOADS.iter().zip(serial).zip(inter) {
        assert_eq!(a.len(), b.len(), "{mode}/{label}: fingerprint length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{mode}/{label}[{i}]: serialized {x} != interleaved {y}"
            );
        }
    }
}

/// In memory: three interleaved tenants match the serialized run bitwise.
#[test]
fn interleaved_sessions_bit_identical_to_serialized_im() {
    let root = Engine::new(im_config()).unwrap();
    let serial = serialized(&root);
    let inter = interleaved(&root, &im_config());
    assert_bitwise(&serial, &inter, "im");
}

/// Out of core, through the shared tiny partition cache, with the pass
/// admission gate engaged (`max_concurrent_passes = 2` forces at least
/// one tenant to wait at a pass boundary mid-run): still bit-identical.
#[test]
fn interleaved_sessions_bit_identical_to_serialized_em() {
    let dir = TempDir::new("sessions-em");
    let mut cfg = em_config(dir.path());
    cfg.max_concurrent_passes = 2;
    let root = Engine::new(cfg).unwrap();
    let serial = serialized(&root);
    let inter = interleaved(&root, &em_config(dir.path()));
    assert_bitwise(&serial, &inter, "em");

    // the tenants really went through the shared cache: every session
    // engine is gone, so its registration must be released too
    assert_eq!(root.cache.as_ref().unwrap().session_count(), 0);
}

/// The async serving front end: submit → ticket → wait drives the same
/// three workloads through a [`JobQueue`], one session opened per job,
/// and the results match the serialized run bitwise.
#[test]
fn job_queue_tickets_drive_sessions_end_to_end() {
    let dir = TempDir::new("sessions-jobs");
    let root = Engine::new(em_config(dir.path())).unwrap();
    let serial = serialized(&root);

    let q = JobQueue::new(WORKLOADS.len());
    let tickets: Vec<_> = WORKLOADS
        .iter()
        .map(|(_, f)| {
            let root = Arc::clone(&root);
            let dir = dir.path().to_path_buf();
            let f = *f;
            q.submit(move || {
                let s = Session::open(&root, em_config(&dir))?;
                Ok(f(s.engine()))
            })
        })
        .collect();
    let inter: Vec<Vec<f64>> = tickets
        .into_iter()
        .map(|t| t.wait().expect("job failed"))
        .collect();
    assert_bitwise(&serial, &inter, "jobs");
    assert_eq!(q.backlog(), 0);
    assert_eq!(root.cache.as_ref().unwrap().session_count(), 0);
}

/// Isolation: a tenant whose working set fits its fair share keeps it
/// (and its hit rate) while a second tenant streams a larger matrix
/// through the same cache — the streamer evicts its own LRU entries,
/// and the cross-tenant eviction count on the hot tenant stays zero.
#[test]
fn streaming_tenant_does_not_flush_hot_tenant_within_share() {
    let dir = TempDir::new("sessions-iso");
    // 4 MiB shared cache; each tenant gets a 2 MiB share
    let root = Engine::new(em_config(dir.path())).unwrap();
    let mut scfg = em_config(dir.path());
    scfg.session_mem_bytes = 2 << 20;
    let hot = Session::open(&root, scfg.clone()).unwrap();
    let streamer = Session::open(&root, scfg).unwrap();

    // hot tenant: one ~1.6 MiB partition, resident within its share
    let hx = datasets::uniform(hot.engine(), 50_000, 4, -1.0, 1.0, 41, None).unwrap();
    let hsum = hx.sum().unwrap();
    let warm = hot.metrics().snapshot();

    // streamer: ~6 MiB in ~2 MiB partitions > its share; its own older
    // partitions are the victims, never the hot tenant's working set
    let sx = datasets::uniform(streamer.engine(), 200_000, 4, -1.0, 1.0, 42, None).unwrap();
    let _ = sx.sum().unwrap();

    // the hot tenant re-reads its partition from the cache: hits, and
    // the same bytes
    let again = hx.sum().unwrap();
    assert_eq!(hsum.to_bits(), again.to_bits());
    let after = hot.metrics().snapshot();
    assert!(
        after.cache_hits > warm.cache_hits,
        "hot tenant's re-read must hit the shared cache \
         (hits {} -> {})",
        warm.cache_hits,
        after.cache_hits
    );
    assert_eq!(
        after.cache_cross_evictions, 0,
        "an in-budget tenant must never be cross-evicted"
    );
}
