//! XLA runtime integration: load real AOT artifacts, execute them through
//! the PJRT service thread, and check the numbers against the native step
//! implementations — the full L1/L2 (Pallas/JAX) vs L3 (Rust) agreement.
//!
//! Requires `make artifacts`; tests skip (with a message) when the
//! manifest is absent so `cargo test` stays green on a fresh checkout.

use flashmatrix::algs::steps;
use flashmatrix::config::EngineConfig;
use flashmatrix::datasets;
use flashmatrix::fmr::Engine;
use flashmatrix::matrix::HostMat;
use flashmatrix::runtime::{HostTensor, XlaService};

fn service() -> Option<XlaService> {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping xla test: run `make artifacts` first");
        return None;
    }
    Some(XlaService::start(dir).expect("manifest loads"))
}

fn eng() -> std::sync::Arc<Engine> {
    Engine::new(EngineConfig {
        xla_dispatch: false, // artifacts driven manually here
        ..Default::default()
    })
    .unwrap()
}

/// Random row-major block + its col-major Buf twin.
fn block(rows: usize, p: usize, seed: u64) -> (Vec<f64>, flashmatrix::vudf::Buf) {
    let mut rm = vec![0.0; rows * p];
    let mut cm = vec![0.0; rows * p];
    for r in 0..rows {
        for c in 0..p {
            let v = flashmatrix::exec::u64_to_unit_f64(flashmatrix::exec::splitmix64_at(
                seed,
                (r * p + c) as u64,
            )) * 4.0
                - 2.0;
            rm[r * p + c] = v;
            cm[c * rows + r] = v;
        }
    }
    (rm, flashmatrix::vudf::Buf::F64(cm))
}

fn close(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what} len");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() < tol * x.abs().max(1.0),
            "{what}[{i}]: xla {x} vs native {y}"
        );
    }
}

#[test]
fn summary_artifact_matches_native_step() {
    let Some(svc) = service() else { return };
    let meta = svc.lookup("summary", 8, 0).expect("summary_p8");
    let rows = meta.rows as usize;
    let (rm, cm) = block(rows, 8, 5);
    let out = svc
        .run(&meta.name.clone(), vec![HostTensor::f64(vec![rows, 8], rm)])
        .unwrap();
    let native = steps::colstats_native(&cm, rows, 8).unwrap();
    close(out[0].as_f64().unwrap(), &native, 1e-10, "summary");
}

#[test]
fn kmeans_artifact_matches_native_step() {
    let Some(svc) = service() else { return };
    let meta = svc.lookup("kmeans", 32, 10).expect("kmeans_p32_k10");
    let rows = meta.rows as usize;
    let (rm, cm) = block(rows, 32, 6);
    let (crm, _) = block(10, 32, 7);
    let c = HostMat::from_row_major_f64(10, 32, &crm);
    let out = svc
        .run(
            &meta.name.clone(),
            vec![
                HostTensor::f64(vec![rows, 32], rm),
                HostTensor::f64(vec![10, 32], crm.clone()),
            ],
        )
        .unwrap();
    let (sums, counts, wcss, assign) = steps::kmeans_step_native(&cm, rows, 32, &c).unwrap();
    close(out[0].as_f64().unwrap(), &sums, 1e-9, "sums");
    close(out[1].as_f64().unwrap(), &counts, 1e-12, "counts");
    assert!((out[2].as_f64().unwrap()[0] - wcss).abs() / wcss < 1e-10);
    let xla_assign = out[3].as_i32().unwrap();
    assert_eq!(xla_assign, &assign[..], "assignments");
}

#[test]
fn gramian_artifacts_match_native_step() {
    let Some(svc) = service() else { return };
    let meta = svc.lookup("gramian", 16, 0).expect("gramian_p16");
    let rows = meta.rows as usize;
    let (rm, cm) = block(rows, 16, 8);
    let out = svc
        .run(&meta.name.clone(), vec![HostTensor::f64(vec![rows, 16], rm.clone())])
        .unwrap();
    let (xtx, cs) = steps::gramian_native(&cm, rows, 16).unwrap();
    close(out[0].as_f64().unwrap(), &xtx, 1e-9, "xtx");
    close(out[1].as_f64().unwrap(), &cs, 1e-9, "colsums");

    let metac = svc.lookup("gramian_centered", 16, 0).expect("centered");
    let mu: Vec<f64> = cs.iter().map(|s| s / rows as f64).collect();
    let outc = svc
        .run(
            &metac.name.clone(),
            vec![
                HostTensor::f64(vec![rows, 16], rm),
                HostTensor::f64(vec![16], mu.clone()),
            ],
        )
        .unwrap();
    let native = steps::gramian_centered_native(&cm, rows, 16, &mu).unwrap();
    close(outc[0].as_f64().unwrap(), &native, 1e-9, "centered");
}

#[test]
fn gmm_artifact_matches_native_step() {
    let Some(svc) = service() else { return };
    let meta = svc.lookup("gmm", 32, 4).expect("gmm_p32_k4");
    let rows = meta.rows as usize;
    let (k, p) = (4usize, 32usize);
    let (rm, cm) = block(rows, p, 9);
    let (means_rm, _) = block(k, p, 10);
    let mut prec = vec![0.0; k * p * p];
    for c in 0..k {
        for i in 0..p {
            prec[c * p * p + i * p + i] = 1.0 + 0.1 * c as f64;
        }
    }
    let logdet: Vec<f64> = (0..k)
        .map(|c| p as f64 * (1.0 + 0.1 * c as f64).ln())
        .collect();
    let logw = vec![(1.0 / k as f64).ln(); k];
    let out = svc
        .run(
            &meta.name.clone(),
            vec![
                HostTensor::f64(vec![rows, p], rm),
                HostTensor::f64(vec![k, p], means_rm.clone()),
                HostTensor::f64(vec![k, p, p], prec.clone()),
                HostTensor::f64(vec![k], logdet.clone()),
                HostTensor::f64(vec![k], logw.clone()),
            ],
        )
        .unwrap();
    let (nk, sk, ssk, ll) =
        steps::gmm_estep_native(&cm, rows, p, &means_rm, &prec, &logdet, &logw).unwrap();
    close(out[0].as_f64().unwrap(), &nk, 1e-8, "nk");
    close(out[1].as_f64().unwrap(), &sk, 1e-8, "sk");
    close(out[2].as_f64().unwrap(), &ssk, 1e-8, "ssk");
    assert!((out[3].as_f64().unwrap()[0] - ll).abs() / ll.abs() < 1e-10);
}

#[test]
fn end_to_end_kmeans_xla_equals_native() {
    let Some(_svc) = service() else { return };
    // full algorithm with dispatch on vs off must agree
    let run = |xla: bool| {
        let e = Engine::new(EngineConfig {
            xla_dispatch: xla,
            xla_kinds: vec!["all".to_string()],
            ..Default::default()
        })
        .unwrap();
        let (x, _) = datasets::mix_gaussian(&e, 70_000, 32, 10, 8.0, 42, None).unwrap();
        let r = flashmatrix::algs::kmeans(&x, 10, 3, 1).unwrap();
        (r.wcss, e.metrics.snapshot().xla_dispatches)
    };
    let (wcss_xla, dispatches) = run(true);
    let (wcss_native, _) = run(false);
    assert!(dispatches > 0, "xla path not exercised");
    for (a, b) in wcss_xla.iter().zip(&wcss_native) {
        assert!((a - b).abs() / b < 1e-9, "xla {a} vs native {b}");
    }
    let _ = eng();
}
