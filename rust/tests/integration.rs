//! Cross-module integration tests: the same computation must produce
//! identical results across every engine configuration the paper
//! compares — in-memory vs external-memory, fused vs eager, vectorized vs
//! per-element UDFs, 1 thread vs many, XLA-dispatched vs native.

use std::sync::Arc;

use flashmatrix::config::{EngineConfig, StorageKind, ThrottleConfig};
use flashmatrix::datasets;
use flashmatrix::fmr::{Engine, EngineExt, FmMatrix};
use flashmatrix::vudf::AggOp;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let p = std::env::temp_dir().join(format!("fm-it-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&p).unwrap();
    p
}

fn cfg_im() -> EngineConfig {
    EngineConfig {
        xla_dispatch: false,
        chunk_bytes: 4 << 20,
        target_part_bytes: 1 << 20,
        ..Default::default()
    }
}

fn cfg_em(tag: &str) -> EngineConfig {
    EngineConfig {
        storage: StorageKind::External,
        data_dir: tmpdir(tag),
        ..cfg_im()
    }
}

/// Run one pipeline under a config, returning a fingerprint of results.
fn pipeline_fingerprint(cfg: EngineConfig) -> Vec<f64> {
    let eng = Engine::new(cfg).unwrap();
    let x = datasets::uniform(&eng, 50_000, 6, -2.0, 2.0, 31, None).unwrap();
    // expression mixing sapply/mapply/rowagg/colagg/groupby/inner
    let y = x.abs().unwrap().add(&x.sq().unwrap()).unwrap();
    let s1 = y.sum().unwrap();
    let rs = y.row_sums().unwrap();
    let s2 = rs.max().unwrap();
    let cs = y.col_sums().unwrap().buf.to_f64_vec();
    let labels = x
        .col(0)
        .unwrap()
        .mapply_scalar(flashmatrix::dtype::Scalar::F64(0.0), flashmatrix::vudf::BinOp::Gt, true)
        .unwrap()
        .cast(flashmatrix::dtype::DType::I32)
        .unwrap();
    let g = y.groupby_row(&labels, 2, AggOp::Sum).unwrap();
    let gram = x.crossprod(&x).unwrap();
    let mut out = vec![s1, s2];
    out.extend(cs);
    out.extend(g.buf.to_f64_vec());
    out.extend(gram.buf.to_f64_vec());
    out
}

fn assert_close(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let scale = x.abs().max(1.0);
        assert!(
            (x - y).abs() / scale < tol,
            "{what}[{i}]: {x} vs {y}"
        );
    }
}

#[test]
fn em_equals_im() {
    let im = pipeline_fingerprint(cfg_im());
    let em = pipeline_fingerprint(cfg_em("emim"));
    assert_close(&im, &em, 1e-12, "EM vs IM");
}

#[test]
fn eager_equals_fused() {
    let fused = pipeline_fingerprint(cfg_im());
    let eager = pipeline_fingerprint(EngineConfig {
        fuse_mem: false,
        fuse_cache: false,
        ..cfg_im()
    });
    assert_close(&fused, &eager, 1e-12, "eager vs fused");
    let no_cache_fuse = pipeline_fingerprint(EngineConfig {
        fuse_cache: false,
        ..cfg_im()
    });
    assert_close(&fused, &no_cache_fuse, 1e-12, "mem-fuse-only vs fused");
}

#[test]
fn scalar_udf_equals_vectorized() {
    let v = pipeline_fingerprint(cfg_im());
    let s = pipeline_fingerprint(EngineConfig {
        vectorized_udf: false,
        ..cfg_im()
    });
    assert_close(&v, &s, 1e-12, "scalar-mode vs vectorized");
}

#[test]
fn thread_count_invariance() {
    let t1 = pipeline_fingerprint(EngineConfig {
        threads: 1,
        ..cfg_im()
    });
    let t4 = pipeline_fingerprint(EngineConfig {
        threads: 4,
        ..cfg_im()
    });
    // partial-agg merge order may differ: tolerate fp reassociation
    assert_close(&t1, &t4, 1e-9, "1 vs 4 threads");
}

#[test]
fn throttled_em_still_correct() {
    let mut cfg = cfg_em("throttle");
    cfg.throttle = Some(ThrottleConfig {
        read_bytes_per_sec: 200 << 20,
        write_bytes_per_sec: 200 << 20,
    });
    let em = pipeline_fingerprint(cfg);
    let im = pipeline_fingerprint(cfg_im());
    assert_close(&im, &em, 1e-12, "throttled EM vs IM");
}

#[test]
fn em_cache_cols_preserves_results() {
    let mut cfg = cfg_em("cache");
    cfg.em_cache_cols = 3; // cache half the columns
    let em = pipeline_fingerprint(cfg);
    let im = pipeline_fingerprint(cfg_im());
    assert_close(&im, &em, 1e-12, "cached EM vs IM");
}

#[test]
fn algorithms_agree_across_storage() {
    for (tag, mk) in [
        ("alg-im", None),
        ("alg-em", Some("em")),
    ] {
        let cfg = match mk {
            None => cfg_im(),
            Some(_) => cfg_em(tag),
        };
        let eng = Engine::new(cfg).unwrap();
        let (x, _) = datasets::mix_gaussian(&eng, 30_000, 8, 4, 8.0, 3, None).unwrap();
        let km = flashmatrix::algs::kmeans(&x, 4, 3, 1).unwrap();
        let sm = flashmatrix::algs::summary(&x).unwrap();
        // deterministic across storage: same seeds, same math
        // (values pinned by the IM run in the first loop iteration)
        if tag == "alg-im" {
            std::env::set_var("FM_TEST_WCSS", format!("{:.12e}", km.wcss[2]));
            std::env::set_var("FM_TEST_MEAN0", format!("{:.12e}", sm.mean[0]));
        } else {
            let w: f64 = std::env::var("FM_TEST_WCSS").unwrap().parse().unwrap();
            let m: f64 = std::env::var("FM_TEST_MEAN0").unwrap().parse().unwrap();
            assert!((km.wcss[2] - w).abs() / w < 1e-10);
            assert!((sm.mean[0] - m).abs() < 1e-10);
        }
    }
}

#[test]
fn groupby_with_virtual_labels_fuses() {
    // k-means-shaped one-pass: labels computed in the same pass as the
    // grouped aggregation (the paper's flagship fusion)
    let eng: Arc<Engine> = Engine::new(cfg_im()).unwrap();
    let x = datasets::uniform(&eng, 20_000, 3, 0.0, 1.0, 5, None).unwrap();
    let labels = x
        .row_sums()
        .unwrap()
        .mapply_scalar(flashmatrix::dtype::Scalar::F64(1.5), flashmatrix::vudf::BinOp::Gt, true)
        .unwrap()
        .cast(flashmatrix::dtype::DType::I32)
        .unwrap();
    let sums = x.groupby_row(&labels, 2, AggOp::Sum).unwrap();
    let total: f64 = sums.buf.to_f64_vec().iter().sum();
    let expect = x.sum().unwrap();
    assert!((total - expect).abs() / expect < 1e-10);
}

#[test]
fn chunk_recycling_observable() {
    let cfg = cfg_im();
    let eng = Engine::new(cfg).unwrap();
    // create + drop matrices; chunks must be reused
    for _ in 0..3 {
        let x = datasets::uniform(&eng, 200_000, 4, 0.0, 1.0, 1, None).unwrap();
        let _ = x.sum().unwrap();
        drop(x);
    }
    let m = eng.metrics.snapshot();
    assert!(
        m.chunks_recycled > 0,
        "expected chunk reuse, got {m:?}"
    );
}

#[test]
fn wide_view_operations() {
    let eng = Engine::new(cfg_im()).unwrap();
    let h = flashmatrix::matrix::HostMat::from_rows_f64(&[
        vec![1.0, 2.0, 3.0],
        vec![4.0, 5.0, 6.0],
    ]);
    let a = FmMatrix::from_host(&eng, &h).unwrap(); // 2x3
    let w = a.t(); // 3x2 view... wait: a is 2x3, t is 3x2
    // agg.row over the wide view == agg.col over the base
    let rs = w.agg_row(AggOp::Sum).unwrap().to_host().unwrap();
    assert_eq!(rs.buf.to_f64_vec(), vec![5.0, 7.0, 9.0]);
    // export of the transposed view
    let ht = w.to_host().unwrap();
    assert_eq!(ht.nrow, 3);
    assert_eq!(ht.get(2, 1).as_f64(), 6.0);
}

#[test]
fn conv_store_roundtrips_between_storages() {
    let eng = Engine::new(cfg_em("convstore")).unwrap();
    let x = datasets::uniform(&eng, 40_000, 4, -1.0, 1.0, 17, None).unwrap();
    let sum_em = x.sum().unwrap();
    // move SSD -> memory and back; values identical
    let x_im = x.conv_store(true).unwrap();
    assert_eq!(x_im.sum().unwrap(), sum_em);
    let x_em2 = x_im.conv_store(false).unwrap();
    assert_eq!(x_em2.sum().unwrap(), sum_em);
    assert!(eng.metrics.snapshot().io_write_bytes > 0);
}

#[test]
fn group_of_matrices_behaves_as_wider_matrix() {
    let eng = Engine::new(cfg_im()).unwrap();
    let a = datasets::uniform(&eng, 30_000, 3, 0.0, 1.0, 1, None).unwrap();
    let b = datasets::uniform(&eng, 30_000, 2, -1.0, 0.0, 2, None).unwrap();
    let g = FmMatrix::group(&eng, &[&a, &b]).unwrap();
    assert_eq!(g.ncol(), 5);
    // colSums of the group == concatenated member colSums
    let gc = g.col_sums().unwrap().buf.to_f64_vec();
    let mut want = a.col_sums().unwrap().buf.to_f64_vec();
    want.extend(b.col_sums().unwrap().buf.to_f64_vec());
    for (x, y) in gc.iter().zip(&want) {
        assert!((x - y).abs() < 1e-9, "{x} vs {y}");
    }
    // elementwise op on the group fuses like a normal matrix
    let s = g.sq().unwrap().sum().unwrap();
    let want = a.sq().unwrap().sum().unwrap() + b.sq().unwrap().sum().unwrap();
    assert!((s - want).abs() / want < 1e-12);
    // groups decompose for mixed-shape members only when nrow matches
    let c = datasets::uniform(&eng, 10, 1, 0.0, 1.0, 3, None).unwrap();
    assert!(FmMatrix::group(&eng, &[&a, &c]).is_err());
}

// ---------------------------------------------------------------------------
// PR 2: locality-aware scheduling, single-flight prefetch, strip-evaluator
// correctness fixes
// ---------------------------------------------------------------------------

/// Multi-worker EM passes must prefetch (I/O overlapping compute, §III-B3)
/// without ever reading one source partition's bytes twice: the range
/// scheduler makes ownership deterministic and the cache's single-flight
/// registry coalesces any residual race.
#[test]
fn multiworker_prefetch_reads_each_partition_once() {
    let mut cfg = cfg_em("sched-singleflight");
    cfg.threads = 4;
    cfg.prefetch_depth = 4;
    let eng = Engine::new(cfg).unwrap();
    // 10 I/O partitions of 65536 rows x 4 cols (io_rows_for(4) = 65536)
    let x = datasets::uniform(&eng, 10 * 65536, 4, -1.0, 1.0, 77, None).unwrap();

    // drop the write-through copies so the pass must hit the file
    let pc = eng.cache.as_ref().expect("partition cache enabled");
    pc.clear();
    eng.metrics.reset();

    let s = x.sum().unwrap();
    let m = eng.metrics.snapshot();
    assert!(
        m.prefetch_issued > 0,
        "multi-worker EM pass issued no prefetches"
    );
    assert_eq!(
        m.io_read_reqs, 10,
        "each source partition's bytes must be read at most once per pass \
         (prefetches: {}, coalesced: {})",
        m.prefetch_issued, m.singleflight_coalesced
    );

    // warm re-run agrees (and, fully cached, reads nothing)
    eng.metrics.reset();
    let s2 = x.sum().unwrap();
    assert_eq!(s, s2);
    assert_eq!(eng.metrics.snapshot().io_read_reqs, 0);
}

/// A worker that drains its range steals from the busy worker, the steal
/// surfaces through `Metrics`, and the stolen work still sums correctly.
/// Deterministic skew: partition 0 is ~1000x slower than the rest, so the
/// fast worker must finish its own range and steal from the slow one.
#[test]
fn scheduler_steals_surface_in_metrics() {
    use flashmatrix::dtype::DType;
    use flashmatrix::vudf::{Buf, CustomVudf};

    struct SlowFirstPartition;
    impl CustomVudf for SlowFirstPartition {
        fn name(&self) -> &str {
            "slow-first-partition"
        }
        fn out_dtype(&self, input: DType) -> DType {
            input
        }
        fn unary(&self, a: &Buf) -> flashmatrix::Result<Buf> {
            // the seq input carries the global row index: rows < 65536 are
            // partition 0 — crawl there, sprint everywhere else
            if a.to_f64_vec().first().map(|v| *v < 65536.0).unwrap_or(false) {
                std::thread::sleep(std::time::Duration::from_millis(15));
            }
            Ok(a.clone())
        }
    }

    let mut cfg = cfg_im();
    cfg.threads = 2;
    let eng = Engine::new(cfg).unwrap();
    eng.registry.register(std::sync::Arc::new(SlowFirstPartition));
    // 4 units over 2 workers: worker 0 owns [0,2), worker 1 owns [2,4).
    // Worker 1 finishes its fast units while worker 0 crawls through
    // partition 0, so unit 1 must be stolen.
    let n = 4u64 * 65536;
    let x = eng.seq_int(0.0, 1.0, n);
    eng.metrics.reset();
    let s = x.sapply_custom("slow-first-partition").unwrap().sum().unwrap();
    let m = eng.metrics.snapshot();
    assert!(
        m.sched_steals >= 1,
        "fast worker must steal from the slow one (steals {})",
        m.sched_steals
    );
    // exact: integer-valued f64 sums below 2^53 have no rounding
    assert_eq!(s, (n * (n - 1) / 2) as f64);
}

/// One failing partition aborts the whole pass: other workers stop
/// claiming instead of processing (and writing) everything that remains.
#[test]
fn failing_partition_aborts_pass_early() {
    use flashmatrix::dtype::DType;
    use flashmatrix::vudf::{Buf, CustomVudf};

    struct Probe;
    impl CustomVudf for Probe {
        fn name(&self) -> &str {
            "abort-probe"
        }
        fn out_dtype(&self, input: DType) -> DType {
            input
        }
        fn unary(&self, a: &Buf) -> flashmatrix::Result<Buf> {
            // the seq matrix carries the global row index: row 0 lives in
            // partition 0, so exactly one partition fails — fast
            if a.to_f64_vec().iter().any(|v| *v == 0.0) {
                return Err(flashmatrix::FmError::Unsupported("probe failure".into()));
            }
            // everywhere else simulate real per-strip work so the abort
            // flag observably cuts the pass short
            std::thread::sleep(std::time::Duration::from_millis(2));
            Ok(a.clone())
        }
    }

    let mut cfg = cfg_im();
    cfg.threads = 2;
    let eng = Engine::new(cfg).unwrap();
    eng.registry.register(std::sync::Arc::new(Probe));
    // 16 pass partitions (io_rows_for(1) = 65536)
    let x = eng.seq_int(0.0, 1.0, 16 * 65536);
    eng.metrics.reset();
    let r = x.sapply_custom("abort-probe").unwrap().sum();
    assert!(r.is_err(), "the failing partition's error must propagate");
    let done = eng.metrics.snapshot().native_partitions;
    assert!(
        done < 4,
        "abort flag must stop the other workers early (processed {done}/16)"
    );
}

/// Abort path of the asynchronous write-back pipeline (§III-B3): a pass
/// that fails mid-flight with `writeback` on must *discard* its queued
/// target writes (`wb_discarded > 0`), leave no partial target files on
/// disk, and leave the engine + cache fully reusable for the next pass.
#[test]
fn writeback_abort_discards_dirty_partitions() {
    use flashmatrix::dtype::DType;
    use flashmatrix::vudf::{Buf, CustomVudf};

    /// Fails on the strip containing `limit` — the LAST row of the pass,
    /// so every earlier partition has already been handed to the
    /// (deliberately slow) write-back writer when the abort fires.
    struct FailAtRow(f64);
    impl CustomVudf for FailAtRow {
        fn name(&self) -> &str {
            "wb-abort-probe"
        }
        fn out_dtype(&self, input: DType) -> DType {
            input
        }
        fn unary(&self, a: &Buf) -> flashmatrix::Result<Buf> {
            if a.to_f64_vec().iter().any(|v| *v == self.0) {
                return Err(flashmatrix::FmError::Unsupported("probe failure".into()));
            }
            Ok(a.clone())
        }
    }

    let dir = tmpdir("wb-abort");
    let n = 4u64 * 65536; // 4 EM pass partitions of 512 KiB each
    let cfg = EngineConfig {
        storage: StorageKind::External,
        data_dir: dir.clone(),
        em_cache_bytes: 8 << 20, // hosts the write-back writer
        prefetch_depth: 0,
        threads: 1,
        // asymmetric throttle: reads free, writes slower than one
        // partition per burst — so the writer is still busy with
        // partition 0 when the last partition's failure aborts the pass,
        // and partitions 1/2 are deterministically still dirty
        throttle: Some(ThrottleConfig {
            read_bytes_per_sec: 1 << 30,
            write_bytes_per_sec: 384 << 10,
        }),
        ..cfg_im()
    };
    assert!(cfg.writeback, "write-back must be the default");
    let eng = Engine::new(cfg).unwrap();
    eng.registry.register(Arc::new(FailAtRow((n - 1) as f64)));

    let x = eng.seq_int(0.0, 1.0, n);
    eng.metrics.reset();
    let r = x.sapply_custom("wb-abort-probe").unwrap().materialize();
    assert!(r.is_err(), "the failing partition's error must propagate");
    let m = eng.metrics.snapshot();
    assert!(
        m.wb_enqueued >= 3,
        "earlier partitions must have been queued (got {})",
        m.wb_enqueued
    );
    assert!(
        m.wb_discarded >= 1,
        "aborted pass must discard still-dirty partitions (got {})",
        m.wb_discarded
    );
    // no partial target files: the doomed builder's backing file is gone
    // entirely once the discard barrier returned (the virtual seq source
    // never had a file, so the data dir must be empty)
    let leftovers: Vec<_> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().file_name())
        .collect();
    assert!(
        leftovers.is_empty(),
        "aborted pass left files behind: {leftovers:?}"
    );

    // the engine, cache and writer thread stay usable: a clean pass on
    // the same engine flushes, and the file alone (cache cleared) holds
    // the full result
    let z = eng.seq_int(0.0, 1.0, 65536);
    let z2 = z.sq().unwrap().materialize().unwrap();
    if let Some(c) = &eng.cache {
        c.clear();
    }
    let h = z2.to_host().unwrap();
    assert_eq!(h.buf.get(10).as_f64(), 100.0);
    assert_eq!(h.buf.get(65535).as_f64(), 65535.0 * 65535.0);
    assert!(
        eng.metrics.snapshot().wb_enqueued > m.wb_enqueued,
        "the follow-up pass must run through the write-back pipeline too"
    );
}

/// Mixed-dtype groups (`fm.cbind.list` factor scenario): each member is
/// decoded with its own dtype and cast to the promoted group dtype.
#[test]
fn mixed_dtype_group_decodes_members_correctly() {
    use flashmatrix::dtype::DType;
    use flashmatrix::vudf::UnOp;

    let eng = Engine::new(cfg_im()).unwrap();
    let f = datasets::uniform(&eng, 30_000, 3, -1.0, 1.0, 21, None).unwrap();
    let i = datasets::uniform(&eng, 30_000, 2, 0.0, 9.0, 22, None)
        .unwrap()
        .sapply(UnOp::Floor)
        .unwrap()
        .cast(DType::I32)
        .unwrap()
        .materialize()
        .unwrap();
    let g = FmMatrix::group(&eng, &[&i, &f]).unwrap();
    assert_eq!(g.dtype(), DType::F64, "group dtype must promote over members");
    assert_eq!(g.ncol(), 5);

    // group colSums == concatenated member colSums
    let gc = g.col_sums().unwrap().buf.to_f64_vec();
    let mut want = i.col_sums().unwrap().buf.to_f64_vec();
    want.extend(f.col_sums().unwrap().buf.to_f64_vec());
    assert_close(&gc, &want, 1e-12, "mixed-dtype group colSums");

    // elementwise op over the promoted group matches the members
    let s = g.sq().unwrap().sum().unwrap();
    let want = i.sq().unwrap().sum().unwrap() + f.sq().unwrap().sum().unwrap();
    assert!((s - want).abs() / want.abs().max(1.0) < 1e-12);
}

/// `which.min`/`which.max` skip NaNs like R skips NAs; a NaN in the first
/// column must not freeze the answer at index 1, and an all-NaN row gives
/// the NA index 0 (R's `which.min` on all-NA returns no index — pinned
/// edge case; `labels - 1` then yields -1, which groupby drops like R
/// drops NA groups).
#[test]
fn which_min_skips_nans() {
    use flashmatrix::matrix::HostMat;

    let eng = Engine::new(cfg_im()).unwrap();
    let h = HostMat::from_rows_f64(&[
        vec![f64::NAN, 2.0, 0.5],
        vec![3.0, f64::NAN, 1.0],
        vec![f64::NAN, f64::NAN, f64::NAN],
    ]);
    let x = FmMatrix::from_host(&eng, &h).unwrap();
    let mins = x.which_min_row().unwrap().to_host().unwrap().buf.to_f64_vec();
    assert_eq!(mins, vec![3.0, 3.0, 0.0]);
    let maxs = x.which_max_row().unwrap().to_host().unwrap().buf.to_f64_vec();
    assert_eq!(maxs, vec![2.0, 1.0, 0.0]);
}

/// All-NaN-row assignment composes with groupby exactly like R drops NA
/// groups: the NA index 0 becomes label -1 after the k-means-style
/// `which.min - 1`, and `fm.groupby.row` ignores the row.
#[test]
fn all_nan_row_assignment_drops_from_groupby() {
    use flashmatrix::dtype::Scalar;
    use flashmatrix::matrix::HostMat;
    use flashmatrix::vudf::BinOp;

    let eng = Engine::new(cfg_im()).unwrap();
    let h = HostMat::from_rows_f64(&[
        vec![1.0, 5.0],
        vec![f64::NAN, f64::NAN],
        vec![6.0, 2.0],
    ]);
    let x = FmMatrix::from_host(&eng, &h).unwrap();
    let labels = x
        .which_min_row()
        .unwrap()
        .mapply_scalar(Scalar::I32(1), BinOp::Sub, true)
        .unwrap();
    let sums = x.groupby_row(&labels, 2, AggOp::Sum).unwrap();
    // row 0 -> group 0, row 2 -> group 1, the NaN row -> label -1: dropped
    assert_eq!(sums.get(0, 0).as_f64(), 1.0);
    assert_eq!(sums.get(0, 1).as_f64(), 5.0);
    assert_eq!(sums.get(1, 0).as_f64(), 6.0);
    assert_eq!(sums.get(1, 1).as_f64(), 2.0);
}

/// `fm.groupby.row` with an empty group pins R's zero-row semantics for
/// additive aggregation: a group no row maps to yields the identity row
/// (zeros for Sum), not garbage and not a shrunken result matrix.
#[test]
fn groupby_empty_group_yields_zero_row() {
    use flashmatrix::matrix::HostMat;
    use flashmatrix::vudf::Buf;

    let eng = Engine::new(cfg_im()).unwrap();
    let h = HostMat::from_rows_f64(&[vec![1.0, 10.0], vec![2.0, 20.0], vec![4.0, 40.0]]);
    let x = FmMatrix::from_host(&eng, &h).unwrap();
    // labels use only groups 0 and 2 of k = 3: group 1 stays empty
    let labels = FmMatrix::from_host(
        &eng,
        &HostMat {
            nrow: 3,
            ncol: 1,
            buf: Buf::I32(vec![0, 2, 0]),
        },
    )
    .unwrap();
    let sums = x.groupby_row(&labels, 3, AggOp::Sum).unwrap();
    assert_eq!(sums.nrow, 3);
    assert_eq!(sums.get(0, 0).as_f64(), 5.0);
    assert_eq!(sums.get(0, 1).as_f64(), 50.0);
    assert_eq!(sums.get(1, 0).as_f64(), 0.0, "empty group must be a zero row");
    assert_eq!(sums.get(1, 1).as_f64(), 0.0);
    assert_eq!(sums.get(2, 0).as_f64(), 2.0);
    // counts via groupby of ones: the empty group counts zero
    let ones = eng.fill(flashmatrix::dtype::Scalar::F64(1.0), 3, 1);
    let counts = ones.groupby_row(&labels, 3, AggOp::Sum).unwrap();
    assert_eq!(counts.get(1, 0).as_f64(), 0.0);
}

// ---------------------------------------------------------------------------
// PR 4: out-of-core forcing harness + sparse subsystem
// ---------------------------------------------------------------------------

fn assert_rel_close(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() / x.abs().max(1.0) < tol,
            "{what}[{i}]: {x} vs {y}"
        );
    }
}

/// K-means under the tiny-cache out-of-core config must match the
/// in-memory run: the EM read path, single-partition cache replacement
/// and read-ahead are exercised by `cargo test`, not only by benches
/// (`FLASHR_TEST_EM=1` additionally throttles the simulated SSD).
#[test]
fn kmeans_out_of_core_matches_in_memory() {
    let (im, em) = flashmatrix::testutil::rerun_out_of_core("kmeans", |eng| {
        let (x, _) = datasets::mix_gaussian(eng, 130_000, 8, 4, 8.0, 3, None).unwrap();
        let km = flashmatrix::algs::kmeans(&x, 4, 3, 1).unwrap();
        let mut fp = km.wcss.clone();
        fp.extend(km.centroids.buf.to_f64_vec());
        fp
    });
    assert_rel_close(&im, &em, 1e-10, "kmeans IM vs out-of-core");
}

/// Same forcing applied to GMM (the heaviest sink pipeline).
#[test]
fn gmm_out_of_core_matches_in_memory() {
    let (im, em) = flashmatrix::testutil::rerun_out_of_core("gmm", |eng| {
        let (x, _) = datasets::mix_gaussian(eng, 80_000, 8, 3, 8.0, 7, None).unwrap();
        let gm = flashmatrix::algs::gmm(&x, 3, 2, 1).unwrap();
        let mut fp = gm.loglik.clone();
        fp.extend(gm.weights.clone());
        fp
    });
    assert_rel_close(&im, &em, 1e-9, "gmm IM vs out-of-core");
}

/// Same forcing applied to correlation (the two-pass algorithm whose
/// second pass re-reads data the single-partition cache already evicted).
#[test]
fn correlation_out_of_core_matches_in_memory() {
    let (im, em) = flashmatrix::testutil::rerun_out_of_core("correlation", |eng| {
        let x = datasets::spectral_like(eng, 120_000, 6, 11, None).unwrap();
        flashmatrix::algs::correlation(&x).unwrap().corr
    });
    assert_rel_close(&im, &em, 1e-10, "correlation IM vs out-of-core");
}

/// Acceptance pin for the sparse subsystem: PageRank completes out of
/// core with `em_cache_bytes` smaller than the edge matrix, and its ranks
/// are **bit-identical** to the in-memory run (single-threaded so sink
/// merge order cannot perturb the convergence log either).
#[test]
fn pagerank_em_small_cache_bitexact_vs_im() {
    let n: u64 = 1 << 14;
    let run = |cfg: EngineConfig| {
        let eng = Engine::new(cfg).unwrap();
        let (g, dangling) = datasets::pagerank_graph(&eng, n, 8, 99, None).unwrap();
        let edge_bytes = g.sparse_bytes().unwrap();
        if eng.config.storage == StorageKind::External {
            let c = eng.cache.as_ref().expect("EM leg runs with a cache");
            assert!(
                (c.capacity() as u64) < edge_bytes,
                "cache {} must be smaller than the edge matrix {edge_bytes}",
                c.capacity()
            );
            c.clear(); // cold start: drop write-through copies
        }
        eng.metrics.reset();
        let pr = flashmatrix::algs::pagerank(&g, &dangling, 0.85, 10, 0.0).unwrap();
        (pr.ranks, eng.metrics.snapshot())
    };

    let (im_ranks, _) = run(EngineConfig {
        threads: 1,
        ..cfg_im()
    });
    let (em_ranks, m) = run(EngineConfig {
        threads: 1,
        em_cache_bytes: 64 << 10, // « the ~1 MiB edge matrix
        prefetch_depth: 2,
        ..cfg_em("pagerank-em")
    });
    assert_eq!(im_ranks.len(), em_ranks.len());
    for (i, (a, b)) in im_ranks.iter().zip(&em_ranks).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "rank[{i}] not bit-identical: {a} vs {b}"
        );
    }
    assert!(m.spmm_nnz > 0, "EM run must stream sparse entries");
    assert!(
        m.io_read_bytes > 0 && m.cache_evictions > 0,
        "EM run must replace cache entries (read {} B, evictions {})",
        m.io_read_bytes,
        m.cache_evictions
    );
    let total: f64 = em_ranks.iter().sum();
    assert!((total - 1.0).abs() < 1e-9, "rank mass {total}");
}

/// Logistic regression agrees across storage modes (the GLM workload's
/// EM path: three fused sinks per IRLS pass).
#[test]
fn logistic_out_of_core_matches_in_memory() {
    let (im, em) = flashmatrix::testutil::rerun_out_of_core("logistic", |eng| {
        let x = datasets::uniform(eng, 120_000, 6, -1.0, 1.0, 21, None).unwrap();
        let y = datasets::logistic_labels(&x, &[1.0, -0.5, 0.25, -1.5, 0.75, 0.0], 22).unwrap();
        flashmatrix::algs::logistic(&x, &y, 4, 1e-8).unwrap().beta
    });
    assert_rel_close(&im, &em, 1e-9, "logistic IM vs out-of-core");
}

/// SVD under the out-of-core forcing harness: the power-iteration loop's
/// repeated Gramian passes re-read partitions the one-partition cache
/// evicted on the previous pass, every iteration.
#[test]
fn svd_out_of_core_matches_in_memory() {
    let (im, em) = flashmatrix::testutil::rerun_out_of_core("svd", |eng| {
        let x = datasets::spectral_like(eng, 120_000, 8, 17, None).unwrap();
        let s = flashmatrix::algs::svd(&x, 4).unwrap();
        let mut fp = s.sigma.clone();
        // right singular vectors up to sign (the deterministic runs agree
        // on signs too, but the parity contract is the subspace)
        fp.extend(s.v.iter().map(|v| v.abs()));
        fp
    });
    assert_rel_close(&im, &em, 1e-9, "svd IM vs out-of-core");
}

/// Summary statistics (six fused agg.col sinks in one pass) under the
/// same forcing: one streaming pass whose column stats must survive cache
/// replacement mid-matrix.
#[test]
fn summary_out_of_core_matches_in_memory() {
    let (im, em) = flashmatrix::testutil::rerun_out_of_core("summary", |eng| {
        let x = datasets::uniform(eng, 130_000, 7, -2.0, 2.0, 29, None).unwrap();
        let s = flashmatrix::algs::summary(&x).unwrap();
        let mut fp = s.min.clone();
        fp.extend(s.max.clone());
        fp.extend(s.mean.clone());
        fp.extend(s.var.clone());
        fp.extend(s.nnz.clone());
        fp
    });
    assert_rel_close(&im, &em, 1e-10, "summary IM vs out-of-core");
}

/// Min/Max aggregation must give identical results with `vectorized_udf`
/// on and off when NaNs are present: the vectorized `reduce` fast paths
/// (`f64::min`/`max`) and the scalar `fold_scalar` path (`<`/`>`) share
/// NaN-skipping semantics. Pins the contract.
#[test]
fn nan_min_max_parity_across_udf_modes() {
    use flashmatrix::matrix::HostMat;

    let h = HostMat::from_rows_f64(&[
        vec![1.0, f64::NAN],
        vec![f64::NAN, -2.0],
        vec![5.0, 0.5],
    ]);
    let mut got = Vec::new();
    for vectorized in [true, false] {
        let cfg = EngineConfig {
            vectorized_udf: vectorized,
            ..cfg_im()
        };
        let eng = Engine::new(cfg).unwrap();
        let x = FmMatrix::from_host(&eng, &h).unwrap();
        let mut fp = vec![x.min().unwrap(), x.max().unwrap()];
        fp.extend(x.agg_col(AggOp::Min).unwrap().buf.to_f64_vec());
        fp.extend(x.agg_col(AggOp::Max).unwrap().buf.to_f64_vec());
        fp.extend(
            x.agg_row(AggOp::Min)
                .unwrap()
                .to_host()
                .unwrap()
                .buf
                .to_f64_vec(),
        );
        got.push(fp);
    }
    assert_eq!(got[0], got[1], "vectorized and scalar NaN semantics differ");
    // and both match R's NA-skipping answers
    assert_eq!(
        got[0],
        vec![-2.0, 5.0, 1.0, -2.0, 5.0, 0.5, 1.0, -2.0, 0.5]
    );
}
