//! Cross-module integration tests: the same computation must produce
//! identical results across every engine configuration the paper
//! compares — in-memory vs external-memory, fused vs eager, vectorized vs
//! per-element UDFs, 1 thread vs many, XLA-dispatched vs native.

use std::sync::Arc;

use flashmatrix::config::{EngineConfig, StorageKind, ThrottleConfig};
use flashmatrix::datasets;
use flashmatrix::fmr::{Engine, FmMatrix};
use flashmatrix::vudf::AggOp;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let p = std::env::temp_dir().join(format!("fm-it-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&p).unwrap();
    p
}

fn cfg_im() -> EngineConfig {
    EngineConfig {
        xla_dispatch: false,
        chunk_bytes: 4 << 20,
        target_part_bytes: 1 << 20,
        ..Default::default()
    }
}

fn cfg_em(tag: &str) -> EngineConfig {
    EngineConfig {
        storage: StorageKind::External,
        data_dir: tmpdir(tag),
        ..cfg_im()
    }
}

/// Run one pipeline under a config, returning a fingerprint of results.
fn pipeline_fingerprint(cfg: EngineConfig) -> Vec<f64> {
    let eng = Engine::new(cfg).unwrap();
    let x = datasets::uniform(&eng, 50_000, 6, -2.0, 2.0, 31, None).unwrap();
    // expression mixing sapply/mapply/rowagg/colagg/groupby/inner
    let y = x.abs().unwrap().add(&x.sq().unwrap()).unwrap();
    let s1 = y.sum().unwrap();
    let rs = y.row_sums().unwrap();
    let s2 = rs.max().unwrap();
    let cs = y.col_sums().unwrap().buf.to_f64_vec();
    let labels = x
        .col(0)
        .unwrap()
        .mapply_scalar(flashmatrix::dtype::Scalar::F64(0.0), flashmatrix::vudf::BinOp::Gt, true)
        .unwrap()
        .cast(flashmatrix::dtype::DType::I32)
        .unwrap();
    let g = y.groupby_row(&labels, 2, AggOp::Sum).unwrap();
    let gram = x.crossprod(&x).unwrap();
    let mut out = vec![s1, s2];
    out.extend(cs);
    out.extend(g.buf.to_f64_vec());
    out.extend(gram.buf.to_f64_vec());
    out
}

fn assert_close(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let scale = x.abs().max(1.0);
        assert!(
            (x - y).abs() / scale < tol,
            "{what}[{i}]: {x} vs {y}"
        );
    }
}

#[test]
fn em_equals_im() {
    let im = pipeline_fingerprint(cfg_im());
    let em = pipeline_fingerprint(cfg_em("emim"));
    assert_close(&im, &em, 1e-12, "EM vs IM");
}

#[test]
fn eager_equals_fused() {
    let fused = pipeline_fingerprint(cfg_im());
    let eager = pipeline_fingerprint(EngineConfig {
        fuse_mem: false,
        fuse_cache: false,
        ..cfg_im()
    });
    assert_close(&fused, &eager, 1e-12, "eager vs fused");
    let no_cache_fuse = pipeline_fingerprint(EngineConfig {
        fuse_cache: false,
        ..cfg_im()
    });
    assert_close(&fused, &no_cache_fuse, 1e-12, "mem-fuse-only vs fused");
}

#[test]
fn scalar_udf_equals_vectorized() {
    let v = pipeline_fingerprint(cfg_im());
    let s = pipeline_fingerprint(EngineConfig {
        vectorized_udf: false,
        ..cfg_im()
    });
    assert_close(&v, &s, 1e-12, "scalar-mode vs vectorized");
}

#[test]
fn thread_count_invariance() {
    let t1 = pipeline_fingerprint(EngineConfig {
        threads: 1,
        ..cfg_im()
    });
    let t4 = pipeline_fingerprint(EngineConfig {
        threads: 4,
        ..cfg_im()
    });
    // partial-agg merge order may differ: tolerate fp reassociation
    assert_close(&t1, &t4, 1e-9, "1 vs 4 threads");
}

#[test]
fn throttled_em_still_correct() {
    let mut cfg = cfg_em("throttle");
    cfg.throttle = Some(ThrottleConfig {
        read_bytes_per_sec: 200 << 20,
        write_bytes_per_sec: 200 << 20,
    });
    let em = pipeline_fingerprint(cfg);
    let im = pipeline_fingerprint(cfg_im());
    assert_close(&im, &em, 1e-12, "throttled EM vs IM");
}

#[test]
fn em_cache_cols_preserves_results() {
    let mut cfg = cfg_em("cache");
    cfg.em_cache_cols = 3; // cache half the columns
    let em = pipeline_fingerprint(cfg);
    let im = pipeline_fingerprint(cfg_im());
    assert_close(&im, &em, 1e-12, "cached EM vs IM");
}

#[test]
fn algorithms_agree_across_storage() {
    for (tag, mk) in [
        ("alg-im", None),
        ("alg-em", Some("em")),
    ] {
        let cfg = match mk {
            None => cfg_im(),
            Some(_) => cfg_em(tag),
        };
        let eng = Engine::new(cfg).unwrap();
        let (x, _) = datasets::mix_gaussian(&eng, 30_000, 8, 4, 8.0, 3, None).unwrap();
        let km = flashmatrix::algs::kmeans(&x, 4, 3, 1).unwrap();
        let sm = flashmatrix::algs::summary(&x).unwrap();
        // deterministic across storage: same seeds, same math
        // (values pinned by the IM run in the first loop iteration)
        if tag == "alg-im" {
            std::env::set_var("FM_TEST_WCSS", format!("{:.12e}", km.wcss[2]));
            std::env::set_var("FM_TEST_MEAN0", format!("{:.12e}", sm.mean[0]));
        } else {
            let w: f64 = std::env::var("FM_TEST_WCSS").unwrap().parse().unwrap();
            let m: f64 = std::env::var("FM_TEST_MEAN0").unwrap().parse().unwrap();
            assert!((km.wcss[2] - w).abs() / w < 1e-10);
            assert!((sm.mean[0] - m).abs() < 1e-10);
        }
    }
}

#[test]
fn groupby_with_virtual_labels_fuses() {
    // k-means-shaped one-pass: labels computed in the same pass as the
    // grouped aggregation (the paper's flagship fusion)
    let eng: Arc<Engine> = Engine::new(cfg_im()).unwrap();
    let x = datasets::uniform(&eng, 20_000, 3, 0.0, 1.0, 5, None).unwrap();
    let labels = x
        .row_sums()
        .unwrap()
        .mapply_scalar(flashmatrix::dtype::Scalar::F64(1.5), flashmatrix::vudf::BinOp::Gt, true)
        .unwrap()
        .cast(flashmatrix::dtype::DType::I32)
        .unwrap();
    let sums = x.groupby_row(&labels, 2, AggOp::Sum).unwrap();
    let total: f64 = sums.buf.to_f64_vec().iter().sum();
    let expect = x.sum().unwrap();
    assert!((total - expect).abs() / expect < 1e-10);
}

#[test]
fn chunk_recycling_observable() {
    let cfg = cfg_im();
    let eng = Engine::new(cfg).unwrap();
    // create + drop matrices; chunks must be reused
    for _ in 0..3 {
        let x = datasets::uniform(&eng, 200_000, 4, 0.0, 1.0, 1, None).unwrap();
        let _ = x.sum().unwrap();
        drop(x);
    }
    let m = eng.metrics.snapshot();
    assert!(
        m.chunks_recycled > 0,
        "expected chunk reuse, got {m:?}"
    );
}

#[test]
fn wide_view_operations() {
    let eng = Engine::new(cfg_im()).unwrap();
    let h = flashmatrix::matrix::HostMat::from_rows_f64(&[
        vec![1.0, 2.0, 3.0],
        vec![4.0, 5.0, 6.0],
    ]);
    let a = FmMatrix::from_host(&eng, &h).unwrap(); // 2x3
    let w = a.t(); // 3x2 view... wait: a is 2x3, t is 3x2
    // agg.row over the wide view == agg.col over the base
    let rs = w.agg_row(AggOp::Sum).unwrap().to_host().unwrap();
    assert_eq!(rs.buf.to_f64_vec(), vec![5.0, 7.0, 9.0]);
    // export of the transposed view
    let ht = w.to_host().unwrap();
    assert_eq!(ht.nrow, 3);
    assert_eq!(ht.get(2, 1).as_f64(), 6.0);
}

#[test]
fn conv_store_roundtrips_between_storages() {
    let eng = Engine::new(cfg_em("convstore")).unwrap();
    let x = datasets::uniform(&eng, 40_000, 4, -1.0, 1.0, 17, None).unwrap();
    let sum_em = x.sum().unwrap();
    // move SSD -> memory and back; values identical
    let x_im = x.conv_store(flashmatrix::StorageKind::InMem).unwrap();
    assert_eq!(x_im.sum().unwrap(), sum_em);
    let x_em2 = x_im.conv_store(flashmatrix::StorageKind::External).unwrap();
    assert_eq!(x_em2.sum().unwrap(), sum_em);
    assert!(eng.metrics.snapshot().io_write_bytes > 0);
}

#[test]
fn group_of_matrices_behaves_as_wider_matrix() {
    let eng = Engine::new(cfg_im()).unwrap();
    let a = datasets::uniform(&eng, 30_000, 3, 0.0, 1.0, 1, None).unwrap();
    let b = datasets::uniform(&eng, 30_000, 2, -1.0, 0.0, 2, None).unwrap();
    let g = FmMatrix::group(&eng, &[&a, &b]).unwrap();
    assert_eq!(g.ncol(), 5);
    // colSums of the group == concatenated member colSums
    let gc = g.col_sums().unwrap().buf.to_f64_vec();
    let mut want = a.col_sums().unwrap().buf.to_f64_vec();
    want.extend(b.col_sums().unwrap().buf.to_f64_vec());
    for (x, y) in gc.iter().zip(&want) {
        assert!((x - y).abs() < 1e-9, "{x} vs {y}");
    }
    // elementwise op on the group fuses like a normal matrix
    let s = g.sq().unwrap().sum().unwrap();
    let want = a.sq().unwrap().sum().unwrap() + b.sq().unwrap().sum().unwrap();
    assert!((s - want).abs() / want < 1e-12);
    // groups decompose for mixed-shape members only when nrow matches
    let c = datasets::uniform(&eng, 10, 1, 0.0, 1.0, 3, None).unwrap();
    assert!(FmMatrix::group(&eng, &[&a, &c]).is_err());
}
