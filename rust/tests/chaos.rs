//! Chaos suite: seeded I/O fault schedules end to end (PR 8).
//!
//! The contract under test, from `docs/ARCHITECTURE.md`'s fault-tolerance
//! section: with a [`flashmatrix::storage::FaultConfig`] wired into an
//! engine, every injected transient fault (EIO, short read, torn write,
//! single-bit flip) is either absorbed **transparently** — bounded
//! retries plus partition checksums, results bit-identical to a fault-free
//! run — or surfaced as a **typed** [`FmError`] that aborts the pass and
//! leaves the engine fully reusable: the same engine re-runs the same
//! workload and converges to the bit-identical clean answer once the
//! seeded sites heal.
//!
//! Determinism: fault sites are keyed `(hash(file name), op, offset)`, so
//! the *named* datasets used here have schedules frozen by the seed alone
//! — the exact fates asserted below (which sites fault, for how many
//! attempts) are fixed properties of the pinned seeds, not luck.
//! Workloads run `threads: 1` so sink merge order is part of the
//! fingerprint, exactly like `tests/cross_pass.rs`.

use std::path::Path;
use std::sync::Arc;

use flashmatrix::algs;
use flashmatrix::config::EngineConfig;
use flashmatrix::datasets;
use flashmatrix::dtype::DType;
use flashmatrix::fmr::Engine;
use flashmatrix::storage::FaultConfig;
use flashmatrix::testutil::{out_of_core_config, TempDir};
use flashmatrix::vudf::{Buf, CustomVudf};
use flashmatrix::{FmError, Result, StorageKind};

fn assert_bits(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: fingerprint length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}[{i}]: {x} != {y}");
    }
}

/// The transient schedule, written as a `FLASHR_FAULTS` spec string so the
/// documented env syntax is exercised end to end. `max_duration=1` keeps
/// every fault within the recovery budget: one retry clears an EIO/short
/// read/torn write, the single checksum re-read clears a bit flip.
fn transient_faults() -> FaultConfig {
    FaultConfig::parse("seed=3201,eio=0.85,short=0.06,torn=0.10,bitflip=0.05,max_duration=1")
        .expect("spec mirrors the README's FLASHR_FAULTS example")
}

/// Tiny-cache out-of-core engine (4 MiB cache, single-threaded) with the
/// fault plan overridden explicitly — a `FLASHR_FAULTS` env var from the
/// CI chaos job must not leak into these controlled schedules.
fn em_cfg(dir: &Path, faults: Option<FaultConfig>) -> EngineConfig {
    EngineConfig {
        threads: 1,
        fault_injection: faults,
        ..out_of_core_config(dir)
    }
}

/// In-memory twin of [`em_cfg`]: same geometry, no storage to fault.
fn im_cfg(faults: Option<FaultConfig>) -> EngineConfig {
    EngineConfig {
        storage: StorageKind::InMem,
        threads: 1,
        chunk_bytes: 4 << 20,
        target_part_bytes: 1 << 20,
        xla_dispatch: false,
        fault_injection: faults,
        ..EngineConfig::default()
    }
}

/// The absorbed-fault matrix for one workload: EM faulty vs EM clean must
/// be bit-identical with faults provably injected and recovered from; IM
/// with the same plan configured has no positioned I/O to fault at all.
fn assert_absorbed<F>(tag: &str, name: &str, workload: F)
where
    F: Fn(&Arc<Engine>, Option<&str>) -> Vec<f64>,
{
    let d0 = TempDir::new(&format!("chaos-{tag}-clean"));
    let clean = workload(&Engine::new(em_cfg(d0.path(), None)).unwrap(), Some(name));

    let d1 = TempDir::new(&format!("chaos-{tag}-faulty"));
    let eng = Engine::new(em_cfg(d1.path(), Some(transient_faults()))).unwrap();
    let faulty = workload(&eng, Some(name));
    let m = eng.metrics.snapshot();
    assert!(m.faults_injected > 0, "{tag}: fault plan never fired");
    assert!(
        m.io_retries > 0 || m.checksum_failures > 0,
        "{tag}: no transparent recovery exercised (retries {}, checksum failures {})",
        m.io_retries,
        m.checksum_failures
    );
    assert_bits(&clean, &faulty, &format!("{tag} EM faulty-vs-clean"));

    let eng_im = Engine::new(im_cfg(Some(transient_faults()))).unwrap();
    let im_faulty = workload(&eng_im, None);
    let im_clean = workload(&Engine::new(im_cfg(None)).unwrap(), None);
    assert_eq!(
        eng_im.metrics.snapshot().faults_injected,
        0,
        "{tag}: in-memory engines have no fault surface"
    );
    assert_bits(&im_clean, &im_faulty, &format!("{tag} IM faulty-vs-clean"));
}

fn kmeans_fp(eng: &Arc<Engine>, name: Option<&str>) -> Vec<f64> {
    let (x, _) = datasets::mix_gaussian(eng, 100_000, 6, 3, 8.0, 3, name).unwrap();
    let km = algs::kmeans(&x, 3, 3, 1).unwrap();
    let mut fp = km.wcss;
    fp.extend(km.centroids.buf.to_f64_vec());
    fp.extend(km.sizes);
    fp
}

fn irls_fp(eng: &Arc<Engine>, name: Option<&str>) -> Vec<f64> {
    let x = datasets::uniform(eng, 80_000, 4, -1.0, 1.0, 21, name).unwrap();
    let y = datasets::logistic_labels(&x, &[1.0, -0.5, 0.25, -1.5], 22).unwrap();
    let fit = algs::logistic(&x, &y, 4, 1e-8).unwrap();
    let mut fp = fit.beta;
    fp.extend(fit.deviances);
    fp
}

fn pagerank_fp(eng: &Arc<Engine>, name: Option<&str>) -> Vec<f64> {
    let (g, dangling) = datasets::pagerank_graph(eng, 1 << 13, 6, 17, name).unwrap();
    let pr = algs::pagerank(&g, &dangling, 0.85, 6, 0.0).unwrap();
    let mut fp = pr.ranks;
    fp.extend(pr.deltas);
    fp
}

/// Seed 3201 gives every named site of this dataset a 1-attempt EIO
/// (verified against the site model): k-means must retry through all of
/// them and land bit-identical.
#[test]
fn kmeans_absorbs_transient_faults_bitwise() {
    assert_absorbed("kmeans", "chaos-kmeans.mat", kmeans_fp);
}

/// Same schedule over IRLS: the x build, the label pass and four IRLS
/// iterations all cross the faulty store.
#[test]
fn irls_absorbs_transient_faults_bitwise() {
    assert_absorbed("irls", "chaos-irls.mat", irls_fp);
}

/// Sparse leg: the CSR graph plus the per-iteration rank targets give the
/// schedule both named and anonymous write sites to hit.
#[test]
fn pagerank_absorbs_transient_faults_bitwise() {
    assert_absorbed("pagerank", "chaos-pr.graph", pagerank_fp);
}

// ---------------------------------------------------------------------------
// Abort-then-heal: faults past the retry budget
// ---------------------------------------------------------------------------

/// Direct sinks over a named dataset — no virtual intermediates, so every
/// byte of I/O belongs to `chaos-outage.mat`'s stable fault namespace and
/// the outage below provably converges (anonymous files would draw fresh
/// sites each run and never heal at `eio=1.0`).
fn outage_workload(eng: &Arc<Engine>) -> Result<Vec<f64>> {
    let x = datasets::uniform(eng, 60_000, 6, -1.0, 1.0, 5, Some("chaos-outage.mat"))?;
    let mut fp = x.col_sums()?.buf.to_f64_vec();
    fp.push(x.sum()?);
    fp.push(x.min()?);
    fp.push(x.max()?);
    Ok(fp)
}

fn outage_cfg(dir: &Path, faults: Option<FaultConfig>) -> EngineConfig {
    EngineConfig {
        threads: 1,
        prefetch_depth: 0, // demand reads only: the abort/heal sequence is exact
        writeback: false,  // write failures surface at the faulting pass, not a flush
        io_retry_limit: 1,
        fault_injection: faults,
        ..out_of_core_config(dir)
    }
}

/// An `eio=1.0` outage outlasting the retry budget: passes abort with the
/// typed I/O error — never a panic, never a poisoned engine — and because
/// site attempt counters accumulate monotonically across runs, re-running
/// the *same* engine heals within a bounded number of aborts and then
/// produces the bit-identical clean answer. Seed 77 schedules 2 failing
/// attempts on the dataset's write site (site model), so with a budget of
/// 1 retry the first run is guaranteed to abort.
#[test]
fn outage_aborts_typed_then_heals_on_the_same_engine() {
    let d0 = TempDir::new("chaos-outage-clean");
    let clean = outage_workload(&Engine::new(outage_cfg(d0.path(), None)).unwrap()).unwrap();

    let outage = FaultConfig {
        seed: 77,
        eio: 1.0,
        max_duration: 4,
        ..FaultConfig::default()
    };
    let d1 = TempDir::new("chaos-outage");
    let eng = Engine::new(outage_cfg(d1.path(), Some(outage))).unwrap();
    let mut aborts = 0u32;
    let healed = loop {
        match outage_workload(&eng) {
            Ok(fp) => break fp,
            Err(e) => {
                assert!(
                    matches!(e, FmError::Io(_)),
                    "outage must surface the injected EIO as a typed error, got: {e}"
                );
                aborts += 1;
                assert!(
                    aborts <= 16,
                    "sites fault for at most 4 attempts; still failing after {aborts} runs: {e}"
                );
            }
        }
    };
    assert!(aborts >= 1, "the first run must exhaust the 1-retry budget and abort");
    let m = eng.metrics.snapshot();
    assert!(m.faults_injected > 0, "outage never fired");
    assert!(m.io_retries > 0, "every failing op must burn its retry budget first");
    assert_bits(&clean, &healed, "outage healed-vs-clean");
}

// ---------------------------------------------------------------------------
// Persistent corruption: checksums turn silent bit rot into typed errors
// ---------------------------------------------------------------------------

/// Every read flips a bit forever (`bit_flip=1.0, persistent=1.0`): the
/// partition checksum catches it, the single re-read hits the same fate,
/// and the pass aborts with [`FmError::Corrupt`] — twice in a row on the
/// same engine, proving the failure is contained, typed and repeatable
/// rather than a panic, a wrong answer or a wedged engine.
#[test]
fn persistent_corruption_surfaces_typed_errors_and_engine_stays_usable() {
    let dir = TempDir::new("chaos-corrupt");
    let corrupt = FaultConfig {
        seed: 11,
        bit_flip: 1.0,
        persistent: 1.0,
        ..FaultConfig::default()
    };
    // 9.6 MiB matrix vs the 4 MiB cache: column sums must re-read cold
    // partitions from the (corrupting) store.
    let eng = Engine::new(em_cfg(dir.path(), Some(corrupt))).unwrap();
    for round in 0..2 {
        let x = datasets::uniform(&eng, 200_000, 6, -1.0, 1.0, 9, None).unwrap();
        match x.col_sums() {
            Err(FmError::Corrupt(msg)) => {
                assert!(msg.contains("checksum"), "round {round}: {msg}");
            }
            Err(e) => panic!("round {round}: expected FmError::Corrupt, got: {e}"),
            Ok(_) => panic!("round {round}: every read flips a bit; checksums must catch it"),
        }
    }
    let m = eng.metrics.snapshot();
    assert!(
        m.checksum_failures >= 2,
        "each failing read verifies twice (mismatch + one re-read), saw {}",
        m.checksum_failures
    );
    assert!(m.faults_injected > 0, "bit flips must be counted as injections");
}

// ---------------------------------------------------------------------------
// Worker panic containment
// ---------------------------------------------------------------------------

struct PanicVudf;

impl CustomVudf for PanicVudf {
    fn name(&self) -> &str {
        "chaos-panic"
    }

    fn out_dtype(&self, input: DType) -> DType {
        input
    }

    fn unary(&self, _a: &Buf) -> Result<Buf> {
        panic!("chaos: deliberate VUDF panic")
    }
}

/// A panic inside a pass worker (here: a user VUDF) must not tear down
/// the process or poison the engine: the pass aborts with a typed
/// `Runtime` error naming the panic, and the same engine then runs a
/// clean pass whose result is bit-identical to a fresh engine's.
#[test]
fn worker_panic_aborts_the_pass_and_the_engine_stays_usable() {
    let dir = TempDir::new("chaos-panic");
    let eng = Engine::new(em_cfg(dir.path(), None)).unwrap();
    eng.registry.register(Arc::new(PanicVudf));
    let x = datasets::uniform(&eng, 100_000, 6, -1.0, 1.0, 13, None).unwrap();
    match x.sapply_custom("chaos-panic").and_then(|m| m.to_host()) {
        Err(FmError::Runtime(msg)) => {
            assert!(msg.contains("panicked"), "error must name the panic: {msg}");
        }
        Err(e) => panic!("expected a contained worker panic, got: {e}"),
        Ok(_) => panic!("a panicking VUDF cannot produce a result"),
    }

    let survived = x.col_sums().unwrap().buf.to_f64_vec();
    let d2 = TempDir::new("chaos-panic-fresh");
    let eng2 = Engine::new(em_cfg(d2.path(), None)).unwrap();
    let x2 = datasets::uniform(&eng2, 100_000, 6, -1.0, 1.0, 13, None).unwrap();
    let fresh = x2.col_sums().unwrap().buf.to_f64_vec();
    assert_bits(&fresh, &survived, "post-panic col_sums");
}

// ---------------------------------------------------------------------------
// Configuration validation
// ---------------------------------------------------------------------------

/// Invalid `FLASHR_FAULTS` specs and unsafe knob combinations are
/// rejected up front with typed config errors.
#[test]
fn fault_specs_are_validated() {
    assert!(FaultConfig::parse("eio=1.2").is_err(), "probability outside [0,1]");
    assert!(FaultConfig::parse("seed=1,bogus=2").is_err(), "unknown key");
    assert!(
        FaultConfig::parse("eio=0.9,bitflip=0.2").is_err(),
        "read-side probabilities sum past 1"
    );
    assert!(FaultConfig::parse("max_duration=0").is_err(), "zero duration");
    let cfg = EngineConfig {
        io_checksums: false,
        fault_injection: Some(FaultConfig {
            bit_flip: 0.1,
            ..FaultConfig::default()
        }),
        ..EngineConfig::default()
    };
    assert!(
        Engine::new(cfg).is_err(),
        "bit flips without checksums would corrupt results silently"
    );
}

// ---------------------------------------------------------------------------
// Aborted planned batches must not strand residency pins (PR 9)
// ---------------------------------------------------------------------------

/// The cross-pass optimizer pins its memoized intermediates resident in
/// the shared partition cache (the [`flashmatrix::plan`] residency hint).
/// Those pins are tenant-invisible cache pressure, so an injected-fault
/// abort of a later planned batch must release every one of them:
/// `pinned_bytes` returns to zero, and the same engine keeps producing
/// clean answers afterwards.
///
/// Recipe: three rounds of the recurring-intermediate chain on a small,
/// fully-cached dataset memoize (and pin) the shared intermediate while
/// never touching the (persistently corrupting) store; a larger second
/// dataset then forces cold reads, every one of which flips a bit, so its
/// batch deterministically aborts with the memo populated.
#[test]
fn aborted_planned_batch_strands_no_residency_pins() {
    use flashmatrix::dag::UnFn;
    use flashmatrix::dtype::Scalar;
    use flashmatrix::genops;
    use flashmatrix::plan::PlanRequest;
    use flashmatrix::vudf::{AggOp, BinOp, UnOp};

    let dir = TempDir::new("chaos-pins");
    let corrupt = FaultConfig {
        seed: 23,
        bit_flip: 1.0,
        persistent: 1.0,
        ..FaultConfig::default()
    };
    let mut cfg = em_cfg(dir.path(), Some(corrupt));
    cfg.cross_pass_opt = true; // independent of FLASHR_NO_CROSS_PASS_OPT
    cfg.prefetch_depth = 0; // no read-ahead pins: memo pins only
    let eng = Engine::new(cfg).unwrap();
    let cache = eng.cache.clone().expect("EM config has a partition cache");

    // 32 KiB dataset « 4 MiB cache: every round is served write-through,
    // the corrupting store is never read, and round 2 materializes +
    // round 3 substitutes the shared intermediate (plan unit tests pin
    // this exact recurrence recipe)
    let x = datasets::uniform(&eng, 2048, 2, 0.0, 1.0, 13, Some("chaos-pins.mat")).unwrap();
    for _ in 0..3 {
        let shared = genops::sapply(&x.m, UnFn::Builtin(UnOp::Sqrt));
        let t = genops::mapply_scalar(&shared, Scalar::F64(2.0), BinOp::Mul, true);
        let s_src = genops::mapply_scalar(&shared, Scalar::F64(1.0), BinOp::Add, true);
        let s = genops::agg_full(&s_src, AggOp::Sum);
        eng.plan_batch(&[PlanRequest::target(&t), PlanRequest::sink(s)])
            .unwrap();
    }
    assert!(
        cache.pinned_bytes() > 0,
        "the memoized intermediate must be pinned resident before the abort"
    );

    // 9.6 MiB » cache: the scan reads cold partitions from the store,
    // every read flips a bit, the checksum catches it and the planned
    // batch aborts — with the memo still holding its pins
    let aborted = datasets::uniform(&eng, 200_000, 6, -1.0, 1.0, 9, None)
        .and_then(|big| big.col_sums());
    match aborted {
        Err(FmError::Corrupt(_)) | Err(FmError::Io(_)) => {}
        Err(e) => panic!("expected a typed I/O/corruption abort, got: {e}"),
        Ok(_) => panic!("persistent bit flips on cold reads must abort the batch"),
    }
    assert!(eng.metrics.snapshot().faults_injected > 0, "no fault ever fired");
    assert_eq!(
        cache.pinned_bytes(),
        0,
        "aborted batch stranded memo residency pins in the shared cache"
    );

    // the engine is reusable and the small, fully-cached chain still
    // produces results after the abort released the memo
    let s = genops::agg_full(
        &genops::sapply(&x.m, UnFn::Builtin(UnOp::Sqrt)),
        AggOp::Sum,
    );
    let out = eng.plan_batch(&[PlanRequest::sink(s)]).unwrap();
    match out[0].clone().sink().scalar() {
        Scalar::F64(v) => assert!(v.is_finite() && v > 0.0),
        other => panic!("unexpected sink dtype: {other:?}"),
    }
}
