//! Ingestion integration suite: the redesigned loader API end to end.
//!
//! The contract under test, from `docs/ARCHITECTURE.md`'s ingestion
//! section:
//! * an EM multi-file corpus load (partition cache smaller than the
//!   data), followed by `as_factor` + `cbind_list` + logistic IRLS, is
//!   **bit-identical** to the same pipeline run fully in memory;
//! * ingestion rides the PR 8 fault-tolerance machinery: with a seeded
//!   transient fault plan on the engine, the loaded matrix is
//!   bit-identical to a fault-free load (text-chunk CRCs recorded in the
//!   scan phase catch corrupted re-reads; bounded retries absorb
//!   EIO/short reads/torn writes);
//! * malformed input surfaces as a typed [`FmError::Parse`] carrying the
//!   (file, line, column) location, and named loads persist factor level
//!   tables in the `<name>.dense.json` sidecar.
//!
//! Workloads run `threads: 1` so sink merge order is part of the
//! fingerprint (same restriction as `tests/chaos.rs`); the ingest worker
//! pool is ramped independently via `ingest_workers`, whose schedule
//! cannot affect bytes (each partition is parsed and written by exactly
//! one worker from an exclusive newline-aligned byte range).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use flashmatrix::algs;
use flashmatrix::config::EngineConfig;
use flashmatrix::datasets;
use flashmatrix::fmr::Engine;
use flashmatrix::storage::FaultConfig;
use flashmatrix::testutil::{out_of_core_config, TempDir};
use flashmatrix::{EngineExt, FmError, LoadOptions, Schema, StorageKind};

/// Deterministic delimited corpus, schema `FFFI`: three float features
/// and one small-range integer category, with NA cells sprinkled into
/// the second float column and whitespace padding on some rows. Values
/// are counter-based on the global row id, so any (files × rows_per)
/// split of the same total row count produces the same logical table.
fn write_corpus(dir: &Path, files: usize, rows_per: u64) -> Vec<PathBuf> {
    use std::fmt::Write as _;
    let mut paths = Vec::new();
    for f in 0..files {
        let mut text = String::new();
        for r in 0..rows_per {
            let g = f as u64 * rows_per + r;
            let a = (g.wrapping_mul(2654435761) % 1000) as f64 / 500.0 - 1.0;
            let b = (g.wrapping_mul(40503) % 777) as f64 / 388.5 - 1.0;
            let c = (g.wrapping_mul(9176) % 333) as f64 / 166.5 - 1.0;
            let cat = g % 5;
            if g % 97 == 13 {
                writeln!(text, "{a},NA,{c},{cat}").unwrap();
            } else if g % 101 == 7 {
                writeln!(text, " {a} , {b} ,{c},{cat}").unwrap();
            } else {
                writeln!(text, "{a},{b},{c},{cat}").unwrap();
            }
        }
        let p = dir.join(format!("part-{f}.csv"));
        std::fs::write(&p, text).unwrap();
        paths.push(p);
    }
    paths
}

fn opts() -> LoadOptions {
    LoadOptions::new(Schema::parse("FFFI").unwrap())
}

fn assert_bits(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: fingerprint length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}[{i}]: {x} != {y}");
    }
}

/// The full redesigned-API pipeline: `fm.load.list.vecs` →
/// `fm.as.factor` on the category column → `fm.cbind.list` →
/// NA-aware mean on the NA-bearing column → logistic IRLS on the bound
/// design matrix. Returns a fingerprint of every stage.
fn pipeline_fp(eng: &Arc<Engine>, paths: &[PathBuf]) -> Vec<f64> {
    let vecs = eng.load_list_vecs(paths, &opts()).unwrap();
    assert_eq!(vecs.len(), 4);

    let f = vecs[3].v.as_factor().unwrap();
    let levels = f.levels.as_ref().unwrap();
    assert_eq!(
        levels.as_slice(),
        ["0", "1", "2", "3", "4"],
        "categories 0..5 must sort into five levels"
    );

    // na.rm mean of the NA-bearing float column (the NA-aware kernels)
    let b_mean = vecs[1].v.mean(true).unwrap();

    let x = eng
        .cbind_list(&[vecs[0].clone(), vecs[2].clone(), f])
        .unwrap()
        .materialize()
        .unwrap();
    assert_eq!(x.ncol(), 3);
    assert_eq!(x.dtype(), flashmatrix::dtype::DType::F64);

    let y = datasets::logistic_labels(&x, &[0.75, -0.5, 0.25], 91).unwrap();
    let fit = algs::logistic(&x, &y, 4, 1e-8).unwrap();

    let mut fp = vec![b_mean, x.nrow() as f64, y.sum().unwrap()];
    fp.extend(fit.beta);
    fp.extend(fit.deviances);
    fp
}

/// ISSUE acceptance: EM corpus load (cache < data) + as_factor +
/// cbind_list + logistic IRLS, bit-identical to the fully-in-memory
/// pipeline — with the EM leg's parse phase running on several workers.
#[test]
fn em_pipeline_bit_identical_to_in_memory() {
    let src = TempDir::new("ingest-e2e-src");
    let paths = write_corpus(src.path(), 3, 20_000);
    let text_bytes: u64 = paths
        .iter()
        .map(|p| std::fs::metadata(p).unwrap().len())
        .sum();

    let im = Engine::new(EngineConfig {
        storage: StorageKind::InMem,
        threads: 1,
        ingest_workers: 1,
        chunk_bytes: 4 << 20,
        target_part_bytes: 1 << 20,
        xla_dispatch: false,
        ..EngineConfig::default()
    })
    .unwrap();
    let im_fp = pipeline_fp(&im, &paths);

    let dir = TempDir::new("ingest-e2e-em");
    let em = Engine::new(EngineConfig {
        threads: 1,
        ingest_workers: 4,
        ingest_chunk_bytes: 64 << 10, // many chunks per file
        em_cache_bytes: 512 << 10,
        ..out_of_core_config(dir.path())
    })
    .unwrap();
    let cap = em.cache.as_ref().unwrap().capacity() as u64;
    assert!(
        cap < text_bytes,
        "cache {cap} must be smaller than the corpus ({text_bytes} B)"
    );
    let em_fp = pipeline_fp(&em, &paths);

    let m = em.metrics.snapshot();
    assert_eq!(m.ingest_rows, 60_000, "the loader saw every corpus row");
    assert!(m.ingest_na_cells > 0, "corpus carries NA cells");
    assert!(m.ingest_chunks > 2 * 3, "chunking never split the files");
    assert!(m.io_read_bytes > 0, "EM leg never touched the store");

    assert_bits(&im_fp, &em_fp, "ingest pipeline IM vs EM");
}

/// Chunking and worker count must not leak into the bytes: 1 worker with
/// one big chunk vs many workers with tiny chunks, same matrix.
#[test]
fn worker_and_chunk_geometry_is_invisible() {
    let src = TempDir::new("ingest-geom-src");
    let paths = write_corpus(src.path(), 2, 5_000);
    let run = |workers: usize, chunk: usize| {
        let eng = Engine::new(EngineConfig {
            storage: StorageKind::InMem,
            threads: 1,
            ingest_workers: workers,
            ingest_chunk_bytes: chunk,
            chunk_bytes: 4 << 20,
            target_part_bytes: 1 << 20,
            xla_dispatch: false,
            ..EngineConfig::default()
        })
        .unwrap();
        let x = eng.load_dense_matrix(&paths, &opts()).unwrap();
        x.to_host().unwrap().buf.to_f64_vec()
    };
    let one = run(1, 8 << 20);
    let many = run(5, 4 << 10);
    assert_bits(&one, &many, "1-worker/1-chunk vs 5-worker/tiny-chunk");
}

/// Ingestion chaos, riding PR 8: a pinned transient fault plan (EIO +
/// short reads + torn writes, all healing within the retry budget; no
/// bit flips — plain text has no write-time checksum to catch a flip
/// injected on the *first* read of a chunk, so a flip is outside the
/// text reader's detection contract) must leave the loaded matrix
/// bit-identical to a fault-free load, with faults provably injected
/// and transparently recovered.
#[test]
fn ingestion_absorbs_transient_faults_bit_identically() {
    let src = TempDir::new("ingest-chaos-src");
    let paths = write_corpus(src.path(), 3, 8_000);
    let faults = || {
        FaultConfig::parse("seed=4117,eio=0.8,short=0.1,torn=0.1,max_duration=2")
            .expect("valid FLASHR_FAULTS spec")
    };
    let run = |plan: Option<FaultConfig>, tag: &str| {
        let dir = TempDir::new(tag);
        let eng = Engine::new(EngineConfig {
            threads: 1,
            ingest_workers: 3,
            ingest_chunk_bytes: 64 << 10,
            fault_injection: plan,
            ..out_of_core_config(dir.path())
        })
        .unwrap();
        let x = eng.load_dense_matrix(&paths, &opts()).unwrap();
        let host = x.to_host().unwrap().buf.to_f64_vec();
        (host, eng, dir)
    };
    let (clean, _e0, _d0) = run(None, "ingest-chaos-clean");
    let (faulty, eng, _d1) = run(Some(faults()), "ingest-chaos-faulty");
    let m = eng.metrics.snapshot();
    assert!(m.faults_injected > 0, "fault plan never fired");
    assert!(m.io_retries > 0, "no transparent recovery exercised");
    assert_bits(&clean, &faulty, "ingest EM faulty-vs-clean");
}

/// Malformed input surfaces as FmError::Parse with an exact location,
/// pointing at the right file of a multi-file load.
#[test]
fn parse_errors_locate_file_line_and_column() {
    let src = TempDir::new("ingest-err-src");
    let good = src.path().join("good.csv");
    std::fs::write(&good, "1.0,2.0,3.0,4\n5.0,6.0,7.0,8\n").unwrap();
    let bad = src.path().join("bad.csv");
    std::fs::write(&bad, "1.0,2.0,3.0,0\n2.5,oops,3.5,1\n").unwrap();
    let eng = Engine::new(EngineConfig {
        storage: StorageKind::InMem,
        xla_dispatch: false,
        chunk_bytes: 4 << 20,
        target_part_bytes: 1 << 20,
        ..EngineConfig::default()
    })
    .unwrap();
    match eng.load_dense_matrix(&[&good, &bad], &opts()) {
        Err(FmError::Parse { file, line, col, .. }) => {
            assert!(file.ends_with("bad.csv"), "wrong file: {file}");
            assert_eq!((line, col), (2, 2), "location of the bad field");
        }
        Err(other) => panic!("expected FmError::Parse, got {other}"),
        Ok(_) => panic!("bad float must fail the load"),
    }
    // the error Display carries the clickable location
    match eng.load_dense_matrix(&[&bad], &opts()) {
        Err(e) => {
            let shown = format!("{e}");
            assert!(shown.contains("bad.csv:2:2"), "display: {shown}");
        }
        Ok(_) => panic!("bad float must fail the load"),
    }
}

/// Named EM loads persist the column schema and factor level tables in
/// the dense sidecar; `get_dense_matrix` reattaches bit-identically and
/// the sidecar alone restores the levels.
#[test]
fn named_load_persists_schema_and_levels() {
    let dir = TempDir::new("ingest-named");
    let eng = Engine::new(EngineConfig {
        threads: 1,
        ..out_of_core_config(dir.path())
    })
    .unwrap();
    let csv = dir.path().join("animals.csv");
    std::fs::write(
        &csv,
        "1,0.5,cat\n2,NA,dog\n3,1.5,ant\n4,2.5,cat\n5,-0.5,dog\n",
    )
    .unwrap();
    let o = LoadOptions::new(Schema::parse("IFX").unwrap()).name("animals");
    let x = eng.load_dense_matrix(&[&csv], &o).unwrap();
    let want = x.to_host().unwrap();

    let again = eng.get_dense_matrix("animals").unwrap();
    assert_eq!(again.dtype(), flashmatrix::dtype::DType::F64);
    assert_eq!(again.to_host().unwrap(), want);

    let meta = flashmatrix::runtime::manifest::DenseMeta::load(
        &dir.path().join("animals.dense.json"),
    )
    .unwrap();
    let codes: Vec<char> = meta.cols.iter().map(|c| c.code).collect();
    assert_eq!(codes, ['I', 'F', 'X']);
    assert_eq!(meta.cols[2].levels, ["ant", "cat", "dog"]);
    assert!(
        meta.crcs.iter().all(|c| c.is_some()),
        "write-time partition checksums must be persisted"
    );
}
