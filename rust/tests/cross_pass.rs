//! Cross-pass optimizer property tests: `cross_pass_opt` must change
//! WHAT gets evaluated (fewer passes, less read I/O), never WHAT comes
//! out — every workload must be **byte-identical** with the optimizer on
//! and off, across storage modes (IM / tiny-cache EM), `vectorized_udf`
//! and `simd_kernels` (the [`flashmatrix::testutil::rerun_opt_ablation`]
//! battery). Single-threaded inside the battery so fold order is the
//! only variable under test.

use std::sync::Arc;

use flashmatrix::algs;
use flashmatrix::config::EngineConfig;
use flashmatrix::datasets;
use flashmatrix::fmr::Engine;
use flashmatrix::testutil::{rerun_opt_ablation, TempDir};

fn assert_bitwise(rows: &[(String, Vec<f64>, Vec<f64>)], what: &str) {
    for (label, on, off) in rows {
        assert_eq!(on.len(), off.len(), "{what}/{label}: fingerprint length");
        for (i, (a, b)) in on.iter().zip(off).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{what}/{label}[{i}]: opt-on {a} != opt-off {b}"
            );
        }
    }
}

/// K-means: three grouped sinks per Lloyd iteration submitted as one
/// planned batch — the optimizer fuses them; results must not move a bit.
#[test]
fn kmeans_opt_on_bitwise_equals_opt_off() {
    let rows = rerun_opt_ablation("kmeans", |eng| {
        let (x, _) = datasets::mix_gaussian(eng, 100_000, 6, 3, 8.0, 3, None).unwrap();
        let km = algs::kmeans(&x, 3, 3, 1).unwrap();
        let mut fp = km.wcss.clone();
        fp.extend(km.centroids.buf.to_f64_vec());
        fp.extend(km.sizes.clone());
        fp
    });
    assert_bitwise(&rows, "kmeans");
}

/// IRLS: the three per-step sinks (XtWX, gradient, log-likelihood) share
/// the eta/mu chain; fused or eager, coefficients and deviances match.
#[test]
fn irls_opt_on_bitwise_equals_opt_off() {
    let rows = rerun_opt_ablation("irls", |eng| {
        let x = datasets::uniform(eng, 80_000, 4, -1.0, 1.0, 21, None).unwrap();
        let y = datasets::logistic_labels(&x, &[1.0, -0.5, 0.25, -1.5], 22).unwrap();
        let fit = algs::logistic(&x, &y, 4, 1e-8).unwrap();
        let mut fp = fit.beta.clone();
        fp.extend(fit.deviances);
        fp
    });
    assert_bitwise(&rows, "irls");
}

/// PageRank: the new-rank target and the L1-delta sink share the SpMM
/// chain; ranks and the convergence log must match bitwise.
#[test]
fn pagerank_opt_on_bitwise_equals_opt_off() {
    let rows = rerun_opt_ablation("pagerank", |eng| {
        let (g, dangling) = datasets::pagerank_graph(eng, 1 << 13, 6, 17, None).unwrap();
        let pr = algs::pagerank(&g, &dangling, 0.85, 6, 0.0).unwrap();
        let mut fp = pr.ranks.clone();
        fp.extend(pr.deltas);
        fp
    });
    assert_bitwise(&rows, "pagerank");
}

/// The optimizer's whole point: an IRLS iteration is one planned pass
/// instead of three eager ones — strictly fewer `passes_run` for the
/// same (bit-identical) coefficients.
#[test]
fn irls_runs_strictly_fewer_passes_with_opt_on() {
    let run = |opt: bool| {
        let eng = Engine::new(EngineConfig {
            threads: 1,
            xla_dispatch: false,
            chunk_bytes: 4 << 20,
            target_part_bytes: 1 << 20,
            cross_pass_opt: opt,
            ..EngineConfig::default()
        })
        .unwrap();
        let x = datasets::uniform(&eng, 60_000, 6, -1.0, 1.0, 31, None).unwrap();
        let y =
            datasets::logistic_labels(&x, &[1.0, -0.5, 0.25, -1.5, 0.75, 0.0], 32).unwrap();
        eng.metrics.reset();
        let fit = algs::logistic(&x, &y, 4, 1e-8).unwrap();
        (fit.beta, eng.metrics.snapshot())
    };
    let (beta_off, m_off) = run(false);
    let (beta_on, m_on) = run(true);
    assert!(
        m_on.passes_run < m_off.passes_run,
        "opt-on must run strictly fewer passes: {} vs {}",
        m_on.passes_run,
        m_off.passes_run
    );
    for (i, (a, b)) in beta_on.iter().zip(&beta_off).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "beta[{i}]: {a} vs {b}");
    }
}

/// Out of core the pass savings become I/O savings: with a partition
/// cache far smaller than the edge matrix, every eliminated pass is an
/// eliminated re-stream of the edges — strictly fewer read bytes per
/// PageRank run, bit-identical ranks.
#[test]
fn pagerank_out_of_core_reads_strictly_less_with_opt_on() {
    let run = |opt: bool| {
        let dir = TempDir::new("xpass-io");
        let mut cfg = flashmatrix::testutil::out_of_core_config(dir.path());
        cfg.threads = 1;
        cfg.em_cache_bytes = 64 << 10; // « the ~1.7 MiB edge matrix
        cfg.cross_pass_opt = opt;
        let eng: Arc<Engine> = Engine::new(cfg).unwrap();
        let (g, dangling) = datasets::pagerank_graph(&eng, 1 << 14, 8, 7, None).unwrap();
        if let Some(c) = &eng.cache {
            c.clear(); // cold start: drop the write-through copies
        }
        eng.metrics.reset();
        let pr = algs::pagerank(&g, &dangling, 0.85, 6, 0.0).unwrap();
        (pr.ranks, eng.metrics.snapshot())
    };
    let (ranks_off, m_off) = run(false);
    let (ranks_on, m_on) = run(true);
    assert!(
        m_on.passes_run < m_off.passes_run,
        "opt-on must run strictly fewer passes: {} vs {}",
        m_on.passes_run,
        m_off.passes_run
    );
    assert!(
        m_on.io_read_bytes < m_off.io_read_bytes,
        "opt-on must read strictly less: {} vs {} bytes",
        m_on.io_read_bytes,
        m_off.io_read_bytes
    );
    assert!(m_off.io_read_bytes > 0, "EM leg never touched the store");
    for (i, (a, b)) in ranks_on.iter().zip(&ranks_off).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "rank[{i}]: {a} vs {b}");
    }
}
