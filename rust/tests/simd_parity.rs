//! SIMD/scalar parity property suite (`EngineConfig::simd_kernels`).
//!
//! The lane kernels and blocked GEMM microkernels are *reorderings of
//! independent outputs*: no single output's accumulation order changes, so
//! every default-config result must be bit-identical to the scalar path —
//! including NaN payloads, signed zeros and infinities. This suite pins
//! that contract at both layers:
//!
//! * kernel level: `vudf::*_lanes` vs the plain forms, across every dtype
//!   (F64/F32/I64/I32/Bool), every tail remainder of the 4-wide f64 and
//!   8-wide f32 lane groups, and generated IEEE-special placements;
//! * engine level: a workload battery (fused elementwise chain, GEMM both
//!   orientations, row/col aggregation, which.min) byte-compared between
//!   `simd_kernels` on/off, in memory and out of core, for both
//!   `vectorized_udf` modes.
//!
//! The one opt-in exception, `simd_reductions`, reassociates sums across
//! four lane accumulators; its bound — at most 4 ULP per strip reduction —
//! is asserted here too, alongside bit-identity for the order-insensitive
//! min/max lane forms (all-NaN and first-lane-NaN included).

use std::sync::Arc;

use flashmatrix::config::EngineConfig;
use flashmatrix::datasets;
use flashmatrix::dtype::{DType, Scalar};
use flashmatrix::fmr::{Engine, FmMatrix};
use flashmatrix::matrix::HostMat;
use flashmatrix::testutil::{out_of_core_config, TempDir};
use flashmatrix::util::quickcheck::{forall, Gen};
use flashmatrix::vudf::{self, AggOp, BinOp, Buf, UnOp, F32_LANES, F64_LANES};

const SPECIALS: [f64; 5] = [f64::NAN, 0.0, -0.0, f64::INFINITY, f64::NEG_INFINITY];

/// Every length 0..=17 hits every tail remainder of both lane widths
/// (17 > 2 * F32_LANES); the const assertions keep that in sync.
const TAIL_LENS: std::ops::RangeInclusive<usize> = 0..=17;
const _: () = assert!(F64_LANES == 4 && F32_LANES == 8);

const ALL_DTYPES: [DType; 5] = [DType::F64, DType::F32, DType::I64, DType::I32, DType::Bool];

/// Random buffer of a dtype; float draws land on an IEEE special
/// (NaN/±0.0/±Inf) roughly one time in eight so tails, lane heads and
/// specials cross.
fn gen_buf(g: &mut Gen, dtype: DType, len: usize) -> Buf {
    let mut b = Buf::alloc(dtype, len);
    for i in 0..len {
        let mut v = g.f64_in(-3.0, 3.0);
        if g.usize_in(0, 7) == 0 {
            v = *g.choose(&SPECIALS);
        }
        let s = match dtype {
            DType::F64 => Scalar::F64(v),
            DType::F32 => Scalar::F32(v as f32),
            DType::I64 => Scalar::I64(g.usize_in(0, 12) as i64 - 6),
            DType::I32 => Scalar::I32(g.usize_in(0, 12) as i32 - 6),
            DType::Bool => Scalar::Bool(g.bool()),
        };
        b.set(i, s);
    }
    b
}

/// Bit-exact, NaN-safe comparison (Buf's PartialEq is IEEE).
fn same_bits(a: &Buf, b: &Buf) -> bool {
    a.dtype() == b.dtype() && a.to_bytes() == b.to_bytes()
}

const ALL_UNOPS: [UnOp; 13] = [
    UnOp::Neg,
    UnOp::Abs,
    UnOp::Sqrt,
    UnOp::Sq,
    UnOp::Exp,
    UnOp::Log,
    UnOp::Floor,
    UnOp::Ceil,
    UnOp::Round,
    UnOp::Sign,
    UnOp::Not,
    UnOp::NotZero,
    UnOp::IsNa,
];

const ALL_BINOPS: [BinOp; 16] = [
    BinOp::Add,
    BinOp::Sub,
    BinOp::Mul,
    BinOp::Div,
    BinOp::Pow,
    BinOp::Min,
    BinOp::Max,
    BinOp::Eq,
    BinOp::Ne,
    BinOp::Lt,
    BinOp::Le,
    BinOp::Gt,
    BinOp::Ge,
    BinOp::And,
    BinOp::Or,
    BinOp::IfElse0,
];

#[test]
fn prop_unary_lanes_bit_exact() {
    forall(40, |g| {
        let dtype = *g.choose(&ALL_DTYPES);
        let op = *g.choose(&ALL_UNOPS);
        for len in TAIL_LENS.chain([g.usize_in(18, 400)]) {
            let a = gen_buf(g, dtype, len);
            match (vudf::unary(op, &a, true), vudf::unary_lanes(op, &a)) {
                (Ok(want), Ok((got, _))) => {
                    if !same_bits(&want, &got) {
                        return Err(format!("{op:?} {dtype:?} len {len}: lane != plain"));
                    }
                }
                (Err(_), Err(_)) => {}
                (w, l) => {
                    return Err(format!(
                        "{op:?} {dtype:?} len {len}: Ok/Err disagree (plain {}, lanes {})",
                        w.is_ok(),
                        l.is_ok()
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_binary_lanes_bit_exact() {
    forall(40, |g| {
        let dtype = *g.choose(&ALL_DTYPES);
        let op = *g.choose(&ALL_BINOPS);
        for len in TAIL_LENS.chain([g.usize_in(18, 400)]) {
            let a = gen_buf(g, dtype, len);
            let b = gen_buf(g, dtype, len);
            match (vudf::binary_vv(op, &a, &b, true), vudf::binary_vv_lanes(op, &a, &b)) {
                (Ok(want), Ok((got, _))) => {
                    if !same_bits(&want, &got) {
                        return Err(format!("vv {op:?} {dtype:?} len {len}: lane != plain"));
                    }
                }
                (Err(_), Err(_)) => {}
                (w, l) => {
                    return Err(format!(
                        "vv {op:?} {dtype:?} len {len}: Ok/Err disagree (plain {}, lanes {})",
                        w.is_ok(),
                        l.is_ok()
                    ));
                }
            }
            // broadcast forms, scalar sometimes an IEEE special
            let s = if g.bool() {
                Scalar::F64(*g.choose(&SPECIALS))
            } else {
                Scalar::F64(g.f64_in(-3.0, 3.0))
            };
            for scalar_right in [true, false] {
                let want = if scalar_right {
                    vudf::binary_vs(op, &a, s, true)
                } else {
                    vudf::binary_sv(op, s, &a, true)
                };
                let got = if scalar_right {
                    vudf::binary_vs_lanes(op, &a, s)
                } else {
                    vudf::binary_sv_lanes(op, s, &a)
                };
                match (want, got) {
                    (Ok(want), Ok((got, _))) => {
                        if !same_bits(&want, &got) {
                            return Err(format!(
                                "vs/sv {op:?} {dtype:?} len {len} right={scalar_right}: \
                                 lane != plain"
                            ));
                        }
                    }
                    (Err(_), Err(_)) => {}
                    (w, l) => {
                        return Err(format!(
                            "vs/sv {op:?} {dtype:?} len {len}: Ok/Err disagree (plain {}, \
                             lanes {})",
                            w.is_ok(),
                            l.is_ok()
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_row_col_vec_lanes_bit_exact() {
    forall(60, |g| {
        let op = *g.choose(&[BinOp::Add, BinOp::Sub, BinOp::Mul, BinOp::Div, BinOp::Max]);
        let rows = g.usize_in(1, 21);
        let cols = g.usize_in(1, 4);
        let a = gen_buf(g, DType::F64, rows * cols);
        let v = gen_buf(g, DType::F64, rows);
        let w = gen_buf(g, DType::F64, cols);
        let want = vudf::binary_colvec(op, &a, &v, rows, cols, true).map_err(|e| e.to_string())?;
        let (got, _) =
            vudf::binary_colvec_lanes(op, &a, &v, rows, cols).map_err(|e| e.to_string())?;
        if !same_bits(&want, &got) {
            return Err(format!("colvec {op:?} {rows}x{cols}: lane != plain"));
        }
        let want = vudf::binary_rowvec(op, &a, &w, rows, cols, true).map_err(|e| e.to_string())?;
        let (got, _) =
            vudf::binary_rowvec_lanes(op, &a, &w, rows, cols).map_err(|e| e.to_string())?;
        if !same_bits(&want, &got) {
            return Err(format!("rowvec {op:?} {rows}x{cols}: lane != plain"));
        }
        Ok(())
    });
}

/// Monotone integer mapping of f64 for ULP distance (±0.0 coincide).
fn ulp_ord(x: f64) -> i64 {
    let b = x.to_bits() as i64;
    if b < 0 {
        i64::MIN - b
    } else {
        b
    }
}

fn ulp_diff(a: f64, b: f64) -> u64 {
    if a.is_nan() && b.is_nan() {
        return 0;
    }
    if a.is_nan() != b.is_nan() {
        return u64::MAX;
    }
    ulp_ord(a).abs_diff(ulp_ord(b))
}

#[test]
fn prop_lane_reductions_min_max_exact_sum_4ulp() {
    forall(60, |g| {
        for len in TAIL_LENS.chain([g.usize_in(18, 600)]) {
            let a = gen_buf(g, DType::F64, len);
            for op in [AggOp::Min, AggOp::Max] {
                let want = op.reduce(&a);
                if let Some(got) = op.reduce_lanes(&a) {
                    // min/max lane kernels are order-insensitive: bit-exact
                    if want.as_f64().to_bits() != got.as_f64().to_bits() {
                        return Err(format!("{op:?} len {len}: {want:?} vs {got:?}"));
                    }
                }
            }
            let want = AggOp::Sum.reduce(&a).as_f64();
            if let Some(got) = AggOp::Sum.reduce_lanes(&a) {
                let d = ulp_diff(want, got.as_f64());
                if d > 4 {
                    return Err(format!(
                        "Sum len {len}: lane sum {} vs {} is {d} ULP apart",
                        got.as_f64(),
                        want
                    ));
                }
            }
        }
        Ok(())
    });
}

fn im_engine(simd: bool, vectorized: bool) -> Arc<Engine> {
    Engine::new(EngineConfig {
        simd_kernels: simd,
        vectorized_udf: vectorized,
        xla_dispatch: false,
        chunk_bytes: 1 << 20,
        target_part_bytes: 1 << 18,
        ..Default::default()
    })
    .expect("engine")
}

/// Small weight matrix with stored zeros and negatives: pins the blocked
/// GEMM kernels' `w != 0.0` skip (stored zero times Inf/NaN contributes
/// nothing on either path).
fn weights(p: usize, q: usize) -> HostMat {
    let rows: Vec<Vec<f64>> = (0..p)
        .map(|i| {
            (0..q)
                .map(|j| {
                    if (i + j) % 3 == 0 {
                        0.0
                    } else {
                        (i as f64 - 1.5) * 0.25 - j as f64 * 0.125
                    }
                })
                .collect()
        })
        .collect();
    HostMat::from_rows_f64(&rows)
}

/// The engine-level workload battery, serialized to bytes for exact
/// comparison: fused elementwise chain, both GEMM orientations, row/col
/// aggregation, full-matrix sum and which.min.
fn battery(eng: &Arc<Engine>, n: u64, p: u64, seed: u64) -> Vec<u8> {
    let x = datasets::uniform(eng, n, p, -2.0, 2.0, seed, None).expect("dataset");
    let mut out = Vec::new();
    let fused = x
        .sq()
        .and_then(|m| m.mapply_scalar(Scalar::F64(0.5), BinOp::Mul, true))
        .and_then(|m| m.mapply_scalar(Scalar::F64(1.0), BinOp::Add, true))
        .and_then(|m| m.row_sums())
        .and_then(|m| m.to_host())
        .expect("fused chain");
    out.extend(fused.buf.to_bytes());
    out.extend(x.crossprod(&x).expect("crossprod").buf.to_bytes());
    let w = weights(p as usize, 3);
    let ip = x
        .inner_prod_small(&w, BinOp::Mul, AggOp::Sum)
        .and_then(|m| m.to_host())
        .expect("inner_prod_small");
    out.extend(ip.buf.to_bytes());
    out.extend(x.col_sums().expect("col_sums").buf.to_bytes());
    out.extend(x.agg(AggOp::Sum).expect("agg").as_f64().to_bits().to_le_bytes());
    let wm = x
        .which_min_row()
        .and_then(|m| m.to_host())
        .expect("which_min_row");
    out.extend(wm.buf.to_bytes());
    out
}

#[test]
fn prop_engine_simd_parity_in_memory() {
    forall(6, |g| {
        let n = g.usize_in(500, 4000) as u64;
        let p = g.usize_in(1, 8) as u64;
        let seed = g.u64();
        for vectorized in [true, false] {
            let want = battery(&im_engine(false, vectorized), n, p, seed);
            let got = battery(&im_engine(true, vectorized), n, p, seed);
            if want != got {
                return Err(format!(
                    "{n}x{p} seed {seed} vectorized={vectorized}: simd on/off differ"
                ));
            }
        }
        Ok(())
    });
}

/// EM leg: the same battery out of core (tiny one-partition cache, > 1 io
/// partition at ≤ 8 columns) must match the in-memory scalar reference
/// bit-for-bit with the kernels on and off.
#[test]
fn simd_parity_out_of_core() {
    let (n, p, seed) = (150_000u64, 6u64, 9u64);
    let reference = battery(&im_engine(false, true), n, p, seed);
    for simd in [false, true] {
        let im = battery(&im_engine(simd, true), n, p, seed);
        assert_eq!(reference, im, "IM simd={simd} diverged");
        let dir = TempDir::new(&format!("simd-par-{simd}"));
        let mut cfg = out_of_core_config(dir.path());
        cfg.simd_kernels = simd;
        let eng = Engine::new(cfg).expect("EM engine");
        let em = battery(&eng, n, p, seed);
        let m = eng.metrics.snapshot();
        assert!(m.io_read_bytes > 0, "simd={simd}: EM leg never hit the store");
        assert!(m.cache_misses > 0, "simd={simd}: EM cache never missed");
        assert_eq!(reference, em, "EM simd={simd} diverged");
        if simd {
            assert!(
                m.simd_strips > 0 && m.simd_lanes_f64 > 0 && m.gemm_panels > 0,
                "EM simd run recorded no microkernel work: {} strips, {} lanes, {} panels",
                m.simd_strips,
                m.simd_lanes_f64,
                m.gemm_panels
            );
        }
    }
}

/// which.min / which.max under NaN: an all-NaN row yields NA (index 0)
/// and a NaN in a row's first lane is skipped — identically with the lane
/// kernels on and off (argmin/argmax stay scalar by design).
#[test]
fn which_extreme_nan_pins_match_across_simd() {
    let nan = f64::NAN;
    let rows = vec![
        vec![nan, nan, nan, nan, nan],      // all-NaN: NA (0)
        vec![nan, 5.0, 1.0, 7.0, 2.0],      // NaN in lane 0: skipped
        vec![3.0, nan, nan, nan, nan],      // only lane 0 valid
        vec![2.0, -1.0, 4.0, -1.0, 9.0],    // tie: first wins
        vec![-0.0, 0.0, 1.0, 2.0, 3.0],     // signed-zero head
    ];
    let h = HostMat::from_rows_f64(&rows);
    let mut outs: Vec<(Vec<u8>, Vec<u8>)> = Vec::new();
    for simd in [false, true] {
        let eng = im_engine(simd, true);
        let x = FmMatrix::from_host(&eng, &h).expect("from_host");
        let mins = x.which_min_row().and_then(|m| m.to_host()).expect("min");
        let maxs = x.which_max_row().and_then(|m| m.to_host()).expect("max");
        // pinned semantics (R match.arg style: 1-based, NA encoded as 0)
        assert_eq!(mins.get(0, 0).as_f64(), 0.0, "all-NaN row must be NA");
        assert_eq!(maxs.get(0, 0).as_f64(), 0.0, "all-NaN row must be NA");
        assert_eq!(mins.get(1, 0).as_f64(), 3.0, "first-lane NaN skipped (min)");
        assert_eq!(maxs.get(1, 0).as_f64(), 4.0, "first-lane NaN skipped (max)");
        assert_eq!(mins.get(2, 0).as_f64(), 1.0);
        assert_eq!(maxs.get(2, 0).as_f64(), 1.0);
        assert_eq!(mins.get(3, 0).as_f64(), 2.0, "ties resolve to first");
        outs.push((mins.buf.to_bytes(), maxs.buf.to_bytes()));
    }
    assert_eq!(outs[0], outs[1], "which.min/max diverged across simd_kernels");
}

/// The opt-in lane reductions (`simd_reductions`) may reassociate sums;
/// engine-level results stay within a tight relative bound of the ordered
/// path and min/max stay bit-identical.
#[test]
fn opt_in_lane_reductions_within_bound() {
    let mk = |lanes: bool| {
        Engine::new(EngineConfig {
            simd_kernels: true,
            simd_reductions: lanes,
            xla_dispatch: false,
            chunk_bytes: 1 << 20,
            target_part_bytes: 1 << 18,
            ..Default::default()
        })
        .expect("engine")
    };
    let (n, p, seed) = (30_000u64, 5u64, 21u64);
    let ordered = mk(false);
    let lanes = mk(true);
    let xo = datasets::uniform(&ordered, n, p, -2.0, 2.0, seed, None).unwrap();
    let xl = datasets::uniform(&lanes, n, p, -2.0, 2.0, seed, None).unwrap();

    let so = xo.agg(AggOp::Sum).unwrap().as_f64();
    let sl = xl.agg(AggOp::Sum).unwrap().as_f64();
    let rel = (so - sl).abs() / so.abs().max(1.0);
    assert!(rel < 1e-12, "lane sum drifted: {so} vs {sl} (rel {rel:e})");

    let co = xo.col_sums().unwrap();
    let cl = xl.col_sums().unwrap();
    for j in 0..p as usize {
        let (a, b) = (co.get(0, j).as_f64(), cl.get(0, j).as_f64());
        let rel = (a - b).abs() / a.abs().max(1.0);
        assert!(rel < 1e-12, "col {j} lane sum drifted: {a} vs {b}");
    }

    for op in [AggOp::Min, AggOp::Max] {
        let a = xo.agg(op).unwrap().as_f64();
        let b = xl.agg(op).unwrap().as_f64();
        assert_eq!(a.to_bits(), b.to_bits(), "{op:?} must stay bit-identical");
    }
}
