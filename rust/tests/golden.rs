//! Golden-fixture tests: the Rust engine vs the JAX oracle.
//!
//! `python/tests/test_golden.py` generates a deterministic input matrix
//! (SplitMix64 stream) and stores the oracle outputs of every algorithm
//! step as JSON. Here the SAME matrix is regenerated from the seed
//! (datasets::golden_uniform shares the generator) and pushed through
//! (a) the native per-partition steps and (b) the full GenOp algorithms;
//! both must match the JAX numbers. This pins all three layers to one
//! spec.

use flashmatrix::algs::steps;
use flashmatrix::config::EngineConfig;
use flashmatrix::datasets;
use flashmatrix::fmr::Engine;
use flashmatrix::matrix::HostMat;
use flashmatrix::util::json::Json;
use flashmatrix::vudf::{AggOp, BinOp};

const TOL: f64 = 1e-9;

/// Locate a checked-in fixture whether `cargo test` runs from the repo
/// root (`--manifest-path rust/Cargo.toml`) or from `rust/`.
fn fixture_path(name: &str) -> std::path::PathBuf {
    for base in ["python/tests/golden", "../python/tests/golden"] {
        let p = std::path::Path::new(base).join(name);
        if p.exists() {
            return p;
        }
    }
    panic!("golden fixture {name} missing — run `pytest python/tests` first");
}

fn load_named_fixture(name: &str) -> Json {
    Json::parse(&std::fs::read_to_string(fixture_path(name)).unwrap()).unwrap()
}

fn load_fixture() -> Json {
    load_named_fixture("steps_256x8.json")
}

fn close(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what} length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() < TOL * x.abs().max(1.0),
            "{what}[{i}]: rust {x} vs jax {y}"
        );
    }
}

struct Fixture {
    j: Json,
    eng: std::sync::Arc<Engine>,
    x: flashmatrix::fmr::FmMatrix,
    c: HostMat,
    rows: usize,
    p: usize,
    k: usize,
}

fn setup() -> Fixture {
    let j = load_fixture();
    let rows = j.get("rows").unwrap().as_usize().unwrap();
    let p = j.get("p").unwrap().as_usize().unwrap();
    let k = j.get("k").unwrap().as_usize().unwrap();
    let x_seed = j.get("x_seed").unwrap().as_u64().unwrap();
    let c_seed = j.get("c_seed").unwrap().as_u64().unwrap();
    let scale = j.get("x_scale").unwrap().as_f64().unwrap();
    let shift = j.get("x_shift").unwrap().as_f64().unwrap();
    let clip = j.get("zero_clip").unwrap().as_f64().unwrap();

    let eng = Engine::new(EngineConfig {
        xla_dispatch: false,
        chunk_bytes: 1 << 20,
        target_part_bytes: 1 << 20,
        ..Default::default()
    })
    .unwrap();
    let x = datasets::golden_uniform(&eng, rows as u64, p as u64, x_seed, scale, shift, clip)
        .unwrap();
    // centroids: same stream convention, no clipping
    let cfm = datasets::golden_uniform(&eng, k as u64, p as u64, c_seed, scale, shift, 0.0)
        .unwrap();
    let c = cfm.to_host().unwrap();
    Fixture {
        j,
        eng,
        x,
        c,
        rows,
        p,
        k,
    }
}

#[test]
fn native_steps_match_jax_oracle() {
    let f = setup();
    let d = match &*f.x.m.data {
        flashmatrix::matrix::MatrixData::Dense(d) => d,
        _ => panic!("dense expected"),
    };
    assert_eq!(d.parts.n_parts(), 1, "fixture fits one partition");
    let buf = d.partition_buf(0).unwrap();

    // colstats
    let got = steps::colstats_native(&buf, f.rows, f.p).unwrap();
    let want = f.j.get("colstats").unwrap().f64_vec().unwrap();
    close(&got, &want, "colstats");

    // kmeans step
    let (sums, counts, wcss, assign) =
        steps::kmeans_step_native(&buf, f.rows, f.p, &f.c).unwrap();
    let km = f.j.get("kmeans").unwrap();
    close(&sums, &km.get("sums").unwrap().f64_vec().unwrap(), "kmeans sums");
    close(&counts, &km.get("counts").unwrap().f64_vec().unwrap(), "kmeans counts");
    assert!((wcss - km.get("wcss").unwrap().as_f64().unwrap()).abs() < 1e-8);
    let want_assign = km.get("assign").unwrap().f64_vec().unwrap();
    for (i, (a, b)) in assign.iter().zip(&want_assign).enumerate() {
        assert_eq!(*a as f64, *b, "assign[{i}]");
    }

    // gramian
    let (xtx, cs) = steps::gramian_native(&buf, f.rows, f.p).unwrap();
    let gr = f.j.get("gramian").unwrap();
    close(&xtx, &gr.get("xtx").unwrap().f64_vec().unwrap(), "xtx");
    close(&cs, &gr.get("colsums").unwrap().f64_vec().unwrap(), "colsums");
    let mu: Vec<f64> = cs.iter().map(|s| s / f.rows as f64).collect();
    let xtxc = steps::gramian_centered_native(&buf, f.rows, f.p, &mu).unwrap();
    close(&xtxc, &gr.get("xtx_centered").unwrap().f64_vec().unwrap(), "xtx centered");

    // gmm e-step (identity*1.25 precisions, uniform weights — as in the fixture)
    let prec_diag = f.j.get("gmm_prec_diag").unwrap().as_f64().unwrap();
    let mut prec = vec![0.0; f.k * f.p * f.p];
    for c in 0..f.k {
        for i in 0..f.p {
            prec[c * f.p * f.p + i * f.p + i] = prec_diag;
        }
    }
    let logdet = vec![f.p as f64 * prec_diag.ln(); f.k];
    let logw = vec![(1.0 / f.k as f64).ln(); f.k];
    let (nk, sk, ssk, ll) = steps::gmm_estep_native(
        &buf,
        f.rows,
        f.p,
        &f.c.to_row_major_f64(),
        &prec,
        &logdet,
        &logw,
    )
    .unwrap();
    let gm = f.j.get("gmm").unwrap();
    close(&nk, &gm.get("nk").unwrap().f64_vec().unwrap(), "gmm nk");
    close(&sk, &gm.get("sk").unwrap().f64_vec().unwrap(), "gmm sk");
    close(&ssk, &gm.get("ssk").unwrap().f64_vec().unwrap(), "gmm ssk");
    assert!((ll - gm.get("loglik").unwrap().as_f64().unwrap()).abs() < 1e-8);
}

#[test]
fn genop_pipeline_matches_jax_oracle() {
    let f = setup();

    // colstats via six fused agg.col sinks
    let s = flashmatrix::algs::summary(&f.x).unwrap();
    let want = f.j.get("colstats").unwrap().f64_vec().unwrap();
    let p = f.p;
    close(&s.min, &want[0..p], "genop min");
    close(&s.max, &want[p..2 * p], "genop max");
    let sums: Vec<f64> = s.mean.iter().map(|m| m * f.rows as f64).collect();
    close(&sums, &want[2 * p..3 * p], "genop colsums");
    close(&s.nnz, &want[5 * p..6 * p], "genop nnz");

    // one k-means GenOp step: distances + argmin + groupby in one pass
    let km = f.j.get("kmeans").unwrap();
    // build the same distance expression kmeans::step_genop uses
    let mut ct2 = HostMat::zeros(p, f.k, flashmatrix::dtype::DType::F64);
    let mut c2 = HostMat::zeros(1, f.k, flashmatrix::dtype::DType::F64);
    for ci in 0..f.k {
        let mut acc = 0.0;
        for j in 0..p {
            let v = f.c.get(ci, j).as_f64();
            ct2.set(j, ci, flashmatrix::dtype::Scalar::F64(-2.0 * v));
            acc += v * v;
        }
        c2.set(0, ci, flashmatrix::dtype::Scalar::F64(acc));
    }
    let x2 = f.x.sq().unwrap().row_sums().unwrap();
    let dmat = f
        .x
        .inner_prod_small(&ct2, BinOp::Mul, AggOp::Sum)
        .unwrap()
        .mapply_row(&c2, BinOp::Add)
        .unwrap()
        .mapply_col(&x2, BinOp::Add)
        .unwrap();
    let labels = dmat
        .which_min_row()
        .unwrap()
        .mapply_scalar(flashmatrix::dtype::Scalar::I32(1), BinOp::Sub, true)
        .unwrap();
    let gsums = f.x.groupby_row(&labels, f.k, AggOp::Sum).unwrap();
    close(
        &gsums.to_row_major_f64(),
        &km.get("sums").unwrap().f64_vec().unwrap(),
        "genop kmeans sums",
    );
    let wcss = dmat.agg_row(AggOp::Min).unwrap().sum().unwrap();
    assert!((wcss - km.get("wcss").unwrap().as_f64().unwrap()).abs() < 1e-7);

    // gramian via the wide×tall inner product
    let g = f.x.crossprod(&f.x).unwrap();
    close(
        &g.to_row_major_f64(),
        &f.j.get("gramian").unwrap().get("xtx").unwrap().f64_vec().unwrap(),
        "genop gramian",
    );
    let _ = &f.eng;
}

/// Blocked-GEMM microkernels vs the numpy oracle (`test_write_gemm_fixture`),
/// BIT for bit: the fixture stores X·W (the `inner_prod_small` MR=8 panel
/// kernel's orientation) and t(X)·Y (the crossprod wide-tall KB=4 kernel's)
/// computed in the engine's exact fold order — ascending-k with the
/// stored-zero skip, one sequential ascending-r accumulator per dot. Both
/// orientations must reproduce every bit with `simd_kernels` off AND on:
/// the microkernels block across independent outputs, never inside one
/// output's accumulation. (96 rows = one partition, one CPU strip, so no
/// cross-strip reassociation hides in the sink either.)
#[test]
fn gemm_microkernels_match_python_oracle_bitwise() {
    use flashmatrix::exec::{splitmix64_at, u64_to_unit_f64};

    let j = load_named_fixture("gemm_96x64x32.json");
    let m = j.get("m").unwrap().as_u64().unwrap();
    let kdim = j.get("k").unwrap().as_u64().unwrap();
    let q = j.get("q").unwrap().as_u64().unwrap();
    let x_seed = j.get("x_seed").unwrap().as_u64().unwrap();
    let y_seed = j.get("y_seed").unwrap().as_u64().unwrap();
    let w_seed = j.get("w_seed").unwrap().as_u64().unwrap();
    let x_scale = j.get("x_scale").unwrap().as_f64().unwrap();
    let x_shift = j.get("x_shift").unwrap().as_f64().unwrap();
    let w_scale = j.get("w_scale").unwrap().as_f64().unwrap();
    let w_shift = j.get("w_shift").unwrap().as_f64().unwrap();
    let w_clip = j.get("w_zero_clip").unwrap().as_f64().unwrap();
    let want_w = j.get("w").unwrap().f64_vec().unwrap();
    let want_prod = j.get("prod").unwrap().f64_vec().unwrap();
    let want_gram = j.get("gramian").unwrap().f64_vec().unwrap();

    // W regenerated from the shared stream (row-major like the mirror)
    let mut w = HostMat::zeros(kdim as usize, q as usize, flashmatrix::dtype::DType::F64);
    for r in 0..kdim as usize {
        for c in 0..q as usize {
            let v = u64_to_unit_f64(splitmix64_at(w_seed, (r * q as usize + c) as u64))
                * w_scale
                + w_shift;
            let v = if v.abs() < w_clip { 0.0 } else { v };
            w.set(r, c, flashmatrix::dtype::Scalar::F64(v));
        }
    }
    let got_w = w.to_row_major_f64();
    for (i, (a, b)) in got_w.iter().zip(&want_w).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "w[{i}]: generator diverged from the python mirror ({a} vs {b})"
        );
    }

    for simd in [false, true] {
        let eng = Engine::new(EngineConfig {
            threads: 1,
            simd_kernels: simd,
            xla_dispatch: false,
            chunk_bytes: 1 << 20,
            target_part_bytes: 1 << 20,
            ..Default::default()
        })
        .unwrap();
        let x = datasets::golden_uniform(&eng, m, kdim, x_seed, x_scale, x_shift, 0.0).unwrap();
        let y = datasets::golden_uniform(&eng, m, q, y_seed, x_scale, x_shift, 0.0).unwrap();

        let prod = x
            .inner_prod_small(&w, BinOp::Mul, AggOp::Sum)
            .unwrap()
            .to_host()
            .unwrap()
            .to_row_major_f64();
        for (i, (a, b)) in prod.iter().zip(&want_prod).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "simd={simd} prod[{i}]: rust {a} vs numpy {b}"
            );
        }

        let gram = x.crossprod(&y).unwrap().to_row_major_f64();
        for (i, (a, b)) in gram.iter().zip(&want_gram).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "simd={simd} gramian[{i}]: rust {a} vs numpy {b}"
            );
        }
    }
}

/// PageRank vs the numpy oracle (`test_write_pagerank_fixture`): the
/// engine regenerates the same synthetic graph from the fixture's seed
/// (datasets::pagerank_graph mirrors `pagerank_graph_ref`) and the power
/// iteration through the streaming SpMM GenOp must land within 1e-10 of
/// the dense-matvec reference — in memory AND out of core with a cache
/// smaller than the edge matrix, bit-identically between the two.
#[test]
fn pagerank_matches_python_oracle_im_and_em() {
    let j = load_named_fixture("pagerank_512.json");
    let n = j.get("n").unwrap().as_u64().unwrap();
    let max_deg = j.get("max_deg").unwrap().as_u64().unwrap();
    let seed = j.get("seed").unwrap().as_u64().unwrap();
    let damping = j.get("damping").unwrap().as_f64().unwrap();
    let iters = j.get("iters").unwrap().as_usize().unwrap();
    let want_ranks = j.get("ranks").unwrap().f64_vec().unwrap();
    let want_deltas = j.get("deltas").unwrap().f64_vec().unwrap();
    let want_dangling = j.get("dangling_count").unwrap().as_usize().unwrap();

    let mut results: Vec<Vec<f64>> = Vec::new();
    let tmp = flashmatrix::testutil::TempDir::new("golden-pagerank");
    for em in [false, true] {
        let cfg = if em {
            // out of core with a cache far below the edge-matrix bytes
            EngineConfig {
                em_cache_bytes: 16 << 10,
                prefetch_depth: 2,
                threads: 1,
                ..flashmatrix::testutil::out_of_core_config(tmp.path())
            }
        } else {
            EngineConfig {
                threads: 1,
                xla_dispatch: false,
                chunk_bytes: 4 << 20,
                target_part_bytes: 1 << 20,
                ..Default::default()
            }
        };
        let eng = Engine::new(cfg).unwrap();
        let (g, dangling) = datasets::pagerank_graph(&eng, n, max_deg, seed, None).unwrap();
        assert_eq!(
            dangling.iter().filter(|d| **d).count(),
            want_dangling,
            "graph generator diverged from the python mirror"
        );
        if em {
            let edge_bytes = g.sparse_bytes().unwrap();
            let cap = eng.cache.as_ref().unwrap().capacity() as u64;
            assert!(cap < edge_bytes, "cache {cap} !< edges {edge_bytes}");
            eng.cache.as_ref().unwrap().clear();
        }
        let pr = flashmatrix::algs::pagerank(&g, &dangling, damping, iters, 0.0).unwrap();
        assert_eq!(pr.iterations, iters);
        for (i, (a, b)) in pr.ranks.iter().zip(&want_ranks).enumerate() {
            assert!(
                (a - b).abs() < 1e-10,
                "em={em} rank[{i}]: rust {a} vs numpy {b}"
            );
        }
        for (i, (a, b)) in pr.deltas.iter().zip(&want_deltas).enumerate() {
            assert!(
                (a - b).abs() < 1e-10,
                "em={em} delta[{i}]: rust {a} vs numpy {b}"
            );
        }
        results.push(pr.ranks);
    }
    // IM and EM runs must agree BIT for bit (same strips, same bytes)
    for (i, (a, b)) in results[0].iter().zip(&results[1]).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "rank[{i}] IM {a} vs EM {b}");
    }
}

/// Logistic regression (IRLS) vs the numpy oracle
/// (`test_write_logistic_fixture`): same X (golden_uniform), same labels
/// (u < sigmoid(X beta_true), checked element-wise against the fixture),
/// same ridge — fitted coefficients within 1e-9.
#[test]
fn logistic_matches_python_oracle() {
    let j = load_named_fixture("logistic_256x4.json");
    let rows = j.get("rows").unwrap().as_u64().unwrap();
    let p = j.get("p").unwrap().as_u64().unwrap();
    let x_seed = j.get("x_seed").unwrap().as_u64().unwrap();
    let u_seed = j.get("u_seed").unwrap().as_u64().unwrap();
    let scale = j.get("x_scale").unwrap().as_f64().unwrap();
    let shift = j.get("x_shift").unwrap().as_f64().unwrap();
    let beta_true = j.get("beta_true").unwrap().f64_vec().unwrap();
    let iters = j.get("iters").unwrap().as_usize().unwrap();
    let ridge = j.get("ridge").unwrap().as_f64().unwrap();
    let want_y = j.get("y").unwrap().f64_vec().unwrap();
    let want_beta = j.get("beta").unwrap().f64_vec().unwrap();
    let want_dev = j.get("deviances").unwrap().f64_vec().unwrap();

    let eng = Engine::new(EngineConfig {
        threads: 1,
        xla_dispatch: false,
        chunk_bytes: 1 << 20,
        target_part_bytes: 1 << 20,
        ..Default::default()
    })
    .unwrap();
    let x = datasets::golden_uniform(&eng, rows, p, x_seed, scale, shift, 0.0).unwrap();
    let y = datasets::logistic_labels(&x, &beta_true, u_seed).unwrap();
    let y_host = y.to_host().unwrap().buf.to_f64_vec();
    assert_eq!(y_host, want_y, "label generator diverged from the python mirror");

    let fit = flashmatrix::algs::logistic(&x, &y, iters, ridge).unwrap();
    for (i, (a, b)) in fit.beta.iter().zip(&want_beta).enumerate() {
        assert!(
            (a - b).abs() < 1e-9 * b.abs().max(1.0),
            "beta[{i}]: rust {a} vs numpy {b}"
        );
    }
    for (i, (a, b)) in fit.deviances.iter().zip(&want_dev).enumerate() {
        assert!(
            (a - b).abs() < 1e-7 * b.abs().max(1.0),
            "deviance[{i}]: rust {a} vs numpy {b}"
        );
    }
}

/// Delimited ingestion vs the python mirror: `test_golden.py` writes two
/// literal text files plus every typed cell it expects — ints, floats,
/// FNV-1a hash buckets, sorted 1-based factor codes, and `null` where a
/// cell is NA. Both the per-column (`load_list_vecs`) and the uniform-F64
/// (`load_dense_matrix`) views must reproduce the oracle exactly; this
/// pins the parse spec (trimming, NA set, sentinel choices, level order,
/// hash function) against an independent implementation.
#[test]
fn ingestion_matches_python_oracle() {
    use flashmatrix::dtype::{DType, Scalar};
    use flashmatrix::ingest::DEFAULT_HASH_BUCKETS;
    use flashmatrix::testutil::TempDir;
    use flashmatrix::{EngineExt, LoadOptions, Schema};

    let j = load_named_fixture("ingest_7x4.json");
    let schema = Schema::parse(j.get("schema").unwrap().as_str().unwrap()).unwrap();
    assert_eq!(
        j.get("buckets").unwrap().as_u64().unwrap(),
        u64::from(DEFAULT_HASH_BUCKETS),
        "python mirror hashes into a different bucket count"
    );
    let delim = j.get("delim").unwrap().as_str().unwrap().as_bytes()[0];
    let nas: Vec<&str> = j
        .get("na_values")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_str().unwrap())
        .collect();
    let o = LoadOptions::new(schema).delim(delim).na_values(&nas);

    let tmp = TempDir::new("golden-ingest");
    let mut paths = Vec::new();
    for (i, f) in j.get("files").unwrap().as_arr().unwrap().iter().enumerate() {
        let p = tmp.path().join(format!("part-{i}.txt"));
        std::fs::write(&p, f.as_str().unwrap()).unwrap();
        paths.push(p);
    }

    let eng = Engine::new(EngineConfig {
        xla_dispatch: false,
        chunk_bytes: 1 << 20,
        target_part_bytes: 1 << 20,
        ..Default::default()
    })
    .unwrap();
    let nrow = j.get("nrow").unwrap().as_u64().unwrap();
    let cols = j.get("cols").unwrap().as_arr().unwrap();

    // Typed per-column view: exact values, NA sentinels, factor levels.
    let vecs = eng.load_list_vecs(&paths, &o).unwrap();
    assert_eq!(vecs.len(), cols.len());
    let want_dtypes = [DType::I32, DType::F64, DType::I32, DType::I32];
    for (ci, (v, want)) in vecs.iter().zip(cols).enumerate() {
        assert_eq!(v.v.nrow(), nrow, "col {ci} row count");
        assert_eq!(v.v.dtype(), want_dtypes[ci], "col {ci} dtype");
        let host = v.v.to_host().unwrap();
        for (r, w) in want.as_arr().unwrap().iter().enumerate() {
            let got = host.get(r, 0);
            match (w, got) {
                (Json::Null, Scalar::I32(g)) => {
                    assert_eq!(g, i32::MIN, "col {ci} row {r}: expected int NA")
                }
                (Json::Null, Scalar::F64(g)) => {
                    assert!(g.is_nan(), "col {ci} row {r}: expected NaN, got {g}")
                }
                (w, Scalar::I32(g)) => {
                    assert_eq!(i64::from(g), w.as_f64().unwrap() as i64, "col {ci} row {r}")
                }
                (w, Scalar::F64(g)) => {
                    assert_eq!(g, w.as_f64().unwrap(), "col {ci} row {r}")
                }
                (w, g) => panic!("col {ci} row {r}: oracle {w:?} vs rust {g:?}"),
            }
        }
    }
    let want_levels: Vec<&str> = j
        .get("levels")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_str().unwrap())
        .collect();
    let levels = vecs[2].levels.as_ref().expect("factor column carries levels");
    assert_eq!(levels.as_slice(), want_levels.as_slice());
    assert!(vecs[0].levels.is_none() && vecs[3].levels.is_none());

    // Uniform-F64 matrix view: every NA (whatever the column type)
    // becomes NaN; everything else is exactly the typed value as f64.
    let x = eng.load_dense_matrix(&paths, &o).unwrap();
    assert_eq!((x.nrow(), x.ncol()), (nrow, cols.len() as u64));
    assert_eq!(x.dtype(), DType::F64);
    let host = x.to_host().unwrap();
    for (ci, want) in cols.iter().enumerate() {
        for (r, w) in want.as_arr().unwrap().iter().enumerate() {
            let g = host.get(r, ci).as_f64();
            match w {
                Json::Null => assert!(g.is_nan(), "dense [{r},{ci}]: want NaN, got {g}"),
                w => assert_eq!(g, w.as_f64().unwrap(), "dense [{r},{ci}]"),
            }
        }
    }
}
