//! Property-based tests on engine invariants (util::quickcheck generators;
//! a proptest substitute — see Cargo.toml header note).
//!
//! Each property runs across dozens of generated shapes/seeds/configs and
//! checks the engine against a straightforward host-side oracle.

use flashmatrix::config::EngineConfig;
use flashmatrix::datasets;
use flashmatrix::dtype::{DType, Scalar};
use flashmatrix::fmr::{Engine, EngineExt, FmMatrix};
use flashmatrix::matrix::{io_rows_for, HostMat, Partitioning};
use flashmatrix::util::quickcheck::forall;
use flashmatrix::vudf::{AggOp, BinOp, UnOp};

fn eng_with(threads: usize, fuse: bool) -> std::sync::Arc<Engine> {
    Engine::new(EngineConfig {
        threads,
        fuse_mem: fuse,
        fuse_cache: fuse,
        xla_dispatch: false,
        chunk_bytes: 1 << 20,
        target_part_bytes: 1 << 18,
        ..Default::default()
    })
    .unwrap()
}

/// Host-side oracle matrix mirroring datasets::uniform.
fn host_uniform(n: usize, p: usize, lo: f64, hi: f64, seed: u64) -> Vec<Vec<f64>> {
    (0..n)
        .map(|r| {
            (0..p)
                .map(|c| {
                    lo + (hi - lo)
                        * flashmatrix::exec::u64_to_unit_f64(flashmatrix::exec::splitmix64_at(
                            seed,
                            (r * p + c) as u64,
                        ))
                })
                .collect()
        })
        .collect()
}

#[test]
fn prop_partitioning_covers_and_nests() {
    forall(200, |g| {
        let nrow = g.usize_in(1, 500_000) as u64;
        let ncol = g.usize_in(1, 600) as u64;
        let parts = Partitioning::new(nrow, ncol);
        if !parts.io_rows.is_power_of_two() {
            return Err(format!("io_rows {} not pow2", parts.io_rows));
        }
        let mut covered = 0u64;
        for i in 0..parts.n_parts() {
            let (s, e) = parts.part_rows(i);
            if s != covered || e <= s {
                return Err(format!("gap at partition {i}: [{s},{e}) after {covered}"));
            }
            covered = e;
            // cpu ranges tile the partition exactly
            let mut local = 0;
            for (a, b) in parts.cpu_ranges(i, 64 << 10) {
                if a != local || b <= a {
                    return Err(format!("cpu strip gap {a}..{b} after {local}"));
                }
                local = b;
            }
            if local != parts.rows_in(i) {
                return Err("cpu strips do not cover partition".into());
            }
        }
        if covered != nrow {
            return Err(format!("covered {covered} != {nrow}"));
        }
        // nesting: any narrower matrix's partitions nest within wider ones
        let r1 = io_rows_for(ncol);
        let r2 = io_rows_for(ncol * 2);
        if r1 % r2.min(r1) != 0 || r2 % r1.min(r2) != 0 {
            return Err(format!("io rows {r1}/{r2} do not nest"));
        }
        Ok(())
    });
}

#[test]
fn prop_elementwise_matches_oracle() {
    forall(25, |g| {
        let n = g.usize_in(100, 5000);
        let p = g.usize_in(1, 7);
        let seed = g.u64();
        let threads = g.usize_in(1, 3);
        let fuse = g.bool();
        let eng = eng_with(threads, fuse);
        let x = datasets::uniform(&eng, n as u64, p as u64, -2.0, 2.0, seed, None).unwrap();
        let oracle = host_uniform(n, p, -2.0, 2.0, seed);

        let op = *g.choose(&[UnOp::Abs, UnOp::Sq, UnOp::Neg, UnOp::Exp]);
        let sf = |v: f64| match op {
            UnOp::Abs => v.abs(),
            UnOp::Sq => v * v,
            UnOp::Neg => -v,
            UnOp::Exp => v.exp(),
            _ => unreachable!(),
        };
        let got = x.sapply(op).unwrap().sum().unwrap();
        let want: f64 = oracle.iter().flatten().map(|v| sf(*v)).sum();
        if (got - want).abs() / want.abs().max(1.0) > 1e-9 {
            return Err(format!("{op:?}: {got} vs {want}"));
        }
        Ok(())
    });
}

#[test]
fn prop_rowagg_colagg_consistent() {
    forall(20, |g| {
        let n = g.usize_in(50, 3000);
        let p = g.usize_in(1, 6);
        let seed = g.u64();
        let eng = eng_with(g.usize_in(1, 3), true);
        let x = datasets::uniform(&eng, n as u64, p as u64, 0.0, 1.0, seed, None).unwrap();
        // sum(rowSums) == sum(colSums) == sum(x)
        let total = x.sum().unwrap();
        let via_rows = x.row_sums().unwrap().sum().unwrap();
        let via_cols: f64 = x.col_sums().unwrap().buf.to_f64_vec().iter().sum();
        for (name, v) in [("rows", via_rows), ("cols", via_cols)] {
            if (v - total).abs() / total.max(1.0) > 1e-9 {
                return Err(format!("sum via {name}: {v} vs {total}"));
            }
        }
        // min <= mean <= max per column
        let s = flashmatrix::algs::summary(&x).unwrap();
        for j in 0..p {
            if !(s.min[j] <= s.mean[j] && s.mean[j] <= s.max[j]) {
                return Err(format!("col {j}: min/mean/max ordering violated"));
            }
            if s.var[j] < 0.0 {
                return Err(format!("col {j}: negative variance"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_groupby_total_preserved() {
    forall(20, |g| {
        let n = g.usize_in(100, 4000);
        let p = g.usize_in(1, 5);
        let k = g.usize_in(1, 6);
        let seed = g.u64();
        let eng = eng_with(g.usize_in(1, 3), g.bool());
        let x = datasets::uniform(&eng, n as u64, p as u64, -1.0, 1.0, seed, None).unwrap();
        // labels = floor(u * k) from an independent column
        let u = eng.runif_matrix(n as u64, 1, 0.0, k as f64, seed ^ 1);
        let labels = u
            .sapply(UnOp::Floor)
            .unwrap()
            .cast(DType::I32)
            .unwrap();
        let grouped = x.groupby_row(&labels, k, AggOp::Sum).unwrap();
        let total_grouped: f64 = grouped.buf.to_f64_vec().iter().sum();
        let total = x.sum().unwrap();
        if (total_grouped - total).abs() / total.abs().max(1.0) > 1e-9 {
            return Err(format!("groupby lost mass: {total_grouped} vs {total}"));
        }
        // counts per group sum to n
        let ones = eng.fill(Scalar::F64(1.0), n as u64, 1);
        let counts = ones.groupby_row(&labels, k, AggOp::Sum).unwrap();
        let csum: f64 = counts.buf.to_f64_vec().iter().sum();
        if csum != n as f64 {
            return Err(format!("counts {csum} != n {n}"));
        }
        Ok(())
    });
}

#[test]
fn prop_inner_products_agree_with_host() {
    forall(15, |g| {
        let n = g.usize_in(50, 2000);
        let p = g.usize_in(1, 5);
        let q = g.usize_in(1, 4);
        let seed = g.u64();
        let eng = eng_with(g.usize_in(1, 3), true);
        let x = datasets::uniform(&eng, n as u64, p as u64, -1.0, 1.0, seed, None).unwrap();
        let oracle = host_uniform(n, p, -1.0, 1.0, seed);
        let bvals = g.f64_vec(p * q, -1.0, 1.0);
        let mut b = HostMat::zeros(p, q, DType::F64);
        for i in 0..p {
            for j in 0..q {
                b.set(i, j, Scalar::F64(bvals[i * q + j]));
            }
        }
        // tall × small
        let y = x.matmul_small(&b).unwrap().to_host().unwrap();
        for r in (0..n).step_by((n / 7).max(1)) {
            for c in 0..q {
                let want: f64 = (0..p).map(|kk| oracle[r][kk] * bvals[kk * q + c]).sum();
                let got = y.get(r, c).as_f64();
                if (got - want).abs() > 1e-9 {
                    return Err(format!("matmul[{r},{c}]: {got} vs {want}"));
                }
            }
        }
        // wide × tall (Gramian) vs host
        let gm = x.crossprod(&x).unwrap();
        for i in 0..p {
            for j in 0..p {
                let want: f64 = (0..n).map(|r| oracle[r][i] * oracle[r][j]).sum();
                let got = gm.get(i, j).as_f64();
                if (got - want).abs() / want.abs().max(1.0) > 1e-9 {
                    return Err(format!("gramian[{i},{j}]: {got} vs {want}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dtype_promotion_safe() {
    forall(30, |g| {
        let n = g.usize_in(10, 2000) as u64;
        let eng = eng_with(1, true);
        let dt = *g.choose(&[DType::Bool, DType::I32, DType::I64, DType::F32, DType::F64]);
        let a = eng.fill(Scalar::F64(1.0).cast(dt), n, 2);
        let b = eng.fill(Scalar::F64(2.0), n, 2);
        let c = a.add(&b).unwrap();
        let s = c.sum().unwrap();
        if s != 3.0 * 2.0 * n as f64 {
            return Err(format!("{dt:?} + f64: sum {s}"));
        }
        // comparisons produce booleans countable via sum
        let lt = a.mapply(&b, BinOp::Lt).unwrap();
        if lt.dtype() != DType::Bool {
            return Err("comparison must be Bool".into());
        }
        let cnt = lt.agg(AggOp::Sum).unwrap().as_i64();
        if cnt != 2 * n as i64 {
            return Err(format!("lt count {cnt}"));
        }
        Ok(())
    });
}

#[test]
fn prop_inplace_recycle_fusion_bitexact() {
    // The liveness-driven register plan (recycling + in-place kernels +
    // peephole-fused chains) must be invisible: every evaluator output
    // bit-identical to the fresh-alloc path, across dtypes and the
    // vectorized_udf ablation.
    forall(12, |g| {
        let n = g.usize_in(100, 3000);
        let p = g.usize_in(1, 6);
        let seed = g.u64();
        let vudf = g.bool();
        let threads = g.usize_in(1, 3);
        let dt = *g.choose(&[DType::F64, DType::F32, DType::I32]);

        // one run of the whole pipeline zoo under a given optimization mode
        type Outputs = (HostMat, HostMat, HostMat, f64);
        let run = |optimized: bool| -> Result<Outputs, flashmatrix::FmError> {
            let eng = Engine::new(EngineConfig {
                threads,
                vectorized_udf: vudf,
                recycle_chunks: optimized,
                inplace_ops: optimized,
                peephole_fuse: optimized,
                xla_dispatch: false,
                chunk_bytes: 1 << 20,
                target_part_bytes: 1 << 18,
                ..Default::default()
            })
            .unwrap();
            let x = datasets::uniform(&eng, n as u64, p as u64, -2.0, 2.0, seed, None)?
                .cast(dt)?;
            // fusable chain: abs -> +0.25 -> sqrt (dtype promotions vary
            // with dt, exercising fused and unfused compilations)
            let y = x
                .sapply(UnOp::Abs)?
                .mapply_scalar(Scalar::F64(0.25), BinOp::Add, true)?
                .sapply(UnOp::Sqrt)?;
            let yh = y.to_host()?;
            // per-row reduction + arg-extreme over the chain output
            let rs = y.row_sums()?.to_host()?;
            let am = y.which_min_row()?.to_host()?;
            // mixed-dtype cbind + full-aggregation sink
            let cb = FmMatrix::cbind(&eng, &[&x, &y])?;
            let total = cb.sum()?;
            Ok((yh, rs, am, total))
        };

        let base = run(false).map_err(|e| e.to_string())?;
        let opt = run(true).map_err(|e| e.to_string())?;
        if opt.0 != base.0 {
            return Err(format!("{dt:?} vudf={vudf}: chain output differs"));
        }
        if opt.1 != base.1 {
            return Err(format!("{dt:?} vudf={vudf}: row_sums differ"));
        }
        if opt.2 != base.2 {
            return Err(format!("{dt:?} vudf={vudf}: which_min differs"));
        }
        // sink partials merge in worker-completion order, so the scalar
        // sum is only bit-stable single-threaded; multi-threaded runs get
        // a tight tolerance instead
        if threads == 1 {
            if opt.3.to_bits() != base.3.to_bits() {
                return Err(format!("{dt:?} vudf={vudf}: sum {} vs {}", opt.3, base.3));
            }
        } else if (opt.3 - base.3).abs() / base.3.abs().max(1.0) > 1e-12 {
            return Err(format!("{dt:?} vudf={vudf}: sum {} vs {}", opt.3, base.3));
        }
        Ok(())
    });
}

#[test]
fn prop_spmm_matches_densified() {
    // SpMM parity: a random sparse matrix multiplied through the
    // streaming CSR kernel must be BIT-identical to densifying the same
    // matrix and going through `inner_prod_small` (Mul, Sum) — across
    // densities (0, 2%, 10%, 50%), EM/IM storage, and the
    // `vectorized_udf` ablation. The contraction order contract in
    // `exec/pipeline.rs::spmm_strip` is what makes this exact.
    forall(12, |g| {
        let n = g.usize_in(300, 40_000) as u64;
        let m = g.usize_in(3, 40) as u64;
        let q = g.usize_in(1, 3);
        let density = *g.choose(&[0.0, 0.02, 0.1, 0.5]);
        let seed = g.u64();
        let vudf = g.bool();
        let em = g.bool();

        let tmp = flashmatrix::testutil::TempDir::new("prop-spmm");
        let mut cfg = if em {
            flashmatrix::testutil::out_of_core_config(tmp.path())
        } else {
            EngineConfig {
                chunk_bytes: 4 << 20,
                target_part_bytes: 1 << 20,
                xla_dispatch: false,
                ..Default::default()
            }
        };
        cfg.vectorized_udf = vudf;
        cfg.threads = g.usize_in(1, 3);
        // a 40k x 40 dense partition can reach ~5 MiB; chunks must fit it
        cfg.chunk_bytes = 16 << 20;
        let eng = Engine::new(cfg).unwrap();

        let present = |r: u64, c: u64| {
            flashmatrix::exec::u64_to_unit_f64(flashmatrix::exec::splitmix64_at(
                seed ^ 0x5AAD,
                r * m + c,
            )) < density
        };
        let value = |r: u64, c: u64| {
            flashmatrix::exec::u64_to_unit_f64(flashmatrix::exec::splitmix64_at(
                seed ^ 0x7A1E,
                r * m + c,
            )) * 2.0
                - 1.0
        };
        let sparse = datasets::sparse_from_rows(&eng, n, m, None, |r| {
            (0..m)
                .filter(|c| present(r, *c))
                .map(|c| (c as u32, value(r, c)))
                .collect()
        })
        .map_err(|e| e.to_string())?;
        let dense = datasets::from_fn(&eng, n, m, None, |r, c| {
            if present(r, c) {
                value(r, c)
            } else {
                0.0
            }
        })
        .map_err(|e| e.to_string())?;

        // nnz bookkeeping matches the generator
        let want_nnz: u64 = (0..n)
            .map(|r| (0..m).filter(|c| present(r, *c)).count() as u64)
            .sum();
        if sparse.nnz() != Some(want_nnz) {
            return Err(format!("nnz {:?} != {want_nnz}", sparse.nnz()));
        }

        let bvals = g.f64_vec(m as usize * q, -2.0, 2.0);
        let mut b = HostMat::zeros(m as usize, q, DType::F64);
        for i in 0..m as usize {
            for j in 0..q {
                b.set(i, j, Scalar::F64(bvals[i * q + j]));
            }
        }

        let ys = sparse.spmm(b.clone()).map_err(|e| e.to_string())?;
        if (ys.nrow(), ys.ncol()) != (n, q as u64) {
            return Err(format!("spmm shape {}x{}", ys.nrow(), ys.ncol()));
        }
        let ys = ys.to_host().map_err(|e| e.to_string())?;
        let yd = dense
            .matmul_small(&b)
            .and_then(|y| y.to_host())
            .map_err(|e| e.to_string())?;
        let (vs, vd) = (ys.buf.to_f64_vec(), yd.buf.to_f64_vec());
        for (i, (a, d)) in vs.iter().zip(&vd).enumerate() {
            if a.to_bits() != d.to_bits() {
                return Err(format!(
                    "density {density} em={em} vudf={vudf}: \
                     spmm[{i}] = {a} != densified {d}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_transpose_is_involution() {
    forall(20, |g| {
        let n = g.usize_in(5, 200);
        let p = g.usize_in(1, 6);
        let eng = eng_with(1, true);
        let x = datasets::uniform(&eng, n as u64, p as u64, 0.0, 1.0, g.u64(), None).unwrap();
        let h1 = x.to_host().unwrap();
        let h2 = x.t().t().to_host().unwrap();
        if h1 != h2 {
            return Err("t(t(x)) != x".into());
        }
        let ht = x.t().to_host().unwrap();
        if ht.nrow != p || ht.ncol != n {
            return Err("t(x) dims wrong".into());
        }
        for r in 0..n.min(10) {
            for c in 0..p {
                if h1.get(r, c) != ht.get(c, r) {
                    return Err(format!("t mismatch at {r},{c}"));
                }
            }
        }
        Ok(())
    });
}
