//! Comparison baselines (DESIGN.md §Substitutions).
//!
//! * **MLlib-like** — not code here: the engine run with
//!   [`crate::config::EngineConfig::mllib_like`] (eager materialization of
//!   every op, per-element boxed UDF calls, fresh allocation per op, no
//!   XLA). Fig 6's comparison uses exactly the same algorithm sources.
//! * **R reference** ([`reference`]) — single-threaded, eager,
//!   temp-allocating implementations in the style of R's C/FORTRAN
//!   backends: each logical matrix op materializes a full temporary, ops
//!   run one after another (no fusion, no partitioning), one thread.
//!   These are the Fig 7 comparators.

pub mod reference;
