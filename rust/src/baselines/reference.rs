//! Single-threaded eager reference implementations (the "R C/FORTRAN"
//! comparators of Fig 7).
//!
//! Style rules, mirroring how R's interpreter drives its C backends:
//! every operation allocates and fills a full n×p temporary before the
//! next op starts (no fusion), everything is one thread, data is one flat
//! column-major `Vec<f64>`. The algorithms match [`crate::algs`]
//! numerically (same formulas), so the comparison isolates the *execution
//! model*, exactly as the paper's Fig 7 does.

use crate::algs::linalg;
use crate::error::Result;
use crate::matrix::HostMat;

/// Column-major n×p host matrix for the reference path.
pub struct RefMat {
    pub n: usize,
    pub p: usize,
    pub data: Vec<f64>,
}

impl RefMat {
    pub fn get(&self, r: usize, c: usize) -> f64 {
        self.data[c * self.n + r]
    }

    /// Export an engine matrix for the reference baselines.
    pub fn from_fm(x: &crate::fmr::FmMatrix) -> Result<RefMat> {
        let h = x.to_host()?;
        Ok(RefMat {
            n: h.nrow,
            p: h.ncol,
            data: h.buf.to_f64_vec(),
        })
    }

    fn col(&self, c: usize) -> &[f64] {
        &self.data[c * self.n..(c + 1) * self.n]
    }
}

/// Summary: min/max/mean/L1/L2/nnz/var per column — each statistic is its
/// own full pass with its own temporaries (R: `apply(x, 2, min)`, `x^2`,
/// `colSums`, ...).
pub fn summary_ref(
    x: &RefMat,
) -> (Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>) {
    let (n, p) = (x.n, x.p);
    let mut min = vec![f64::INFINITY; p];
    let mut max = vec![f64::NEG_INFINITY; p];
    for c in 0..p {
        for &v in x.col(c) {
            min[c] = min[c].min(v);
        }
    }
    for c in 0..p {
        for &v in x.col(c) {
            max[c] = max[c].max(v);
        }
    }
    // x^2 temporary (the eager allocation R would make)
    let sq: Vec<f64> = x.data.iter().map(|v| v * v).collect();
    let absx: Vec<f64> = x.data.iter().map(|v| v.abs()).collect();
    let nz: Vec<f64> = x.data.iter().map(|v| (*v != 0.0) as u8 as f64).collect();
    let colsum = |d: &[f64]| -> Vec<f64> {
        (0..p).map(|c| d[c * n..(c + 1) * n].iter().sum()).collect()
    };
    let sum = colsum(&x.data);
    let sumsq = colsum(&sq);
    let l1 = colsum(&absx);
    let nnz = colsum(&nz);
    let mean: Vec<f64> = sum.iter().map(|s| s / n as f64).collect();
    let var: Vec<f64> = sumsq
        .iter()
        .zip(&mean)
        .map(|(ss, m)| (ss - n as f64 * m * m) / (n as f64 - 1.0).max(1.0))
        .collect();
    let l2: Vec<f64> = sumsq.iter().map(|s| s.sqrt()).collect();
    (min, max, mean, l1, l2, nnz, var)
}

/// Correlation: center (full temporary), then `crossprod` (the dgemm call
/// R's `cor` ends up in), then normalize.
pub fn correlation_ref(x: &RefMat) -> Vec<f64> {
    let (n, p) = (x.n, x.p);
    let mean: Vec<f64> = (0..p)
        .map(|c| x.col(c).iter().sum::<f64>() / n as f64)
        .collect();
    // centered copy (eager)
    let mut xc = vec![0.0; n * p];
    for c in 0..p {
        for r in 0..n {
            xc[c * n + r] = x.get(r, c) - mean[c];
        }
    }
    let mut g = vec![0.0; p * p];
    for i in 0..p {
        for j in i..p {
            let (ci, cj) = (&xc[i * n..(i + 1) * n], &xc[j * n..(j + 1) * n]);
            let dot: f64 = ci.iter().zip(cj).map(|(a, b)| a * b).sum();
            g[i * p + j] = dot;
            g[j * p + i] = dot;
        }
    }
    let mut corr = vec![0.0; p * p];
    for i in 0..p {
        for j in 0..p {
            let d = (g[i * p + i] * g[j * p + j]).sqrt();
            corr[i * p + j] = if d > 0.0 { g[i * p + j] / d } else { 0.0 };
        }
    }
    corr
}

/// SVD via Gramian + Jacobi (same math as `algs::svd`, eager layout).
pub fn svd_ref(x: &RefMat, nv: usize) -> Result<(Vec<f64>, Vec<f64>)> {
    let (n, p) = (x.n, x.p);
    let mut g = vec![0.0; p * p];
    for i in 0..p {
        for j in i..p {
            let dot: f64 = x.col(i).iter().zip(x.col(j)).map(|(a, b)| a * b).sum();
            g[i * p + j] = dot;
            g[j * p + i] = dot;
        }
    }
    let _ = n;
    let (vals, vecs) = linalg::jacobi_eigen(&g, p, 100)?;
    let sigma: Vec<f64> = vals.iter().take(nv).map(|l| l.max(0.0).sqrt()).collect();
    Ok((sigma, vecs))
}

/// Lloyd k-means, eager: a full n×k distance matrix is materialized every
/// iteration (R's `dist`-style memory behaviour).
pub fn kmeans_ref(x: &RefMat, init: &HostMat, iters: usize) -> (HostMat, Vec<f64>) {
    let (n, p) = (x.n, x.p);
    let k = init.nrow;
    let mut c: Vec<f64> = init.to_row_major_f64();
    let mut wcss_log = Vec::new();
    for _ in 0..iters {
        // full distance matrix (eager, n×k)
        let mut dist = vec![0.0; n * k];
        for ci in 0..k {
            for r in 0..n {
                let mut d = 0.0;
                for j in 0..p {
                    let diff = x.get(r, j) - c[ci * p + j];
                    d += diff * diff;
                }
                dist[ci * n + r] = d;
            }
        }
        let mut sums = vec![0.0; k * p];
        let mut counts = vec![0.0; k];
        let mut wcss = 0.0;
        for r in 0..n {
            let mut best = f64::INFINITY;
            let mut bi = 0;
            for ci in 0..k {
                if dist[ci * n + r] < best {
                    best = dist[ci * n + r];
                    bi = ci;
                }
            }
            counts[bi] += 1.0;
            wcss += best;
            for j in 0..p {
                sums[bi * p + j] += x.get(r, j);
            }
        }
        for ci in 0..k {
            if counts[ci] > 0.0 {
                for j in 0..p {
                    c[ci * p + j] = sums[ci * p + j] / counts[ci];
                }
            }
        }
        wcss_log.push(wcss);
    }
    (HostMat::from_row_major_f64(k, p, &c), wcss_log)
}

/// Full-covariance GMM EM, eager: n×k responsibility matrix materialized
/// per iteration (mclust-style memory behaviour).
pub fn gmm_ref(x: &RefMat, init_means: &HostMat, iters: usize) -> Result<(HostMat, Vec<f64>)> {
    let (n, p) = (x.n, x.p);
    let k = init_means.nrow;
    let mut means = init_means.to_row_major_f64();
    let mut prec = vec![0.0; k * p * p];
    for c in 0..k {
        for i in 0..p {
            prec[c * p * p + i * p + i] = 1.0;
        }
    }
    let mut logdet = vec![0.0; k];
    let mut logw = vec![(1.0 / k as f64).ln(); k];
    let cst = -0.5 * p as f64 * (2.0 * std::f64::consts::PI).ln();
    let mut ll_log = Vec::new();

    for _ in 0..iters {
        // eager responsibilities
        let mut resp = vec![0.0; n * k];
        let mut ll = 0.0;
        let mut logp = vec![0.0; k];
        for r in 0..n {
            for c in 0..k {
                let mut maha = 0.0;
                for i in 0..p {
                    let di = x.get(r, i) - means[c * p + i];
                    let mut s = 0.0;
                    for j in 0..p {
                        s += prec[c * p * p + i * p + j] * (x.get(r, j) - means[c * p + j]);
                    }
                    maha += di * s;
                }
                logp[c] = logw[c] + 0.5 * logdet[c] - 0.5 * maha + cst;
            }
            let m = logp.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let se: f64 = logp.iter().map(|v| (v - m).exp()).sum();
            let lse = m + se.ln();
            ll += lse;
            for c in 0..k {
                resp[c * n + r] = (logp[c] - lse).exp();
            }
        }
        ll_log.push(ll);
        // M-step
        for c in 0..k {
            let rcol = &resp[c * n..(c + 1) * n];
            let nc: f64 = rcol.iter().sum::<f64>().max(1e-12);
            logw[c] = (nc / n as f64).ln();
            for j in 0..p {
                means[c * p + j] =
                    (0..n).map(|r| rcol[r] * x.get(r, j)).sum::<f64>() / nc;
            }
            let mut cov = vec![0.0; p * p];
            for r in 0..n {
                for i in 0..p {
                    let di = x.get(r, i) - means[c * p + i];
                    for j in 0..p {
                        cov[i * p + j] += rcol[r] * di * (x.get(r, j) - means[c * p + j]);
                    }
                }
            }
            for v in cov.iter_mut() {
                *v /= nc;
            }
            for i in 0..p {
                cov[i * p + i] += 1e-6;
            }
            let (inv, ld) = linalg::spd_inverse_logdet(&cov, p)?;
            prec[c * p * p..(c + 1) * p * p].copy_from_slice(&inv);
            logdet[c] = -ld;
        }
    }
    Ok((HostMat::from_row_major_f64(k, p, &means), ll_log))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;
    use crate::fmr::Engine;

    fn eng() -> std::sync::Arc<Engine> {
        Engine::new(EngineConfig {
            xla_dispatch: false,
            chunk_bytes: 1 << 20,
            target_part_bytes: 1 << 20,
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn reference_summary_matches_engine() {
        let e = eng();
        let x = crate::datasets::uniform(&e, 6000, 3, -2.0, 2.0, 77, None).unwrap();
        let s = crate::algs::summary(&x).unwrap();
        let r = RefMat::from_fm(&x).unwrap();
        let (min, max, mean, l1, l2, nnz, var) = summary_ref(&r);
        for j in 0..3 {
            assert!((s.min[j] - min[j]).abs() < 1e-12);
            assert!((s.max[j] - max[j]).abs() < 1e-12);
            assert!((s.mean[j] - mean[j]).abs() < 1e-10);
            assert!((s.l1[j] - l1[j]).abs() < 1e-7);
            assert!((s.l2[j] - l2[j]).abs() < 1e-9);
            assert_eq!(s.nnz[j], nnz[j]);
            assert!((s.var[j] - var[j]).abs() < 1e-10);
        }
    }

    #[test]
    fn reference_correlation_matches_engine() {
        let e = eng();
        let x = crate::datasets::spectral_like(&e, 4000, 4, 9, None).unwrap();
        let a = crate::algs::correlation(&x).unwrap();
        let r = RefMat::from_fm(&x).unwrap();
        let b = correlation_ref(&r);
        for (u, v) in a.corr.iter().zip(&b) {
            assert!((u - v).abs() < 1e-9, "{u} vs {v}");
        }
    }

    #[test]
    fn reference_kmeans_matches_engine_wcss() {
        let e = eng();
        let (x, _) = crate::datasets::mix_gaussian(&e, 6000, 3, 3, 10.0, 5, None).unwrap();
        let init = crate::algs::kmeans::init_centroids(&x, 3, 1).unwrap();
        let eng_r = crate::algs::kmeans(&x, 3, 4, 1).unwrap();
        let r = RefMat::from_fm(&x).unwrap();
        let (_c, wcss) = kmeans_ref(&r, &init, 4);
        for (a, b) in eng_r.wcss.iter().zip(&wcss) {
            assert!((a - b).abs() / b.max(1.0) < 1e-9, "{a} vs {b}");
        }
    }

    #[test]
    fn reference_gmm_matches_engine_loglik() {
        let e = eng();
        let (x, _) = crate::datasets::mix_gaussian(&e, 3000, 2, 2, 8.0, 13, None).unwrap();
        let init = crate::algs::kmeans::init_centroids(&x, 2, 3).unwrap();
        let eng_r = crate::algs::gmm(&x, 2, 3, 3).unwrap();
        let r = RefMat::from_fm(&x).unwrap();
        let (_m, ll) = gmm_ref(&r, &init, 3).unwrap();
        for (a, b) in eng_r.loglik.iter().zip(&ll) {
            assert!((a - b).abs() / b.abs().max(1.0) < 1e-8, "{a} vs {b}");
        }
    }
}
