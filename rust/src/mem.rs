//! Memory-chunk pool (paper §III-B5).
//!
//! Linux serves large allocations with `mmap` and populates pages on fault;
//! at 48 threads the paper found page faults throttle the whole machine, so
//! FlashMatrix allocates fixed-size chunks once and recycles them across
//! matrices of all shapes. We reproduce that: a global pool of fixed-size
//! `Vec<u8>` chunks; in-memory matrices borrow chunks and return them on
//! drop. The Fig 11 "mem-alloc" ablation flips [`ChunkPool::recycling`] off,
//! making every acquisition a fresh allocation (and every release a free).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::dtype::DType;
use crate::metrics::Metrics;
use crate::util::sync::LockExt;
use crate::vudf::Buf;

/// A fixed-size recycled memory chunk. Returned to its pool on drop.
pub struct Chunk {
    buf: Vec<u8>,
    pool: Arc<ChunkPoolInner>,
}

impl Chunk {
    pub fn bytes(&self) -> &[u8] {
        &self.buf
    }

    pub fn bytes_mut(&mut self) -> &mut [u8] {
        &mut self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

impl Drop for Chunk {
    fn drop(&mut self) {
        let buf = std::mem::take(&mut self.buf);
        self.pool.release(buf);
    }
}

struct ChunkPoolInner {
    chunk_bytes: usize,
    free: Mutex<Vec<Vec<u8>>>,
    recycling: AtomicBool,
    metrics: Arc<Metrics>,
}

impl ChunkPoolInner {
    fn release(&self, buf: Vec<u8>) {
        self.metrics.mem_release(buf.len() as u64);
        if self.recycling.load(Ordering::Relaxed) && buf.len() == self.chunk_bytes {
            self.free.lock_recover().push(buf);
        }
        // else: dropped, freeing to the OS (the unoptimized mode)
    }
}

/// Pool of fixed-size chunks shared by all matrices of an engine.
#[derive(Clone)]
pub struct ChunkPool {
    inner: Arc<ChunkPoolInner>,
}

impl ChunkPool {
    pub fn new(chunk_bytes: usize, recycling: bool, metrics: Arc<Metrics>) -> Self {
        ChunkPool {
            inner: Arc::new(ChunkPoolInner {
                chunk_bytes,
                free: Mutex::new(Vec::new()),
                recycling: AtomicBool::new(recycling),
                metrics,
            }),
        }
    }

    /// The global chunk size (same for all matrices — that is what makes
    /// chunks reusable across shapes, §III-B5).
    pub fn chunk_bytes(&self) -> usize {
        self.inner.chunk_bytes
    }

    /// Acquire one chunk: recycled if available, freshly allocated
    /// (and zeroed) otherwise.
    pub fn acquire(&self) -> Chunk {
        let m = &self.inner.metrics;
        let buf = if self.inner.recycling.load(Ordering::Relaxed) {
            self.inner.free.lock_recover().pop()
        } else {
            None
        };
        let buf = match buf {
            Some(b) => {
                m.chunks_recycled.fetch_add(1, Ordering::Relaxed);
                b
            }
            None => {
                m.chunks_allocated.fetch_add(1, Ordering::Relaxed);
                vec![0u8; self.inner.chunk_bytes]
            }
        };
        m.mem_acquire(buf.len() as u64);
        Chunk {
            buf,
            pool: Arc::clone(&self.inner),
        }
    }

    /// Acquire a chunk of a non-standard size (small matrices, sink
    /// results). Never recycled — tracked for accounting only.
    pub fn acquire_sized(&self, bytes: usize) -> Chunk {
        let m = &self.inner.metrics;
        m.chunks_allocated.fetch_add(1, Ordering::Relaxed);
        m.mem_acquire(bytes as u64);
        Chunk {
            buf: vec![0u8; bytes],
            pool: Arc::clone(&self.inner),
        }
    }

    /// Number of chunks currently parked in the free list.
    pub fn free_chunks(&self) -> usize {
        self.inner.free.lock_recover().len()
    }

    /// Toggle recycling (ablation control).
    pub fn set_recycling(&self, on: bool) {
        self.inner.recycling.store(on, Ordering::Relaxed);
        if !on {
            self.inner.free.lock_recover().clear();
        }
    }

    /// Typed strip-buffer recycler bound to this pool's recycling mode
    /// and metrics. One per pass worker — see [`StripPool`].
    pub fn strip_pool(&self) -> StripPool {
        StripPool::new(
            self.inner.recycling.load(Ordering::Relaxed),
            Arc::clone(&self.inner.metrics),
        )
    }
}

// ---------------------------------------------------------------------------
// Strip-register recycling (§III-B5 applied to the CPU-strip hot path)
// ---------------------------------------------------------------------------

/// Per-worker recycler for the strip evaluator's register buffers.
///
/// [`ChunkPool`] recycles the I/O-level byte chunks; `StripPool` is the
/// typed small-buffer arm of the same optimization. The compile-time
/// liveness plan in [`crate::exec::pipeline`] identifies dead registers;
/// their `Buf`s come back here and the next strip's acquisitions reuse
/// their capacity instead of hitting the allocator. It honors the same
/// `recycle_chunks` knob, so the Fig 11 "mem-alloc" ablation turns both
/// recyclers off together.
///
/// One pool per pass worker keeps the strip hot path lock-free; counters
/// accumulate locally and flush to the shared [`Metrics`] on drop.
pub struct StripPool {
    recycling: bool,
    /// Free buffers bucketed by dtype (see [`dtype_slot`]). Capacity is
    /// reused across strips regardless of length — `Buf::reset` resizes.
    free: [Vec<Buf>; 5],
    metrics: Arc<Metrics>,
    allocs: u64,
    reuses: u64,
    inplace: u64,
    spmm_strips: u64,
    spmm_nnz: u64,
    simd_strips: u64,
    simd_lanes_f64: u64,
    gemm_panels: u64,
}

fn dtype_slot(dt: DType) -> usize {
    match dt {
        DType::Bool => 0,
        DType::I32 => 1,
        DType::I64 => 2,
        DType::F32 => 3,
        DType::F64 => 4,
    }
}

impl StripPool {
    /// A pool recycling (or not) into per-dtype free lists. Use
    /// [`ChunkPool::strip_pool`] to inherit an engine's recycling mode.
    pub fn new(recycling: bool, metrics: Arc<Metrics>) -> StripPool {
        StripPool {
            recycling,
            free: Default::default(),
            metrics,
            allocs: 0,
            reuses: 0,
            inplace: 0,
            spmm_strips: 0,
            spmm_nnz: 0,
            simd_strips: 0,
            simd_lanes_f64: 0,
            gemm_panels: 0,
        }
    }

    /// Zeroed buffer of `len` elements — recycled capacity when available.
    pub fn acquire(&mut self, dtype: DType, len: usize) -> Buf {
        if self.recycling {
            if let Some(mut b) = self.free[dtype_slot(dtype)].pop() {
                b.reset(len);
                self.reuses += 1;
                return b;
            }
        }
        self.allocs += 1;
        Buf::alloc(dtype, len)
    }

    /// Return a dead register's buffer for reuse. Drops it when recycling
    /// is off (the Fig 11 unoptimized mode); empty placeholder buffers
    /// (already-moved registers) are ignored.
    pub fn release(&mut self, b: Buf) {
        if self.recycling && !b.is_empty() {
            self.free[dtype_slot(b.dtype())].push(b);
        }
    }

    /// Record a register buffer allocated outside the pool (a VUDF
    /// kernel's fresh output vector), so `buf_allocs` counts every
    /// register buffer created, pooled or not.
    pub fn count_alloc(&mut self) {
        self.allocs += 1;
    }

    /// Record an instruction executed in place on its input's buffer.
    pub fn count_inplace(&mut self) {
        self.inplace += 1;
    }

    /// Record one SpMM strip evaluation and the sparse entries it
    /// streamed (flushed to `Metrics::{spmm_strips, spmm_nnz}` on drop).
    pub fn count_spmm(&mut self, nnz: u64) {
        self.spmm_strips += 1;
        self.spmm_nnz += nnz;
    }

    /// Record a strip whose evaluation ran at least one explicit SIMD
    /// lane kernel or blocked GEMM panel (`Metrics::simd_strips`).
    pub fn count_simd_strip(&mut self) {
        self.simd_strips += 1;
    }

    /// Record full f64x4 lane groups processed by a hand-unrolled
    /// elementwise/fused-chain kernel (`Metrics::simd_lanes_f64`).
    pub fn count_simd_lanes_f64(&mut self, lanes: u64) {
        self.simd_lanes_f64 += lanes;
    }

    /// Record register-blocked GEMM panels (`Metrics::gemm_panels`).
    pub fn count_gemm_panels(&mut self, panels: u64) {
        self.gemm_panels += panels;
    }

    /// Total SIMD work recorded so far (lane groups + GEMM panels). The
    /// strip evaluator snapshots this around a strip to decide whether the
    /// strip counts toward `Metrics::simd_strips`.
    pub fn simd_work(&self) -> u64 {
        self.simd_lanes_f64 + self.gemm_panels
    }
}

impl Drop for StripPool {
    fn drop(&mut self) {
        self.metrics.buf_allocs.fetch_add(self.allocs, Ordering::Relaxed);
        self.metrics.buf_reuses.fetch_add(self.reuses, Ordering::Relaxed);
        self.metrics.inplace_ops.fetch_add(self.inplace, Ordering::Relaxed);
        self.metrics
            .spmm_strips
            .fetch_add(self.spmm_strips, Ordering::Relaxed);
        self.metrics.spmm_nnz.fetch_add(self.spmm_nnz, Ordering::Relaxed);
        self.metrics
            .simd_strips
            .fetch_add(self.simd_strips, Ordering::Relaxed);
        self.metrics
            .simd_lanes_f64
            .fetch_add(self.simd_lanes_f64, Ordering::Relaxed);
        self.metrics
            .gemm_panels
            .fetch_add(self.gemm_panels, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(recycle: bool) -> (ChunkPool, Arc<Metrics>) {
        let m = Arc::new(Metrics::new());
        (ChunkPool::new(1024, recycle, Arc::clone(&m)), m)
    }

    #[test]
    fn recycles_chunks() {
        let (p, m) = pool(true);
        let c1 = p.acquire();
        drop(c1);
        assert_eq!(p.free_chunks(), 1);
        let _c2 = p.acquire();
        assert_eq!(p.free_chunks(), 0);
        let s = m.snapshot();
        assert_eq!(s.chunks_allocated, 1);
        assert_eq!(s.chunks_recycled, 1);
    }

    #[test]
    fn no_recycling_allocates_fresh() {
        let (p, m) = pool(false);
        drop(p.acquire());
        drop(p.acquire());
        assert_eq!(p.free_chunks(), 0);
        let s = m.snapshot();
        assert_eq!(s.chunks_allocated, 2);
        assert_eq!(s.chunks_recycled, 0);
    }

    #[test]
    fn accounting_balances() {
        let (p, m) = pool(true);
        {
            let _a = p.acquire();
            let _b = p.acquire_sized(100);
            assert_eq!(m.snapshot().mem_in_use, 1124);
        }
        assert_eq!(m.snapshot().mem_in_use, 0);
        assert_eq!(m.snapshot().mem_peak, 1124);
    }

    #[test]
    fn odd_sized_chunks_not_recycled() {
        let (p, _m) = pool(true);
        drop(p.acquire_sized(77));
        assert_eq!(p.free_chunks(), 0);
    }

    #[test]
    fn strip_pool_recycles_and_counts() {
        let (p, m) = pool(true);
        {
            let mut sp = p.strip_pool();
            let b = sp.acquire(DType::F64, 8);
            sp.release(b);
            // reuse shrinks/zeroes to the requested length
            let b2 = sp.acquire(DType::F64, 4);
            assert_eq!(b2.len(), 4);
            assert_eq!(b2.to_f64_vec(), vec![0.0; 4]);
            // a different dtype misses the f64 bucket
            let c = sp.acquire(DType::I32, 2);
            sp.release(c);
        }
        let s = m.snapshot();
        assert_eq!(s.buf_allocs, 2);
        assert_eq!(s.buf_reuses, 1);
    }

    #[test]
    fn strip_pool_off_never_reuses() {
        let (p, m) = pool(false);
        {
            let mut sp = p.strip_pool();
            let b = sp.acquire(DType::F64, 8);
            sp.release(b);
            let b2 = sp.acquire(DType::F64, 8);
            sp.release(b2);
        }
        let s = m.snapshot();
        assert_eq!(s.buf_allocs, 2);
        assert_eq!(s.buf_reuses, 0);
    }
}
