//! Test-only helpers shared across unit-test modules.

use std::path::{Path, PathBuf};

/// Unique self-cleaning temp dir: removed on drop, so tests stay
/// panic-safe and leave no litter behind.
pub struct TempDir(PathBuf);

impl TempDir {
    pub fn new(tag: &str) -> TempDir {
        let p = std::env::temp_dir().join(format!(
            "fm-{tag}-{}-{:x}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&p).unwrap();
        TempDir(p)
    }

    pub fn path(&self) -> &Path {
        &self.0
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}
