//! Test-only helpers shared by unit tests, integration tests and benches.
//!
//! Compiled into the library (not `#[cfg(test)]`) so `rust/tests/*.rs`
//! can reuse them; nothing here is part of the engine proper.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::config::{EngineConfig, StorageKind, ThrottleConfig};
use crate::fmr::Engine;

/// Unique self-cleaning temp dir: removed on drop, so tests stay
/// panic-safe and leave no litter behind.
pub struct TempDir(PathBuf);

impl TempDir {
    pub fn new(tag: &str) -> TempDir {
        let p = std::env::temp_dir().join(format!(
            "fm-{tag}-{}-{:x}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&p).unwrap();
        TempDir(p)
    }

    pub fn path(&self) -> &Path {
        &self.0
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// Whether the dedicated out-of-core CI job is running
/// (`FLASHR_TEST_EM=1 cargo test`): the EM leg of
/// [`rerun_out_of_core`] then adds a deterministic bandwidth throttle so
/// the simulated-SSD path is exercised too, not only the file reads.
pub fn em_forcing_enabled() -> bool {
    crate::config::env_flag("FLASHR_TEST_EM").unwrap_or(false)
}

/// Configuration that *forces* the out-of-core machinery even at test
/// scale: external storage with a partition cache that holds roughly ONE
/// io-level partition, so any multi-partition scan misses, evicts and
/// re-reads — the EM read path, cache replacement and read-ahead all run
/// inside `cargo test` instead of only inside benches.
///
/// Sizing note: io-partition sizes come from the **pinned** formula in
/// `matrix/partition.rs` (8 MiB target, 1024–65536 rows), NOT from
/// `target_part_bytes` — a matrix with ≤ 8 columns has 4 MiB full
/// partitions, so a 4 MiB cache admits exactly one and must evict it for
/// the next. Callers should keep forcing datasets at ≤ 8 columns; wider
/// matrices (larger partitions) degrade to the never-admitted bypass
/// path, which is still an EM read but exercises no replacement.
pub fn out_of_core_config(data_dir: &Path) -> EngineConfig {
    EngineConfig {
        storage: StorageKind::External,
        data_dir: data_dir.to_path_buf(),
        chunk_bytes: 4 << 20,
        target_part_bytes: 1 << 20,
        em_cache_bytes: 4 << 20, // one full 8-column io partition
        prefetch_depth: 2,
        xla_dispatch: false,
        throttle: em_forcing_enabled().then_some(ThrottleConfig {
            read_bytes_per_sec: 512 << 20,
            write_bytes_per_sec: 512 << 20,
        }),
        ..EngineConfig::default()
    }
}

/// Run `f` under the fully-optimized in-memory engine, then re-run it
/// under the tiny-cache out-of-core engine, asserting the EM leg really
/// left memory (file reads happened and the one-partition cache missed).
/// Returns `(in_memory_result, out_of_core_result)` for the caller's
/// parity assertion.
pub fn rerun_out_of_core<T>(tag: &str, f: impl Fn(&Arc<Engine>) -> T) -> (T, T) {
    let im_cfg = EngineConfig {
        chunk_bytes: 4 << 20,
        target_part_bytes: 1 << 20,
        xla_dispatch: false,
        ..EngineConfig::default()
    };
    let im = f(&Engine::new(im_cfg).expect("in-memory engine"));

    let dir = TempDir::new(&format!("ooc-{tag}"));
    let eng = Engine::new(out_of_core_config(dir.path())).expect("out-of-core engine");
    let em = f(&eng);
    let m = eng.metrics.snapshot();
    assert!(
        m.io_read_bytes > 0,
        "{tag}: out-of-core leg never read the external store"
    );
    assert!(
        m.cache_misses > 0,
        "{tag}: the single-partition cache never missed — workload too small \
         to exercise the EM path"
    );
    assert!(
        m.cache_evictions > 0,
        "{tag}: no cache replacement happened — dataset partitions were \
         either fully resident or too large to admit (keep forcing \
         datasets at ≤ 8 columns and > 1 io partition)"
    );
    (im, em)
}

/// Cross-pass-optimizer parity battery: run `f` under matched engine
/// pairs that differ ONLY in [`EngineConfig::cross_pass_opt`], across
/// storage (IM / tiny-cache EM) × `vectorized_udf` × `simd_kernels`.
/// Returns one `(label, opt_on, opt_off)` row per combination for the
/// caller's bitwise assertion: the planner may only drop or share whole
/// redundant evaluations, never change any single output's fold order,
/// so every pair must match exactly — no tolerance.
pub fn rerun_opt_ablation<T>(tag: &str, f: impl Fn(&Arc<Engine>) -> T) -> Vec<(String, T, T)> {
    let mut rows = Vec::new();
    for em in [false, true] {
        for vudf in [false, true] {
            for simd in [false, true] {
                let label = format!(
                    "{}/{}/{}",
                    if em { "em" } else { "im" },
                    if vudf { "vudf" } else { "boxed" },
                    if simd { "simd" } else { "scalar" }
                );
                let run = |opt: bool| {
                    // fresh store per engine so the EM legs never share files
                    let dir = em.then(|| TempDir::new(&format!("xpass-{tag}")));
                    let mut cfg = match &dir {
                        Some(d) => out_of_core_config(d.path()),
                        None => EngineConfig {
                            chunk_bytes: 4 << 20,
                            target_part_bytes: 1 << 20,
                            xla_dispatch: false,
                            ..EngineConfig::default()
                        },
                    };
                    cfg.vectorized_udf = vudf;
                    cfg.simd_kernels = simd;
                    cfg.cross_pass_opt = opt;
                    // sink partials merge in worker-completion order, so
                    // bitwise comparisons are only meaningful at 1 thread
                    // (same restriction as the spmm_pagerank bit-exactness
                    // pins) — the planner parity claim is orthogonal to it
                    cfg.threads = 1;
                    f(&Engine::new(cfg).expect("opt-ablation engine"))
                };
                rows.push((label, run(true), run(false)));
            }
        }
    }
    rows
}
