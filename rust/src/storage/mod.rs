//! SAFS-like external-memory storage (paper §III, [32]).
//!
//! The paper stores large matrices on a 24-SSD array through SAFS, a
//! user-space filesystem that streams data at the array's aggregate
//! bandwidth and deliberately bypasses the page cache (streaming a matrix
//! would only evict useful pages, §III-B3). We reproduce the *behaviour*
//! on a single local disk:
//!
//! * [`FileStore`] — one file per matrix; reads/writes whole I/O-level
//!   partitions with positioned I/O (`pread`/`pwrite`), no mmap, no
//!   reliance on page-cache reuse.
//! * [`TokenBucket`] — a deterministic bandwidth throttle so experiments
//!   can impose the paper's DRAM:SSD speed *ratio* (~10x) regardless of
//!   what the local disk actually does (DESIGN.md §Substitutions).
//! * [`StreamReader`] — bounded-queue read-ahead (backpressure included)
//!   for sequential scans; with depth 2 it double-buffers a scan so the
//!   next partition's read overlaps the current partition's compute.
//!
//! The explicit *matrix cache* of §III-B3 lives in
//! [`crate::matrix::cache::PartitionCache`], layered on top of this store:
//! reads consult it before issuing a `pread` here, its prefetch thread
//! issues the asynchronous read-ahead for out-of-core passes, and its
//! write-back writer thread is the store's write-side mirror — pass
//! workers queue finished target partitions there and this store's
//! (throttled) [`FileStore::write_at`] runs on the writer thread, so the
//! paper's overlap of computation with I/O holds in *both* directions.
//! [`FileStore`] I/O is positioned and stateless (`pread`/`pwrite`), so
//! demand reads, the prefetch thread and the write-back writer can all
//! touch one store concurrently without coordination. See
//! `docs/ARCHITECTURE.md` for the full paper-section-to-module map.

pub mod fault;
pub mod throttle;

pub use fault::{crc32, ChecksumTable, FaultConfig, FaultPlan};
pub use throttle::TokenBucket;

use std::fs::{File, OpenOptions};
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::Arc;

use crate::config::ThrottleConfig;
use crate::error::{FmError, Result};
use crate::metrics::Metrics;

use fault::{Injection, Op};

/// Simulated SSD-array model shared by every [`FileStore`] of an engine:
/// the bandwidth buckets (`None` = raw disk speed) plus the engine-wide
/// I/O *policy* — the deterministic fault plan, the transient-retry
/// budget and the partition-checksum switch — so stores created anywhere
/// in the engine inherit one consistent failure model.
pub struct SsdSim {
    read_bucket: Option<TokenBucket>,
    write_bucket: Option<TokenBucket>,
    faults: Option<FaultPlan>,
    retry_limit: u32,
    checksums: bool,
}

impl SsdSim {
    /// Throttle-only simulator with the default tolerance policy
    /// (checksums on, 3 retries, no injected faults).
    pub fn new(cfg: Option<&ThrottleConfig>) -> Self {
        Self::with_policy(cfg, None, 3, true)
    }

    /// Full policy constructor ([`crate::fmr::Engine`] feeds this from
    /// `EngineConfig::{throttle, fault_injection, io_retry_limit,
    /// io_checksums}`).
    pub fn with_policy(
        cfg: Option<&ThrottleConfig>,
        faults: Option<FaultConfig>,
        retry_limit: u32,
        checksums: bool,
    ) -> Self {
        SsdSim {
            read_bucket: cfg.map(|c| TokenBucket::new(c.read_bytes_per_sec)),
            write_bucket: cfg.map(|c| TokenBucket::new(c.write_bytes_per_sec)),
            faults: faults.map(FaultPlan::new),
            retry_limit,
            checksums,
        }
    }

    /// The engine's fault schedule, if chaos is configured.
    pub fn fault_plan(&self) -> Option<&FaultPlan> {
        self.faults.as_ref()
    }

    /// Whether partition checksums are recorded/verified.
    pub fn checksums_enabled(&self) -> bool {
        self.checksums
    }

    /// Max retries after a transient I/O failure (per positioned op).
    pub fn retry_limit(&self) -> u32 {
        self.retry_limit
    }

    fn charge_read(&self, bytes: u64) {
        if let Some(b) = &self.read_bucket {
            b.take(bytes);
        }
    }

    fn charge_write(&self, bytes: u64) {
        if let Some(b) = &self.write_bucket {
            b.take(bytes);
        }
    }

    /// Drain both buckets' standing one-second burst
    /// ([`TokenBucket::drain`]): benches call this right before their
    /// timed region so every byte of the measured workload pays the
    /// configured rate — deterministic wall-times, which is what lets CI
    /// gate them (`python/bench_gate.py`). No-op without a throttle.
    pub fn drain_bursts(&self) {
        if let Some(b) = &self.read_bucket {
            b.drain();
        }
        if let Some(b) = &self.write_bucket {
            b.drain();
        }
    }
}

/// Monotonic id for unnamed external matrices.
static NEXT_FILE_ID: AtomicU64 = AtomicU64::new(0);

/// Stable fault-site namespace for a store: named datasets hash their
/// file name (so a reopened dataset keeps its schedule), anonymous
/// intermediates embed a unique id in theirs (fresh sites per target
/// file, which is what lets a *retried* pass write clean partitions).
fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// One external-memory matrix file.
pub struct FileStore {
    path: PathBuf,
    file: File,
    len: u64,
    ssd: Arc<SsdSim>,
    metrics: Arc<Metrics>,
    /// Fault-site namespace (hash of the file name).
    ns: u64,
    /// Expected CRC32 per written partition; verified on every
    /// exactly-matching read when the policy enables checksums.
    crcs: ChecksumTable,
    /// Delete the backing file when the store is dropped (anonymous
    /// intermediates; named datasets are kept).
    unlink_on_drop: bool,
}

impl FileStore {
    /// Create (or truncate) a store of `len` bytes under `dir`.
    pub fn create(
        dir: &Path,
        name: Option<&str>,
        len: u64,
        ssd: Arc<SsdSim>,
        metrics: Arc<Metrics>,
    ) -> Result<FileStore> {
        std::fs::create_dir_all(dir)?;
        let (fname, unlink) = match name {
            Some(n) => (n.to_string(), false),
            None => (
                format!(
                    "fm-anon-{}-{}.mat",
                    std::process::id(),
                    NEXT_FILE_ID.fetch_add(1, Ordering::Relaxed)
                ),
                true,
            ),
        };
        let path = dir.join(fname);
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)?;
        file.set_len(len)?;
        let ns = fnv1a(&path.file_name().unwrap_or_default().to_string_lossy());
        Ok(FileStore {
            path,
            file,
            len,
            ssd,
            metrics,
            ns,
            crcs: ChecksumTable::new(),
            unlink_on_drop: unlink,
        })
    }

    /// Open an existing matrix file read-write.
    pub fn open(path: &Path, ssd: Arc<SsdSim>, metrics: Arc<Metrics>) -> Result<FileStore> {
        let file = OpenOptions::new().read(true).write(true).open(path)?;
        let len = file.metadata()?.len();
        let ns = fnv1a(&path.file_name().unwrap_or_default().to_string_lossy());
        Ok(FileStore {
            path: path.to_path_buf(),
            file,
            len,
            ssd,
            metrics,
            ns,
            crcs: ChecksumTable::new(),
            unlink_on_drop: false,
        })
    }

    pub fn len(&self) -> u64 {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// The store's checksum table (sidecar persistence for named
    /// datasets; tests).
    pub fn checksums(&self) -> &ChecksumTable {
        &self.crcs
    }

    /// Whether a transient failure of one attempt is worth another try.
    fn retryable(e: &FmError) -> bool {
        matches!(e, FmError::Io(_))
    }

    /// Short exponential backoff between retries of one positioned op.
    fn backoff(attempt: u32) {
        std::thread::sleep(std::time::Duration::from_micros(50 << attempt.min(6)));
    }

    /// One physical read attempt: fault pre-hook, throttle charge, pread,
    /// payload-corruption post-hook.
    fn read_attempt(&self, off: u64, buf: &mut [u8]) -> Result<()> {
        let flip = match self.ssd.fault_plan() {
            Some(plan) => match plan.draw(self.ns, Op::Read, off, buf.len(), &self.metrics) {
                Some(Injection::Fail(e)) => return Err(e),
                Some(Injection::FlipBit { byte, bit }) => Some((byte, bit)),
                _ => None,
            },
            None => None,
        };
        self.ssd.charge_read(buf.len() as u64);
        self.file.read_exact_at(buf, off)?;
        if let Some((byte, bit)) = flip {
            if !buf.is_empty() {
                buf[byte] ^= 1 << bit;
            }
        }
        Ok(())
    }

    /// Read exactly `buf.len()` bytes at `off` (one I/O-level partition).
    ///
    /// Tolerance: transient failures (real or injected `EIO`/short reads)
    /// are retried up to [`SsdSim::retry_limit`] times with backoff
    /// (`Metrics::io_retries`); when a partition checksum is on record
    /// for exactly `(off, len)`, the payload is verified and a mismatch
    /// triggers **one** re-read before surfacing [`FmError::Corrupt`]
    /// (`Metrics::checksum_failures`).
    pub fn read_at(&self, off: u64, buf: &mut [u8]) -> Result<()> {
        if off + buf.len() as u64 > self.len {
            return Err(FmError::Storage(format!(
                "read past end: off={off} len={} file={}",
                buf.len(),
                self.len
            )));
        }
        let mut io_attempt = 0u32;
        let mut reread_after_mismatch = false;
        loop {
            match self.read_attempt(off, buf) {
                Ok(()) => {
                    let want = self
                        .ssd
                        .checksums_enabled()
                        .then(|| self.crcs.expected(off, buf.len()))
                        .flatten();
                    if let Some(want) = want {
                        let got = crc32(buf);
                        if got != want {
                            self.metrics
                                .checksum_failures
                                .fetch_add(1, Ordering::Relaxed);
                            if !reread_after_mismatch {
                                reread_after_mismatch = true;
                                continue;
                            }
                            return Err(FmError::Corrupt(format!(
                                "partition checksum mismatch at off={off} len={} \
                                 (want {want:#010x}, got {got:#010x}) in {} after re-read",
                                buf.len(),
                                self.path.display()
                            )));
                        }
                    }
                    self.metrics.add_read(buf.len() as u64);
                    return Ok(());
                }
                Err(e) if Self::retryable(&e) && io_attempt < self.ssd.retry_limit() => {
                    io_attempt += 1;
                    self.metrics.io_retries.fetch_add(1, Ordering::Relaxed);
                    Self::backoff(io_attempt);
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// One physical write attempt. A torn-write injection persists only a
    /// prefix yet "succeeds", so under an active fault plan every attempt
    /// is read back raw (no throttle/injection — a verification probe,
    /// not modeled I/O) and a mismatch is surfaced for the retry loop;
    /// fault-free runs skip the probe so checksums cost no extra I/O.
    fn write_attempt(&self, off: u64, buf: &[u8]) -> Result<()> {
        let mut persist = buf.len();
        if let Some(plan) = self.ssd.fault_plan() {
            match plan.draw(self.ns, Op::Write, off, buf.len(), &self.metrics) {
                Some(Injection::Fail(e)) => return Err(e),
                Some(Injection::Truncate(n)) => persist = n.min(buf.len()),
                _ => {}
            }
        }
        self.ssd.charge_write(buf.len() as u64);
        self.file.write_all_at(&buf[..persist], off)?;
        if self.ssd.fault_plan().is_some() {
            let mut back = vec![0u8; buf.len()];
            self.file.read_exact_at(&mut back, off)?;
            if back != buf {
                return Err(FmError::Corrupt(format!(
                    "write read-back mismatch at off={off} len={} in {} (torn write)",
                    buf.len(),
                    self.path.display()
                )));
            }
        }
        Ok(())
    }

    /// Write `buf` at `off`. Positioned and thread-safe like
    /// [`read_at`](Self::read_at); under write-back this runs on the
    /// cache's background writer thread, which is where the throttled
    /// write cost is paid while pass workers keep computing.
    ///
    /// Tolerance mirrors the read side: transient failures and torn
    /// writes (caught by the read-back probe) are retried with backoff;
    /// a successful write records the partition's CRC32 for later read
    /// verification. A tear that survives every retry surfaces as
    /// [`FmError::Corrupt`].
    pub fn write_at(&self, off: u64, buf: &[u8]) -> Result<()> {
        if off + buf.len() as u64 > self.len {
            return Err(FmError::Storage(format!(
                "write past end: off={off} len={} file={}",
                buf.len(),
                self.len
            )));
        }
        let mut attempt = 0u32;
        loop {
            match self.write_attempt(off, buf) {
                Ok(()) => {
                    if self.ssd.checksums_enabled() {
                        self.crcs.record(off, buf.len(), crc32(buf));
                    }
                    self.metrics.add_write(buf.len() as u64);
                    return Ok(());
                }
                // a torn write (Corrupt from the read-back probe) is as
                // retryable as an EIO at this layer: the data is still in
                // hand, so rewriting can heal it
                Err(e)
                    if (Self::retryable(&e) || matches!(e, FmError::Corrupt(_)))
                        && attempt < self.ssd.retry_limit() =>
                {
                    attempt += 1;
                    self.metrics.io_retries.fetch_add(1, Ordering::Relaxed);
                    Self::backoff(attempt);
                }
                Err(e) => return Err(e),
            }
        }
    }
}

impl Drop for FileStore {
    fn drop(&mut self) {
        if self.unlink_on_drop {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

/// Bounded read-ahead for a sequential scan over byte ranges.
///
/// A background thread reads ranges in order into a bounded queue (depth =
/// backpressure: the reader blocks when the consumer falls behind, so read-
/// ahead memory stays bounded — the paper's streaming I/O discipline).
pub struct StreamReader {
    rx: Receiver<Result<Vec<u8>>>,
}

impl StreamReader {
    pub fn new(store: Arc<FileStore>, ranges: Vec<(u64, usize)>, depth: usize) -> StreamReader {
        let (tx, rx) = sync_channel(depth.max(1));
        std::thread::spawn(move || {
            for (off, len) in ranges {
                let mut buf = vec![0u8; len];
                let r = store.read_at(off, &mut buf).map(|()| buf);
                if tx.send(r).is_err() {
                    break; // consumer dropped
                }
            }
        });
        StreamReader { rx }
    }

    /// Next partition's bytes, in submission order.
    pub fn next(&self) -> Option<Result<Vec<u8>>> {
        self.rx.recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(len: u64) -> (FileStore, tempdir::TempDir) {
        let dir = tempdir::TempDir::new();
        let ssd = Arc::new(SsdSim::new(None));
        let m = Arc::new(Metrics::new());
        let s = FileStore::create(dir.path(), None, len, ssd, m).unwrap();
        (s, dir)
    }

    /// Minimal self-cleaning temp dir (avoid external dev-deps).
    mod tempdir {
        use std::path::{Path, PathBuf};
        pub struct TempDir(PathBuf);
        impl TempDir {
            pub fn new() -> TempDir {
                let p = std::env::temp_dir().join(format!(
                    "fm-test-{}-{:x}",
                    std::process::id(),
                    std::time::SystemTime::now()
                        .duration_since(std::time::UNIX_EPOCH)
                        .unwrap()
                        .as_nanos()
                ));
                std::fs::create_dir_all(&p).unwrap();
                TempDir(p)
            }
            pub fn path(&self) -> &Path {
                &self.0
            }
        }
        impl Drop for TempDir {
            fn drop(&mut self) {
                let _ = std::fs::remove_dir_all(&self.0);
            }
        }
    }

    #[test]
    fn roundtrip() {
        let (s, _d) = mk(64);
        s.write_at(8, &[1, 2, 3, 4]).unwrap();
        let mut buf = [0u8; 4];
        s.read_at(8, &mut buf).unwrap();
        assert_eq!(buf, [1, 2, 3, 4]);
    }

    #[test]
    fn bounds_checked() {
        let (s, _d) = mk(16);
        let mut buf = [0u8; 8];
        assert!(s.read_at(12, &mut buf).is_err());
        assert!(s.write_at(12, &buf).is_err());
    }

    #[test]
    fn anon_file_removed_on_drop() {
        let (s, _d) = mk(16);
        let p = s.path().to_path_buf();
        assert!(p.exists());
        drop(s);
        assert!(!p.exists());
    }

    #[test]
    fn stream_reader_in_order() {
        let (s, _d) = mk(32);
        for i in 0..32u8 {
            s.write_at(i as u64, &[i]).unwrap();
        }
        let s = Arc::new(s);
        let ranges = vec![(0u64, 8usize), (8, 8), (16, 8), (24, 8)];
        let r = StreamReader::new(Arc::clone(&s), ranges, 2);
        let mut seen = Vec::new();
        while let Some(b) = r.next() {
            seen.extend(b.unwrap());
        }
        assert_eq!(seen, (0..32u8).collect::<Vec<_>>());
    }

    #[test]
    fn unaligned_offsets_roundtrip() {
        let (s, _d) = mk(4096 + 7);
        // a write at an odd offset spanning a typical block boundary
        let pat: Vec<u8> = (0..997u32).map(|i| (i * 31 % 251) as u8).collect();
        s.write_at(3, &pat).unwrap();
        s.write_at(4093, &[9, 8, 7, 6]).unwrap();
        let mut back = vec![0u8; 997];
        s.read_at(3, &mut back).unwrap();
        assert_eq!(back, pat);
        // re-read the tail with a different split than it was written with
        let mut tail = vec![0u8; 6];
        s.read_at(4091, &mut tail).unwrap();
        assert_eq!(&tail[..2], &[0, 0], "untouched bytes stay zero");
        assert_eq!(&tail[2..], &[9, 8, 7, 6]);
        // exact end-of-file read at an unaligned offset is still in bounds
        let mut last = [0u8; 1];
        s.read_at(4102, &mut last).unwrap();
    }

    #[test]
    fn stream_reader_backpressure_under_slow_consumer() {
        let dir = tempdir::TempDir::new();
        let ssd = Arc::new(SsdSim::new(None));
        let m = Arc::new(Metrics::new());
        let s = Arc::new(FileStore::create(dir.path(), None, 64, ssd, Arc::clone(&m)).unwrap());
        s.write_at(0, &(0..64u8).collect::<Vec<_>>()).unwrap();
        m.reset();
        let ranges: Vec<(u64, usize)> = (0..16u64).map(|i| (i * 4, 4usize)).collect();
        let depth = 2;
        let r = StreamReader::new(Arc::clone(&s), ranges, depth);
        // consume slowly; the producer can run at most `depth` queued
        // reads plus one blocked-in-send read ahead of the consumer
        let mut seen = Vec::new();
        for consumed in 1..=16usize {
            let b = r.next().unwrap().unwrap();
            seen.extend(b);
            std::thread::sleep(std::time::Duration::from_millis(3));
            let reqs = m.snapshot().io_read_reqs as usize;
            assert!(
                reqs <= consumed + depth + 1,
                "producer ran ahead of backpressure: {reqs} reads after {consumed} consumed"
            );
        }
        // ordering: the slow consumer still sees submission order
        assert_eq!(seen, (0..64u8).collect::<Vec<_>>());
        assert!(r.next().is_none());
    }

    fn mk_faulty(
        len: u64,
        cfg: FaultConfig,
        retry_limit: u32,
    ) -> (FileStore, Arc<Metrics>, tempdir::TempDir) {
        let dir = tempdir::TempDir::new();
        let ssd = Arc::new(SsdSim::with_policy(None, Some(cfg), retry_limit, true));
        let m = Arc::new(Metrics::new());
        let s = FileStore::create(dir.path(), None, len, ssd, Arc::clone(&m)).unwrap();
        (s, m, dir)
    }

    #[test]
    fn transient_eio_absorbed_with_pinned_retry_counts() {
        // every site fails exactly its first attempt (max_duration=1)
        let cfg = FaultConfig {
            eio: 1.0,
            max_duration: 1,
            ..FaultConfig::default()
        };
        let (s, m, _d) = mk_faulty(64, cfg, 3);
        s.write_at(0, &[7u8; 64]).unwrap();
        let mut buf = [0u8; 64];
        s.read_at(0, &mut buf).unwrap();
        assert_eq!(buf, [7u8; 64]);
        let snap = m.snapshot();
        // one injected failure per op, each absorbed by exactly one retry
        assert_eq!(snap.io_retries, 2, "write retry + read retry");
        assert_eq!(snap.faults_injected, 2);
        assert_eq!(snap.checksum_failures, 0);
    }

    #[test]
    fn persistent_eio_exhausts_retries_into_typed_error() {
        let cfg = FaultConfig {
            eio: 1.0,
            persistent: 1.0,
            ..FaultConfig::default()
        };
        let (s, m, _d) = mk_faulty(64, cfg, 2);
        let mut buf = [0u8; 64];
        let err = s.read_at(0, &mut buf).unwrap_err();
        assert!(matches!(err, FmError::Io(_)), "typed error, not a panic: {err}");
        assert_eq!(m.snapshot().io_retries, 2, "budget spent exactly");
    }

    #[test]
    fn torn_write_caught_by_readback_and_healed() {
        let cfg = FaultConfig {
            torn_write: 1.0,
            max_duration: 1,
            ..FaultConfig::default()
        };
        let (s, m, _d) = mk_faulty(4096, cfg, 3);
        let pat: Vec<u8> = (0..4096u32).map(|i| (i % 251) as u8).collect();
        s.write_at(0, &pat).unwrap();
        let mut back = vec![0u8; 4096];
        s.read_at(0, &mut back).unwrap();
        assert_eq!(back, pat, "healed write persisted the full partition");
        let snap = m.snapshot();
        assert!(snap.io_retries >= 1, "tear was caught and retried");
        assert!(snap.faults_injected >= 1);
    }

    #[test]
    fn out_of_band_corruption_surfaces_corrupt_after_one_reread() {
        let (s, _d) = mk(64);
        let m = Arc::clone(&s.metrics);
        s.write_at(0, &[5u8; 64]).unwrap();
        // corrupt the file behind the store's back (no fault plan: this
        // models real silent media corruption)
        {
            let f = OpenOptions::new().write(true).open(s.path()).unwrap();
            f.write_all_at(&[6u8], 10).unwrap();
        }
        let mut buf = [0u8; 64];
        let err = s.read_at(0, &mut buf).unwrap_err();
        assert!(matches!(err, FmError::Corrupt(_)), "got: {err}");
        // first verify fails, the single re-read fails again => 2
        assert_eq!(m.snapshot().checksum_failures, 2);
        // partial reads have no recorded checksum => still served
        let mut half = [0u8; 32];
        s.read_at(16, &mut half).unwrap();
    }

    #[test]
    fn bitflip_read_healed_by_checksum_reread() {
        // bit flips on the first attempt of every read site, then heals:
        // the checksum catches it and the single re-read returns clean
        // bytes transparently
        let cfg = FaultConfig {
            bit_flip: 1.0,
            max_duration: 1,
            ..FaultConfig::default()
        };
        let (s, m, _d) = mk_faulty(64, cfg, 3);
        s.write_at(0, &[9u8; 64]).unwrap();
        let mut buf = [0u8; 64];
        s.read_at(0, &mut buf).unwrap();
        assert_eq!(buf, [9u8; 64]);
        let snap = m.snapshot();
        assert_eq!(snap.checksum_failures, 1, "one caught flip");
    }

    #[test]
    fn metrics_accumulate() {
        let dir = tempdir::TempDir::new();
        let ssd = Arc::new(SsdSim::new(None));
        let m = Arc::new(Metrics::new());
        let s = FileStore::create(dir.path(), None, 64, ssd, Arc::clone(&m)).unwrap();
        s.write_at(0, &[0u8; 64]).unwrap();
        let mut b = [0u8; 32];
        s.read_at(0, &mut b).unwrap();
        let snap = m.snapshot();
        assert_eq!(snap.io_write_bytes, 64);
        assert_eq!(snap.io_read_bytes, 32);
        assert_eq!(snap.io_read_reqs, 1);
    }
}
