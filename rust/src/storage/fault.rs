//! Deterministic I/O fault injection + partition checksums.
//!
//! The paper trusts SSDs as the backing store for billion-point EM passes
//! (§III, SAFS); this module extends the seeded-determinism discipline the
//! [`TokenBucket`](super::TokenBucket) throttle applies to *bandwidth*
//! (DESIGN.md §Substitutions) to *failures*, so the tolerance machinery in
//! [`FileStore`](super::FileStore) can be exercised reproducibly:
//!
//! * [`FaultPlan`] — a seeded (SplitMix64) schedule of injected faults.
//!   Every positioned I/O has a stable **site** — `(store namespace, op,
//!   offset)` — and the plan draws the site's fate once, purely from
//!   `(seed, site)`: which fault kind fires (transient/persistent `EIO`,
//!   short read, torn write, single-bit payload corruption, latency
//!   spike) and for how many attempts (its *duration*). Per-site attempt
//!   counters then accumulate **across retries and across passes**, so a
//!   schedule is deterministic regardless of thread interleaving, a
//!   fault with duration ≤ the retry budget is absorbed transparently,
//!   and one that outlives the budget aborts the pass but heals for the
//!   caller's retried pass — the abort/recover path is testable.
//! * [`ChecksumTable`] + [`crc32`] — per-partition CRC32 (hand-rolled
//!   slice-by-8 table; the crate is std-only) recorded on every write and
//!   verified on every exactly-matching read, persisted for named sparse
//!   datasets through the manifest sidecar.
//!
//! Configuration enters through [`crate::config::EngineConfig`]
//! (`fault_injection`, parsed from the `FLASHR_FAULTS` env spec by
//! default) and is carried by [`SsdSim`](super::SsdSim) so every store of
//! an engine shares one plan. Injections are counted in
//! [`Metrics::faults_injected`](crate::metrics::Metrics).

use std::collections::HashMap;
use std::sync::Mutex;

use crate::error::{FmError, Result};
use crate::exec::{splitmix64_at, u64_to_unit_f64};
use crate::metrics::Metrics;
use crate::util::sync::LockExt;

// ---------------------------------------------------------------------------
// CRC32 (IEEE, reflected) — hand-rolled slice-by-8 tables, std-only
// ---------------------------------------------------------------------------

const CRC_POLY: u32 = 0xEDB8_8320; // reflected IEEE 802.3 polynomial

const fn crc_tables() -> [[u32; 256]; 8] {
    let mut t = [[0u32; 256]; 8];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { (c >> 1) ^ CRC_POLY } else { c >> 1 };
            k += 1;
        }
        t[0][i] = c;
        i += 1;
    }
    let mut s = 1;
    while s < 8 {
        let mut i = 0;
        while i < 256 {
            let prev = t[s - 1][i];
            t[s][i] = t[0][(prev & 0xFF) as usize] ^ (prev >> 8);
            i += 1;
        }
        s += 1;
    }
    t
}

static CRC_TABLES: [[u32; 256]; 8] = crc_tables();

/// CRC32 (IEEE) of `data`. Slice-by-8: fast enough (> 1 GB/s) that the
/// checksum cost hides under the simulated-SSD token bucket's earned
/// tokens on throttled workloads — `benches/fault_overhead.rs` gates the
/// fault-free overhead at ≤ 5%.
pub fn crc32(data: &[u8]) -> u32 {
    let t = &CRC_TABLES;
    let mut crc = !0u32;
    let mut chunks = data.chunks_exact(8);
    for c in &mut chunks {
        let lo = u32::from_le_bytes([c[0], c[1], c[2], c[3]]) ^ crc;
        let hi = u32::from_le_bytes([c[4], c[5], c[6], c[7]]);
        crc = t[7][(lo & 0xFF) as usize]
            ^ t[6][((lo >> 8) & 0xFF) as usize]
            ^ t[5][((lo >> 16) & 0xFF) as usize]
            ^ t[4][(lo >> 24) as usize]
            ^ t[3][(hi & 0xFF) as usize]
            ^ t[2][((hi >> 8) & 0xFF) as usize]
            ^ t[1][((hi >> 16) & 0xFF) as usize]
            ^ t[0][(hi >> 24) as usize];
    }
    for &b in chunks.remainder() {
        crc = t[0][((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    !crc
}

// ---------------------------------------------------------------------------
// Per-store checksum table
// ---------------------------------------------------------------------------

/// Expected CRC32 of every partition written to one
/// [`FileStore`](super::FileStore), keyed by byte offset. Reads verify
/// only on an exact `(offset, len)` match, so partial reads (the dense
/// column cache) skip verification naturally instead of false-failing.
#[derive(Default)]
pub struct ChecksumTable {
    map: Mutex<HashMap<u64, (u32, usize)>>,
}

impl ChecksumTable {
    pub fn new() -> ChecksumTable {
        ChecksumTable::default()
    }

    /// Record the checksum of a successful write at `off`.
    pub fn record(&self, off: u64, len: usize, crc: u32) {
        self.map.lock_recover().insert(off, (crc, len));
    }

    /// Expected CRC for a read at `(off, len)`, if one partition was
    /// written there with exactly that length.
    pub fn expected(&self, off: u64, len: usize) -> Option<u32> {
        match self.map.lock_recover().get(&off) {
            Some((crc, l)) if *l == len => Some(*crc),
            _ => None,
        }
    }

    /// Number of recorded partitions (tests/benches).
    pub fn len(&self) -> usize {
        self.map.lock_recover().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// CRCs for `parts` in order (sidecar persistence; `None` for a
    /// partition never written through this store handle).
    pub fn export(&self, parts: &[(u64, usize)]) -> Vec<Option<u32>> {
        let m = self.map.lock_recover();
        parts
            .iter()
            .map(|(o, l)| match m.get(o) {
                Some((crc, len)) if len == l => Some(*crc),
                _ => None,
            })
            .collect()
    }

    /// Seed the table from a sidecar's persisted `(off, len, crc)` rows
    /// (reopening a named dataset).
    pub fn seed(&self, rows: impl IntoIterator<Item = (u64, usize, u32)>) {
        let mut m = self.map.lock_recover();
        for (off, len, crc) in rows {
            m.insert(off, (crc, len));
        }
    }
}

// ---------------------------------------------------------------------------
// Fault configuration
// ---------------------------------------------------------------------------

/// Deterministic fault-injection schedule parameters
/// ([`crate::config::EngineConfig::fault_injection`]). Probabilities are
/// per *site* — one positioned-I/O `(store, op, offset)` — not per
/// attempt: a site either never faults or faults for its whole drawn
/// duration, which is what makes retry/abort behaviour reproducible.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultConfig {
    /// Seed of the SplitMix64 schedule (same discipline as `datasets`).
    pub seed: u64,
    /// P(site returns `EIO`) — reads and writes.
    pub eio: f64,
    /// P(read site short-reads) — surfaces as a retryable
    /// `UnexpectedEof`.
    pub short_read: f64,
    /// P(write site tears) — only a prefix of the partition persists and
    /// the write *reports success*; caught by the write-side read-back
    /// verify, or by the partition checksum on a later read.
    pub torn_write: f64,
    /// P(read site flips one payload bit) — silent corruption; caught by
    /// the partition checksum.
    pub bit_flip: f64,
    /// P(site stalls for [`latency_ms`](Self::latency_ms)) — reads and
    /// writes; the op still succeeds.
    pub latency: f64,
    /// Stall length for latency-spike sites.
    pub latency_ms: u64,
    /// P(a faulting site is *persistent* — never heals). Everything else
    /// is transient with a drawn duration.
    pub persistent: f64,
    /// Transient fault duration ceiling in attempts: each transient site
    /// fails its first `1..=max_duration` attempts (drawn per site), then
    /// heals. Durations ≤ the retry budget are absorbed transparently;
    /// longer ones abort the pass but heal for a retried pass.
    pub max_duration: u32,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            seed: 0x5EED,
            eio: 0.0,
            short_read: 0.0,
            torn_write: 0.0,
            bit_flip: 0.0,
            latency: 0.0,
            latency_ms: 1,
            persistent: 0.0,
            max_duration: 2,
        }
    }
}

impl FaultConfig {
    /// Parse a `FLASHR_FAULTS` spec:
    /// `seed=42,eio=0.01,short=0.005,torn=0.005,bitflip=0.005,latency=0.001,latency_ms=2,persistent=0.0,max_duration=2`.
    /// Every key is optional; unknown keys are errors so typos don't
    /// silently disable chaos runs.
    pub fn parse(spec: &str) -> Result<FaultConfig> {
        let mut c = FaultConfig::default();
        for kv in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (k, v) = kv.split_once('=').ok_or_else(|| {
                FmError::Config(format!("fault spec '{kv}': expected key=value"))
            })?;
            let bad = |e: &dyn std::fmt::Display| {
                FmError::Config(format!("fault spec '{kv}': {e}"))
            };
            match k.trim() {
                "seed" => c.seed = v.trim().parse().map_err(|e| bad(&e))?,
                "eio" => c.eio = v.trim().parse().map_err(|e| bad(&e))?,
                "short" => c.short_read = v.trim().parse().map_err(|e| bad(&e))?,
                "torn" => c.torn_write = v.trim().parse().map_err(|e| bad(&e))?,
                "bitflip" => c.bit_flip = v.trim().parse().map_err(|e| bad(&e))?,
                "latency" => c.latency = v.trim().parse().map_err(|e| bad(&e))?,
                "latency_ms" => c.latency_ms = v.trim().parse().map_err(|e| bad(&e))?,
                "persistent" => c.persistent = v.trim().parse().map_err(|e| bad(&e))?,
                "max_duration" => c.max_duration = v.trim().parse().map_err(|e| bad(&e))?,
                other => {
                    return Err(FmError::Config(format!(
                        "fault spec: unknown key '{other}'"
                    )))
                }
            }
        }
        c.validate()?;
        Ok(c)
    }

    pub fn validate(&self) -> Result<()> {
        for (name, p) in [
            ("eio", self.eio),
            ("short", self.short_read),
            ("torn", self.torn_write),
            ("bitflip", self.bit_flip),
            ("latency", self.latency),
            ("persistent", self.persistent),
        ] {
            if !(0.0..=1.0).contains(&p) {
                return Err(FmError::Config(format!(
                    "fault probability {name}={p} outside [0,1]"
                )));
            }
        }
        if self.eio + self.short_read + self.bit_flip + self.latency > 1.0 {
            return Err(FmError::Config(
                "read fault probabilities (eio+short+bitflip+latency) sum past 1".into(),
            ));
        }
        if self.eio + self.torn_write + self.latency > 1.0 {
            return Err(FmError::Config(
                "write fault probabilities (eio+torn+latency) sum past 1".into(),
            ));
        }
        if self.max_duration == 0 {
            return Err(FmError::Config("max_duration must be > 0".into()));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Fault plan
// ---------------------------------------------------------------------------

/// Which way a site misbehaves (drawn once per site from the seed).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// Transient/persistent I/O error (retryable).
    Eio,
    /// Read returns fewer bytes than asked (retryable `UnexpectedEof`).
    ShortRead,
    /// Write persists only a prefix yet reports success (silent; caught
    /// by read-back verify / checksums).
    TornWrite,
    /// One payload bit flips on the way back (silent; caught by
    /// checksums).
    BitFlip,
    /// The op stalls but succeeds.
    Latency,
}

/// What [`FaultPlan::draw`] tells [`FileStore`](super::FileStore) to do to
/// the current attempt.
pub enum Injection {
    /// Fail the attempt with this (retryable) error.
    Fail(FmError),
    /// Persist/return only the first `n` bytes, report success.
    Truncate(usize),
    /// Flip bit `bit` of payload byte `byte` after a successful read.
    FlipBit { byte: usize, bit: u8 },
}

/// I/O direction of a site (part of the site key: a read and a write at
/// the same offset are independent sites).
#[derive(Clone, Copy)]
pub enum Op {
    Read,
    Write,
}

/// Seeded, site-keyed fault schedule shared by every store of an engine.
pub struct FaultPlan {
    cfg: FaultConfig,
    /// Attempts seen per faulting site — the only mutable state, and it
    /// only ever *advances*, so schedules are interleaving-independent.
    attempts: Mutex<HashMap<u64, u32>>,
}

impl FaultPlan {
    pub fn new(cfg: FaultConfig) -> FaultPlan {
        FaultPlan {
            cfg,
            attempts: Mutex::new(HashMap::new()),
        }
    }

    pub fn config(&self) -> &FaultConfig {
        &self.cfg
    }

    fn site_key(ns: u64, op: Op, off: u64) -> u64 {
        let op = match op {
            Op::Read => 0x52,
            Op::Write => 0x57,
        };
        // one SplitMix64 round mixes the triple into a well-spread key
        splitmix64_at(ns ^ (op as u64) << 56, off)
    }

    /// The site's drawn fate: `None` = never faults, else the kind and
    /// how many attempts it fails/affects before healing
    /// (`u32::MAX` = persistent).
    fn fate(&self, site: u64, op: Op) -> Option<(FaultKind, u32)> {
        let u = u64_to_unit_f64(splitmix64_at(self.cfg.seed, site));
        let c = &self.cfg;
        let mut lo = 0.0;
        let mut pick = None;
        let kinds: &[(FaultKind, f64)] = match op {
            Op::Read => &[
                (FaultKind::Eio, c.eio),
                (FaultKind::ShortRead, c.short_read),
                (FaultKind::BitFlip, c.bit_flip),
                (FaultKind::Latency, c.latency),
            ],
            Op::Write => &[
                (FaultKind::Eio, c.eio),
                (FaultKind::TornWrite, c.torn_write),
                (FaultKind::Latency, c.latency),
            ],
        };
        for &(kind, p) in kinds {
            if u >= lo && u < lo + p {
                pick = Some(kind);
                break;
            }
            lo += p;
        }
        let kind = pick?;
        let persistent =
            u64_to_unit_f64(splitmix64_at(self.cfg.seed ^ 0x9E3779B9, site)) < c.persistent;
        let duration = if persistent {
            u32::MAX
        } else {
            1 + (splitmix64_at(self.cfg.seed ^ 0x7F4A7C15, site) % c.max_duration as u64) as u32
        };
        Some((kind, duration))
    }

    /// Decide this attempt's injection for the positioned op
    /// `(ns, op, off)` over `len` payload bytes. Advances the site's
    /// attempt counter only while the site is still within its faulting
    /// duration, so healed sites cost one map probe and faultless sites
    /// only arithmetic.
    pub fn draw(&self, ns: u64, op: Op, off: u64, len: usize, metrics: &Metrics) -> Option<Injection> {
        let site = Self::site_key(ns, op, off);
        let (kind, duration) = self.fate(site, op)?;
        let attempt = {
            let mut m = self.attempts.lock_recover();
            let a = m.entry(site).or_insert(0);
            let cur = *a;
            if cur >= duration {
                return None; // healed
            }
            *a = a.saturating_add(1);
            cur
        };
        metrics
            .faults_injected
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        // per-attempt salt so e.g. the flipped bit moves between attempts
        let z = splitmix64_at(self.cfg.seed ^ site, attempt as u64);
        Some(match kind {
            FaultKind::Eio => Injection::Fail(FmError::Io(std::io::Error::new(
                std::io::ErrorKind::Other,
                format!("injected EIO (site {site:#x}, attempt {attempt})"),
            ))),
            FaultKind::ShortRead => Injection::Fail(FmError::Io(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                format!("injected short read (site {site:#x}, attempt {attempt})"),
            ))),
            FaultKind::TornWrite => Injection::Truncate(if len <= 1 {
                0
            } else {
                1 + (z % (len as u64 - 1)) as usize
            }),
            FaultKind::BitFlip => Injection::FlipBit {
                byte: if len == 0 { 0 } else { (z % len as u64) as usize },
                bit: (z >> 32) as u8 & 7,
            },
            FaultKind::Latency => {
                std::thread::sleep(std::time::Duration::from_millis(self.cfg.latency_ms));
                return None; // op proceeds normally after the stall
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // canonical IEEE CRC32 check values
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn crc32_slice_by_8_matches_bytewise() {
        // lengths straddling the 8-byte fast path + tail
        let data: Vec<u8> = (0..4099u32).map(|i| (i.wrapping_mul(2654435761) >> 13) as u8).collect();
        for take in [0, 1, 7, 8, 9, 64, 4099] {
            let d = &data[..take];
            let mut crc = !0u32;
            for &b in d {
                crc ^= b as u32;
                for _ in 0..8 {
                    crc = if crc & 1 != 0 { (crc >> 1) ^ CRC_POLY } else { crc >> 1 };
                }
            }
            assert_eq!(crc32(d), !crc, "len {take}");
        }
    }

    #[test]
    fn checksum_table_exact_match_only() {
        let t = ChecksumTable::new();
        t.record(64, 16, 0xDEAD);
        assert_eq!(t.expected(64, 16), Some(0xDEAD));
        assert_eq!(t.expected(64, 8), None, "partial read skips verify");
        assert_eq!(t.expected(0, 16), None);
        assert_eq!(t.export(&[(64, 16), (0, 4)]), vec![Some(0xDEAD), None]);
        let t2 = ChecksumTable::new();
        t2.seed([(64, 16, 0xDEAD)]);
        assert_eq!(t2.expected(64, 16), Some(0xDEAD));
    }

    #[test]
    fn spec_parses_and_rejects() {
        let c = FaultConfig::parse("seed=7, eio=0.25, torn=0.5, max_duration=4").unwrap();
        assert_eq!(c.seed, 7);
        assert_eq!(c.eio, 0.25);
        assert_eq!(c.torn_write, 0.5);
        assert_eq!(c.max_duration, 4);
        assert!(FaultConfig::parse("bogus=1").is_err(), "unknown key");
        assert!(FaultConfig::parse("eio").is_err(), "missing value");
        assert!(FaultConfig::parse("eio=1.5").is_err(), "p outside [0,1]");
        assert!(FaultConfig::parse("eio=0.8,bitflip=0.5").is_err(), "sum past 1");
        assert_eq!(FaultConfig::parse("").unwrap(), FaultConfig::default());
    }

    #[test]
    fn schedule_is_deterministic_and_heals() {
        let cfg = FaultConfig {
            eio: 1.0,
            persistent: 0.0,
            max_duration: 2,
            ..FaultConfig::default()
        };
        let metrics = Metrics::new();
        let fates: Vec<_> = (0..16)
            .map(|i| {
                let p = FaultPlan::new(cfg.clone());
                let mut fails = 0;
                // attempts accumulate: the site must heal within max_duration
                while let Some(Injection::Fail(_)) =
                    p.draw(1, Op::Read, i * 4096, 4096, &metrics)
                {
                    fails += 1;
                    assert!(fails <= cfg.max_duration, "site never healed");
                }
                fails
            })
            .collect();
        assert!(fates.iter().all(|&f| (1..=2).contains(&f)));
        // same seed, fresh plan => identical schedule
        let rerun: Vec<_> = (0..16)
            .map(|i| {
                let p = FaultPlan::new(cfg.clone());
                let mut fails = 0;
                while let Some(Injection::Fail(_)) =
                    p.draw(1, Op::Read, i * 4096, 4096, &metrics)
                {
                    fails += 1;
                }
                fails
            })
            .collect();
        assert_eq!(fates, rerun);
        assert!(metrics.snapshot().faults_injected > 0);
    }

    #[test]
    fn persistent_sites_never_heal() {
        let cfg = FaultConfig {
            bit_flip: 1.0,
            persistent: 1.0,
            ..FaultConfig::default()
        };
        let p = FaultPlan::new(cfg);
        let metrics = Metrics::new();
        for _ in 0..64 {
            match p.draw(9, Op::Read, 0, 4096, &metrics) {
                Some(Injection::FlipBit { byte, bit }) => {
                    assert!(byte < 4096);
                    assert!(bit < 8);
                }
                _ => panic!("persistent bit-flip site must fire every attempt"),
            }
        }
    }

    #[test]
    fn read_and_write_sites_are_independent() {
        // eio=1.0 on both: the read site consuming attempts must not
        // advance the write site's counter
        let cfg = FaultConfig {
            eio: 1.0,
            max_duration: 1,
            ..FaultConfig::default()
        };
        let p = FaultPlan::new(cfg);
        let m = Metrics::new();
        assert!(matches!(p.draw(3, Op::Read, 0, 64, &m), Some(Injection::Fail(_))));
        assert!(p.draw(3, Op::Read, 0, 64, &m).is_none(), "read healed");
        assert!(
            matches!(p.draw(3, Op::Write, 0, 64, &m), Some(Injection::Fail(_))),
            "write site still has its own first attempt"
        );
    }
}
