//! Token-bucket bandwidth throttle — the deterministic SSD-array model.
//!
//! The paper's EM results are governed by the *ratio* of compute speed to
//! I/O bandwidth (Table IV, Figs 9/10), not by absolute GB/s. A token
//! bucket lets benches impose that ratio on any disk: callers `take(bytes)`
//! before an I/O and sleep until the budget allows it.
//!
//! Read and write budgets are **separate buckets**
//! ([`crate::storage::SsdSim`]), mirroring an SSD array's full-duplex
//! bandwidth — which is
//! what makes the §III-B3 overlap benches meaningful: with write-back on,
//! the pass worker sleeps in the read bucket while the background writer
//! sleeps in the write bucket, and the two costs are paid concurrently
//! instead of serially (`benches/writeback.rs` pins the resulting
//! wall-time win; determinism of the buckets makes it CI-gateable).

use std::sync::Mutex;

use crate::util::sync::LockExt;
use std::time::{Duration, Instant};

/// Classic token bucket: capacity of one second of budget, refilled by
/// elapsed wall time.
pub struct TokenBucket {
    bytes_per_sec: u64,
    state: Mutex<State>,
}

struct State {
    available: f64,
    last: Instant,
}

impl TokenBucket {
    pub fn new(bytes_per_sec: u64) -> TokenBucket {
        TokenBucket {
            bytes_per_sec: bytes_per_sec.max(1),
            state: Mutex::new(State {
                available: bytes_per_sec as f64,
                last: Instant::now(),
            }),
        }
    }

    pub fn rate(&self) -> u64 {
        self.bytes_per_sec
    }

    /// Empty the bucket: the next [`take`](Self::take) pays the full rate
    /// from a standing start. A fresh bucket holds one second of budget
    /// (the burst), so short bench workloads could otherwise run entirely
    /// burst-free of throttling — benches drain before their timed region
    /// to make token-bucket costs deterministic from `t = 0`
    /// ([`crate::storage::SsdSim::drain_bursts`]).
    pub fn drain(&self) {
        let mut st = self.state.lock_recover();
        st.available = 0.0;
        st.last = Instant::now();
    }

    /// Consume `bytes` of budget, sleeping as needed. Requests larger than
    /// one second of budget are paid for across multiple refills.
    pub fn take(&self, bytes: u64) {
        let mut remaining = bytes as f64;
        loop {
            let wait = {
                let mut st = self.state.lock_recover();
                let now = Instant::now();
                let dt = now.duration_since(st.last).as_secs_f64();
                st.last = now;
                st.available =
                    (st.available + dt * self.bytes_per_sec as f64).min(self.bytes_per_sec as f64);
                if st.available >= remaining {
                    st.available -= remaining;
                    return;
                }
                // drain what's there, wait for the rest (bounded by 1s)
                remaining -= st.available;
                st.available = 0.0;
                Duration::from_secs_f64(
                    (remaining / self.bytes_per_sec as f64).min(1.0).max(0.0005),
                )
            };
            std::thread::sleep(wait);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enforces_rate_roughly() {
        // 1 MB/s budget, ask for 300 KB beyond the initial burst:
        // must take >= ~0.2s.
        let tb = TokenBucket::new(1 << 20);
        tb.take(1 << 20); // drain the initial burst
        let t0 = Instant::now();
        tb.take(300 * 1024);
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt > 0.15, "throttle too permissive: {dt}s");
        assert!(dt < 2.0, "throttle too strict: {dt}s");
    }

    #[test]
    fn burst_within_budget_is_free() {
        let tb = TokenBucket::new(10 << 20);
        let t0 = Instant::now();
        tb.take(1024); // tiny request against a full bucket
        assert!(t0.elapsed().as_millis() < 50);
    }

    #[test]
    fn drain_forces_full_rate_from_standing_start() {
        let tb = TokenBucket::new(1 << 20);
        tb.drain();
        let t0 = Instant::now();
        tb.take(256 * 1024); // a quarter second of budget at 1 MiB/s
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt > 0.15, "drained bucket must pay the full rate: {dt}s");
    }

    #[test]
    fn oversized_request_completes() {
        let tb = TokenBucket::new(64 << 20);
        // 2 seconds of budget — must still return (in ~1s after burst).
        tb.take(96 << 20);
    }
}
