//! Measurement harness for `rust/benches/*` (criterion is not vendored;
//! benches are `harness = false` binaries built on this module).
//!
//! [`measure`] runs a closure `warmup + iters` times and reports
//! min/median/mean wall time. [`Row`] accumulates a results table that
//! prints in the same layout the paper's figures use and can be dumped as
//! JSON for EXPERIMENTS.md.
//!
//! # Bench parameterization — one mechanism
//!
//! Every bench binary takes **CLI flags** (parsed by [`bench_args`] /
//! `util::cli::Args`), passed through cargo after `--`:
//!
//! ```sh
//! cargo bench --bench spmm_pagerank -- --nodes 16384
//! cargo bench --bench writeback -- --iters 5 --json-dir bench-json
//! ```
//!
//! Flags, not ad-hoc environment variables, are the documented mechanism
//! (`FM_BENCH_*` env vars were retired): they show up in `ps`, in CI
//! logs, and in the workflow file next to the bench they parameterize.
//! Every bench accepts `--json-dir DIR` and writes its machine-readable
//! `BENCH_<name>.json` report there
//! ([`crate::harness::BenchReport`], default `.`) — the artifact the CI
//! regression gate consumes. Cargo itself appends a bare `--bench` flag
//! when invoking bench targets; [`bench_args`] tolerates it.

use std::time::{Duration, Instant};

use crate::util::json::{obj, Json};

/// Parse a bench binary's command line (`cargo bench --bench <x> -- ...`)
/// into the same `--key value` / `--switch` form the launcher uses.
pub fn bench_args() -> crate::util::cli::Args {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    crate::util::cli::Args::parse(&argv)
}

/// Timing summary of one measured configuration.
#[derive(Clone, Debug)]
pub struct Sample {
    pub iters: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
}

impl Sample {
    pub fn secs(&self) -> f64 {
        self.median.as_secs_f64()
    }
}

/// Run `f` (`warmup` unmeasured + `iters` measured times).
pub fn measure<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Sample {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters.max(1));
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed());
    }
    times.sort();
    let mean = times.iter().sum::<Duration>() / times.len() as u32;
    Sample {
        iters: times.len(),
        min: times[0],
        median: times[times.len() / 2],
        mean,
    }
}

/// One row of a results table.
#[derive(Clone, Debug)]
pub struct Row {
    pub label: String,
    pub value: f64,
    pub unit: String,
    pub extra: Vec<(String, f64)>,
}

/// A named results table that prints aligned and serializes to JSON.
pub struct Table {
    pub title: String,
    pub rows: Vec<Row>,
}

impl Table {
    pub fn new(title: impl Into<String>) -> Table {
        Table {
            title: title.into(),
            rows: Vec::new(),
        }
    }

    pub fn add(&mut self, label: impl Into<String>, value: f64, unit: impl Into<String>) {
        self.rows.push(Row {
            label: label.into(),
            value,
            unit: unit.into(),
            extra: Vec::new(),
        });
    }

    pub fn add_with(
        &mut self,
        label: impl Into<String>,
        value: f64,
        unit: impl Into<String>,
        extra: Vec<(String, f64)>,
    ) {
        self.rows.push(Row {
            label: label.into(),
            value,
            unit: unit.into(),
            extra,
        });
    }

    /// Print in a fixed-width layout.
    pub fn print(&self) {
        println!("\n== {} ==", self.title);
        let w = self.rows.iter().map(|r| r.label.len()).max().unwrap_or(8);
        for r in &self.rows {
            let extras: String = r
                .extra
                .iter()
                .map(|(k, v)| format!("  {k}={v:.4}"))
                .collect();
            println!("  {:w$}  {:>12.4} {}{}", r.label, r.value, r.unit, extras);
        }
    }

    /// JSON record (appended to bench logs consumed by EXPERIMENTS.md).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("title", Json::from(self.title.clone())),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            let mut fields = vec![
                                ("label", Json::from(r.label.clone())),
                                ("value", Json::from(r.value)),
                                ("unit", Json::from(r.unit.clone())),
                            ];
                            for (k, v) in &r.extra {
                                fields.push((k.as_str(), Json::from(*v)));
                            }
                            // keys must own their strings: rebuild
                            Json::Obj(
                                fields
                                    .into_iter()
                                    .map(|(k, v)| (k.to_string(), v))
                                    .collect(),
                            )
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_reports_ordered_stats() {
        let s = measure(1, 5, || std::thread::sleep(Duration::from_millis(1)));
        assert_eq!(s.iters, 5);
        assert!(s.min <= s.median && s.median.as_secs_f64() > 0.0005);
    }

    #[test]
    fn table_json_roundtrips() {
        let mut t = Table::new("fig-test");
        t.add("fm-im", 1.25, "s");
        t.add_with("fm-em", 2.5, "s", vec![("io_gb".into(), 3.5)]);
        let j = t.to_json();
        let s = j.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back.get("title").unwrap().as_str().unwrap(), "fig-test");
        assert_eq!(back.get("rows").unwrap().as_arr().unwrap().len(), 2);
    }
}
