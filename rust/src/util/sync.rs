//! Poison-recovering lock primitives.
//!
//! A worker panic contained by `catch_unwind` (see `exec::run_pass` and
//! the cache's background threads) still *poisons* any `Mutex` it held —
//! and with `.lock().unwrap()` that poison cascades: every later pass
//! touching the same cache/pool state panics too, turning one contained
//! fault into a wedged engine. All shared engine state guards protect
//! plain data whose invariants are re-established by the abort path
//! (dirty queues discarded, in-flight registries cleared), so recovering
//! the guard is always safe here; these helpers make that the one-line
//! default.

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};

/// `Mutex` extension: acquire the guard even when a previous holder
/// panicked.
pub trait LockExt<T> {
    /// `lock()` that shrugs off poison instead of panicking.
    fn lock_recover(&self) -> MutexGuard<'_, T>;
    /// `into_inner()` that shrugs off poison instead of panicking.
    fn into_inner_recover(self) -> T;
}

impl<T> LockExt<T> for Mutex<T> {
    fn lock_recover(&self) -> MutexGuard<'_, T> {
        self.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn into_inner_recover(self) -> T {
        self.into_inner().unwrap_or_else(PoisonError::into_inner)
    }
}

/// `Condvar::wait` that recovers a poisoned guard instead of panicking
/// (the waiting side of the same cascade `lock_recover` breaks).
pub fn wait_recover<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn lock_recover_survives_poison() {
        let m = Arc::new(Mutex::new(7usize));
        let m2 = Arc::clone(&m);
        // poison the mutex from a panicking thread
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "mutex should be poisoned");
        assert_eq!(*m.lock_recover(), 7);
        *m.lock_recover() = 8;
        assert_eq!(*m.lock_recover(), 8);
    }

    #[test]
    fn into_inner_recover_survives_poison() {
        let m = Arc::new(Mutex::new(vec![1, 2, 3]));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        let m = Arc::into_inner(m).expect("sole owner");
        assert_eq!(m.into_inner_recover(), vec![1, 2, 3]);
    }
}
