//! Small in-repo utilities that replace unavailable external crates in
//! this offline build (see Cargo.toml header note):
//!
//! * [`json`] — minimal JSON parser/writer (artifacts manifest, golden
//!   fixtures, bench result records).
//! * [`cli`] — tiny `--flag value` argument parser for the launcher.
//! * [`bench`] — measurement harness used by `rust/benches/*` (criterion
//!   is not vendored; benches are `harness = false` mains).
//! * [`quickcheck`] — property-test case generation on top of the
//!   deterministic SplitMix64 generator (proptest substitute).
//! * [`sync`] — poison-recovering `Mutex`/`Condvar` helpers so a
//!   contained worker panic cannot wedge shared engine state.

pub mod bench;
pub mod cli;
pub mod json;
pub mod quickcheck;
pub mod sync;
