//! Property-test case generation (proptest is not vendored; this provides
//! the subset the test-suite needs on top of the engine's deterministic
//! SplitMix64).
//!
//! [`Gen`] yields primitive draws; [`forall`] runs a property across
//! `cases` seeded inputs and reports the failing seed — re-run a failure
//! by pinning [`Gen::new`] to that seed.

use crate::exec::{splitmix64_at, u64_to_unit_f64};

/// Deterministic case generator.
pub struct Gen {
    seed: u64,
    counter: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Gen {
        Gen { seed, counter: 0 }
    }

    fn next_u64(&mut self) -> u64 {
        let v = splitmix64_at(self.seed, self.counter);
        self.counter += 1;
        v
    }

    /// Uniform in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * u64_to_unit_f64(self.next_u64())
    }

    /// Uniform integer in [lo, hi].
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.next_u64() as usize) % (hi - lo + 1)
    }

    pub fn u64(&mut self) -> u64 {
        self.next_u64()
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Vector of uniform doubles.
    pub fn f64_vec(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(lo, hi)).collect()
    }

    /// Pick one of the provided items.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize_in(0, items.len() - 1)]
    }
}

/// Run `prop` on `cases` generated inputs; panics with the offending seed
/// on the first failure.
pub fn forall(cases: usize, mut prop: impl FnMut(&mut Gen) -> Result<(), String>) {
    for case in 0..cases {
        let seed = 0x9E37_0000 + case as u64;
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!("property failed (seed {seed}, case {case}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_is_deterministic() {
        let mut a = Gen::new(1);
        let mut b = Gen::new(1);
        for _ in 0..10 {
            assert_eq!(a.u64(), b.u64());
        }
    }

    #[test]
    fn ranges_respected() {
        forall(50, |g| {
            let v = g.f64_in(-2.0, 3.0);
            let n = g.usize_in(1, 7);
            if !(-2.0..3.0).contains(&v) {
                return Err(format!("f64 out of range: {v}"));
            }
            if !(1..=7).contains(&n) {
                return Err(format!("usize out of range: {n}"));
            }
            Ok(())
        });
    }
}
