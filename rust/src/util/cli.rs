//! Tiny `--flag value` argument parser for the launcher (clap is not
//! vendored in this offline environment).

use std::collections::HashMap;

/// Parsed command line: a subcommand, positional args and `--key value` /
/// `--switch` options.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    opts: HashMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse `std::env::args()[1..]`. The first non-flag token is the
    /// subcommand; `--key value` pairs become options; a `--key` followed
    /// by another flag (or nothing) is a boolean switch.
    pub fn parse(argv: &[String]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let next_is_value = argv
                    .get(i + 1)
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false);
                if next_is_value {
                    out.opts.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    out.switches.push(key.to_string());
                    i += 1;
                }
            } else {
                if out.subcommand.is_none() {
                    out.subcommand = Some(a.clone());
                } else {
                    out.positional.push(a.clone());
                }
                i += 1;
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key) || self.opts.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_opts_switches() {
        let a = Args::parse(&sv(&["bench", "fig6a", "--n", "100000", "--em", "--k", "10"]));
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.positional, vec!["fig6a"]);
        assert_eq!(a.u64_or("n", 0), 100_000);
        assert!(a.has("em"));
        assert_eq!(a.u64_or("k", 0), 10);
        assert_eq!(a.u64_or("missing", 7), 7);
    }
}
