//! Minimal JSON: a recursive-descent parser and a writer.
//!
//! Supports the full JSON grammar needed by `artifacts/manifest.json`,
//! `python/tests/golden/*.json` and bench result records: objects, arrays,
//! strings (with escapes), numbers, booleans, null. Not a general-purpose
//! library — inputs are machine-generated files from this repo.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{FmError, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(err(format!("trailing data at byte {}", p.i)));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => Err(err("expected number")),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        Ok(self.as_f64()? as u64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(err("expected string")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(err("expected array")),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(err("expected object")),
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| err(format!("missing key '{key}'")))
    }

    /// Array of numbers as `Vec<f64>`.
    pub fn f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    /// Array of numbers as `Vec<usize>`.
    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // -- writer ------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for writers.
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl From<Vec<f64>> for Json {
    fn from(v: Vec<f64>) -> Json {
        Json::Arr(v.into_iter().map(Json::Num).collect())
    }
}

/// Build a `Json::Obj` from pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn err(msg: impl Into<String>) -> FmError {
    FmError::Json(msg.into())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| err("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            Err(err(format!(
                "expected '{}' at byte {}, found '{}'",
                c as char, self.i, self.b[self.i] as char
            )))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(err(format!("bad literal at byte {}", self.i)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'N' => self.lit("NaN", Json::Num(f64::NAN)),
            b'I' => self.lit("Infinity", Json::Num(f64::INFINITY)),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => return Err(err(format!("expected ',' or '}}', found '{}'", c as char))),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => return Err(err(format!("expected ',' or ']', found '{}'", c as char))),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| err("bad \\u escape"))?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        c => return Err(err(format!("bad escape '\\{}'", c as char))),
                    }
                }
                c => {
                    // copy raw UTF-8 bytes through
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        // multi-byte: find the full char
                        let start = self.i - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let chunk = std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| err("bad utf8"))?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
            if self.peek()? == b'I' {
                return self.lit("Infinity", Json::Num(f64::NEG_INFINITY));
            }
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| err("bad number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| err(format!("bad number '{s}'")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let t = r#"{"elem_bytes": 8, "artifacts": [{"name": "kmeans_p32_k10",
            "inputs": [{"shape": [32768, 32], "dtype": "float64"}],
            "rows": 32768, "k": 10}]}"#;
        let j = Json::parse(t).unwrap();
        assert_eq!(j.get("elem_bytes").unwrap().as_u64().unwrap(), 8);
        let a = &j.get("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(a.get("name").unwrap().as_str().unwrap(), "kmeans_p32_k10");
        assert_eq!(
            a.get("inputs").unwrap().as_arr().unwrap()[0]
                .get("shape")
                .unwrap()
                .usize_vec()
                .unwrap(),
            vec![32768, 32]
        );
    }

    #[test]
    fn roundtrip() {
        let j = obj(vec![
            ("a", Json::from(1.5)),
            ("b", Json::Arr(vec![Json::Bool(true), Json::Null])),
            ("s", Json::from("x\"y\n")),
        ]);
        let s = j.to_string();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64().unwrap(), -1500.0);
        assert_eq!(Json::parse("0").unwrap().as_f64().unwrap(), 0.0);
        assert!(Json::parse("--3").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":1,}").is_err());
    }

    #[test]
    fn non_finite_extensions() {
        // python json.dump emits these for inf/nan (golden fixtures)
        assert!(Json::parse("NaN").unwrap().as_f64().unwrap().is_nan());
        assert_eq!(
            Json::parse("[Infinity, -Infinity]").unwrap().f64_vec().unwrap(),
            vec![f64::INFINITY, f64::NEG_INFINITY]
        );
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""A\t\\ö""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "A\t\\ö");
    }
}
