//! Dataset generators (paper Table V, substituted per DESIGN.md).
//!
//! * [`mix_gaussian`] — the paper's MixGaussian-1B generative model at
//!   configurable scale: k multivariate Gaussians with identity covariance
//!   and distinct means.
//! * [`spectral_like`] — stands in for Friendster-32 (65M×32 graph
//!   eigenvectors): correlated columns with per-column decaying scale, the
//!   shape k-means/GMM costs depend on.
//! * [`uniform`] / [`golden_uniform`] — random-65M-style matrices; the
//!   golden variant reproduces byte-for-byte the fixture inputs of
//!   `python/tests/test_golden.py` (same SplitMix64 stream).
//!
//! All generators are **counter-based** (value = f(seed, row, col)), so
//! partitions materialize deterministically in any order on any thread
//! count, and the Python oracle can regenerate identical matrices from the
//! seed alone.

use std::sync::Arc;

use crate::dtype::{DType, Scalar};
use crate::error::Result;
use crate::exec::{splitmix64_at, u64_to_unit_f64};
use crate::fmr::{Engine, EngineExt, FmMatrix};
use crate::matrix::{DenseBuilder, HostMat, Matrix, Partitioning};
use crate::util::sync::LockExt;
use crate::vudf::Buf;
use crate::StorageKind;

/// Materialize an `n x p` f64 matrix from an element function
/// `f(row, col) -> f64`, honoring the engine's storage kind. `name` makes
/// the on-disk file persistent (EM datasets are reusable across runs).
pub fn from_fn(
    eng: &Arc<Engine>,
    n: u64,
    p: u64,
    name: Option<&str>,
    f: impl Fn(u64, u64) -> f64 + Sync,
) -> Result<FmMatrix> {
    let parts = Partitioning::new(n, p);
    let builder = match eng.config.storage {
        StorageKind::InMem => DenseBuilder::new_mem(DType::F64, parts.clone(), &eng.pool)?,
        StorageKind::External => DenseBuilder::new_ext(
            DType::F64,
            parts.clone(),
            &eng.config.data_dir,
            name,
            eng.config.em_cache_cols as u64,
            Arc::clone(&eng.ssd),
            Arc::clone(&eng.metrics),
            // datasets are the repeatedly-scanned inputs of EM algorithms:
            // always cache-resident (§III-B3)
            eng.cache.clone(),
        )?,
    };
    // parallel generation: partitions are independent
    let next = std::sync::atomic::AtomicUsize::new(0);
    let n_parts = parts.n_parts();
    let threads = eng.config.threads.max(1).min(n_parts.max(1));
    let err: std::sync::Mutex<Option<crate::FmError>> = std::sync::Mutex::new(None);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n_parts {
                    break;
                }
                let (r0, r1) = parts.part_rows(i);
                let prows = (r1 - r0) as usize;
                let mut buf = Buf::alloc(DType::F64, prows * p as usize);
                for j in 0..p {
                    for r in 0..prows {
                        buf.set(j as usize * prows + r, Scalar::F64(f(r0 + r as u64, j)));
                    }
                }
                if let Err(e) = builder.write_partition_buf(i, &buf) {
                    let mut g = err.lock_recover();
                    if g.is_none() {
                        *g = Some(e);
                    }
                    break;
                }
            });
        }
    });
    if let Some(e) = err.into_inner_recover() {
        return Err(e);
    }
    let data = builder.finish();
    // named EM datasets are reopenable across engine restarts
    // (EngineExt::get_dense_matrix): persist the dense sidecar with the
    // dtype, shape and write-time partition checksums. Generators have
    // no ingestion schema, hence the empty column list.
    if let (StorageKind::External, Some(nm)) = (&eng.config.storage, name) {
        data.save_named_meta(&eng.config.data_dir, nm, &[])?;
    }
    Ok(FmMatrix {
        eng: Arc::clone(eng),
        m: Matrix::from_dense(data),
    })
}

/// Uniform [lo, hi) matrix, counter-based by (row, col).
pub fn uniform(
    eng: &Arc<Engine>,
    n: u64,
    p: u64,
    lo: f64,
    hi: f64,
    seed: u64,
    name: Option<&str>,
) -> Result<FmMatrix> {
    from_fn(eng, n, p, name, |r, c| {
        lo + (hi - lo) * u64_to_unit_f64(splitmix64_at(seed, r * p + c))
    })
}

/// The exact input-matrix convention of `python/tests/test_golden.py`:
/// `x = uniform01(stream)[r*p+c] * scale + shift`, with |x| < zero_clip
/// snapped to 0 to exercise nnz counting.
pub fn golden_uniform(
    eng: &Arc<Engine>,
    n: u64,
    p: u64,
    seed: u64,
    scale: f64,
    shift: f64,
    zero_clip: f64,
) -> Result<FmMatrix> {
    from_fn(eng, n, p, None, |r, c| {
        let v = u64_to_unit_f64(splitmix64_at(seed, r * p + c)) * scale + shift;
        if v.abs() < zero_clip {
            0.0
        } else {
            v
        }
    })
}

/// Standard normal via Box-Muller on two counter-based uniforms.
#[inline]
pub fn normal_at(seed: u64, idx: u64) -> f64 {
    let u1 = u64_to_unit_f64(splitmix64_at(seed, idx * 2)).max(1e-300);
    let u2 = u64_to_unit_f64(splitmix64_at(seed, idx * 2 + 1));
    (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
}

/// The MixGaussian model: `k` components with identity covariance and
/// means drawn from `N(0, sep^2)` per coordinate; each row is assigned a
/// component by hash. Returns the matrix and the true component means
/// (k×p) for quality evaluation.
pub fn mix_gaussian(
    eng: &Arc<Engine>,
    n: u64,
    p: u64,
    k: u64,
    sep: f64,
    seed: u64,
    name: Option<&str>,
) -> Result<(FmMatrix, HostMat)> {
    // component means: deterministic from the seed
    let mut means = HostMat::zeros(k as usize, p as usize, DType::F64);
    for c in 0..k {
        for j in 0..p {
            let z = normal_at(seed ^ 0x00C0_FFEE, c * p + j);
            means.set(c as usize, j as usize, Scalar::F64(sep * z));
        }
    }
    let means_ref = &means;
    let x = from_fn(eng, n, p, name, move |r, j| {
        let comp = (splitmix64_at(seed ^ 0x5EED_CAFE, r) % k) as usize;
        means_ref.get(comp, j as usize).as_f64() + normal_at(seed, r * p + j)
    })?;
    Ok((x, means))
}

/// Materialize an `n x m` sparse CSR matrix from a row function
/// `f(row) -> [(col, value)]`, honoring the engine's storage kind. Rows
/// are split on the same io-row grid as dense matrices (so sparse
/// sources nest in any pass); external matrices are admitted to the
/// partition cache and, when named, persisted with a sidecar manifest.
pub fn sparse_from_rows(
    eng: &Arc<Engine>,
    nrow: u64,
    ncol: u64,
    name: Option<&str>,
    mut f: impl FnMut(u64) -> Vec<(u32, f64)>,
) -> Result<FmMatrix> {
    let parts = Partitioning::new(nrow, ncol);
    let mut b = crate::matrix::SparseBuilder::new(parts.clone());
    let mut rows: Vec<Vec<(u32, f64)>> = Vec::new();
    for i in 0..parts.n_parts() {
        let (r0, r1) = parts.part_rows(i);
        rows.clear();
        rows.extend((r0..r1).map(&mut f));
        b.push_partition(&mut rows)?;
    }
    let sd = match eng.config.storage {
        StorageKind::InMem => b.finish_mem()?,
        StorageKind::External => b.finish_ext(
            &eng.config.data_dir,
            name,
            Arc::clone(&eng.ssd),
            Arc::clone(&eng.metrics),
            // edge matrices are the repeatedly-scanned inputs of sparse
            // workloads: cache-resident, like dense datasets (§III-B3)
            eng.cache.clone(),
        )?,
    };
    Ok(FmMatrix {
        eng: Arc::clone(eng),
        m: Matrix::new(crate::matrix::MatrixData::Sparse(sd)),
    })
}

/// Synthetic directed graph for PageRank, counter-based and mirrored by
/// `python/tests/test_golden.py::pagerank_graph_ref`:
///
/// * node `v` has out-degree `splitmix64_at(seed ^ 0xDE66, v) % (max_deg
///   + 1)` — 0 makes it *dangling*;
/// * its `t`-th out-edge points at `splitmix64_at(seed, v*max_deg + t) %
///   n` (multi-edges accumulate weight).
///
/// Returns the **transposed, column-stochastic** transition matrix (row
/// `i` holds in-edges `j -> i` weighted `1/outdeg(j)`, columns ascending)
/// plus the dangling mask — exactly what
/// [`crate::algs::pagerank::pagerank`] consumes.
pub fn pagerank_graph(
    eng: &Arc<Engine>,
    n: u64,
    max_deg: u64,
    seed: u64,
    name: Option<&str>,
) -> Result<(FmMatrix, Vec<bool>)> {
    let mut in_edges: Vec<Vec<(u32, f64)>> = vec![Vec::new(); n as usize];
    let mut dangling = vec![false; n as usize];
    for v in 0..n {
        let deg = splitmix64_at(seed ^ 0xDE66, v) % (max_deg + 1);
        if deg == 0 {
            dangling[v as usize] = true;
            continue;
        }
        let w = 1.0 / deg as f64;
        for t in 0..deg {
            let u = splitmix64_at(seed, v * max_deg + t) % n;
            // v ascending => each in-edge list is already column-sorted;
            // multi-edges merge additively in the CSR encoder
            in_edges[u as usize].push((v as u32, w));
        }
    }
    // rows are consumed exactly once: hand them over instead of cloning
    let g = sparse_from_rows(eng, n, n, name, |r| {
        std::mem::take(&mut in_edges[r as usize])
    })?;
    Ok((g, dangling))
}

/// Bernoulli labels for logistic regression, drawn through the engine
/// itself so they are deterministic and storage-independent:
/// `y = 1[u < sigmoid(x beta_true)]` with `u = fm.runif(n, 1)` — the
/// logistic generative model (mirrored by the python fixture).
pub fn logistic_labels(
    x: &FmMatrix,
    beta_true: &[f64],
    seed: u64,
) -> Result<FmMatrix> {
    let p = x.ncol() as usize;
    if beta_true.len() != p {
        return Err(crate::FmError::Shape(format!(
            "logistic_labels: beta_true has {} coefficients for {p} columns",
            beta_true.len()
        )));
    }
    let mut bh = HostMat::zeros(p, 1, DType::F64);
    for (j, b) in beta_true.iter().enumerate() {
        bh.set(j, 0, Scalar::F64(*b));
    }
    let pmu = x.matmul_small(&bh)?.sigmoid()?;
    let u = x.eng.runif_matrix(x.nrow(), 1, 0.0, 1.0, seed);
    u.mapply(&pmu, crate::vudf::BinOp::Lt)?
        .cast(DType::F64)?
        .materialize()
}

/// Friendster-32 stand-in: column j has scale `1/(1+j)` (spectral decay)
/// plus a low-rank structure that gives the columns correlation, so
/// clustering has non-trivial geometry.
pub fn spectral_like(
    eng: &Arc<Engine>,
    n: u64,
    p: u64,
    seed: u64,
    name: Option<&str>,
) -> Result<FmMatrix> {
    from_fn(eng, n, p, name, move |r, j| {
        let scale = 1.0 / (1.0 + j as f64);
        // 4 latent factors shared across columns -> correlated columns
        let mut v = 0.0;
        for f in 0..4u64 {
            let load = u64_to_unit_f64(splitmix64_at(seed ^ 0xFAC7, f * p + j)) - 0.5;
            v += load * normal_at(seed ^ (0xB00 + f), r);
        }
        scale * (v + 0.25 * normal_at(seed, r * p + j))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;

    fn eng() -> Arc<Engine> {
        Engine::new(EngineConfig {
            xla_dispatch: false,
            chunk_bytes: 1 << 22,
            target_part_bytes: 1 << 20,
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn uniform_is_deterministic_and_bounded() {
        let e = eng();
        let a = uniform(&e, 5000, 4, -1.0, 1.0, 9, None).unwrap();
        let b = uniform(&e, 5000, 4, -1.0, 1.0, 9, None).unwrap();
        assert_eq!(a.to_host().unwrap(), b.to_host().unwrap());
        assert!(a.max().unwrap() < 1.0);
        assert!(a.min().unwrap() >= -1.0);
        // mean of U(-1,1) ~ 0
        assert!(a.sum().unwrap().abs() / 20_000.0 < 0.05);
    }

    #[test]
    fn mix_gaussian_centers_separate() {
        let e = eng();
        let (x, means) = mix_gaussian(&e, 20_000, 4, 3, 8.0, 11, None).unwrap();
        assert_eq!(means.nrow, 3);
        // column means of x should be a convex combination of the
        // component means — bounded by the extreme component means
        let cm = x.col_means().unwrap();
        for j in 0..4 {
            let lo = (0..3)
                .map(|c| means.get(c, j).as_f64())
                .fold(f64::INFINITY, f64::min);
            let hi = (0..3)
                .map(|c| means.get(c, j).as_f64())
                .fold(f64::NEG_INFINITY, f64::max);
            let m = cm.buf.get(j).as_f64();
            assert!(m > lo - 1.0 && m < hi + 1.0, "col {j}: {m} not in [{lo},{hi}]");
        }
    }

    #[test]
    fn spectral_columns_decay() {
        let e = eng();
        let x = spectral_like(&e, 30_000, 8, 5, None).unwrap();
        // variance of col 0 must exceed variance of col 7 (scale decay)
        let sq = x.sq().unwrap();
        let ss = sq.col_sums().unwrap();
        assert!(ss.buf.get(0).as_f64() > 4.0 * ss.buf.get(7).as_f64());
    }

    fn em_eng(dir: &std::path::Path) -> Arc<Engine> {
        Engine::new(EngineConfig {
            xla_dispatch: false,
            storage: StorageKind::External,
            data_dir: dir.to_path_buf(),
            chunk_bytes: 1 << 20,
            target_part_bytes: 1 << 16,
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn named_em_dataset_reopens_via_sidecar() {
        let tmp = crate::testutil::TempDir::new("ds-sidecar");
        let e = em_eng(tmp.path());
        let a = uniform(&e, 4000, 3, -1.0, 1.0, 13, Some("unif")).unwrap();
        let want = a.to_host().unwrap();
        assert!(tmp.path().join("unif.dense.json").exists());
        // reattach through the manifest alone (fresh handle, same engine)
        let b = e.get_dense_matrix("unif").unwrap();
        assert_eq!(b.dtype(), DType::F64);
        assert_eq!((b.nrow(), b.ncol()), (4000, 3));
        assert_eq!(b.to_host().unwrap(), want);
        assert!(e.get_dense_matrix("no-such").is_err());
    }

    #[test]
    fn dense_sidecar_roundtrips_every_dtype() {
        use crate::matrix::Partitioning;
        let tmp = crate::testutil::TempDir::new("ds-dtypes");
        let e = em_eng(tmp.path());
        for (k, dt) in [
            DType::F64,
            DType::F32,
            DType::I64,
            DType::I32,
            DType::Bool,
        ]
        .into_iter()
        .enumerate()
        {
            let name = format!("m-{dt}");
            let parts = Partitioning::new(300, 2);
            let b = DenseBuilder::new_ext(
                dt,
                parts.clone(),
                &e.config.data_dir,
                Some(&name),
                0,
                Arc::clone(&e.ssd),
                Arc::clone(&e.metrics),
                e.cache.clone(),
            )
            .unwrap();
            for i in 0..parts.n_parts() {
                let prows = parts.rows_in(i) as usize;
                let mut buf = Buf::alloc(dt, prows * 2);
                for r in 0..buf.len() {
                    buf.set(r, Scalar::F64(((k + 1) * (r % 97)) as f64).cast(dt));
                }
                b.write_partition_buf(i, &buf).unwrap();
            }
            let data = b.finish();
            let want = data.to_buf().unwrap();
            data.save_named_meta(&e.config.data_dir, &name, &[]).unwrap();
            drop(data);
            // the sidecar must restore the dtype — the file alone cannot
            let again = e.get_dense_matrix(&name).unwrap();
            assert_eq!(again.dtype(), dt, "{name}");
            match &*again.m.data {
                crate::matrix::MatrixData::Dense(d) => {
                    assert_eq!(d.to_buf().unwrap(), want, "{name}")
                }
                _ => unreachable!(),
            }
        }
    }

    #[test]
    fn generation_matches_virtual_randu() {
        // datasets::uniform must agree with the lazy VKind::RandU node
        // (same counter-based stream)
        let e = eng();
        let a = uniform(&e, 3000, 3, 0.0, 2.0, 21, None).unwrap();
        let v = e.runif_matrix(3000, 3, 0.0, 2.0, 21);
        let d = a.sub(&v).unwrap().abs().unwrap().max().unwrap();
        assert_eq!(d, 0.0);
    }
}
