//! # FlashMatrix (FlashR) — parallel, scalable out-of-core matrix analysis
//!
//! Reproduction of Zheng et al., *"FlashMatrix: Parallel, Scalable Data
//! Analysis with Generalized Matrix Operations"* (a.k.a. *"FlashR:
//! R-Programmed Parallel and Scalable Machine Learning using SSDs"*,
//! arXiv:1604.06414).
//!
//! The engine executes R-style matrix programs in parallel and out of core:
//!
//! * [`genops`] — the four generalized operators (`inner.prod`, the `apply`
//!   family, `aggregation`, `groupby`) that all higher-level matrix
//!   functions are built from (paper §III-C).
//! * [`vudf`] — vectorized user-defined functions with the paper's multiple
//!   *forms* (`uVUDF`, `bVUDF1/2/3`, `aVUDF1/2`) (§III-D).
//! * [`dag`] + [`plan`] + [`exec`] — lazy evaluation, the cross-pass
//!   optimizer (structural CSE, dead-sink pruning and materialize-vs-
//!   recompute planning over whole materialize batches), operation fusion
//!   and the two-level-partitioned parallel materializer (§III-E/F).
//! * [`matrix`], [`mem`], [`storage`] — dense matrices (row/col-major,
//!   tall/wide, virtual, grouped), the recycled memory-chunk pool, the
//!   SAFS-like streaming external-memory store, and the write-through
//!   matrix cache + async read-ahead that keep out-of-core passes close
//!   to in-memory speed (§III-B, §III-B3; see `docs/ARCHITECTURE.md`).
//! * [`runtime`] — the AOT XLA/PJRT compute path: per-partition algorithm
//!   steps compiled from JAX/Pallas at build time (`make artifacts`) play
//!   the role BLAS plays in the paper.
//! * [`fmr`] — the R-like user API (`fm.*` functions, operators).
//! * [`algs`] — the paper's five evaluation algorithms written against
//!   `fmr`: summary, correlation, SVD, k-means, GMM.
//! * [`baselines`] — the comparison systems: an eager "MLlib-like" engine
//!   mode and single-threaded R-style reference implementations.
//!
//! See `DESIGN.md` for the system inventory and the experiment index, and
//! `EXPERIMENTS.md` for measured reproductions of the paper's figures.

pub mod algs;
pub mod baselines;
pub mod config;
pub mod dag;
pub mod datasets;
pub mod dtype;
pub mod error;
pub mod exec;
pub mod fmr;
pub mod genops;
pub mod harness;
pub mod ingest;
pub mod matrix;
pub mod mem;
pub mod metrics;
pub mod plan;
pub mod runtime;
pub mod storage;
pub mod testutil;
pub mod vudf;
pub(crate) mod xla_stub;

pub use config::{EngineConfig, StorageKind};
pub use error::{FmError, Result};
pub use fmr::engine::Engine;
pub use fmr::{EngineExt, FmMatrix, FmVector, Session};
pub use ingest::{ColType, LoadOptions, Schema};
pub use runtime::jobs::{JobQueue, Ticket};
pub mod util;
