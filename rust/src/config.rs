//! Engine configuration.
//!
//! Every optimization the paper evaluates (Fig 11/12 ablations) and every
//! baseline mode (the eager "MLlib-like" engine of Fig 6) is a point in
//! this configuration space, so all benches exercise the same code paths.

use std::path::PathBuf;

use crate::storage::FaultConfig;

/// One truthy-value grammar for every boolean `FLASHR_*` env knob:
/// `1`/`true`/`yes`/`on` (case-insensitive) are true; `0`/`false`/`no`/
/// `off` and the empty string are false; anything else is false too (a
/// typo must fail safe, not silently flip a default). Historically
/// `FLASHR_NO_CROSS_PASS_OPT` was presence-tested (`is_none()`), so
/// `FLASHR_NO_CROSS_PASS_OPT=0` *disabled* the optimizer while
/// `FLASHR_TEST_EM=0` did nothing — every knob now parses through here.
fn truthy(v: &str) -> bool {
    matches!(
        v.trim().to_ascii_lowercase().as_str(),
        "1" | "true" | "yes" | "on"
    )
}

/// Read a boolean env knob: `None` when unset, `Some(truthy(value))`
/// otherwise (non-UTF-8 values read as false).
pub fn env_flag(name: &str) -> Option<bool> {
    std::env::var_os(name).map(|v| truthy(&v.to_string_lossy()))
}

/// Where materialized matrices live.
#[derive(Clone, Debug, PartialEq)]
pub enum StorageKind {
    /// Everything in DRAM (FM-IM in the paper's figures).
    InMem,
    /// Large matrices on "SSDs" (FM-EM): file-backed streaming store.
    External,
}

/// Simulated SSD-array bandwidth model (substitution for the paper's
/// 24-SSD SAFS array; see DESIGN.md §Substitutions). `None` disables
/// throttling and the local disk's real speed applies.
#[derive(Clone, Debug, PartialEq)]
pub struct ThrottleConfig {
    /// Aggregate read bandwidth budget in bytes/sec.
    pub read_bytes_per_sec: u64,
    /// Aggregate write bandwidth budget in bytes/sec.
    pub write_bytes_per_sec: u64,
}

/// Engine-wide configuration. Defaults reproduce the fully-optimized
/// FlashMatrix configuration of the paper.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Worker threads for materialization (paper: 48; default: all cores).
    pub threads: usize,
    /// Storage for matrices created by `fmr` constructors.
    pub storage: StorageKind,
    /// Directory for external-memory matrix files.
    pub data_dir: PathBuf,
    /// Fixed memory-chunk size in bytes (paper default: 64 MiB).
    pub chunk_bytes: usize,
    /// Recycle freed chunks instead of releasing to the OS
    /// (Fig 11 "mem-alloc" optimization). Also governs the strip
    /// evaluator's per-worker register recycler
    /// ([`crate::mem::StripPool`]).
    pub recycle_chunks: bool,
    /// Execute unary/scalar/cast instructions in place on their input
    /// register's buffer when compile-time liveness shows the input is
    /// dead (§III-B5 applied to the strip hot path). Ablated by
    /// `benches/strip_fusion.rs`.
    pub inplace_ops: bool,
    /// Peephole-fuse single-consumer `Sapply`/`MapplyScalar` f64 chains
    /// into one composite instruction, so a CPU strip is traversed once
    /// per chain instead of once per step (§III-E at the instruction
    /// level). Ablated by `benches/strip_fusion.rs`.
    pub peephole_fuse: bool,
    /// Fuse DAG operations within main memory: one streaming pass per DAG
    /// instead of one per operation (Fig 11 "mem-fuse"). Off = the eager,
    /// materialize-every-op engine (the MLlib-like baseline).
    pub fuse_mem: bool,
    /// Pipeline CPU-level partitions through the whole DAG so intermediates
    /// stay in CPU cache (Fig 11 "cache-fuse"). Requires `fuse_mem`.
    pub fuse_cache: bool,
    /// Vectorized UDFs (paper §III-D). Off = one boxed function call per
    /// element (Fig 12 ablation).
    pub vectorized_udf: bool,
    /// Explicit SIMD microkernels in the strip evaluator: hand-unrolled
    /// f64x4/f32x8 lane loops for the elementwise/fused-chain VUDFs and
    /// register-blocked GEMM panels behind `inner_prod_small` /
    /// `inner_wide_tall`. Every lane kernel preserves each output
    /// element's accumulation order, so results are **bit-identical** to
    /// the scalar paths (pinned by `tests/simd_parity.rs`). Off in
    /// `mllib_like`; ablated by `benches/simd_kernels.rs`.
    pub simd_kernels: bool,
    /// Lane-parallel order-**changing** reductions (sum/mean/var keep 4
    /// partial accumulators instead of one sequential fold). Off by
    /// default so full-pass reductions stay bit-exact; turning it on
    /// trades ≤4-ULP drift (documented bound, pinned by
    /// `tests/simd_parity.rs`) for reduction throughput.
    pub simd_reductions: bool,
    /// Dispatch per-partition algorithm steps to AOT XLA artifacts when an
    /// artifact with a matching shape exists (the paper's BLAS dispatch).
    pub xla_dispatch: bool,
    /// Which artifact kinds dispatch to XLA. Default is the measured-win
    /// set for this CPU testbed (EXPERIMENTS.md §Perf: the einsum-heavy GMM
    /// E-step is ~2x faster under XLA; the other steps are faster native).
    /// `"all"` enables every kind (used by tests and TPU-like targets).
    pub xla_kinds: Vec<String>,
    /// Directory containing `manifest.json` + `*.hlo.txt` artifacts.
    pub artifacts_dir: PathBuf,
    /// Target I/O-level partition size in bytes (paper: "order of MBs").
    /// Kept in sync with python/compile/model.py::io_rows_for.
    pub target_part_bytes: usize,
    /// Bandwidth throttle for the external store (None = raw disk).
    pub throttle: Option<ThrottleConfig>,
    /// CPU-level partition budget in bytes (fits L1/L2; paper: KBs).
    pub cpu_part_bytes: usize,
    /// Number of simulated NUMA nodes for partition→worker affinity: the
    /// pass scheduler pins contiguous worker blocks to nodes, gives each
    /// node one contiguous slab of the pass, and prefers same-node victims
    /// when work-stealing ([`crate::exec::sched::RangeScheduler`]).
    pub numa_nodes: usize,
    /// Columns of the explicit matrix cache for EM matrices (0 = no cache).
    pub em_cache_cols: usize,
    /// Capacity in bytes of the write-through **partition cache** for EM
    /// matrices ([`crate::matrix::cache::PartitionCache`], paper §III-B3).
    /// 0 disables the cache — the `benches/cache_ablation.rs` knob.
    pub em_cache_bytes: usize,
    /// Queue depth of the async partition read-ahead thread that overlaps
    /// an EM scan's I/O with compute (0 disables read-ahead). Every pass
    /// worker prefetches the next partition of its own scheduled range;
    /// the cache's single-flight registry keeps that double-read-free at
    /// any thread count.
    pub prefetch_depth: usize,
    /// Asynchronous **write-back** of EM target partitions (§III-B3, the
    /// write half of the I/O/compute overlap): a pass worker hands a
    /// finished target partition to the cache's background writer thread
    /// and immediately claims the next unit instead of stalling on the
    /// (throttled) `pwrite`. Every pass ends with a flush barrier
    /// (success) or a dirty discard (abort), so results are bit-identical
    /// to synchronous write-through and a doomed pass leaves no partial
    /// partitions on disk. Requires the partition cache
    /// (`em_cache_bytes > 0`) to host the writer; off (or no cache) =
    /// write-through. Ablated by `benches/writeback.rs`.
    pub writeback: bool,
    /// Bound in bytes on dirty (queued + in-flight) write-back partitions.
    /// An enqueue past the bound blocks the worker until the writer
    /// drains (`Metrics::wb_flush_waits`), keeping write-back memory as
    /// bounded as the read-ahead queue keeps prefetch memory.
    pub writeback_queue_bytes: usize,
    /// Cross-pass lazy optimizer ([`crate::plan`]): every materialize
    /// batch is canonicalized into a plan IR and run through structural
    /// CSE, dead-sink/dead-target pruning and materialize-vs-recompute
    /// planning before the strip evaluator sees it. Results stay
    /// bit-identical to the unoptimized path — the planner only removes
    /// or reorders whole redundant evaluations, never a fold order
    /// (pinned by `tests/cross_pass.rs`). Off in `mllib_like` (an eager
    /// engine has no batches to optimize); the `FLASHR_NO_CROSS_PASS_OPT`
    /// env var forces the default off so CI can run the whole suite down
    /// the unoptimized path. Ablated by `benches/cross_pass.rs`.
    pub cross_pass_opt: bool,
    /// Size ceiling in bytes for a shared intermediate the planner may
    /// materialize (and keep cache-resident) instead of recomputing in
    /// every pass that uses it. 0 disables materialize-vs-recompute
    /// planning while keeping CSE/pruning active.
    pub opt_materialize_threshold: usize,
    /// Deterministic I/O fault injection ([`crate::storage::fault`]):
    /// a seeded schedule of transient/persistent `EIO`, short reads,
    /// torn write-back partitions, bit flips and latency spikes applied
    /// to every [`crate::storage::FileStore`] of the engine. `None`
    /// (production) injects nothing. The default honors the
    /// `FLASHR_FAULTS` env spec (`seed=42,eio=0.01,...` — see
    /// [`FaultConfig::parse`]) so CI chaos jobs can fault an unmodified
    /// test suite, mirroring the `FLASHR_NO_CROSS_PASS_OPT` hook.
    pub fault_injection: Option<FaultConfig>,
    /// Max retries (with backoff) of one positioned I/O after a
    /// transient failure before the error aborts the pass.
    pub io_retry_limit: u32,
    /// Record a CRC32 per written partition and verify it on every
    /// exactly-matching read; a mismatch gets one re-read, then surfaces
    /// as [`crate::FmError::Corrupt`]. Cheap (slice-by-8, hidden under
    /// the SSD throttle; gated ≤5% by `benches/fault_overhead.rs`) —
    /// off only for benches isolating raw I/O cost.
    pub io_checksums: bool,
    /// Fair-share residency budget in bytes for this engine's matrices
    /// when several engine **sessions** share one partition cache
    /// ([`crate::fmr::Session`]): a tenant within its budget is shielded
    /// from other tenants' eviction pressure; one over it becomes a
    /// preferred victim. 0 = dynamic (an equal split of the shared
    /// cache's capacity across registered sessions). Ignored by a
    /// single-tenant engine.
    pub session_mem_bytes: usize,
    /// Cap on passes executing concurrently against this engine's
    /// partition cache (admission control for the multi-tenant serving
    /// path): the pass that would exceed it blocks until a slot frees.
    /// 0 = unlimited. Derived sessions share the root engine's cap.
    pub max_concurrent_passes: usize,
    /// Parse workers for delimited-text ingestion ([`crate::ingest`]):
    /// both the chunk-scan and the partition-parse phases run on this
    /// many threads. 0 = use `threads`.
    pub ingest_workers: usize,
    /// Target text-chunk size in bytes for the ingestion scanner; each
    /// chunk is extended to the next record (newline) boundary, so this
    /// also bounds per-worker text memory during a load.
    pub ingest_chunk_bytes: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            storage: StorageKind::InMem,
            data_dir: PathBuf::from("data"),
            chunk_bytes: 64 << 20,
            recycle_chunks: true,
            inplace_ops: true,
            peephole_fuse: true,
            fuse_mem: true,
            fuse_cache: true,
            vectorized_udf: true,
            simd_kernels: true,
            simd_reductions: false,
            xla_dispatch: true,
            xla_kinds: vec!["gmm".to_string()],
            artifacts_dir: PathBuf::from("artifacts"),
            target_part_bytes: 8 << 20,
            throttle: None,
            cpu_part_bytes: 64 << 10,
            numa_nodes: 1,
            em_cache_cols: 0,
            em_cache_bytes: 128 << 20,
            prefetch_depth: 2,
            writeback: true,
            writeback_queue_bytes: 32 << 20,
            cross_pass_opt: !env_flag("FLASHR_NO_CROSS_PASS_OPT").unwrap_or(false),
            opt_materialize_threshold: 16 << 20,
            fault_injection: std::env::var("FLASHR_FAULTS")
                .ok()
                .and_then(|spec| match FaultConfig::parse(&spec) {
                    Ok(c) => Some(c),
                    Err(e) => {
                        eprintln!("ignoring invalid FLASHR_FAULTS: {e}");
                        None
                    }
                }),
            io_retry_limit: 3,
            io_checksums: true,
            session_mem_bytes: 0,
            max_concurrent_passes: 0,
            ingest_workers: 0,
            ingest_chunk_bytes: 4 << 20,
        }
    }
}

impl EngineConfig {
    /// The eager, per-element baseline standing in for Spark MLlib
    /// (DESIGN.md §Substitutions): every matrix operation materializes
    /// separately, UDFs are boxed per-element calls, fresh allocation per
    /// op, no XLA fast path for the generic GenOps.
    pub fn mllib_like() -> Self {
        EngineConfig {
            fuse_mem: false,
            fuse_cache: false,
            vectorized_udf: false,
            simd_kernels: false,
            recycle_chunks: false,
            inplace_ops: false,
            peephole_fuse: false,
            xla_dispatch: false,
            writeback: false,
            cross_pass_opt: false,
            ..Default::default()
        }
    }

    /// Fully-optimized in-memory configuration (FM-IM).
    pub fn fm_im() -> Self {
        Self::default()
    }

    /// Fully-optimized external-memory configuration (FM-EM).
    pub fn fm_em(data_dir: impl Into<PathBuf>) -> Self {
        EngineConfig {
            storage: StorageKind::External,
            data_dir: data_dir.into(),
            ..Default::default()
        }
    }

    /// Validate cross-field invariants.
    pub fn validate(&self) -> crate::Result<()> {
        if self.threads == 0 {
            return Err(crate::FmError::Config("threads must be > 0".into()));
        }
        if self.fuse_cache && !self.fuse_mem {
            return Err(crate::FmError::Config(
                "fuse_cache requires fuse_mem".into(),
            ));
        }
        if self.chunk_bytes < self.target_part_bytes {
            return Err(crate::FmError::Config(format!(
                "chunk_bytes ({}) must be >= target_part_bytes ({})",
                self.chunk_bytes, self.target_part_bytes
            )));
        }
        if self.numa_nodes == 0 {
            return Err(crate::FmError::Config("numa_nodes must be > 0".into()));
        }
        if self.writeback && self.writeback_queue_bytes == 0 {
            return Err(crate::FmError::Config(
                "writeback requires writeback_queue_bytes > 0".into(),
            ));
        }
        if self.ingest_chunk_bytes == 0 {
            return Err(crate::FmError::Config(
                "ingest_chunk_bytes must be > 0".into(),
            ));
        }
        if let Some(f) = &self.fault_injection {
            f.validate()?;
            if f.bit_flip > 0.0 && !self.io_checksums {
                return Err(crate::FmError::Config(
                    "bit-flip injection without io_checksums would corrupt results \
                     silently; enable io_checksums"
                        .into(),
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        EngineConfig::default().validate().unwrap();
    }

    #[test]
    fn mllib_like_disables_optimizations() {
        let c = EngineConfig::mllib_like();
        assert!(!c.fuse_mem && !c.fuse_cache && !c.vectorized_udf);
        c.validate().unwrap();
    }

    #[test]
    fn cache_fuse_requires_mem_fuse() {
        let c = EngineConfig {
            fuse_mem: false,
            fuse_cache: true,
            ..Default::default()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn default_enables_partition_cache() {
        let c = EngineConfig::default();
        assert!(c.em_cache_bytes > 0);
        assert!(c.prefetch_depth > 0);
        c.validate().unwrap();
    }

    #[test]
    fn strip_fusion_knobs_default_on() {
        let c = EngineConfig::default();
        assert!(c.inplace_ops && c.peephole_fuse);
        let m = EngineConfig::mllib_like();
        assert!(!m.inplace_ops && !m.peephole_fuse);
    }

    #[test]
    fn writeback_defaults_and_validation() {
        let c = EngineConfig::default();
        assert!(c.writeback && c.writeback_queue_bytes > 0);
        c.validate().unwrap();
        // the eager baseline stays synchronous write-through
        assert!(!EngineConfig::mllib_like().writeback);
        let bad = EngineConfig {
            writeback: true,
            writeback_queue_bytes: 0,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn simd_knob_defaults() {
        let c = EngineConfig::default();
        // SIMD microkernels on, order-changing lane reductions opt-in:
        // default results stay bit-exact vs the scalar paths
        assert!(c.simd_kernels && !c.simd_reductions);
        assert!(!EngineConfig::mllib_like().simd_kernels);
    }

    #[test]
    fn cross_pass_knob_defaults() {
        let c = EngineConfig::default();
        // default follows the CI ablation env hook; absent the hook (or
        // with a falsy value like "0") the optimizer is on, and the
        // threshold leaves headroom for the small shared intermediates
        // iterative algorithms produce
        let env_off = env_flag("FLASHR_NO_CROSS_PASS_OPT").unwrap_or(false);
        assert_eq!(c.cross_pass_opt, !env_off);
        assert!(c.opt_materialize_threshold > 0);
        // the eager baseline never batches, so it has nothing to plan
        assert!(!EngineConfig::mllib_like().cross_pass_opt);
    }

    #[test]
    fn truthy_grammar_is_uniform() {
        // the one parser every FLASHR_* boolean knob goes through:
        // FLASHR_NO_CROSS_PASS_OPT=0 must no longer disable the
        // optimizer, and FLASHR_TEST_EM=true must now enable EM forcing
        for v in ["1", "true", "TRUE", "yes", "on", " 1 ", "On"] {
            assert!(truthy(v), "{v:?} must parse as true");
        }
        for v in ["0", "", "false", "no", "off", "OFF", " ", "2", "bogus"] {
            assert!(!truthy(v), "{v:?} must parse as false");
        }
    }

    #[test]
    fn env_flag_distinguishes_unset_from_falsy() {
        // a name no test sets: unset reads as None, not Some(false) —
        // callers choose their own default via unwrap_or
        assert_eq!(env_flag("FLASHR_TEST_KNOB_THAT_IS_NEVER_SET"), None);
    }

    #[test]
    fn session_knob_defaults() {
        let c = EngineConfig::default();
        // multi-tenant knobs default to "off": dynamic fair share and
        // unlimited concurrent passes, so single-tenant behavior (and
        // every existing test) is unchanged
        assert_eq!(c.session_mem_bytes, 0);
        assert_eq!(c.max_concurrent_passes, 0);
        c.validate().unwrap();
    }

    #[test]
    fn fault_knob_defaults_and_validation() {
        let c = EngineConfig::default();
        // production default: tolerance on, chaos off (unless the
        // FLASHR_FAULTS hook is set, as in the CI chaos job)
        assert!(c.io_checksums);
        assert_eq!(c.io_retry_limit, 3);
        if std::env::var_os("FLASHR_FAULTS").is_none() {
            assert!(c.fault_injection.is_none());
        }
        c.validate().unwrap();
        let bad = EngineConfig {
            fault_injection: Some(FaultConfig {
                bit_flip: 0.5,
                ..FaultConfig::default()
            }),
            io_checksums: false,
            ..Default::default()
        };
        assert!(bad.validate().is_err(), "bit flips need checksums");
        let bad_p = EngineConfig {
            fault_injection: Some(FaultConfig {
                eio: 2.0,
                ..FaultConfig::default()
            }),
            ..Default::default()
        };
        assert!(bad_p.validate().is_err(), "fault config is validated too");
    }

    #[test]
    fn ingest_knob_defaults_and_validation() {
        let c = EngineConfig::default();
        // ingestion follows the engine's thread pool by default, with a
        // multi-MB chunk so the scan amortizes per-read overheads
        assert_eq!(c.ingest_workers, 0);
        assert!(c.ingest_chunk_bytes >= 1 << 20);
        c.validate().unwrap();
        let bad = EngineConfig {
            ingest_chunk_bytes: 0,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn zero_threads_rejected() {
        let c = EngineConfig {
            threads: 0,
            ..Default::default()
        };
        assert!(c.validate().is_err());
    }
}
