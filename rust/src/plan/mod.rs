//! Cross-pass lazy optimizer: the plan IR between the virtual-matrix DAG
//! (§III-E) and the strip evaluator ([`crate::exec`]).
//!
//! Every materialize batch — the `fm.materialize` surface
//! ([`crate::fmr::engine::Engine::{materialize, materialize_sinks,
//! run_pass, plan_batch}`](crate::fmr::engine::Engine)) — is canonicalized
//! into a plan IR and run through three optimizer passes before any pass
//! streams:
//!
//! 1. **Structural CSE** (hash-consing): every [`VKind`] node gets a
//!    structural value key — kind + parameters + canonical child keys —
//!    and structurally-equal nodes are merged onto one canonical node, so
//!    repeated `sapply`/`mapply`/inner-product chains evaluate once per
//!    pass even when callers rebuilt them from scratch
//!    (`Metrics::opt_cse_hits`).
//! 2. **Dead-sink/dead-target pruning**: requests whose structural key
//!    already appears earlier in the batch are dead — they are pruned and
//!    fed from the surviving request's result
//!    (`Metrics::opt_sinks_pruned`).
//! 3. **Materialize-vs-recompute planning**: a shared intermediate that
//!    recurs across batches (iteration 2..n of a loop) is either
//!    materialized once through the `PartitionCache`/write-back path —
//!    with a residency pin ([`crate::matrix::DenseData::pin_resident`]) —
//!    or recomputed
//!    inside every fused pass, decided by a byte-cost model (bytes moved
//!    under the current cache budget vs. re-streamed compute, calibrated
//!    against the existing [`Metrics`](crate::metrics::Metrics) byte
//!    counters; `Metrics::opt_mat_decisions`).
//!
//! A small per-engine **plan cache** keyed by the batch's DAG *shape*
//! (structure only — not the constants and small host operands an
//! iterative loop changes every iteration) lets iteration 2..n of a loop
//! reuse the optimized pass grouping (`Metrics::opt_plan_cache_hits`).
//!
//! # Bit-identity
//!
//! The optimizer may only eliminate or reorder **whole redundant
//! evaluations** — never any single output's fold order. Three guards
//! enforce that:
//!
//! * CSE merges change neither the pass's source set nor its instruction
//!   shapes (leaves are keyed by `Arc` identity), so pass geometry —
//!   `pass_io`, the locality unit, the partition grid — is untouched.
//! * Requests merge into one pass only when the merged pass geometry
//!   equals each request's solo-pass geometry ([`Geometry`]), so a
//!   sink's per-worker partial boundaries and a target's stored
//!   partitioning are identical to the unoptimized schedule.
//! * A memoized intermediate substitutes into a pass only when the
//!   substituted DAG's geometry equals the recompute DAG's geometry; the
//!   memo value itself was materialized on that same grid.
//!
//! `tests/cross_pass.rs` pins optimizer-on vs optimizer-off byte equality
//! across IM/EM × `vectorized_udf` × `simd_kernels` on the iterative
//! workloads; `benches/cross_pass.rs` gates the pass/IO win.

use std::collections::hash_map::DefaultHasher;
use std::collections::{HashMap, HashSet};
use std::hash::{Hash, Hasher};
use std::sync::atomic::Ordering;
use std::sync::Mutex;

use crate::config::StorageKind;
use crate::dag::{SinkResult, SinkSpec, VKind, VNode};
use crate::error::Result;
use crate::util::sync::LockExt;
use crate::exec::{self, ExecCtx, PassGroup};
use crate::matrix::{io_rows_for, Matrix, MatrixData, Partitioning};

/// One forced materialization in a batch: a target matrix or a sink.
/// Logically each request is its own R statement — the planner decides
/// how many streaming passes actually run.
pub enum PlanRequest {
    Target(Matrix),
    Sink(SinkSpec),
}

impl PlanRequest {
    /// Target request from any matrix handle (the view is preserved).
    pub fn target(m: &Matrix) -> PlanRequest {
        PlanRequest::Target(m.clone())
    }

    /// Sink request.
    pub fn sink(s: SinkSpec) -> PlanRequest {
        PlanRequest::Sink(s)
    }
}

/// Result of one [`PlanRequest`], in request order.
#[derive(Clone)]
pub enum PlanOutput {
    Target(Matrix),
    Sink(SinkResult),
}

impl PlanOutput {
    pub fn target(self) -> Matrix {
        match self {
            PlanOutput::Target(m) => m,
            PlanOutput::Sink(_) => panic!("request produced a sink result, not a target"),
        }
    }

    pub fn sink(self) -> SinkResult {
        match self {
            PlanOutput::Sink(s) => s,
            PlanOutput::Target(_) => panic!("request produced a target, not a sink result"),
        }
    }
}

/// Maximum memoized intermediates kept per engine (LRU beyond this).
const MEMO_CAP: usize = 8;
/// Maximum cached plans / recurrence keys before the maps are reset
/// (bounds unrelated-workload growth; iteration loops never get close).
const STATE_CAP: usize = 4096;

/// A materialized shared intermediate, keyed by its structural value key.
/// The entry holds the canonical virtual subtree it replaces: that keeps
/// every `Arc` identity the key hashes alive, so a recycled allocation
/// can never alias an existing key.
struct MemoEntry {
    key: u64,
    value: Matrix,
    _subtree: Matrix,
    /// Partition-cache residency pins (RAII: released when the entry
    /// drops, on any path).
    _pins: PinGuard,
    stamp: u64,
}

/// RAII residency pins for a memoized intermediate. Pinning and
/// unpinning used to be two separate calls with every error path in
/// between able to leak the pins (shrinking the shared cache until
/// engine teardown); the guard ties the release to the entry's lifetime,
/// so memo eviction, planner resets, aborted batches and panics all
/// unpin.
struct PinGuard {
    value: Matrix,
    pinned: Vec<usize>,
}

impl PinGuard {
    fn pin(value: &Matrix) -> PinGuard {
        let pinned = match &*value.data {
            MatrixData::Dense(d) => d.pin_resident(),
            _ => Vec::new(),
        };
        PinGuard {
            value: value.clone(),
            pinned,
        }
    }
}

impl Drop for PinGuard {
    fn drop(&mut self) {
        if let MatrixData::Dense(d) = &*self.value.data {
            d.unpin_resident(&self.pinned);
        }
    }
}

/// Cached pass grouping for one batch shape.
struct CachedPlan {
    n_unique: usize,
    /// Unique-request indices per pass group, in execution order.
    groups: Vec<Vec<usize>>,
    /// Long dimension per group (validated against the next batch).
    long_dims: Vec<u64>,
}

/// Per-engine optimizer state ([`crate::fmr::engine::Engine::planner`]).
#[derive(Default)]
pub struct Planner {
    /// Structural key -> batches it appeared in (recurrence detection).
    seen: HashMap<u64, u32>,
    /// Structural key -> cost-model outcome, decided once when the key
    /// first recurs.
    decided: HashMap<u64, bool>,
    memo: Vec<MemoEntry>,
    plans: HashMap<u64, CachedPlan>,
    stamp: u64,
}

impl Planner {
    pub fn new() -> Planner {
        Planner::default()
    }

    fn memo_get(&mut self, key: u64) -> Option<Matrix> {
        let stamp = self.stamp;
        self.memo.iter_mut().find(|e| e.key == key).map(|e| {
            e.stamp = stamp;
            e.value.clone()
        })
    }

    fn memo_insert(&mut self, key: u64, value: Matrix, subtree: Matrix) {
        let pins = PinGuard::pin(&value);
        self.memo.push(MemoEntry {
            key,
            value,
            _subtree: subtree,
            _pins: pins,
            stamp: self.stamp,
        });
        while self.memo.len() > MEMO_CAP {
            let (i, _) = self
                .memo
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.stamp)
                .expect("non-empty memo");
            // dropping the entry's PinGuard releases its residency pins
            self.memo.swap_remove(i);
        }
    }
}

// ---------------------------------------------------------------------------
// Pass 1: canonicalization + structural CSE (hash-consing)

struct NodeInfo {
    /// Structural value key (parameters + canonical child identities).
    vkey: u64,
    /// Structural shape key (structure only; plan-cache identity).
    skey: u64,
    /// CSE-canonical node (no memo substitution).
    plain: Matrix,
    /// Canonical node with memoized intermediates substituted in.
    sub: Matrix,
}

struct Interner {
    /// Original `data_ptr` -> interned info.
    nodes: HashMap<usize, NodeInfo>,
    /// Value key -> canonical plain node (the hash-cons table).
    canon: HashMap<u64, Matrix>,
    /// Value key -> (plain, sub) canonical pair for virtual nodes.
    virt: HashMap<u64, (Matrix, Matrix)>,
    /// Leaf `data_ptr` -> first-visit ordinal (shape-key identity).
    leaf_ord: HashMap<usize, u64>,
    /// Snapshot of the planner's memoized intermediates (key, value).
    memo: Vec<(u64, Matrix)>,
    /// Memo keys substituted somewhere in this batch.
    memo_used: Vec<u64>,
    cse_hits: u64,
}

impl Interner {
    fn new(memo: Vec<(u64, Matrix)>) -> Interner {
        Interner {
            nodes: HashMap::new(),
            canon: HashMap::new(),
            virt: HashMap::new(),
            leaf_ord: HashMap::new(),
            memo,
            memo_used: Vec::new(),
            cse_hits: 0,
        }
    }

    /// Intern the matrix's *data* (the transpose flag belongs to the use
    /// site and is hashed by the consumer edge). Returns (vkey, skey).
    fn intern(&mut self, m: &Matrix) -> (u64, u64) {
        let ptr = m.data_ptr();
        if let Some(i) = self.nodes.get(&ptr) {
            return (i.vkey, i.skey);
        }
        let info = match &*m.data {
            MatrixData::Virtual(v) => {
                let parents: Vec<Matrix> = v.kind.parents().into_iter().cloned().collect();
                let mut edges: Vec<(u64, u64, bool)> = Vec::with_capacity(parents.len());
                for p in &parents {
                    let (vk, sk) = self.intern(p);
                    edges.push((vk, sk, p.transposed));
                }
                let mut hv = DefaultHasher::new();
                let mut hs = DefaultHasher::new();
                for h in [&mut hv, &mut hs] {
                    b"vnode".hash(h);
                    v.nrow.hash(h);
                    v.ncol.hash(h);
                    (v.dtype as u8).hash(h);
                }
                v.kind.hash_params(&mut hv, true);
                v.kind.hash_params(&mut hs, false);
                // SpMM's operands are sources, not `parents()`: anchor
                // the structural key on the sparse operand's grid, so two
                // same-shaped graphs over different matrices cannot alias
                // one cached plan (pass geometry follows that grid)
                if let VKind::Spmm { a, .. } = &v.kind {
                    a.data.nrow().hash(&mut hs);
                    a.data.ncol().hash(&mut hs);
                    (a.data.dtype() as u8).hash(&mut hs);
                    if let Some(io) = leaf_io_rows(&a.data) {
                        io.hash(&mut hs);
                    }
                }
                for (vk, sk, t) in &edges {
                    (vk, t).hash(&mut hv);
                    (sk, t).hash(&mut hs);
                }
                let (vkey, skey) = (hv.finish(), hs.finish());

                let plain = match self.canon.get(&vkey) {
                    Some(c) => {
                        if c.data_ptr() != ptr {
                            self.cse_hits += 1;
                        }
                        c.clone()
                    }
                    None => {
                        let p = self.rebuild(m, v, &parents, false);
                        self.canon.insert(vkey, p.clone());
                        p
                    }
                };
                // substitute a memoized materialization of this exact
                // value, if one exists (shape-checked against the node:
                // a 64-bit key collision must not slip a wrong matrix in)
                let hit = self.memo.iter().find(|(k, mv)| {
                    *k == vkey
                        && mv.data.nrow() == v.nrow
                        && mv.data.ncol() == v.ncol
                        && mv.data.dtype() == v.dtype
                });
                let sub = match hit {
                    Some((_, mv)) => {
                        let mv = mv.clone();
                        if !self.memo_used.contains(&vkey) {
                            self.memo_used.push(vkey);
                        }
                        mv
                    }
                    None => self.rebuild(m, v, &parents, true),
                };
                self.virt
                    .entry(vkey)
                    .or_insert_with(|| (plain.clone(), sub.clone()));
                NodeInfo {
                    vkey,
                    skey,
                    plain,
                    sub,
                }
            }
            _ => {
                // leaf (dense / sparse / group): Arc identity IS the value
                let ord = self.leaf_ord.len() as u64;
                let ord = *self.leaf_ord.entry(ptr).or_insert(ord);
                let mut hv = DefaultHasher::new();
                b"leaf".hash(&mut hv);
                ptr.hash(&mut hv);
                let mut hs = DefaultHasher::new();
                b"leaf".hash(&mut hs);
                ord.hash(&mut hs);
                m.data.nrow().hash(&mut hs);
                m.data.ncol().hash(&mut hs);
                (m.data.dtype() as u8).hash(&mut hs);
                // actual stored partitioning feeds pass geometry, so it is
                // part of the *shape* a cached plan may be reused for
                if let Some(io) = leaf_io_rows(&m.data) {
                    io.hash(&mut hs);
                }
                NodeInfo {
                    vkey: hv.finish(),
                    skey: hs.finish(),
                    plain: m.canonical(),
                    sub: m.canonical(),
                }
            }
        };
        let out = (info.vkey, info.skey);
        self.nodes.insert(ptr, info);
        out
    }

    /// Canonical rebuild: children replaced by their canonical
    /// representatives (plain or memo-substituted); reuses the original
    /// `Arc` when nothing below it changed.
    fn rebuild(&self, m: &Matrix, v: &VNode, parents: &[Matrix], sub: bool) -> Matrix {
        let reps: Vec<Matrix> = parents
            .iter()
            .map(|p| {
                let info = &self.nodes[&p.data_ptr()];
                let rep = if sub { &info.sub } else { &info.plain };
                Matrix {
                    data: rep.data.clone(),
                    transposed: p.transposed,
                }
            })
            .collect();
        if reps
            .iter()
            .zip(parents)
            .all(|(r, p)| r.data_ptr() == p.data_ptr())
        {
            return m.canonical();
        }
        Matrix::new(MatrixData::Virtual(VNode {
            nrow: v.nrow,
            ncol: v.ncol,
            dtype: v.dtype,
            kind: v.kind.with_parents(&reps),
        }))
    }

    /// Intern a sink: source + embedded matrices by canonical identity,
    /// kind parameters by value. Returns (vkey, skey, plain, sub).
    fn intern_sink(&mut self, s: &SinkSpec) -> (u64, u64, SinkSpec, SinkSpec) {
        let (src_vk, src_sk) = self.intern(&s.source);
        let kparents: Vec<Matrix> = s.kind.parents().into_iter().cloned().collect();
        let mut edges: Vec<(u64, u64, bool)> = Vec::with_capacity(kparents.len());
        for p in &kparents {
            let (vk, sk) = self.intern(p);
            edges.push((vk, sk, p.transposed));
        }
        let mut hv = DefaultHasher::new();
        let mut hs = DefaultHasher::new();
        for h in [&mut hv, &mut hs] {
            b"sink".hash(h);
            s.kind.hash_params(h);
        }
        (src_vk, s.source.transposed).hash(&mut hv);
        (src_sk, s.source.transposed).hash(&mut hs);
        for (vk, sk, t) in &edges {
            (vk, t).hash(&mut hv);
            (sk, t).hash(&mut hs);
        }
        let rebuilt = |iner: &Interner, sub: bool| -> SinkSpec {
            let pick = |p: &Matrix| {
                let info = &iner.nodes[&p.data_ptr()];
                let rep = if sub { &info.sub } else { &info.plain };
                Matrix {
                    data: rep.data.clone(),
                    transposed: p.transposed,
                }
            };
            let reps: Vec<Matrix> = kparents.iter().map(&pick).collect();
            SinkSpec {
                source: pick(&s.source),
                kind: s.kind.with_parents(&reps),
            }
        };
        (
            hv.finish(),
            hs.finish(),
            rebuilt(self, false),
            rebuilt(self, true),
        )
    }
}

fn leaf_io_rows(d: &MatrixData) -> Option<u64> {
    match d {
        MatrixData::Dense(dd) => Some(dd.parts.io_rows),
        MatrixData::Sparse(sp) => Some(sp.parts.io_rows),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// Pass geometry (exec::run_pass_opts's partitioning decisions, taken from
// the actually-compiled program)

/// The pass-shaping quantities of a (targets, sinks) DAG: everything that
/// determines partition boundaries, per-worker ranges and strip heights —
/// and therefore sink fold grouping and target partitioning. Computed
/// from the same compiled [`pipeline::Program`](crate::exec::pipeline)
/// the evaluator would run, so the mirror cannot drift from exec.
/// `None` when the DAG does not compile or would violate exec's
/// source-divisibility rule: the planner then refuses to merge or
/// substitute, which is always safe.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
struct Geometry {
    pass_io: u64,
    unit_io: u64,
    n_parts: usize,
    /// Widest instruction of the compiled program: with `fuse_cache` on
    /// it sets the CPU-strip heights, which group sink folds.
    widest: u64,
}

fn geometry(ctx: &ExecCtx<'_>, targets: &[&Matrix], sinks: &[&SinkSpec]) -> Option<Geometry> {
    let ts: Vec<Matrix> = targets.iter().map(|t| (*t).clone()).collect();
    let ss: Vec<SinkSpec> = sinks.iter().map(|s| clone_spec(s)).collect();
    let prog = exec::pipeline::compile_opts(
        &ts,
        &ss,
        exec::pipeline::CompileOpts {
            peephole_fuse: ctx.config.peephole_fuse,
            inplace_ops: ctx.config.inplace_ops,
        },
    )
    .ok()?;
    let mut pass_io = u64::MAX;
    for s in &prog.sources {
        if let Some(io) = leaf_io_rows(s.as_ref()) {
            pass_io = pass_io.min(io);
        }
    }
    for t in targets {
        pass_io = pass_io.min(io_rows_for(t.ncol()));
    }
    let widest = prog.instrs.iter().map(|i| i.ncol).max().unwrap_or(1);
    if pass_io == u64::MAX {
        // sinks over generator-only DAGs
        pass_io = io_rows_for(widest);
    }
    for s in &prog.sources {
        if let Some(io) = leaf_io_rows(s.as_ref()) {
            if io % pass_io != 0 {
                // exec rejects such passes outright; never plan one
                return None;
            }
        }
    }
    let mut unit_io = pass_io;
    for s in &prog.sources {
        if let Some(io) = leaf_io_rows(s.as_ref()) {
            unit_io = unit_io.max(io);
        }
    }
    let n_parts = Partitioning::with_io_rows(prog.nrow, 1, pass_io).n_parts();
    Some(Geometry {
        pass_io,
        unit_io,
        n_parts,
        widest,
    })
}

/// Value copy of a sink spec (`SinkSpec` is intentionally not `Clone`).
fn clone_spec(s: &SinkSpec) -> SinkSpec {
    SinkSpec {
        source: s.source.clone(),
        kind: s
            .kind
            .with_parents(&s.kind.parents().into_iter().cloned().collect::<Vec<_>>()),
    }
}

// ---------------------------------------------------------------------------
// Pass 3: materialize-vs-recompute cost model

/// Relative cost of a byte moved through the external store vs a byte of
/// streamed compute. Calibrated against the engine's own counters: the
/// vectorized GenOp path streams ~4x the bytes/sec of the (throttled)
/// SSD model (`benches/genops_micro.rs` GB/s rows vs `ThrottleConfig`),
/// and in-memory "I/O" is another ~8x cheaper than that.
const COMPUTE_DISCOUNT: f64 = 4.0;
const IN_MEM_IO_DISCOUNT: f64 = 8.0;

/// Decide whether the shared intermediate `cand` (canonical plain node)
/// should be materialized once and re-read, rather than recomputed inside
/// every pass that uses it. `roots` are the batch's canonical roots —
/// a source feeding the rest of the batch even without `cand` is not
/// chargeable to recomputation.
fn should_materialize(ctx: &ExecCtx<'_>, cand: &Matrix, roots: &[Matrix]) -> bool {
    let threshold = ctx.config.opt_materialize_threshold as u64;
    if threshold == 0 {
        return false;
    }
    let v = match &*cand.data {
        MatrixData::Virtual(v) => v,
        _ => return false,
    };
    let bytes = v.nrow * v.ncol * v.dtype.size() as u64;
    if bytes == 0 || bytes > threshold {
        return false;
    }

    // subtree accounting: streamed compute bytes + leaf sources
    let mut compute_bytes: u64 = 0;
    let mut leaves: HashMap<usize, u64> = HashMap::new();
    let mut visited: HashSet<usize> = HashSet::new();
    let mut stack = vec![cand.canonical()];
    while let Some(m) = stack.pop() {
        if !visited.insert(m.data_ptr()) {
            continue;
        }
        match &*m.data {
            MatrixData::Virtual(vv) => {
                compute_bytes += vv.nrow * vv.ncol * 8;
                for p in vv.kind.parents() {
                    stack.push(p.canonical());
                }
                if let crate::dag::VKind::Spmm { a, .. } = &vv.kind {
                    stack.push(a.canonical());
                }
            }
            MatrixData::Dense(d) => {
                leaves.insert(m.data_ptr(), d.nrow() * d.ncol() * d.dtype().size() as u64);
            }
            MatrixData::Sparse(sp) => {
                // nnz is not tracked on the handle; a row-index estimate
                // keeps sparse-fed candidates conservative
                leaves.insert(m.data_ptr(), sp.nrow() * 16);
            }
            MatrixData::Group(_) => return false,
        }
    }

    // leaves still reachable from the batch with `cand` cut out are
    // streamed anyway — only exclusive leaves charge to recomputation
    let cand_ptr = cand.data_ptr();
    let mut shared: HashSet<usize> = HashSet::new();
    let mut visited: HashSet<usize> = HashSet::new();
    let mut stack: Vec<Matrix> = roots.iter().map(|r| r.canonical()).collect();
    while let Some(m) = stack.pop() {
        let ptr = m.data_ptr();
        if ptr == cand_ptr || !visited.insert(ptr) {
            continue;
        }
        match &*m.data {
            MatrixData::Virtual(vv) => {
                for p in vv.kind.parents() {
                    stack.push(p.canonical());
                }
                if let crate::dag::VKind::Spmm { a, .. } = &vv.kind {
                    stack.push(a.canonical());
                }
            }
            _ => {
                shared.insert(ptr);
            }
        }
    }
    let exclusive_bytes: u64 = leaves
        .iter()
        .filter(|(ptr, _)| !shared.contains(*ptr))
        .map(|(_, b)| *b)
        .sum();

    let io_unit = match ctx.config.storage {
        StorageKind::External => 1.0,
        StorageKind::InMem => 1.0 / IN_MEM_IO_DISCOUNT,
    };
    let recompute = exclusive_bytes as f64 * io_unit + compute_bytes as f64 / COMPUTE_DISCOUNT;
    let write = bytes as f64 * io_unit;
    let fits_cache = ctx.config.storage == StorageKind::InMem
        || (ctx.cache.is_some() && (bytes as usize).saturating_mul(4) <= ctx.config.em_cache_bytes);
    let read_back = if fits_cache { 0.0 } else { bytes as f64 * io_unit };
    write + read_back < recompute
}

// ---------------------------------------------------------------------------
// Batch planning + execution

/// Planned form of one unique (post-pruning) request.
enum Unique {
    Target {
        plain: Matrix,
        sub: Matrix,
        vkey: u64,
        transposed: bool,
    },
    Sink {
        plain: SinkSpec,
        sub: SinkSpec,
    },
}

impl Unique {
    fn long_dim(&self) -> u64 {
        match self {
            Unique::Target {
                plain, transposed, ..
            } => view(plain, *transposed).nrow(),
            Unique::Sink { plain, .. } => plain.source.nrow(),
        }
    }

    /// The target node actually sent to the pass: children may be
    /// substituted with memoized copies, the root never is (a substituted
    /// root would return a matrix whose stored partitioning depends on
    /// the pass it was memoized from — not on this request).
    fn target_node<'a>(plain: &'a Matrix, sub: &'a Matrix, use_sub: bool) -> &'a Matrix {
        if use_sub && sub.data.is_virtual() {
            sub
        } else {
            plain
        }
    }

    fn solo_geometry(&self, ctx: &ExecCtx<'_>, sub: bool) -> Option<Geometry> {
        match self {
            Unique::Target {
                plain,
                sub: s,
                transposed,
                ..
            } => {
                let m = view(Unique::target_node(plain, s, sub), *transposed);
                geometry(ctx, &[&m], &[])
            }
            Unique::Sink { plain, sub: s } => geometry(ctx, &[], &[if sub { s } else { plain }]),
        }
    }
}

fn view(m: &Matrix, transposed: bool) -> Matrix {
    Matrix {
        data: m.data.clone(),
        transposed,
    }
}

/// Execute a batch of requests through the optimizer.
///
/// `fused = true` preserves the explicit batch surfaces' contract — the
/// whole batch is one hand-fused pass (`fm.materialize`); `false` is the
/// [`Engine::plan_batch`](crate::fmr::engine::Engine::plan_batch)
/// surface, where each request is an independent forced materialization
/// and the planner chooses the pass grouping. With `cross_pass_opt` off,
/// `fused` batches run exactly the legacy single pass and un-fused
/// batches run one pass per request.
pub fn execute_batch(
    ctx: &ExecCtx<'_>,
    planner: &Mutex<Planner>,
    requests: &[PlanRequest],
    fused: bool,
) -> Result<Vec<PlanOutput>> {
    if requests.is_empty() {
        return Ok(Vec::new());
    }
    if !ctx.config.cross_pass_opt {
        return execute_unplanned(ctx, requests, fused);
    }
    let mut pl = planner.lock_recover();
    pl.stamp += 1;

    // ---- optimizer pass 1+2: canonicalize, hash-cons, prune duplicates
    let memo_snapshot: Vec<(u64, Matrix)> =
        pl.memo.iter().map(|e| (e.key, e.value.clone())).collect();
    let mut it = Interner::new(memo_snapshot);
    let mut uniques: Vec<Unique> = Vec::new();
    let mut shape = DefaultHasher::new();
    fused.hash(&mut shape);
    let mut unique_of: Vec<usize> = Vec::with_capacity(requests.len());
    let mut by_key: HashMap<u64, usize> = HashMap::new();
    for r in requests {
        let (key, skey, u) = match r {
            PlanRequest::Target(t) => {
                let (vk, sk) = it.intern(t);
                let mut h = DefaultHasher::new();
                (b"t", vk, t.transposed).hash(&mut h);
                let info = &it.nodes[&t.data_ptr()];
                (
                    h.finish(),
                    sk,
                    Unique::Target {
                        plain: info.plain.clone(),
                        sub: info.sub.clone(),
                        vkey: vk,
                        transposed: t.transposed,
                    },
                )
            }
            PlanRequest::Sink(s) => {
                let (vk, sk, plain, sub) = it.intern_sink(s);
                let mut h = DefaultHasher::new();
                (b"s", vk).hash(&mut h);
                (h.finish(), sk, Unique::Sink { plain, sub })
            }
        };
        let root_t = match &u {
            Unique::Target { transposed, .. } => *transposed,
            Unique::Sink { .. } => false,
        };
        let ui = match by_key.get(&key) {
            Some(&ui) => ui,
            None => {
                let next = uniques.len();
                by_key.insert(key, next);
                uniques.push(u);
                next
            }
        };
        unique_of.push(ui);
        // plan-cache key: *structural* shape only. `skey` ignores leaf
        // `Arc` identity, so iteration 2..n of a loop — fresh data and
        // fresh host operands, same statement list — lands on the same
        // cached grouping; `ui` folds in this batch's value-level dedup
        // pattern and `root_t` the requested view, neither of which the
        // structural key can see.
        (skey, root_t, ui).hash(&mut shape);
    }
    let pruned = (requests.len() - uniques.len()) as u64;
    if pruned > 0 {
        ctx.metrics.opt_sinks_pruned.fetch_add(pruned, Ordering::Relaxed);
    }
    if it.cse_hits > 0 {
        ctx.metrics
            .opt_cse_hits
            .fetch_add(it.cse_hits, Ordering::Relaxed);
    }
    let shape_key = shape.finish();

    // ---- recurrence bookkeeping + one-shot cost decisions
    if pl.seen.len() > STATE_CAP {
        pl.seen.clear();
    }
    let batch_roots: Vec<Matrix> = uniques
        .iter()
        .flat_map(|u| match u {
            Unique::Target { plain, .. } => vec![plain.clone()],
            Unique::Sink { plain, .. } => {
                let mut v = vec![plain.source.canonical()];
                v.extend(plain.kind.parents().into_iter().map(|p| p.canonical()));
                v
            }
        })
        .collect();
    let target_root_keys: HashSet<u64> = uniques
        .iter()
        .filter_map(|u| match u {
            Unique::Target { vkey, .. } => Some(*vkey),
            Unique::Sink { .. } => None,
        })
        .collect();
    let mut to_materialize: Vec<u64> = Vec::new();
    let virt_keys: Vec<u64> = it.virt.keys().copied().collect();
    for vk in virt_keys {
        let count = {
            let c = pl.seen.entry(vk).or_insert(0);
            *c += 1;
            *c
        };
        // a target's result is never memoized: its key embeds the batch's
        // per-iteration leaves, so it cannot recur — and it is already
        // being materialized for the caller
        if target_root_keys.contains(&vk) {
            continue;
        }
        if count == 2 && !pl.decided.contains_key(&vk) {
            let cand = it.virt[&vk].0.clone();
            let mat = should_materialize(ctx, &cand, &batch_roots);
            if pl.decided.len() > STATE_CAP {
                pl.decided.clear();
            }
            pl.decided.insert(vk, mat);
        }
        if pl.decided.get(&vk) == Some(&true)
            && pl.memo.iter().all(|e| e.key != vk)
            && !to_materialize.contains(&vk)
        {
            to_materialize.push(vk);
        }
    }

    // ---- pass grouping: plan cache, else long-dim grouping + the
    // geometry fixpoint that keeps every merged request on its solo grid
    let long_dims: Vec<u64> = uniques.iter().map(|u| u.long_dim()).collect();
    let cached = pl.plans.get(&shape_key).and_then(|p| {
        let valid = p.n_unique == uniques.len()
            && p.groups.len() == p.long_dims.len()
            && p.groups.iter().zip(&p.long_dims).all(|(g, ld)| {
                !g.is_empty() && g.iter().all(|&ui| ui < uniques.len() && long_dims[ui] == *ld)
            });
        if valid {
            Some(p.groups.clone())
        } else {
            None
        }
    });
    let groups: Vec<Vec<usize>> = match cached {
        Some(g) => {
            ctx.metrics.opt_plan_cache_hits.fetch_add(1, Ordering::Relaxed);
            g
        }
        None => {
            let groups = if fused {
                vec![(0..uniques.len()).collect::<Vec<usize>>()]
            } else {
                plan_groups(ctx, &uniques, &long_dims)
            };
            if pl.plans.len() > STATE_CAP {
                pl.plans.clear();
            }
            pl.plans.insert(
                shape_key,
                CachedPlan {
                    n_unique: uniques.len(),
                    groups: groups.clone(),
                    long_dims: groups
                        .iter()
                        .map(|g| long_dims[g[0]])
                        .collect(),
                },
            );
            groups
        }
    };

    // ---- assemble pass groups; decide memo substitution per group
    let mut outputs: Vec<Option<PlanOutput>> = vec![None; uniques.len()];
    let mut pass_groups: Vec<PassGroup> = Vec::new();
    // per pass group: (target unique ids, sink unique ids, extra keys)
    let mut group_meta: Vec<(Vec<usize>, Vec<usize>, Vec<u64>)> = Vec::new();
    let mut subs_used = false;
    for g in &groups {
        let mut t_ids: Vec<usize> = Vec::new();
        let mut s_ids: Vec<usize> = Vec::new();
        for &ui in g {
            match &uniques[ui] {
                Unique::Target { .. } => t_ids.push(ui),
                Unique::Sink { .. } => s_ids.push(ui),
            }
        }
        // substitute memoized intermediates only when the rewritten DAG
        // keeps the exact pass geometry of the recompute DAG
        let use_sub = if it.memo_used.is_empty() {
            false
        } else {
            let geo_of = |sub: bool| {
                let ts: Vec<Matrix> = t_ids
                    .iter()
                    .map(|&ui| match &uniques[ui] {
                        Unique::Target {
                            plain,
                            sub: s,
                            transposed,
                            ..
                        } => view(Unique::target_node(plain, s, sub), *transposed),
                        Unique::Sink { .. } => unreachable!(),
                    })
                    .collect();
                let ss: Vec<&SinkSpec> = s_ids
                    .iter()
                    .map(|&ui| match &uniques[ui] {
                        Unique::Sink { plain, sub: s } => {
                            if sub {
                                s
                            } else {
                                plain
                            }
                        }
                        Unique::Target { .. } => unreachable!(),
                    })
                    .collect();
                let trefs: Vec<&Matrix> = ts.iter().collect();
                geometry(ctx, &trefs, &ss)
            };
            match (geo_of(false), geo_of(true)) {
                (Some(a), Some(b)) => a == b,
                _ => false,
            }
        };
        if use_sub {
            subs_used = true;
        }

        let mut targets: Vec<Matrix> = Vec::new();
        for &ui in &t_ids {
            if let Unique::Target {
                plain,
                sub,
                transposed,
                ..
            } = &uniques[ui]
            {
                targets.push(view(Unique::target_node(plain, sub, use_sub), *transposed));
            }
        }
        let sinks: Vec<SinkSpec> = s_ids
            .iter()
            .map(|&ui| match &uniques[ui] {
                Unique::Sink { plain, sub } => {
                    let s = if use_sub { sub } else { plain };
                    SinkSpec {
                        source: s.source.clone(),
                        kind: s.kind.with_parents(
                            &s.kind.parents().into_iter().cloned().collect::<Vec<_>>(),
                        ),
                    }
                }
                Unique::Target { .. } => unreachable!(),
            })
            .collect();
        if targets.is_empty() && sinks.is_empty() {
            continue;
        }

        // cost-model extra targets: materialize recurring intermediates
        // in the pass that already computes them — but only when writing
        // the extra output leaves the pass geometry exactly as it was
        // (an extra target enters exec's `pass_io` min, so this is
        // re-checked with the full geometry mirror, not just a bound)
        let mut extras: Vec<u64> = Vec::new();
        let mut extra_targets: Vec<Matrix> = Vec::new();
        if !to_materialize.is_empty() {
            let srefs: Vec<&SinkSpec> = sinks.iter().collect();
            let base: Vec<&Matrix> = targets.iter().collect();
            if let Some(geo) = geometry(ctx, &base, &srefs) {
                let mut reach: HashSet<usize> = HashSet::new();
                {
                    let mut stack: Vec<Matrix> = targets.iter().map(|t| t.canonical()).collect();
                    for s in &sinks {
                        stack.push(s.source.canonical());
                        for p in s.kind.parents() {
                            stack.push(p.canonical());
                        }
                    }
                    while let Some(m) = stack.pop() {
                        if !reach.insert(m.data_ptr()) {
                            continue;
                        }
                        if let MatrixData::Virtual(v) = &*m.data {
                            for p in v.kind.parents() {
                                stack.push(p.canonical());
                            }
                        }
                    }
                }
                for &vk in &to_materialize {
                    let node = &it.virt[&vk];
                    let node = if use_sub { &node.1 } else { &node.0 };
                    if !node.data.is_virtual() || !reach.contains(&node.data_ptr()) {
                        continue;
                    }
                    let cand = node.canonical();
                    let trial: Vec<&Matrix> = targets
                        .iter()
                        .chain(extra_targets.iter())
                        .chain(std::iter::once(&cand))
                        .collect();
                    if geometry(ctx, &trial, &srefs) == Some(geo) {
                        extra_targets.push(cand);
                        extras.push(vk);
                    }
                }
            }
        }
        targets.extend(extra_targets);
        to_materialize.retain(|vk| !extras.contains(vk));

        pass_groups.push(PassGroup { targets, sinks });
        group_meta.push((t_ids, s_ids, extras));
    }
    if subs_used {
        ctx.metrics
            .opt_mat_decisions
            .fetch_add(it.memo_used.len() as u64, Ordering::Relaxed);
        for &vk in &it.memo_used {
            let _ = pl.memo_get(vk); // refresh LRU stamps
        }
    }

    // ---- execute the planned pass groups
    let results = match exec::run_groups(ctx, &pass_groups) {
        Ok(r) => r,
        Err(e) => {
            // An aborted batch must not strand residency pins: the memo
            // may reference intermediates whose backing pass never
            // flushed, and pins held past the abort would shrink the
            // shared cache for every tenant. Dropping the memo releases
            // each entry's PinGuard, so `pinned_bytes` returns to the
            // pre-batch level.
            pl.memo.clear();
            return Err(e);
        }
    };
    for (ri, (out_targets, out_sinks)) in results.into_iter().enumerate() {
        let (t_ids, s_ids, extras) = &group_meta[ri];
        let mut ot = out_targets.into_iter();
        for &ui in t_ids {
            outputs[ui] = Some(PlanOutput::Target(ot.next().expect("target result")));
        }
        for (&vk, value) in extras.iter().zip(ot) {
            ctx.metrics.opt_mat_decisions.fetch_add(1, Ordering::Relaxed);
            let subtree = it.virt[&vk].0.clone();
            pl.memo_insert(vk, value, subtree);
        }
        for (&ui, sr) in s_ids.iter().zip(out_sinks) {
            outputs[ui] = Some(PlanOutput::Sink(sr));
        }
    }

    Ok(unique_of
        .into_iter()
        .map(|ui| outputs[ui].clone().expect("planned request resolved"))
        .collect())
}

/// Long-dim grouping with the geometry fixpoint: requests merge into one
/// pass only while the merged pass keeps every member's solo geometry
/// (identical partition grid and per-worker ranges ⇒ identical fold
/// grouping). Members that would shift the grid run as their own passes —
/// still CSE-canonicalized, never reshaped.
fn plan_groups(ctx: &ExecCtx<'_>, uniques: &[Unique], long_dims: &[u64]) -> Vec<Vec<usize>> {
    let mut groups: Vec<Vec<usize>> = Vec::new();
    let mut dim_group: HashMap<u64, usize> = HashMap::new();
    for (ui, ld) in long_dims.iter().enumerate() {
        match dim_group.get(ld) {
            Some(&g) => groups[g].push(ui),
            None => {
                dim_group.insert(*ld, groups.len());
                groups.push(vec![ui]);
            }
        }
    }
    let mut out: Vec<Vec<usize>> = Vec::new();
    for mut g in groups {
        // fixpoint: dropping a member can change the merged geometry, so
        // re-evaluate until a round drops nobody
        while g.len() > 1 {
            let ts: Vec<Matrix> = g
                .iter()
                .filter_map(|&ui| match &uniques[ui] {
                    Unique::Target {
                        plain, transposed, ..
                    } => Some(view(plain, *transposed)),
                    Unique::Sink { .. } => None,
                })
                .collect();
            let ss: Vec<&SinkSpec> = g
                .iter()
                .filter_map(|&ui| match &uniques[ui] {
                    Unique::Sink { plain, .. } => Some(plain),
                    Unique::Target { .. } => None,
                })
                .collect();
            let trefs: Vec<&Matrix> = ts.iter().collect();
            let merged = match geometry(ctx, &trefs, &ss) {
                Some(m) => m,
                None => {
                    // unmodeled source kind: fall back to solo passes
                    for ui in g.drain(..) {
                        out.push(vec![ui]);
                    }
                    break;
                }
            };
            let before = g.len();
            g.retain(|&ui| {
                let keep = match uniques[ui].solo_geometry(ctx, false) {
                    Some(solo) => match &uniques[ui] {
                        // target values are row-local: only the stored
                        // partitioning (pass_io) must match the solo run
                        Unique::Target { .. } => solo.pass_io == merged.pass_io,
                        // sink folds group by partition AND strip: the
                        // merged program must reproduce both boundaries
                        Unique::Sink { .. } => {
                            solo.widest == merged.widest
                                && ((solo.pass_io == merged.pass_io
                                    && solo.unit_io == merged.unit_io)
                                    || (solo.n_parts == 1 && merged.n_parts == 1))
                        }
                    },
                    None => false,
                };
                if !keep {
                    out.push(vec![ui]);
                }
                keep
            });
            if g.len() == before {
                break;
            }
        }
        if !g.is_empty() {
            out.push(g);
        }
    }
    out
}

/// The unoptimized execution paths: the legacy single fused pass for the
/// explicit batch surfaces, or one pass per request for `plan_batch`.
fn execute_unplanned(
    ctx: &ExecCtx<'_>,
    requests: &[PlanRequest],
    fused: bool,
) -> Result<Vec<PlanOutput>> {
    if fused {
        let targets: Vec<Matrix> = requests
            .iter()
            .filter_map(|r| match r {
                PlanRequest::Target(t) => Some(t.clone()),
                PlanRequest::Sink(_) => None,
            })
            .collect();
        let sinks: Vec<SinkSpec> = requests
            .iter()
            .filter_map(|r| match r {
                PlanRequest::Sink(s) => Some(SinkSpec {
                    source: s.source.clone(),
                    kind: s
                        .kind
                        .with_parents(&s.kind.parents().into_iter().cloned().collect::<Vec<_>>()),
                }),
                PlanRequest::Target(_) => None,
            })
            .collect();
        let (out_t, out_s) = exec::run_pass(ctx, &targets, &sinks)?;
        let mut ti = out_t.into_iter();
        let mut si = out_s.into_iter();
        return Ok(requests
            .iter()
            .map(|r| match r {
                PlanRequest::Target(_) => PlanOutput::Target(ti.next().expect("target result")),
                PlanRequest::Sink(_) => PlanOutput::Sink(si.next().expect("sink result")),
            })
            .collect());
    }
    requests
        .iter()
        .map(|r| match r {
            PlanRequest::Target(t) => {
                let (out, _) = exec::run_pass(ctx, std::slice::from_ref(t), &[])?;
                Ok(PlanOutput::Target(out.into_iter().next().expect("target")))
            }
            PlanRequest::Sink(s) => {
                let spec = SinkSpec {
                    source: s.source.clone(),
                    kind: s
                        .kind
                        .with_parents(&s.kind.parents().into_iter().cloned().collect::<Vec<_>>()),
                };
                let (_, out) = exec::run_pass(ctx, &[], &[spec])?;
                Ok(PlanOutput::Sink(out.into_iter().next().expect("sink")))
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;
    use crate::dag::UnFn;
    use crate::dtype::Scalar;
    use crate::fmr::{EngineExt, FmMatrix};
    use crate::genops;
    use crate::matrix::HostMat;
    use crate::vudf::{AggOp, BinOp, UnOp};
    use crate::Engine;
    use std::sync::Arc;

    /// Engine with the optimizer forced on, independent of the
    /// `FLASHR_NO_CROSS_PASS_OPT` environment override.
    fn opt_engine() -> Arc<Engine> {
        let c = EngineConfig {
            cross_pass_opt: true,
            opt_materialize_threshold: 16 << 20,
            ..EngineConfig::default()
        };
        Engine::new(c).unwrap()
    }

    fn host(eng: &Arc<Engine>, m: &Matrix) -> HostMat {
        FmMatrix {
            eng: Arc::clone(eng),
            m: m.clone(),
        }
        .to_host()
        .unwrap()
    }

    #[test]
    fn cse_merges_structural_duplicates_in_one_pass() {
        let eng = opt_engine();
        let x = eng.runif_matrix(2048, 2, 0.0, 1.0, 7);
        // two structurally identical chains built from scratch: distinct
        // Arcs, same recorded computation
        let a1 = genops::sapply(&x.m, UnFn::Builtin(UnOp::Sqrt));
        let a2 = genops::sapply(&x.m, UnFn::Builtin(UnOp::Sqrt));
        assert_ne!(a1.data_ptr(), a2.data_ptr());
        let before = eng.metrics.snapshot();
        let out = eng.materialize(&[a1, a2]).unwrap();
        let after = eng.metrics.snapshot();
        assert_eq!(after.passes_run - before.passes_run, 1);
        assert_eq!(after.opt_cse_hits - before.opt_cse_hits, 1);
        // CSE merged them onto one canonical node -> one evaluation,
        // one shared result
        assert_eq!(out[0].data_ptr(), out[1].data_ptr());
        assert_eq!(host(&eng, &out[0]), host(&eng, &out[1]));
    }

    #[test]
    fn duplicate_targets_and_sinks_are_pruned() {
        let eng = opt_engine();
        let y = eng.runif_matrix(2048, 2, 0.0, 1.0, 9);
        let v = genops::sapply(&y.m, UnFn::Builtin(UnOp::Abs));

        let before = eng.metrics.snapshot();
        let out = eng.materialize(&[v.clone(), v.clone()]).unwrap();
        let mid = eng.metrics.snapshot();
        assert_eq!(mid.passes_run - before.passes_run, 1);
        assert_eq!(mid.opt_sinks_pruned - before.opt_sinks_pruned, 1);
        assert_eq!(out[0].data_ptr(), out[1].data_ptr());

        let s1 = genops::agg_full(&v, AggOp::Sum);
        let s2 = genops::agg_full(&v, AggOp::Sum);
        let r = eng.materialize_sinks(&[s1, s2]).unwrap();
        let after = eng.metrics.snapshot();
        assert_eq!(after.passes_run - mid.passes_run, 1);
        assert_eq!(after.opt_sinks_pruned - mid.opt_sinks_pruned, 1);
        assert_eq!(r[0].scalar(), r[1].scalar());
    }

    #[test]
    fn plan_cache_hits_on_repeated_batch_shape() {
        let eng = opt_engine();
        let before = eng.metrics.snapshot();
        let mut sums = Vec::new();
        for _ in 0..2 {
            // rebuilt from scratch each round, like a loop iteration
            let x = eng.runif_matrix(2048, 2, 0.0, 1.0, 11);
            let t = genops::sapply(&x.m, UnFn::Builtin(UnOp::Sqrt));
            let s = genops::agg_full(&t, AggOp::Sum);
            let reqs = [PlanRequest::target(&t), PlanRequest::Sink(s)];
            let out = eng.plan_batch(&reqs).unwrap();
            sums.push(out[1].clone().sink().scalar());
        }
        let after = eng.metrics.snapshot();
        assert!(after.opt_plan_cache_hits - before.opt_plan_cache_hits >= 1);
        assert_eq!(sums[0], sums[1]);
    }

    /// A recurring shared intermediate is materialized once (round 2) and
    /// substituted from the memo afterwards (round 3) — with identical
    /// results every round.
    #[test]
    fn recurring_intermediate_is_memoized() {
        let eng = opt_engine();
        let before = eng.metrics.snapshot();
        let mut hosts = Vec::new();
        let mut scalars = Vec::new();
        // the data leaf is the loop-invariant part (like X in IRLS):
        // recurrence is *value* identity, so the virtual chains are
        // rebuilt from scratch each round over the same `Arc`
        let x = eng.runif_matrix(2048, 2, 0.0, 1.0, 13);
        for _ in 0..3 {
            let shared = genops::sapply(&x.m, UnFn::Builtin(UnOp::Sqrt));
            let t = genops::mapply_scalar(&shared, Scalar::F64(2.0), BinOp::Mul, true);
            let s_src = genops::mapply_scalar(&shared, Scalar::F64(1.0), BinOp::Add, true);
            let s = genops::agg_full(&s_src, AggOp::Sum);
            let reqs = [PlanRequest::target(&t), PlanRequest::Sink(s)];
            let out = eng.plan_batch(&reqs).unwrap();
            hosts.push(host(&eng, &out[0].clone().target()));
            scalars.push(out[1].clone().sink().scalar());
            let snap = eng.metrics.snapshot();
            assert_eq!(snap.passes_run - before.passes_run, hosts.len() as u64);
        }
        let after = eng.metrics.snapshot();
        // round 2 materializes the recurring intermediates, round 3
        // substitutes them
        assert!(after.opt_mat_decisions - before.opt_mat_decisions >= 2);
        assert_eq!(hosts[0], hosts[1]);
        assert_eq!(hosts[0], hosts[2]);
        assert_eq!(scalars[0], scalars[1]);
        assert_eq!(scalars[0], scalars[2]);
    }

    #[test]
    fn zero_threshold_disables_materialize_planning() {
        let c = EngineConfig {
            cross_pass_opt: true,
            opt_materialize_threshold: 0,
            ..EngineConfig::default()
        };
        let eng = Engine::new(c).unwrap();
        let before = eng.metrics.snapshot();
        let mut scalars = Vec::new();
        let x = eng.runif_matrix(2048, 2, 0.0, 1.0, 13);
        for _ in 0..3 {
            let shared = genops::sapply(&x.m, UnFn::Builtin(UnOp::Sqrt));
            let s_src = genops::mapply_scalar(&shared, Scalar::F64(1.0), BinOp::Add, true);
            let reqs = [PlanRequest::Sink(genops::agg_full(&s_src, AggOp::Sum))];
            let out = eng.plan_batch(&reqs).unwrap();
            scalars.push(out[0].clone().sink().scalar());
        }
        let after = eng.metrics.snapshot();
        assert_eq!(after.opt_mat_decisions - before.opt_mat_decisions, 0);
        assert_eq!(scalars[0], scalars[1]);
        assert_eq!(scalars[0], scalars[2]);
    }

    /// Requests whose solo pass geometry disagrees are not merged: the
    /// planner runs them as separate passes, exactly as the eager path
    /// would, so their stored partitionings never change.
    #[test]
    fn incompatible_geometry_splits_passes() {
        let eng = opt_engine();
        // io_rows_for(1024) = 1024 rows, io_rows_for(2) = 65536 rows
        let wide = eng.runif_matrix(4096, 1024, 0.0, 1.0, 17);
        let narrow = eng.runif_matrix(4096, 2, 0.0, 1.0, 19);
        let tw = genops::sapply(&wide.m, UnFn::Builtin(UnOp::Sqrt));
        let tn = genops::sapply(&narrow.m, UnFn::Builtin(UnOp::Sqrt));
        let before = eng.metrics.snapshot();
        let out = eng
            .plan_batch(&[PlanRequest::target(&tw), PlanRequest::target(&tn)])
            .unwrap();
        let after = eng.metrics.snapshot();
        assert_eq!(after.passes_run - before.passes_run, 2);

        // byte-identical to solo materialization on a fresh engine
        let eng2 = opt_engine();
        let wide2 = eng2.runif_matrix(4096, 1024, 0.0, 1.0, 17);
        let narrow2 = eng2.runif_matrix(4096, 2, 0.0, 1.0, 19);
        let tw2 = genops::sapply(&wide2.m, UnFn::Builtin(UnOp::Sqrt));
        let tn2 = genops::sapply(&narrow2.m, UnFn::Builtin(UnOp::Sqrt));
        assert_eq!(
            host(&eng, &out[0].clone().target()),
            host(&eng2, &eng2.materialize(&[tw2]).unwrap()[0])
        );
        assert_eq!(
            host(&eng, &out[1].clone().target()),
            host(&eng2, &eng2.materialize(&[tn2]).unwrap()[0])
        );
    }

    /// With the optimizer off, the explicit batch surfaces run the legacy
    /// single fused pass and produce the same bytes as with it on.
    #[test]
    fn opt_off_matches_opt_on() {
        let eng_on = opt_engine();
        let c = EngineConfig {
            cross_pass_opt: false,
            ..EngineConfig::default()
        };
        let eng_off = Engine::new(c).unwrap();
        let mk = |eng: &Arc<Engine>| {
            let x = eng.runif_matrix(2048, 3, -1.0, 1.0, 23);
            let t = genops::sapply(&x.m, UnFn::Builtin(UnOp::Abs));
            let s = genops::agg_full(&t, AggOp::Sum);
            (t, s)
        };
        let (t_on, s_on) = mk(&eng_on);
        let (t_off, s_off) = mk(&eng_off);
        let (m_on, r_on) = eng_on.run_pass(&[t_on], &[s_on]).unwrap();
        let (m_off, r_off) = eng_off.run_pass(&[t_off], &[s_off]).unwrap();
        assert_eq!(host(&eng_on, &m_on[0]), host(&eng_off, &m_off[0]));
        assert_eq!(r_on[0].scalar(), r_off[0].scalar());
    }
}
