//! AOT XLA/PJRT compute path (the paper's BLAS dispatch, §III-C).
//!
//! The paper routes floating-point `fm.inner.prod` to BLAS "to achieve the
//! speed and precision required by numeric libraries". This reproduction
//! routes whole per-partition algorithm steps to **AOT-compiled XLA
//! executables** produced from JAX/Pallas at build time (`make artifacts`):
//! the Rust engine stays generic (any dtype, any VUDF), and partitions
//! whose shapes match an artifact take the optimized path.
//!
//! PJRT wrapper types are not `Send`, so the runtime is a dedicated
//! **service thread** owning the `PjRtClient` and the compiled executables;
//! [`XlaService`] is a cloneable, thread-safe handle that marshals
//! [`HostTensor`]s over a channel. Executables compile lazily on first use
//! and are cached for the life of the service.

pub mod jobs;
pub mod manifest;

pub use jobs::{JobQueue, Ticket};
pub use manifest::{ArtifactMeta, TensorSpec};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};

use crate::dtype::DType;
use crate::error::{FmError, Result};
use crate::util::sync::LockExt;
// The `xla` name resolves to the in-tree stub unless the real crate is
// wired in (see src/xla_stub.rs).
use crate::xla_stub as xla;

/// A host-side tensor crossing the service boundary.
#[derive(Clone, Debug)]
pub struct HostTensor {
    /// Row-major dims.
    pub dims: Vec<usize>,
    pub data: TensorData,
}

#[derive(Clone, Debug)]
pub enum TensorData {
    F64(Vec<f64>),
    F32(Vec<f32>),
    I32(Vec<i32>),
    I64(Vec<i64>),
}

impl HostTensor {
    pub fn f64(dims: Vec<usize>, data: Vec<f64>) -> HostTensor {
        debug_assert_eq!(dims.iter().product::<usize>(), data.len());
        HostTensor {
            dims,
            data: TensorData::F64(data),
        }
    }

    pub fn scalar_f64(v: f64) -> HostTensor {
        HostTensor::f64(vec![], vec![v])
    }

    pub fn as_f64(&self) -> Result<&[f64]> {
        match &self.data {
            TensorData::F64(v) => Ok(v),
            _ => Err(FmError::Runtime("expected f64 tensor".into())),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => Err(FmError::Runtime("expected i32 tensor".into())),
        }
    }

    pub fn dtype(&self) -> DType {
        match &self.data {
            TensorData::F64(_) => DType::F64,
            TensorData::F32(_) => DType::F32,
            TensorData::I32(_) => DType::I32,
            TensorData::I64(_) => DType::I64,
        }
    }
}

enum Request {
    Run {
        name: String,
        inputs: Vec<HostTensor>,
        reply: SyncSender<Result<Vec<HostTensor>>>,
    },
}

/// Thread-safe handle to the XLA service thread.
#[derive(Clone)]
pub struct XlaService {
    tx: SyncSender<Request>,
    metas: Arc<Vec<ArtifactMeta>>,
    /// (kind, p, k) -> manifest index; rows is implied by p via the shared
    /// partitioning formula.
    by_key: Arc<HashMap<(String, u64, u64), usize>>,
    /// Names that failed to compile (don't retry every partition).
    poisoned: Arc<Mutex<std::collections::HashSet<String>>>,
}

impl XlaService {
    /// Load the manifest and start the service thread. Fails fast if the
    /// manifest is missing or inconsistent; individual modules compile
    /// lazily on first dispatch.
    pub fn start(artifacts_dir: &Path) -> Result<XlaService> {
        let metas = manifest::load_manifest(artifacts_dir)?;
        let mut by_key = HashMap::new();
        for (i, m) in metas.iter().enumerate() {
            by_key.insert((m.kind.clone(), m.p, m.k), i);
        }
        let (tx, rx) = sync_channel::<Request>(16);
        let dir = artifacts_dir.to_path_buf();
        let meta_for_thread: Vec<(String, String)> = metas
            .iter()
            .map(|m| (m.name.clone(), m.file.clone()))
            .collect();
        std::thread::Builder::new()
            .name("xla-service".into())
            .spawn(move || service_main(dir, meta_for_thread, rx))
            .map_err(|e| FmError::Runtime(format!("cannot spawn xla service: {e}")))?;
        Ok(XlaService {
            tx,
            metas: Arc::new(metas),
            by_key: Arc::new(by_key),
            poisoned: Arc::new(Mutex::new(Default::default())),
        })
    }

    /// Find an artifact by dispatch key.
    pub fn lookup(&self, kind: &str, p: u64, k: u64) -> Option<&ArtifactMeta> {
        let idx = *self.by_key.get(&(kind.to_string(), p, k))?;
        let m = &self.metas[idx];
        if self.poisoned.lock_recover().contains(&m.name) {
            None
        } else {
            Some(m)
        }
    }

    pub fn artifacts(&self) -> &[ArtifactMeta] {
        &self.metas
    }

    /// Execute an artifact by name. Blocks until the service replies.
    pub fn run(&self, name: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        let (rtx, rrx) = sync_channel(1);
        self.tx
            .send(Request::Run {
                name: name.to_string(),
                inputs,
                reply: rtx,
            })
            .map_err(|_| FmError::Runtime("xla service thread died".into()))?;
        let res = rrx
            .recv()
            .map_err(|_| FmError::Runtime("xla service dropped reply".into()))?;
        if res.is_err() {
            self.poisoned.lock_recover().insert(name.to_string());
        }
        res
    }
}

// ---------------------------------------------------------------------------
// Service thread: owns all !Send PJRT state.
// ---------------------------------------------------------------------------

fn service_main(dir: PathBuf, metas: Vec<(String, String)>, rx: Receiver<Request>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            // fail every request with the same error
            while let Ok(Request::Run { reply, .. }) = rx.recv() {
                let _ = reply.send(Err(FmError::Runtime(format!(
                    "PJRT CPU client failed to start: {e}"
                ))));
            }
            return;
        }
    };
    let files: HashMap<String, String> = metas.into_iter().collect();
    let mut compiled: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();

    while let Ok(Request::Run {
        name,
        inputs,
        reply,
    }) = rx.recv()
    {
        let result = (|| -> Result<Vec<HostTensor>> {
            if !compiled.contains_key(&name) {
                let file = files
                    .get(&name)
                    .ok_or_else(|| FmError::Runtime(format!("unknown artifact '{name}'")))?;
                let path = dir.join(file);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str()
                        .ok_or_else(|| FmError::Runtime("non-utf8 path".into()))?,
                )?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client.compile(&comp)?;
                compiled.insert(name.clone(), exe);
            }
            let exe = &compiled[&name];
            let lits: Vec<xla::Literal> = inputs.iter().map(to_literal).collect::<Result<_>>()?;
            let out = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
            // aot.py lowers with return_tuple=True: always a tuple
            let parts = out.to_tuple()?;
            parts.into_iter().map(from_literal).collect()
        })();
        let _ = reply.send(result);
    }
}

fn to_literal(t: &HostTensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
    let lit = match &t.data {
        TensorData::F64(v) => xla::Literal::vec1(v),
        TensorData::F32(v) => xla::Literal::vec1(v),
        TensorData::I32(v) => xla::Literal::vec1(v),
        TensorData::I64(v) => xla::Literal::vec1(v),
    };
    Ok(lit.reshape(&dims)?)
}

fn from_literal(lit: xla::Literal) -> Result<HostTensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = match shape.ty() {
        xla::ElementType::F64 => TensorData::F64(lit.to_vec::<f64>()?),
        xla::ElementType::F32 => TensorData::F32(lit.to_vec::<f32>()?),
        xla::ElementType::S32 => TensorData::I32(lit.to_vec::<i32>()?),
        xla::ElementType::S64 => TensorData::I64(lit.to_vec::<i64>()?),
        other => {
            return Err(FmError::Runtime(format!(
                "unsupported artifact output type {other:?}"
            )))
        }
    };
    Ok(HostTensor { dims, data })
}
