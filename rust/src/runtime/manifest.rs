//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime.
//!
//! `make artifacts` writes `artifacts/manifest.json` describing every AOT
//! HLO module: input/output shapes+dtypes and the (kind, rows, p, k)
//! dispatch key. The engine only dispatches a partition step to XLA when an
//! artifact's input shape matches the partition exactly (tail partitions
//! fall back to the native GenOp path).

use std::path::Path;

use crate::dtype::DType;
use crate::error::{FmError, Result};
use crate::util::json::Json;

/// Shape + dtype of one artifact input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    /// Dispatch kind: "summary" | "gramian" | "gramian_centered" |
    /// "kmeans" | "gmm".
    pub kind: String,
    pub rows: u64,
    pub p: u64,
    /// Cluster count for kmeans/gmm artifacts (0 otherwise).
    pub k: u64,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

fn parse_dtype(s: &str) -> Result<DType> {
    Ok(match s {
        "float64" => DType::F64,
        "float32" => DType::F32,
        "int64" => DType::I64,
        "int32" => DType::I32,
        "bool" => DType::Bool,
        other => {
            return Err(FmError::Runtime(format!(
                "unsupported artifact dtype '{other}'"
            )))
        }
    })
}

/// Canonical on-disk name of a dtype, the inverse of the manifest's
/// dtype parser (both sidecars and artifact manifests use these names).
pub fn dtype_name(dt: DType) -> &'static str {
    match dt {
        DType::F64 => "float64",
        DType::F32 => "float32",
        DType::I64 => "int64",
        DType::I32 => "int32",
        DType::Bool => "bool",
    }
}

fn parse_specs(j: &Json) -> Result<Vec<TensorSpec>> {
    j.as_arr()?
        .iter()
        .map(|t| {
            Ok(TensorSpec {
                shape: t.get("shape")?.usize_vec()?,
                dtype: parse_dtype(t.get("dtype")?.as_str()?)?,
            })
        })
        .collect()
}

/// Load and validate `<dir>/manifest.json`.
pub fn load_manifest(dir: &Path) -> Result<Vec<ArtifactMeta>> {
    let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
        FmError::Runtime(format!(
            "cannot read {}/manifest.json ({e}); run `make artifacts`",
            dir.display()
        ))
    })?;
    let j = Json::parse(&text)?;
    let mut out = Vec::new();
    for a in j.get("artifacts")?.as_arr()? {
        let meta = ArtifactMeta {
            name: a.get("name")?.as_str()?.to_string(),
            file: a.get("file")?.as_str()?.to_string(),
            kind: a.get("kind")?.as_str()?.to_string(),
            rows: a.get("rows")?.as_u64()?,
            p: a.get("p")?.as_u64()?,
            k: a.get("k").map(|v| v.as_u64().unwrap_or(0)).unwrap_or(0),
            inputs: parse_specs(a.get("inputs")?)?,
            outputs: parse_specs(a.get("outputs")?)?,
        };
        if !dir.join(&meta.file).exists() {
            return Err(FmError::Runtime(format!(
                "artifact file missing: {}",
                dir.join(&meta.file).display()
            )));
        }
        // cross-check: the artifact's row count must match the engine's
        // shared partitioning formula (DESIGN.md; python model.io_rows_for)
        if meta.rows != crate::matrix::io_rows_for(meta.p) {
            return Err(FmError::Runtime(format!(
                "artifact {}: rows {} != io_rows_for({}) = {}; \
                 python/compile/model.py and matrix/partition.rs diverged",
                meta.name,
                meta.rows,
                meta.p,
                crate::matrix::io_rows_for(meta.p)
            )));
        }
        out.push(meta);
    }
    Ok(out)
}

/// Sidecar manifest for a *named* external sparse matrix
/// ([`crate::matrix::SparseData`]). The CSR byte layout is
/// variable-length per partition (nnz varies), so — unlike dense
/// matrices, whose offsets follow from the partitioning formula — a
/// reopened sparse dataset needs the per-partition `(offset, len)` table.
/// Written as `<name>.sparse.json` next to the matrix file.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseMeta {
    pub nrow: u64,
    pub ncol: u64,
    pub io_rows: u64,
    pub nnz: u64,
    /// Byte `(offset, len)` of each partition in the packed file.
    pub parts: Vec<(u64, usize)>,
    /// CRC32 of each partition's bytes, parallel to `parts` (`None` for
    /// a partition whose checksum was never recorded — e.g. a sidecar
    /// written before checksums existed). Reopening a named dataset
    /// seeds the store's [`crate::storage::ChecksumTable`] from these,
    /// so corruption of data at rest is caught on first read.
    pub crcs: Vec<Option<u32>>,
}

impl SparseMeta {
    /// Crash-consistent save: write `<path>.tmp`, fsync, rename over
    /// `path`. A crash mid-save leaves either the old manifest or a
    /// stray `.tmp` that [`load`](Self::load) never looks at — readers
    /// see a complete sidecar or none.
    pub fn save(&self, path: &Path) -> Result<()> {
        let j = crate::util::json::obj(vec![
            ("nrow", self.nrow.into()),
            ("ncol", self.ncol.into()),
            ("io_rows", self.io_rows.into()),
            ("nnz", self.nnz.into()),
            (
                "offsets",
                Json::Arr(self.parts.iter().map(|(o, _)| (*o).into()).collect()),
            ),
            (
                "lens",
                Json::Arr(self.parts.iter().map(|(_, l)| (*l).into()).collect()),
            ),
            (
                "crcs",
                Json::Arr(
                    self.crcs
                        .iter()
                        .map(|c| match c {
                            Some(v) => (*v as u64).into(),
                            None => Json::Null,
                        })
                        .collect(),
                ),
            ),
        ]);
        let fname = path
            .file_name()
            .ok_or_else(|| FmError::Storage(format!("bad manifest path {}", path.display())))?;
        let tmp = path.with_file_name(format!("{}.tmp", fname.to_string_lossy()));
        {
            use std::io::Write;
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(j.to_string().as_bytes())?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, path)?;
        // make the rename itself durable where the platform allows
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            if let Ok(d) = std::fs::File::open(dir) {
                let _ = d.sync_all();
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<SparseMeta> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            FmError::Storage(format!(
                "cannot read sparse manifest {} ({e})",
                path.display()
            ))
        })?;
        let j = Json::parse(&text)?;
        let offs: Vec<u64> = j
            .get("offsets")?
            .as_arr()?
            .iter()
            .map(|v| v.as_u64())
            .collect::<Result<_>>()?;
        let lens = j.get("lens")?.usize_vec()?;
        if offs.len() != lens.len() {
            return Err(FmError::Storage(
                "sparse manifest: offsets/lens length mismatch".into(),
            ));
        }
        // pre-checksum sidecars have no "crcs" key: every partition
        // simply stays unverified rather than failing to open
        let crcs: Vec<Option<u32>> = match j.get("crcs") {
            Ok(arr) => arr
                .as_arr()?
                .iter()
                .map(|v| match v {
                    Json::Null => Ok(None),
                    other => Ok(Some(other.as_u64()? as u32)),
                })
                .collect::<Result<_>>()?,
            Err(_) => vec![None; offs.len()],
        };
        if crcs.len() != offs.len() {
            return Err(FmError::Storage(
                "sparse manifest: crcs/offsets length mismatch".into(),
            ));
        }
        Ok(SparseMeta {
            nrow: j.get("nrow")?.as_u64()?,
            ncol: j.get("ncol")?.as_u64()?,
            io_rows: j.get("io_rows")?.as_u64()?,
            nnz: j.get("nnz")?.as_u64()?,
            parts: offs.into_iter().zip(lens).collect(),
            crcs,
        })
    }
}

/// Per-column metadata in a dense sidecar: the ingestion schema code
/// (`I`/`F`/`H`/`X`, see [`crate::ingest::ColType`]) plus, for factor
/// columns, the sorted level table that maps codes `1..=k` back to the
/// original strings. Non-ingested datasets use an empty `cols` list.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseColMeta {
    pub code: char,
    pub levels: Vec<String>,
}

/// Sidecar manifest for a *named* external dense matrix
/// ([`crate::matrix::DenseData`]), written as `<name>.dense.json` next
/// to the matrix file. Dense partition offsets follow from the
/// partitioning formula, so unlike [`SparseMeta`] no byte table is
/// needed — the sidecar carries the shape, dtype, per-partition CRCs
/// and (for ingested data) the column schema + factor level tables.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseMeta {
    pub nrow: u64,
    pub ncol: u64,
    pub io_rows: u64,
    pub dtype: DType,
    /// CRC32 per partition (`None` = never recorded); seeds the store's
    /// [`crate::storage::ChecksumTable`] on reopen, same contract as
    /// [`SparseMeta::crcs`].
    pub crcs: Vec<Option<u32>>,
    pub cols: Vec<DenseColMeta>,
}

impl DenseMeta {
    /// Crash-consistent save (tmp + fsync + rename + dir sync), same
    /// protocol as [`SparseMeta::save`].
    pub fn save(&self, path: &Path) -> Result<()> {
        let j = crate::util::json::obj(vec![
            ("nrow", self.nrow.into()),
            ("ncol", self.ncol.into()),
            ("io_rows", self.io_rows.into()),
            ("dtype", dtype_name(self.dtype).into()),
            (
                "crcs",
                Json::Arr(
                    self.crcs
                        .iter()
                        .map(|c| match c {
                            Some(v) => (*v as u64).into(),
                            None => Json::Null,
                        })
                        .collect(),
                ),
            ),
            (
                "cols",
                Json::Arr(
                    self.cols
                        .iter()
                        .map(|c| {
                            crate::util::json::obj(vec![
                                ("code", c.code.to_string().into()),
                                (
                                    "levels",
                                    Json::Arr(
                                        c.levels.iter().map(|l| l.as_str().into()).collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        let fname = path
            .file_name()
            .ok_or_else(|| FmError::Storage(format!("bad manifest path {}", path.display())))?;
        let tmp = path.with_file_name(format!("{}.tmp", fname.to_string_lossy()));
        {
            use std::io::Write;
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(j.to_string().as_bytes())?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, path)?;
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            if let Ok(d) = std::fs::File::open(dir) {
                let _ = d.sync_all();
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<DenseMeta> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            FmError::Storage(format!(
                "cannot read dense manifest {} ({e})",
                path.display()
            ))
        })?;
        let j = Json::parse(&text)?;
        let crcs: Vec<Option<u32>> = j
            .get("crcs")?
            .as_arr()?
            .iter()
            .map(|v| match v {
                Json::Null => Ok(None),
                other => Ok(Some(other.as_u64()? as u32)),
            })
            .collect::<Result<_>>()?;
        // sidecars written by plain dataset builders (no ingestion
        // schema) may omit "cols" entirely
        let cols = match j.get("cols") {
            Ok(arr) => arr
                .as_arr()?
                .iter()
                .map(|c| {
                    let code_s = c.get("code")?.as_str()?.to_string();
                    let mut it = code_s.chars();
                    let code = it.next().ok_or_else(|| {
                        FmError::Storage("dense manifest: empty column code".into())
                    })?;
                    if it.next().is_some() {
                        return Err(FmError::Storage(format!(
                            "dense manifest: bad column code '{code_s}'"
                        )));
                    }
                    let levels = c
                        .get("levels")?
                        .as_arr()?
                        .iter()
                        .map(|l| Ok(l.as_str()?.to_string()))
                        .collect::<Result<_>>()?;
                    Ok(DenseColMeta { code, levels })
                })
                .collect::<Result<Vec<_>>>()?,
            Err(_) => Vec::new(),
        };
        Ok(DenseMeta {
            nrow: j.get("nrow")?.as_u64()?,
            ncol: j.get("ncol")?.as_u64()?,
            io_rows: j.get("io_rows")?.as_u64()?,
            dtype: parse_dtype(j.get("dtype")?.as_str()?)?,
            crcs,
            cols,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_meta_roundtrips() {
        let tmp = crate::testutil::TempDir::new("sparse-meta");
        let meta = SparseMeta {
            nrow: 5000,
            ncol: 5000,
            io_rows: 1024,
            nnz: 12345,
            parts: vec![(0, 4096), (4096, 2048), (6144, 512)],
            crcs: vec![Some(0xDEAD_BEEF), None, Some(7)],
        };
        let p = tmp.path().join("edges.sparse.json");
        meta.save(&p).unwrap();
        assert_eq!(SparseMeta::load(&p).unwrap(), meta);
        // atomic save leaves no temp file behind
        assert!(!p.with_file_name("edges.sparse.json.tmp").exists());
    }

    #[test]
    fn sparse_meta_load_ignores_crashed_tmp_and_old_schema() {
        let tmp = crate::testutil::TempDir::new("sparse-meta-crash");
        let meta = SparseMeta {
            nrow: 100,
            ncol: 8,
            io_rows: 64,
            nnz: 10,
            parts: vec![(0, 128), (128, 64)],
            crcs: vec![Some(1), Some(2)],
        };
        let p = tmp.path().join("m.sparse.json");
        meta.save(&p).unwrap();
        // simulate a crash mid-save of a NEWER manifest: a stray .tmp
        // with garbage next to the good sidecar must be ignored
        std::fs::write(p.with_file_name("m.sparse.json.tmp"), b"{trunc").unwrap();
        assert_eq!(SparseMeta::load(&p).unwrap(), meta);
        // and saving again replaces the stray tmp without error
        meta.save(&p).unwrap();
        assert!(!p.with_file_name("m.sparse.json.tmp").exists());

        // a pre-checksum sidecar (no "crcs" key) still opens: every
        // partition is just unverified
        let old = r#"{"nrow":100,"ncol":8,"io_rows":64,"nnz":10,
                      "offsets":[0,128],"lens":[128,64]}"#;
        let p_old = tmp.path().join("old.sparse.json");
        std::fs::write(&p_old, old).unwrap();
        let m = SparseMeta::load(&p_old).unwrap();
        assert_eq!(m.crcs, vec![None, None]);
        assert_eq!(m.parts, vec![(0, 128), (128, 64)]);
    }

    #[test]
    fn dense_meta_roundtrips_with_factor_levels() {
        let tmp = crate::testutil::TempDir::new("dense-meta");
        let meta = DenseMeta {
            nrow: 4000,
            ncol: 3,
            io_rows: 1024,
            dtype: DType::I32,
            crcs: vec![Some(42), None, Some(0xFFFF_FFFF), Some(0)],
            cols: vec![
                DenseColMeta {
                    code: 'I',
                    levels: vec![],
                },
                DenseColMeta {
                    code: 'X',
                    levels: vec!["ad".into(), "news".into(), "video".into()],
                },
                DenseColMeta {
                    code: 'H',
                    levels: vec![],
                },
            ],
        };
        let p = tmp.path().join("train.dense.json");
        meta.save(&p).unwrap();
        assert_eq!(DenseMeta::load(&p).unwrap(), meta);
        assert!(!p.with_file_name("train.dense.json.tmp").exists());
    }

    #[test]
    fn dense_meta_tolerates_missing_cols_and_rejects_bad_codes() {
        let tmp = crate::testutil::TempDir::new("dense-meta-old");
        // a dataset-builder sidecar with no ingestion schema
        let old = r#"{"nrow":64,"ncol":2,"io_rows":32,"dtype":"float32",
                      "crcs":[null,7]}"#;
        let p = tmp.path().join("d.dense.json");
        std::fs::write(&p, old).unwrap();
        let m = DenseMeta::load(&p).unwrap();
        assert_eq!(m.dtype, DType::F32);
        assert_eq!(m.crcs, vec![None, Some(7)]);
        assert!(m.cols.is_empty());

        let bad = r#"{"nrow":1,"ncol":1,"io_rows":1,"dtype":"float64",
                      "crcs":[null],"cols":[{"code":"XY","levels":[]}]}"#;
        std::fs::write(&p, bad).unwrap();
        assert!(DenseMeta::load(&p).is_err());
    }

    #[test]
    fn dtype_name_is_inverse_of_parse() {
        for dt in [DType::F64, DType::F32, DType::I64, DType::I32, DType::Bool] {
            assert_eq!(parse_dtype(dtype_name(dt)).unwrap(), dt);
        }
    }

    #[test]
    fn parses_real_manifest_when_present() {
        // integration-level check; skipped when artifacts are not built
        let dir = Path::new("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = load_manifest(dir).unwrap();
        assert!(!m.is_empty());
        let km = m
            .iter()
            .find(|a| a.kind == "kmeans" && a.k == 10)
            .expect("kmeans_p32_k10 present");
        assert_eq!(km.p, 32);
        assert_eq!(km.inputs[0].shape, vec![km.rows as usize, 32]);
        assert_eq!(km.outputs.len(), 4);
    }

    #[test]
    fn dtype_names() {
        assert_eq!(parse_dtype("float64").unwrap(), DType::F64);
        assert_eq!(parse_dtype("int32").unwrap(), DType::I32);
        assert!(parse_dtype("complex64").is_err());
    }
}
