//! Async job-queue serving surface (submit → ticket → poll/wait).
//!
//! The multi-tenant front end: callers — one per [`crate::fmr::Session`],
//! typically — submit closures that run a workload against their session
//! engine, and get back a [`Ticket`] they can poll or block on. A small
//! fixed pool of worker threads drains the queue FIFO; per-pass
//! concurrency against the shared cache is governed separately by
//! `EngineConfig::max_concurrent_passes` (the cache's pass admission
//! gate), so the pool size only bounds how many jobs are *runnable*, not
//! how many passes touch the cache at once.
//!
//! Worker panics are contained: a panicking job resolves its ticket with
//! `FmError::Runtime` instead of wedging the queue. Dropping the queue
//! joins the workers (finishing jobs already dequeued) and then runs any
//! never-started jobs inline, so every issued ticket resolves.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

use crate::error::{FmError, Result};
use crate::util::sync::{wait_recover, LockExt};

/// Result slot shared between a worker and the ticket holder.
enum TicketState<T> {
    Pending,
    Done(Result<T>),
    /// The result was already consumed by `wait`/`poll`.
    Taken,
}

struct TicketShared<T> {
    state: Mutex<TicketState<T>>,
    cv: Condvar,
}

/// Handle to one submitted job.
pub struct Ticket<T> {
    shared: Arc<TicketShared<T>>,
}

impl<T> Ticket<T> {
    fn new() -> (Ticket<T>, Arc<TicketShared<T>>) {
        let shared = Arc::new(TicketShared {
            state: Mutex::new(TicketState::Pending),
            cv: Condvar::new(),
        });
        (
            Ticket {
                shared: Arc::clone(&shared),
            },
            shared,
        )
    }

    /// Non-blocking: `None` while the job is still queued or running,
    /// `Some(result)` exactly once when it finished (subsequent polls
    /// after the result was taken return an error result).
    pub fn poll(&self) -> Option<Result<T>> {
        let mut st = self.shared.state.lock_recover();
        match &*st {
            TicketState::Pending => None,
            _ => Some(take_state(&mut st)),
        }
    }

    /// Block until the job finishes and return its result.
    pub fn wait(self) -> Result<T> {
        let mut st = self.shared.state.lock_recover();
        while matches!(*st, TicketState::Pending) {
            st = wait_recover(&self.shared.cv, st);
        }
        take_state(&mut st)
    }

    /// Whether the job has finished (without consuming the result).
    pub fn is_done(&self) -> bool {
        !matches!(*self.shared.state.lock_recover(), TicketState::Pending)
    }
}

fn take_state<T>(st: &mut TicketState<T>) -> Result<T> {
    match std::mem::replace(st, TicketState::Taken) {
        TicketState::Done(r) => r,
        TicketState::Taken => Err(FmError::Runtime("ticket result already taken".into())),
        TicketState::Pending => unreachable!("caller checked Pending"),
    }
}

type Job = Box<dyn FnOnce() + Send>;

struct QueueInner {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct QueueShared {
    inner: Mutex<QueueInner>,
    cv: Condvar,
}

/// Fixed-pool FIFO job queue.
pub struct JobQueue {
    shared: Arc<QueueShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl JobQueue {
    /// Start a queue with `workers` threads (at least 1).
    pub fn new(workers: usize) -> JobQueue {
        let shared = Arc::new(QueueShared {
            inner: Mutex::new(QueueInner {
                jobs: VecDeque::new(),
                shutdown: false,
            }),
            cv: Condvar::new(),
        });
        let workers = (0..workers.max(1))
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("fm-job-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn job worker")
            })
            .collect();
        JobQueue { shared, workers }
    }

    /// Submit a job; returns immediately with its ticket. A job
    /// submitted after shutdown began runs inline on the submitting
    /// thread (the ticket still resolves — nobody hangs).
    pub fn submit<T, F>(&self, job: F) -> Ticket<T>
    where
        T: Send + 'static,
        F: FnOnce() -> Result<T> + Send + 'static,
    {
        let (ticket, slot) = Ticket::new();
        let run: Job = Box::new(move || {
            let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job))
                .unwrap_or_else(|p| {
                    let msg = p
                        .downcast_ref::<&str>()
                        .map(|s| (*s).to_string())
                        .or_else(|| p.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "opaque panic payload".into());
                    Err(FmError::Runtime(format!("job panicked: {msg}")))
                });
            *slot.state.lock_recover() = TicketState::Done(res);
            slot.cv.notify_all();
        });
        let mut g = self.shared.inner.lock_recover();
        if g.shutdown {
            // the workers are gone; run inline so the ticket resolves
            drop(g);
            run();
        } else {
            g.jobs.push_back(run);
            drop(g);
            self.shared.cv.notify_one();
        }
        ticket
    }

    /// Jobs still queued (not yet picked up by a worker).
    pub fn backlog(&self) -> usize {
        self.shared.inner.lock_recover().jobs.len()
    }
}

fn worker_loop(shared: &QueueShared) {
    loop {
        let job = {
            let mut g = shared.inner.lock_recover();
            loop {
                if let Some(j) = g.jobs.pop_front() {
                    break j;
                }
                if g.shutdown {
                    return;
                }
                g = wait_recover(&shared.cv, g);
            }
        };
        job();
    }
}

impl Drop for JobQueue {
    fn drop(&mut self) {
        {
            let mut g = self.shared.inner.lock_recover();
            g.shutdown = true;
        }
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // fail whatever never got picked up (each closure resolves its
        // own ticket; running it inline here keeps waiters live, and the
        // workers are already gone so there is no double-run risk)
        let leftovers: Vec<Job> = {
            let mut g = self.shared.inner.lock_recover();
            g.jobs.drain(..).collect()
        };
        for j in leftovers {
            j();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn submit_poll_wait_roundtrip() {
        let q = JobQueue::new(2);
        let t = q.submit(|| Ok(21 * 2));
        assert_eq!(t.wait().unwrap(), 42);

        let slow = q.submit(|| {
            std::thread::sleep(Duration::from_millis(30));
            Ok("done".to_string())
        });
        // poll may race the worker; eventually it must yield the value
        let mut got = None;
        for _ in 0..200 {
            if let Some(r) = slow.poll() {
                got = Some(r);
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(got.unwrap().unwrap(), "done");
    }

    #[test]
    fn jobs_run_concurrently_across_workers() {
        let q = JobQueue::new(4);
        let running = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let tickets: Vec<_> = (0..4)
            .map(|_| {
                let running = Arc::clone(&running);
                let peak = Arc::clone(&peak);
                q.submit(move || {
                    let now = running.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(now, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_millis(50));
                    running.fetch_sub(1, Ordering::SeqCst);
                    Ok(())
                })
            })
            .collect();
        for t in tickets {
            t.wait().unwrap();
        }
        assert!(
            peak.load(Ordering::SeqCst) >= 2,
            "4 jobs on 4 workers never overlapped"
        );
    }

    #[test]
    fn panicking_job_resolves_ticket_with_error() {
        let q = JobQueue::new(1);
        let t = q.submit::<(), _>(|| panic!("boom"));
        let err = t.wait().unwrap_err();
        assert!(format!("{err}").contains("boom"));
        // the worker survived the panic
        let t2 = q.submit(|| Ok(7));
        assert_eq!(t2.wait().unwrap(), 7);
    }

    #[test]
    fn drop_resolves_unstarted_jobs() {
        let q = JobQueue::new(1);
        let block = q.submit(|| {
            std::thread::sleep(Duration::from_millis(50));
            Ok(1)
        });
        let queued = q.submit(|| Ok(2));
        drop(q);
        assert_eq!(block.wait().unwrap(), 1);
        assert_eq!(queued.wait().unwrap(), 2);
    }
}
