//! Parallel materialization of DAGs (paper §III-F).
//!
//! A *pass* streams every I/O-level partition of the DAG's long dimension
//! once, evaluating the compiled pipeline ([`pipeline::Program`]) for every
//! CPU-level strip, writing target partitions and folding sink partials.
//! Work is distributed by the locality-aware [`sched::RangeScheduler`]:
//! each worker owns one contiguous range of source-partition-sized
//! locality units, steals half of the largest remaining range when it runs
//! dry, and is pinned to a simulated NUMA node (`EngineConfig::numa_nodes`)
//! that shapes which ranges it prefers to steal from. Each thread keeps
//! per-thread sink accumulators that are merged at the end with the VUDFs'
//! `combine` form — exactly the paper's parallelization +
//! partial-aggregation scheme.
//!
//! Optimization toggles (Fig 11 ablations) act here:
//! * `fuse_mem` is a *caller* decision: the `fmr` layer materializes each
//!   op separately when it is off, so the DAG this module sees is depth-1.
//! * `fuse_cache` selects the strip height: CPU-cache-sized strips when on,
//!   whole I/O partitions when off.
//! * `recycle_chunks` acts in [`crate::mem::ChunkPool`] and, for the
//!   strip evaluator's register buffers, in each worker's
//!   [`crate::mem::StripPool`].
//! * `inplace_ops` / `peephole_fuse` act at compile time in
//!   [`pipeline::compile_opts`] (liveness-planned in-place kernels and
//!   fused `Sapply`/`MapplyScalar` chains — `benches/strip_fusion.rs`).
//! * `em_cache_bytes` / `prefetch_depth` act through the source reads:
//!   every EM partition read consults the write-through matrix cache
//!   ([`crate::matrix::cache`], §III-B3) before touching the file, and
//!   every worker queues the read of the next partition *of its own range*
//!   so I/O overlaps compute instead of alternating — deterministic
//!   ownership (range scheduling) plus the cache's single-flight registry
//!   make that safe with any worker count.
//! * `writeback` / `writeback_queue_bytes` act through the target writes
//!   (the other half of §III-B3's I/O/compute overlap): workers hand
//!   finished EM target partitions to the cache's background writer and
//!   immediately claim the next unit; the pass ends with a flush barrier
//!   (success) or a dirty discard (abort via the scheduler's abort flag),
//!   keeping results bit-identical to synchronous write-through —
//!   `benches/writeback.rs` measures the overlap.

pub mod pipeline;
pub mod sched;

use std::panic::AssertUnwindSafe;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

use crate::config::{EngineConfig, StorageKind};
use crate::dag::{SinkResult, SinkSpec};
use crate::dtype::{DType, Scalar};
use crate::error::{FmError, Result};
use crate::matrix::{DenseBuilder, HostMat, Matrix, MatrixData, PartitionCache, Partitioning};
use crate::mem::{ChunkPool, StripPool};
use crate::metrics::Metrics;
use crate::storage::SsdSim;
use crate::util::sync::LockExt;
use crate::vudf::{AggOp, Buf, NaMode};

use pipeline::{EvalOpts, Program, SinkInstrKind, SourceStrip};

/// Everything a pass needs from the engine.
pub struct ExecCtx<'a> {
    pub config: &'a EngineConfig,
    pub pool: &'a ChunkPool,
    pub metrics: &'a Arc<Metrics>,
    pub ssd: &'a Arc<SsdSim>,
    /// Engine-wide write-through partition cache (§III-B3); `None` when
    /// `em_cache_bytes == 0` (the ablation's cache-off configuration).
    pub cache: Option<Arc<PartitionCache>>,
    /// Cache tenant id of the submitting session (0 = the root engine).
    /// Materialized cache-resident targets are tagged with this owner so
    /// fair-share eviction and per-session hit accounting can attribute
    /// them to the right tenant.
    pub session: u64,
}

/// Materialize `targets` (virtual matrices) and `sinks` in ONE streaming
/// pass over the shared long dimension.
pub fn run_pass(
    ctx: &ExecCtx<'_>,
    targets: &[Matrix],
    sinks: &[SinkSpec],
) -> Result<(Vec<Matrix>, Vec<SinkResult>)> {
    run_pass_to(ctx, targets, sinks, None)
}

/// [`run_pass`] with an explicit storage override for the materialized
/// targets (`fm.conv.store`: move matrices between memory and SSDs).
pub fn run_pass_to(
    ctx: &ExecCtx<'_>,
    targets: &[Matrix],
    sinks: &[SinkSpec],
    storage: Option<StorageKind>,
) -> Result<(Vec<Matrix>, Vec<SinkResult>)> {
    run_pass_opts(ctx, targets, sinks, storage, true)
}

/// [`run_pass_to`] with an explicit cache-residency decision for the
/// materialized EM targets. `cache_resident = false` keeps one-shot
/// intermediates (the eager mode's per-op materializations) out of the
/// write-through partition cache so they cannot evict reusable data —
/// the `fmr` layer's §III-B3 residency policy.
pub fn run_pass_opts(
    ctx: &ExecCtx<'_>,
    targets: &[Matrix],
    sinks: &[SinkSpec],
    storage: Option<StorageKind>,
    cache_resident: bool,
) -> Result<(Vec<Matrix>, Vec<SinkResult>)> {
    let storage = storage.unwrap_or_else(|| ctx.config.storage.clone());
    let prog = Arc::new(pipeline::compile_opts(
        targets,
        sinks,
        pipeline::CompileOpts {
            peephole_fuse: ctx.config.peephole_fuse,
            inplace_ops: ctx.config.inplace_ops,
        },
    )?);
    ctx.metrics
        .fused_chain_len
        .fetch_add(prog.plan.fused_steps, Ordering::Relaxed);
    ctx.metrics.passes_run.fetch_add(1, Ordering::Relaxed);
    let nrow = prog.nrow;

    // ---- pass partitioning: nest within every source's partitions
    // (dense and sparse share the io-row grid, so both constrain the pass)
    let mut pass_io: u64 = u64::MAX;
    for s in &prog.sources {
        if let Some(parts) = source_parts(s) {
            pass_io = pass_io.min(parts.io_rows);
        }
    }
    for t in targets.iter() {
        pass_io = pass_io.min(crate::matrix::io_rows_for(t.ncol()));
    }
    if pass_io == u64::MAX {
        // sinks over generator-only DAGs
        let widest = prog.instrs.iter().map(|i| i.ncol).max().unwrap_or(1);
        pass_io = crate::matrix::io_rows_for(widest);
    }
    // NOTE on granularity (§Perf iteration 5): splitting pass partitions
    // below the source I/O-partition size was tried to reduce skew at low
    // partition counts, but it makes neighbouring workers re-copy the
    // same source partition (the per-worker cache is keyed by source
    // partition) and measured *slower* (summary t=2: 0.038s -> 0.087s).
    // Kept at the source partition size; reverted per the measure-keep-
    // or-revert rule. See EXPERIMENTS.md §Perf. The range scheduler below
    // attacks the same re-copy problem from the dispatch side: pass
    // partitions sharing one source partition are claimed by one worker.
    for s in &prog.sources {
        if let Some(parts) = source_parts(s) {
            if parts.io_rows % pass_io != 0 {
                return Err(FmError::Shape(format!(
                    "source io_rows {} not a multiple of pass io_rows {pass_io}",
                    parts.io_rows
                )));
            }
        }
    }
    let pass_parts = Partitioning::with_io_rows(nrow, 1, pass_io);
    let n_parts = pass_parts.n_parts();

    // ---- output builders
    let mut builders: Vec<DenseBuilder> = Vec::new();
    for t in targets {
        let parts = Partitioning::with_io_rows(nrow, t.ncol(), pass_io);
        let b = match storage {
            StorageKind::InMem => DenseBuilder::new_mem(t.dtype(), parts, ctx.pool)?,
            StorageKind::External => {
                let mut b = DenseBuilder::new_ext(
                    t.dtype(),
                    parts,
                    &ctx.config.data_dir,
                    None,
                    ctx.config.em_cache_cols as u64,
                    Arc::clone(ctx.ssd),
                    Arc::clone(ctx.metrics),
                    if cache_resident { ctx.cache.clone() } else { None },
                )?;
                // §III-B3 write half: queue finished target partitions to
                // the cache's background writer so the (throttled) pwrite
                // overlaps the next partition's read/compute. The pass
                // ends with a flush barrier or a dirty discard below.
                if ctx.config.writeback {
                    if let Some(c) = &ctx.cache {
                        b.enable_writeback(Arc::clone(c));
                    }
                }
                b
            }
        };
        // Tag cache-resident targets with the submitting tenant so the
        // fair-share eviction policy charges their bytes to this session.
        if ctx.session != 0 {
            if let (Some(c), Some(id)) = (&ctx.cache, b.cache_matrix_id()) {
                c.set_matrix_owner(id, ctx.session);
            }
        }
        builders.push(b);
    }

    // ---- per-pass read-ahead generation (§III-B3): register this pass
    // with the cache so its prefetches stay pinned until *this* pass ends,
    // independent of any concurrent tenant's pass. `begin_pass` is also
    // the `max_concurrent_passes` admission gate.
    let pass_guard = ctx.cache.as_ref().map(|c| c.begin_pass());
    let pass_id = pass_guard.as_ref().map_or(0, |g| g.id());

    // ---- parallel pass: locality-aware range scheduling (§III-F)
    let threads = ctx.config.threads.max(1).min(n_parts.max(1));
    // locality unit = all pass partitions nested in one partition of the
    // *coarsest* dense source, so each source partition is copied into
    // exactly one worker's source cache per pass
    let mut unit_io = pass_io;
    for s in &prog.sources {
        if let Some(parts) = source_parts(s) {
            unit_io = unit_io.max(parts.io_rows);
        }
    }
    let group = (unit_io / pass_io) as usize;
    let sched = sched::RangeScheduler::new(n_parts, group, threads, ctx.config.numa_nodes);
    let merged: Mutex<Vec<SinkAccSet>> = Mutex::new(Vec::new());
    let first_err: Mutex<Option<FmError>> = Mutex::new(None);

    std::thread::scope(|scope| {
        for w in 0..threads {
            let prog = Arc::clone(&prog);
            let sched = &sched;
            let builders = &builders;
            let merged = &merged;
            let first_err = &first_err;
            let pass_parts = pass_parts.clone();
            let cfg = ctx.config;
            let metrics = Arc::clone(ctx.metrics);
            let chunk_pool = ctx.pool;
            scope.spawn(move || {
                let mut accs = SinkAccSet::new(&prog);
                let mut cache = SourceCache::new(prog.sources.len());
                // per-worker strip-register recycler (§III-B5 on the hot
                // path): lives for the whole pass so buffers recycle
                // across strips AND partitions; flushes its counters to
                // the engine metrics on drop
                let mut spool = chunk_pool.strip_pool();
                'pass: while let Some(unit) = sched.claim_unit(w) {
                    let (p0, p1) = sched.unit_parts(unit);
                    // rows this worker still owns beyond the current
                    // partition — the safe read-ahead window (ownership is
                    // deterministic under range scheduling). Computed once
                    // per unit: it only changes on claim/steal, and a
                    // stale peek costs at most one wasted prefetch.
                    let next_unit_rows = sched.peek_next(w).map(|u| {
                        let (q0, q1) = sched.unit_parts(u);
                        (q0 as u64 * pass_io, (q1 as u64 * pass_io).min(nrow))
                    });
                    let window = PrefetchWindow {
                        unit_end_row: (p1 as u64 * pass_io).min(nrow),
                        next_unit_rows,
                    };
                    for pi in p0..p1 {
                        // a failed worker aborts the whole pass: nobody
                        // keeps processing (and writing) doomed partitions
                        if sched.aborted() {
                            break 'pass;
                        }
                        // contain worker panics (a UDF index bug, an
                        // injected-fault path nobody hardened): the unit
                        // becomes a pass abort like any other partition
                        // error instead of unwinding through the scope
                        // and poisoning every shared lock
                        let unit_res = std::panic::catch_unwind(AssertUnwindSafe(|| {
                            process_partition(
                                &prog,
                                &pass_parts,
                                pi,
                                cfg,
                                builders,
                                &mut accs,
                                &mut cache,
                                &window,
                                &mut spool,
                                pass_id,
                            )
                        }))
                        .unwrap_or_else(|p| {
                            let msg = p
                                .downcast_ref::<&str>()
                                .map(|s| (*s).to_string())
                                .or_else(|| p.downcast_ref::<String>().cloned())
                                .unwrap_or_else(|| "opaque panic payload".into());
                            Err(FmError::Runtime(format!(
                                "worker panicked in partition {pi}: {msg}"
                            )))
                        });
                        if let Err(e) = unit_res {
                            let mut fe = first_err.lock_recover();
                            if fe.is_none() {
                                *fe = Some(e);
                            }
                            drop(fe);
                            sched.abort();
                            break 'pass;
                        }
                        metrics.native_partitions.fetch_add(1, Ordering::Relaxed);
                    }
                }
                merged.lock_recover().push(accs);
            });
        }
    });

    ctx.metrics
        .sched_steals
        .fetch_add(sched.steals(), Ordering::Relaxed);
    ctx.metrics
        .sched_steals_remote
        .fetch_add(sched.steals_remote(), Ordering::Relaxed);

    // Retire this pass's read-ahead generation: dropping the pass guard
    // removes the pass id from the cache's active set, so leftover queued
    // prefetch requests are dropped (in-flight ones land unpinned), and any
    // prefetched partition nobody consumed — an aborted pass, a stolen
    // unit's wasted hint — loses its pin. Only THIS pass's generation is
    // retired: a concurrent tenant's pass keeps its read-aheads pinned.
    drop(pass_guard);
    for s in &prog.sources {
        match &**s {
            MatrixData::Dense(d) => d.release_prefetch_pins(),
            MatrixData::Sparse(sp) => sp.release_prefetch_pins(),
            _ => {}
        }
    }

    // ---- write-back barrier (§III-B3): a pass either flushes every
    // asynchronously queued target write (success — the file is
    // authoritative before anyone can read the finished matrices, so
    // write-back stays bit-identical to write-through) or discards them
    // (abort — a doomed pass leaves no partial partitions on disk).
    if sched.aborted() {
        for b in &builders {
            b.discard_writes();
        }
    } else {
        for b in &builders {
            if let Err(e) = b.flush_writes() {
                let mut fe = first_err.lock_recover();
                if fe.is_none() {
                    *fe = Some(e);
                }
            }
        }
    }

    if let Some(e) = first_err.into_inner_recover() {
        return Err(e);
    }

    // ---- merge per-thread sink partials (aVUDF2 combine)
    let mut parts_iter = merged.into_inner_recover().into_iter();
    let mut total = parts_iter
        .next()
        .ok_or_else(|| FmError::Shape("no worker results".into()))?;
    for acc in parts_iter {
        total.merge(acc)?;
    }
    let sink_results = total.finish(&prog);

    // ---- freeze targets
    let out_targets = builders
        .into_iter()
        .map(|b| Matrix::from_dense(b.finish()))
        .collect();
    Ok((out_targets, sink_results))
}

/// Materialize virtual matrices (no sinks).
pub fn materialize(ctx: &ExecCtx<'_>, targets: &[Matrix]) -> Result<Vec<Matrix>> {
    Ok(run_pass(ctx, targets, &[])?.0)
}

/// Materialize sinks only.
pub fn materialize_sinks(ctx: &ExecCtx<'_>, sinks: &[SinkSpec]) -> Result<Vec<SinkResult>> {
    Ok(run_pass(ctx, &[], sinks)?.1)
}

/// One planned streaming pass: the [`crate::plan`] optimizer's unit of
/// execution. Each group becomes exactly one [`run_pass`] call.
pub struct PassGroup {
    pub targets: Vec<Matrix>,
    pub sinks: Vec<SinkSpec>,
}

/// Run the optimizer's planned pass groups, in order. Returns one
/// `(targets, sink results)` pair per group, matching each group's
/// request order.
pub fn run_groups(
    ctx: &ExecCtx<'_>,
    groups: &[PassGroup],
) -> Result<Vec<(Vec<Matrix>, Vec<SinkResult>)>> {
    groups
        .iter()
        .map(|g| run_pass(ctx, &g.targets, &g.sinks))
        .collect()
}

// ---------------------------------------------------------------------------

/// Row partitioning of a pass source — dense and sparse matrices are both
/// range-scheduled, read-through-cache, prefetchable sources; virtual /
/// group nodes have no partitioning of their own.
fn source_parts(s: &MatrixData) -> Option<&crate::matrix::Partitioning> {
    match s {
        MatrixData::Dense(d) => Some(&d.parts),
        MatrixData::Sparse(sp) => Some(&sp.parts),
        _ => None,
    }
}

/// Bytes of source partition `i` through the §III-B3 hierarchy.
fn source_partition_bytes(s: &MatrixData, i: usize) -> Result<Arc<Vec<u8>>> {
    match s {
        MatrixData::Dense(d) => d.partition_bytes_shared(i),
        MatrixData::Sparse(sp) => sp.partition_bytes_shared(i),
        _ => Err(FmError::Unsupported("non-materialized source".into())),
    }
}

/// Queue the async read-ahead of source partition `i`, stamped with the
/// issuing pass's id so only that pass's end retires it.
fn source_prefetch(s: &MatrixData, i: usize, pass: u64) {
    match s {
        MatrixData::Dense(d) => d.prefetch_partition(i, pass),
        MatrixData::Sparse(sp) => sp.prefetch_partition(i, pass),
        _ => {}
    }
}

/// Per-worker cache of the most recently read source partition (a pass
/// partition is usually much smaller than a source partition, so
/// consecutive pass partitions hit the same source bytes). The range
/// scheduler keeps all pass partitions of one source partition on one
/// worker, so each source partition lands here exactly once per pass —
/// shared with the engine cache through the `Arc`, not copied.
struct SourceCache {
    slots: Vec<Option<(usize, std::sync::Arc<Vec<u8>>)>>,
}

impl SourceCache {
    fn new(n: usize) -> SourceCache {
        SourceCache {
            slots: (0..n).map(|_| None).collect(),
        }
    }
}

/// Row window a worker still owns beyond the partition it is currently
/// processing: the rest of its locality unit plus its next owned unit.
/// Read-ahead targets inside the window belong to this worker, so a
/// prefetch cannot race the worker that consumes the partition.
struct PrefetchWindow {
    /// End row (exclusive) of the current locality unit.
    unit_end_row: u64,
    /// Row range of the worker's next owned unit, if any.
    next_unit_rows: Option<(u64, u64)>,
}

impl PrefetchWindow {
    fn owns(&self, row: u64) -> bool {
        row < self.unit_end_row
            || self
                .next_unit_rows
                .map(|(a, b)| row >= a && row < b)
                .unwrap_or(false)
    }
}

#[allow(clippy::too_many_arguments)]
fn process_partition(
    prog: &Program,
    pass_parts: &Partitioning,
    pi: usize,
    cfg: &EngineConfig,
    builders: &[DenseBuilder],
    accs: &mut SinkAccSet,
    cache: &mut SourceCache,
    window: &PrefetchWindow,
    spool: &mut StripPool,
    pass: u64,
) -> Result<()> {
    let (g0, g1) = pass_parts.part_rows(pi);
    let prows = (g1 - g0) as usize;

    // load (or reuse) each source's partition containing [g0, g1)
    let mut src_meta: Vec<(usize, usize)> = Vec::with_capacity(prog.sources.len());
    for (si, s) in prog.sources.iter().enumerate() {
        let parts = source_parts(s)
            .ok_or_else(|| FmError::Unsupported("non-materialized source".into()))?;
        let spi = (g0 / parts.io_rows) as usize;
        let (s0, s1) = parts.part_rows(spi);
        debug_assert!(g1 <= s1);
        let need_read = !matches!(&cache.slots[si], Some((p, _)) if *p == spi);
        if need_read {
            cache.slots[si] = Some((spi, source_partition_bytes(s, spi)?));
            // Queue the read of the next source partition *this worker*
            // will consume, so it overlaps this partition's compute
            // (§III-B3). Range scheduling makes that ownership
            // deterministic, and the cache's single-flight registry
            // coalesces any residual race (e.g. the next unit being
            // stolen after the peek) — so multi-worker passes prefetch
            // too, without double reads.
            let next_row0 = (spi as u64 + 1) * parts.io_rows;
            if window.owns(next_row0) {
                source_prefetch(s, spi + 1, pass);
            }
        }
        src_meta.push(((s1 - s0) as usize, (g0 - s0) as usize));
    }

    // per-target partition output buffers (pooled: reused across the
    // partitions of this worker's range)
    let mut out_bufs: Vec<Buf> = builders
        .iter()
        .map(|b| spool.acquire(b.dtype(), prows * b.parts().ncol as usize))
        .collect();

    // strip heights: CPU-cache-sized when cache-fuse is on
    let widest = prog.instrs.iter().map(|i| i.ncol).max().unwrap_or(1);
    let strip_parts = Partitioning::with_io_rows(prows as u64, widest, prows as u64);
    let ranges = if cfg.fuse_cache {
        strip_parts.cpu_ranges(0, cfg.cpu_part_bytes)
    } else {
        vec![(0u64, prows as u64)]
    };

    let opts = EvalOpts::from_config(cfg);
    for (ls, le) in ranges {
        let rows = (le - ls) as usize;
        let srcs: Vec<SourceStrip<'_>> = prog
            .sources
            .iter()
            .enumerate()
            .map(|(si, _)| {
                let (part_rows, local_row0) = src_meta[si];
                let bytes = &cache.slots[si].as_ref().unwrap().1[..];
                SourceStrip {
                    bytes,
                    part_rows,
                    local_row0: local_row0 + ls as usize,
                }
            })
            .collect();
        let regs = pipeline::eval_strip(prog, &srcs, g0 + ls, rows, opts, spool)?;

        // write target strips into the partition buffers (same-dtype
        // strips are copied straight from the register, no cast temp)
        for (ti, reg) in prog.target_regs.iter().enumerate() {
            let strip = regs[*reg].cast_ref(builders[ti].dtype())?;
            let ncol = builders[ti].parts().ncol as usize;
            for j in 0..ncol {
                out_bufs[ti].copy_range_from(j * prows + ls as usize, &strip, j * rows, rows);
            }
        }

        // feed sinks
        accs.feed(prog, &regs, rows, opts, spool)?;

        // recycle the strip's surviving registers for the next strip
        for b in regs {
            spool.release(b);
        }
    }

    for (ti, buf) in out_bufs.iter().enumerate() {
        builders[ti].write_partition_buf(pi, buf)?;
    }
    for b in out_bufs {
        spool.release(b);
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Sink accumulators
// ---------------------------------------------------------------------------

enum SinkAcc {
    Full { acc: Scalar, op: AggOp, na: NaMode },
    Col { acc: Buf, op: AggOp, na: NaMode },
    Group { acc: Buf, k: usize, op: AggOp },
    Inner { acc: Buf, f2: AggOp },
}

struct SinkAccSet {
    accs: Vec<SinkAcc>,
}

impl SinkAccSet {
    fn new(prog: &Program) -> SinkAccSet {
        let accs = prog
            .sinks
            .iter()
            .map(|s| {
                let src_dt = prog.instrs[s.src_reg].dtype;
                match &s.kind {
                    SinkInstrKind::AggFull(op, na) => {
                        let dt = op.acc_dtype(src_dt);
                        let id = if *na == NaMode::Off {
                            op.identity(dt)
                        } else {
                            op.identity_na(dt)
                        };
                        SinkAcc::Full {
                            acc: id,
                            op: *op,
                            na: *na,
                        }
                    }
                    SinkInstrKind::AggCol(op, na) => {
                        let dt = op.acc_dtype(src_dt);
                        let id = if *na == NaMode::Off {
                            op.identity(dt)
                        } else {
                            op.identity_na(dt)
                        };
                        SinkAcc::Col {
                            acc: Buf::fill(dt, s.ncol as usize, id),
                            op: *op,
                            na: *na,
                        }
                    }
                    SinkInstrKind::GroupByRow { k, op, .. } => {
                        let dt = op.acc_dtype(src_dt);
                        SinkAcc::Group {
                            acc: Buf::fill(dt, k * s.ncol as usize, op.identity(dt)),
                            k: *k,
                            op: *op,
                        }
                    }
                    SinkInstrKind::InnerWideTall { right_reg, f2, .. } => {
                        let q = prog.instrs[*right_reg].ncol as usize;
                        let dt = f2.acc_dtype(DType::F64);
                        SinkAcc::Inner {
                            acc: Buf::fill(dt, s.ncol as usize * q, f2.identity(dt)),
                            f2: *f2,
                        }
                    }
                }
            })
            .collect();
        SinkAccSet { accs }
    }

    /// Fold one evaluated strip into the accumulators.
    ///
    /// Strip reductions are *order-sensitive*: by default they stay on the
    /// sequential `reduce` path so results are bit-exact regardless of
    /// `simd_kernels`. Only the explicit `simd_reductions` opt-in routes
    /// them through the lane-parallel `reduce_lanes` form (≤4-ULP drift,
    /// pinned by `tests/simd_parity.rs`).
    fn feed(
        &mut self,
        prog: &Program,
        regs: &[Buf],
        rows: usize,
        opts: EvalOpts,
        pool: &mut StripPool,
    ) -> Result<()> {
        let vectorized = opts.vectorized;
        let lane_reduce = opts.simd && opts.simd_reductions && vectorized;
        for (si, sink) in prog.sinks.iter().enumerate() {
            let src = &regs[sink.src_reg];
            let ncol = sink.ncol as usize;
            match (&mut self.accs[si], &sink.kind) {
                (SinkAcc::Full { acc, op, na }, _) => {
                    if *na != NaMode::Off {
                        // NA-aware: reduce the *uncast* strip so integer
                        // NA sentinels are seen before any widening cast.
                        let part = if vectorized {
                            op.reduce_na(src, *na)
                        } else {
                            op.reduce_na_scalar_mode(src, *na)
                        };
                        *acc = op.fold_scalar_na(*acc, part, *na);
                        continue;
                    }
                    let dt = acc.dtype();
                    // borrow, don't copy, when the strip already has the
                    // accumulator dtype (the homogeneous-f64 fast case)
                    let cast = src.cast_ref(dt)?;
                    let part = if lane_reduce {
                        match op.reduce_lanes(&cast) {
                            Some(s) => s,
                            None => op.reduce(&cast),
                        }
                    } else if vectorized {
                        op.reduce(&cast)
                    } else {
                        op.reduce_scalar_mode(&cast)
                    };
                    *acc = op.fold_scalar(*acc, part);
                }
                (SinkAcc::Col { acc, op, na }, _) => {
                    if *na != NaMode::Off {
                        for j in 0..ncol {
                            let col = src.slice(j * rows, rows);
                            let part = if vectorized {
                                op.reduce_na(&col, *na)
                            } else {
                                op.reduce_na_scalar_mode(&col, *na)
                            };
                            acc.set(j, op.fold_scalar_na(acc.get(j), part, *na));
                        }
                        continue;
                    }
                    let dt = acc.dtype();
                    let cast = src.cast_ref(dt)?;
                    for j in 0..ncol {
                        let col = cast.slice(j * rows, rows);
                        let part = if lane_reduce {
                            match op.reduce_lanes(&col) {
                                Some(s) => s,
                                None => op.reduce(&col),
                            }
                        } else if vectorized {
                            op.reduce(&col)
                        } else {
                            op.reduce_scalar_mode(&col)
                        };
                        acc.set(j, op.fold_scalar(acc.get(j), part));
                    }
                }
                (SinkAcc::Group { acc, k, op }, SinkInstrKind::GroupByRow { labels_reg, .. }) => {
                    let labels = &regs[*labels_reg];
                    let dt = acc.dtype();
                    let cast = src.cast_ref(dt)?;
                    let kk = *k;
                    // f64-sum fast path (the k-means hot loop)
                    if let (Buf::F64(av), Buf::F64(ac), AggOp::Sum, Buf::I32(lv)) =
                        (&*cast, &mut *acc, *op, labels)
                    {
                        for j in 0..ncol {
                            let col = &av[j * rows..(j + 1) * rows];
                            let gcol = &mut ac[j * kk..(j + 1) * kk];
                            for r in 0..rows {
                                let g = lv[r];
                                if (0..kk as i32).contains(&g) {
                                    gcol[g as usize] += col[r];
                                }
                            }
                        }
                    } else {
                        for j in 0..ncol {
                            for r in 0..rows {
                                let g = labels.get(r).as_i64();
                                if g >= 0 && (g as usize) < kk {
                                    let idx = j * kk + g as usize;
                                    let folded =
                                        op.fold_scalar(acc.get(idx), cast.get(j * rows + r));
                                    acc.set(idx, folded);
                                }
                            }
                        }
                    }
                }
                (
                    SinkAcc::Inner { acc, f2 },
                    SinkInstrKind::InnerWideTall { right_reg, f1, .. },
                ) => {
                    let right = &regs[*right_reg];
                    let q = right.len() / rows;
                    let simd = opts.simd && vectorized;
                    inner_wide_tall_accum(acc, src, right, rows, ncol, q, *f1, *f2, simd, pool)?;
                }
                _ => unreachable!("acc/kind mismatch"),
            }
        }
        Ok(())
    }

    /// Merge another worker's partials (aVUDF2 combine).
    fn merge(&mut self, other: SinkAccSet) -> Result<()> {
        for (mine, theirs) in self.accs.iter_mut().zip(other.accs) {
            match (mine, theirs) {
                (SinkAcc::Full { acc, op, na }, SinkAcc::Full { acc: o, .. }) => {
                    *acc = op.fold_scalar_na(*acc, o, *na);
                }
                (SinkAcc::Col { acc, op, na }, SinkAcc::Col { acc: o, .. }) => {
                    op.combine_na(acc, &o, *na)?;
                }
                (SinkAcc::Group { acc, op, .. }, SinkAcc::Group { acc: o, .. }) => {
                    op.combine(acc, &o)?;
                }
                (SinkAcc::Inner { acc, f2 }, SinkAcc::Inner { acc: o, .. }) => {
                    f2.combine(acc, &o)?;
                }
                _ => return Err(FmError::Shape("sink accumulator mismatch".into())),
            }
        }
        Ok(())
    }

    fn finish(self, prog: &Program) -> Vec<SinkResult> {
        self.accs
            .into_iter()
            .zip(&prog.sinks)
            .map(|(acc, sink)| match acc {
                SinkAcc::Full { acc, .. } => SinkResult::Scalar(acc),
                SinkAcc::Col { acc, .. } => SinkResult::Mat(HostMat {
                    nrow: 1,
                    ncol: acc.len(),
                    buf: acc,
                }),
                SinkAcc::Group { acc, k, .. } => SinkResult::Mat(HostMat {
                    nrow: k,
                    ncol: acc.len() / k.max(1),
                    buf: acc,
                }),
                SinkAcc::Inner { acc, .. } => {
                    let p = sink.ncol as usize;
                    SinkResult::Mat(HostMat {
                        nrow: p,
                        ncol: acc.len() / p.max(1),
                        buf: acc,
                    })
                }
            })
            .collect()
    }
}

/// acc (p x q, col-major) ⊕= t(A_strip) ⊗ B_strip with (f1, f2).
///
/// With `simd` on, the (Mul, Sum, f64) Gramian case runs a register-blocked
/// microkernel: KB=4 left columns share one sweep of the right column, each
/// keeping its *own single sequential accumulator* — the same fold order as
/// the scalar dot, so results are bit-exact, but the four independent FP
/// chains break the add-latency bound that serializes the scalar loop
/// (FP non-reassociation keeps the compiler from doing this on its own).
#[allow(clippy::too_many_arguments)]
fn inner_wide_tall_accum(
    acc: &mut Buf,
    a: &Buf,
    b: &Buf,
    rows: usize,
    p: usize,
    q: usize,
    f1: crate::vudf::BinOp,
    f2: AggOp,
    simd: bool,
    pool: &mut StripPool,
) -> Result<()> {
    use crate::vudf::BinOp;
    if f1 == BinOp::Mul && f2 == AggOp::Sum && a.dtype() == DType::F64 && b.dtype() == DType::F64 {
        if let (Buf::F64(av), Buf::F64(bv), Buf::F64(ac)) = (a, b, &mut *acc) {
            if simd {
                const KB: usize = 4;
                let kcut = p - p % KB;
                let mut panels = 0u64;
                for c in 0..q {
                    let bcol = &bv[c * rows..(c + 1) * rows];
                    let acol_base = c * p;
                    let mut k0 = 0;
                    while k0 < kcut {
                        let a0 = &av[k0 * rows..(k0 + 1) * rows];
                        let a1 = &av[(k0 + 1) * rows..(k0 + 2) * rows];
                        let a2 = &av[(k0 + 2) * rows..(k0 + 3) * rows];
                        let a3 = &av[(k0 + 3) * rows..(k0 + 4) * rows];
                        let (mut d0, mut d1, mut d2, mut d3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
                        for r in 0..rows {
                            let y = bcol[r];
                            d0 += a0[r] * y;
                            d1 += a1[r] * y;
                            d2 += a2[r] * y;
                            d3 += a3[r] * y;
                        }
                        ac[acol_base + k0] += d0;
                        ac[acol_base + k0 + 1] += d1;
                        ac[acol_base + k0 + 2] += d2;
                        ac[acol_base + k0 + 3] += d3;
                        panels += 1;
                        k0 += KB;
                    }
                    for k in kcut..p {
                        let akcol = &av[k * rows..(k + 1) * rows];
                        let mut dot = 0.0f64;
                        for r in 0..rows {
                            dot += akcol[r] * bcol[r];
                        }
                        ac[acol_base + k] += dot;
                    }
                }
                pool.count_gemm_panels(panels);
                return Ok(());
            }
            // the Gramian hot loop: p*q dot products of length `rows`
            for c in 0..q {
                let bcol = &bv[c * rows..(c + 1) * rows];
                let acol_base = c * p;
                for k in 0..p {
                    let akcol = &av[k * rows..(k + 1) * rows];
                    let mut dot = 0.0f64;
                    for r in 0..rows {
                        dot += akcol[r] * bcol[r];
                    }
                    ac[acol_base + k] += dot;
                }
            }
            return Ok(());
        }
    }
    let dt = acc.dtype();
    for c in 0..q {
        for k in 0..p {
            let mut part = f2.identity(dt);
            for r in 0..rows {
                let x = a.get(k * rows + r).as_f64();
                let y = b.get(c * rows + r).as_f64();
                let v = match f1 {
                    BinOp::Add => x + y,
                    BinOp::Sub => x - y,
                    BinOp::Mul => x * y,
                    BinOp::Div => x / y,
                    BinOp::Min => x.min(y),
                    BinOp::Max => x.max(y),
                    BinOp::Eq => (x == y) as u8 as f64,
                    BinOp::Ne => (x != y) as u8 as f64,
                    _ => f64::NAN,
                };
                part = f2.fold_scalar(part, Scalar::F64(v));
            }
            let idx = c * p + k;
            let folded = f2.fold_scalar(acc.get(idx), part);
            acc.set(idx, folded);
        }
    }
    Ok(())
}

// Re-exported for the fmr and datasets layers.
pub use pipeline::{splitmix64_at, u64_to_unit_f64};
