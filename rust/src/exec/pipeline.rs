//! DAG -> pipeline program compilation and strip evaluation (paper §III-F).
//!
//! A materialization pass compiles the virtual-matrix DAG **once** into a
//! linear [`Program`] — one instruction per unique node, topologically
//! ordered — then executes that program for every CPU-level strip of every
//! I/O-level partition. Registers (one [`Buf`] per node) hold one strip of
//! each node's value; with cache-fuse enabled a strip fits L1/L2, so a
//! node's output is still cache-resident when its consumer runs — the
//! paper's "pass the partition to the subsequent operation instead of
//! materializing the next partition of the same matrix".
//!
//! Because register lifetimes of a compiled linear program are fully
//! known, allocation is planned **once per pass** instead of paid per
//! strip (§III-B5 applied to the hot path):
//!
//! * a *peephole pass* drops same-dtype casts (register aliasing) and
//!   fuses single-consumer `Sapply`/`MapplyScalar` f64 chains into one
//!   [`InstrKind::FusedChain`], so a strip is traversed once per chain
//!   instead of once per step (§III-E at the instruction level);
//! * a *liveness pass* records each register's last use ([`ExecPlan`]):
//!   unary/scalar/cast instructions whose sole input dies at them run
//!   **in place** on the input's buffer, and every other dead register's
//!   buffer is recycled through the worker's
//!   [`StripPool`](crate::mem::StripPool) honoring `recycle_chunks`.

use std::collections::HashMap;
use std::hint::black_box;
use std::sync::Arc;

use crate::dag::{SinkKind, SinkSpec, UnFn, VKind};
use crate::dtype::{DType, Scalar};
use crate::error::{FmError, Result};
use crate::matrix::{HostMat, Matrix, MatrixData};
use crate::mem::StripPool;
use crate::vudf::{self, AggOp, BinOp, Buf, NaMode, UnOp};

/// One compiled DAG node.
pub struct Instr {
    pub ncol: u64,
    pub dtype: DType,
    pub kind: InstrKind,
}

/// Instruction kinds. Register operands are indices into the program's
/// register file (= instruction order).
pub enum InstrKind {
    /// Strip-load from a materialized dense source (index into the
    /// program's `sources` table).
    LoadDense(usize),
    /// Strip-load from a group: concatenated member columns.
    LoadGroup(Vec<usize>),
    Fill(Scalar),
    Seq { start: f64, step: f64 },
    RandU { seed: u64, lo: f64, hi: f64 },
    RandN { seed: u64, mean: f64, sd: f64 },
    Sapply { a: usize, op: UnFn },
    Mapply { a: usize, b: usize, op: BinOp },
    MapplyScalar { a: usize, s: Scalar, op: BinOp, scalar_right: bool },
    MapplyRow { a: usize, w: Buf, op: BinOp },
    MapplyCol { a: usize, v: usize, op: BinOp },
    RowAgg { a: usize, op: AggOp, na: NaMode },
    RowArgExtreme { a: usize, max: bool },
    InnerSmall { a: usize, b: HostMat, f1: BinOp, f2: AggOp },
    /// Streaming SpMM: decode the CSR rows of sparse source `src` covering
    /// the strip and multiply against the small dense right operand
    /// (shared, not copied, from the DAG node). Reads no register — the
    /// sparse operand is a source, like `LoadDense`'s, but its bytes are
    /// consumed directly instead of densified.
    Spmm { src: usize, b: Arc<HostMat> },
    Cast { a: usize, to: DType },
    ColBind(Vec<usize>),
    SelectCol { a: usize, col: usize },
    /// Peephole-fused chain of single-consumer unary/scalar steps over
    /// one f64-valued register: the strip is traversed once, folding
    /// every step per element, instead of once per step.
    FusedChain { a: usize, steps: Vec<FusedStep> },
}

/// One step of an [`InstrKind::FusedChain`]. Steps always map f64 -> f64;
/// the chain head converts its input register to f64 exactly like the
/// unfused generic kernels do.
#[derive(Clone, Debug)]
pub enum FusedStep {
    Un(UnOp),
    /// `MapplyScalar` with the scalar pre-cast through the step's input
    /// dtype (what `binary_vs`/`binary_sv` would have done at run time).
    Scalar {
        s: f64,
        op: BinOp,
        scalar_right: bool,
    },
}

impl FusedStep {
    #[inline(always)]
    fn eval(&self, x: f64) -> f64 {
        match self {
            FusedStep::Un(u) => u.eval_f64(x),
            FusedStep::Scalar {
                s,
                op,
                scalar_right,
            } => {
                if *scalar_right {
                    op.eval_f64(x, *s)
                } else {
                    op.eval_f64(*s, x)
                }
            }
        }
    }
}

/// Compiled sink: which register feeds it + terminal aggregation.
pub struct SinkInstr {
    pub src_reg: usize,
    pub ncol: u64,
    pub kind: SinkInstrKind,
}

pub enum SinkInstrKind {
    AggFull(AggOp, NaMode),
    AggCol(AggOp, NaMode),
    GroupByRow { labels_reg: usize, k: usize, op: AggOp },
    InnerWideTall { right_reg: usize, f1: BinOp, f2: AggOp },
}

/// A fully compiled materialization pass.
pub struct Program {
    pub instrs: Vec<Instr>,
    /// Distinct dense sources (loaded once per I/O partition).
    pub sources: Vec<Arc<MatrixData>>,
    /// Register index of each requested target matrix.
    pub target_regs: Vec<usize>,
    pub sinks: Vec<SinkInstr>,
    /// Shared long dimension of the DAG.
    pub nrow: u64,
    /// Register-allocation plan (liveness, in-place, fusion bookkeeping).
    pub plan: ExecPlan,
}

/// Compile-time optimization switches (mirrors the `EngineConfig` knobs;
/// `benches/strip_fusion.rs` ablates them).
#[derive(Clone, Copy, Debug)]
pub struct CompileOpts {
    /// Drop same-dtype casts and fuse single-consumer `Sapply` /
    /// `MapplyScalar` f64 chains into [`InstrKind::FusedChain`]s.
    pub peephole_fuse: bool,
    /// Plan in-place execution onto dead input registers.
    pub inplace_ops: bool,
}

impl Default for CompileOpts {
    fn default() -> Self {
        CompileOpts {
            peephole_fuse: true,
            inplace_ops: true,
        }
    }
}

/// Compile-time register-allocation plan: last-use liveness over the
/// program's registers, computed once per pass so strips pay neither the
/// analysis nor (with recycling/in-place on) the allocations.
pub struct ExecPlan {
    /// `dies_at[i]`: registers whose last read is instruction `i` and
    /// that no target or sink needs afterwards; the evaluator releases
    /// their buffers to the strip pool right after `i` executes.
    pub dies_at: Vec<Vec<usize>>,
    /// `inplace[i]`: instruction `i` may steal its input register's
    /// buffer (the input dies at `i`, dtypes match, and the kernel has a
    /// bit-identical in-place form).
    pub inplace: Vec<bool>,
    /// Total steps folded into `FusedChain` instructions (the
    /// `fused_chain_len` metric, counted once per compiled pass).
    pub fused_steps: u64,
}

/// Compile targets + sinks with the default (fully optimized) options.
pub fn compile(targets: &[Matrix], sinks: &[SinkSpec]) -> Result<Program> {
    compile_opts(targets, sinks, CompileOpts::default())
}

/// Compile targets + sinks into a program. All roots must share the long
/// dimension (checked).
pub fn compile_opts(targets: &[Matrix], sinks: &[SinkSpec], opts: CompileOpts) -> Result<Program> {
    let mut roots: Vec<Matrix> = targets.to_vec();
    for s in sinks {
        roots.push(s.source.clone());
        match &s.kind {
            SinkKind::GroupByRow { labels, .. } => roots.push(labels.clone()),
            SinkKind::InnerWideTall { right, .. } => roots.push(right.clone()),
            _ => {}
        }
    }
    if roots.is_empty() {
        return Err(FmError::Shape("nothing to materialize".into()));
    }
    let nrow = crate::dag::validate_long_dim(&roots)?;

    let order = crate::dag::topo_order(&roots);
    let mut reg_of: HashMap<usize, usize> = HashMap::new();
    let mut src_of: HashMap<usize, usize> = HashMap::new();
    let mut instrs = Vec::new();
    let mut sources: Vec<Arc<MatrixData>> = Vec::new();

    let src_idx = |m: &Matrix, sources: &mut Vec<Arc<MatrixData>>,
                       src_of: &mut HashMap<usize, usize>| {
        *src_of.entry(m.data_ptr()).or_insert_with(|| {
            sources.push(Arc::clone(&m.data));
            sources.len() - 1
        })
    };

    for m in &order {
        let reg = instrs.len();
        let kind = match &*m.data {
            MatrixData::Dense(_) => InstrKind::LoadDense(src_idx(m, &mut sources, &mut src_of)),
            MatrixData::Sparse(_) => {
                return Err(FmError::Unsupported(
                    "sparse matrices feed spmm only; they cannot load as dense strips".into(),
                ))
            }
            MatrixData::Group(g) => {
                let mut idxs = Vec::new();
                for mem in &g.members {
                    let mm = Matrix {
                        data: Arc::clone(mem),
                        transposed: false,
                    };
                    match &**mem {
                        MatrixData::Dense(_) => {
                            idxs.push(src_idx(&mm, &mut sources, &mut src_of))
                        }
                        _ => {
                            return Err(FmError::Unsupported(
                                "group members must be materialized dense matrices".into(),
                            ))
                        }
                    }
                }
                InstrKind::LoadGroup(idxs)
            }
            // the SpMM node registers its sparse operand as a pass
            // *source* (read per partition, range-scheduled, prefetched
            // like a dense source) rather than as a register; everything
            // else compiles through the generic table
            MatrixData::Virtual(v) => match &v.kind {
                VKind::Spmm { a, b } => {
                    if !a.data.is_sparse() {
                        return Err(FmError::Unsupported(
                            "spmm operand must be a sparse matrix".into(),
                        ));
                    }
                    InstrKind::Spmm {
                        src: src_idx(a, &mut sources, &mut src_of),
                        b: Arc::clone(b),
                    }
                }
                _ => compile_vkind(&v.kind, &reg_of)?,
            },
        };
        instrs.push(Instr {
            ncol: m.data.ncol(),
            dtype: m.data.dtype(),
            kind,
        });
        reg_of.insert(m.data_ptr(), reg);
    }

    let target_regs: Vec<usize> = targets.iter().map(|t| reg_of[&t.data_ptr()]).collect();
    let sinks: Vec<SinkInstr> = sinks
        .iter()
        .map(|s| {
            let src_reg = reg_of[&s.source.data_ptr()];
            let ncol = s.source.data.ncol();
            let kind = match &s.kind {
                SinkKind::AggFull(op, na) => SinkInstrKind::AggFull(*op, *na),
                SinkKind::AggCol(op, na) => SinkInstrKind::AggCol(*op, *na),
                SinkKind::GroupByRow { labels, k, op } => SinkInstrKind::GroupByRow {
                    labels_reg: reg_of[&labels.data_ptr()],
                    k: *k,
                    op: *op,
                },
                SinkKind::InnerWideTall { right, f1, f2 } => SinkInstrKind::InnerWideTall {
                    right_reg: reg_of[&right.data_ptr()],
                    f1: *f1,
                    f2: *f2,
                },
            };
            SinkInstr { src_reg, ncol, kind }
        })
        .collect();

    let (instrs, target_regs, sinks, fused_steps) = if opts.peephole_fuse {
        peephole(instrs, target_regs, sinks)
    } else {
        (instrs, target_regs, sinks, 0)
    };
    let plan = plan_liveness(&instrs, &target_regs, &sinks, opts, fused_steps);

    Ok(Program {
        instrs,
        sources,
        target_regs,
        sinks,
        nrow,
        plan,
    })
}

// ---------------------------------------------------------------------------
// Compile-time register planning
// ---------------------------------------------------------------------------

/// Registers read by an instruction (with multiplicity).
fn instr_reads(kind: &InstrKind) -> Vec<usize> {
    match kind {
        InstrKind::LoadDense(_)
        | InstrKind::LoadGroup(_)
        | InstrKind::Fill(_)
        | InstrKind::Seq { .. }
        | InstrKind::RandU { .. }
        | InstrKind::RandN { .. }
        | InstrKind::Spmm { .. } => vec![],
        InstrKind::Sapply { a, .. }
        | InstrKind::MapplyScalar { a, .. }
        | InstrKind::MapplyRow { a, .. }
        | InstrKind::RowAgg { a, .. }
        | InstrKind::RowArgExtreme { a, .. }
        | InstrKind::InnerSmall { a, .. }
        | InstrKind::Cast { a, .. }
        | InstrKind::SelectCol { a, .. }
        | InstrKind::FusedChain { a, .. } => vec![*a],
        InstrKind::Mapply { a, b, .. } => vec![*a, *b],
        InstrKind::MapplyCol { a, v, .. } => vec![*a, *v],
        InstrKind::ColBind(ps) => ps.clone(),
    }
}

/// Rewrite every register operand through `f`.
fn remap_operands(kind: &mut InstrKind, f: impl Fn(usize) -> usize) {
    match kind {
        InstrKind::LoadDense(_)
        | InstrKind::LoadGroup(_)
        | InstrKind::Fill(_)
        | InstrKind::Seq { .. }
        | InstrKind::RandU { .. }
        | InstrKind::RandN { .. }
        | InstrKind::Spmm { .. } => {}
        InstrKind::Sapply { a, .. }
        | InstrKind::MapplyScalar { a, .. }
        | InstrKind::MapplyRow { a, .. }
        | InstrKind::RowAgg { a, .. }
        | InstrKind::RowArgExtreme { a, .. }
        | InstrKind::InnerSmall { a, .. }
        | InstrKind::Cast { a, .. }
        | InstrKind::SelectCol { a, .. }
        | InstrKind::FusedChain { a, .. } => *a = f(*a),
        InstrKind::Mapply { a, b, .. } => {
            *a = f(*a);
            *b = f(*b);
        }
        InstrKind::MapplyCol { a, v, .. } => {
            *a = f(*a);
            *v = f(*v);
        }
        InstrKind::ColBind(ps) => {
            for p in ps.iter_mut() {
                *p = f(*p);
            }
        }
    }
}

/// Peephole rewrite (§III-E at the instruction level):
///
/// 1. **Identity-cast elimination** — a `Cast` whose producer already has
///    the target dtype becomes a register alias (same-dtype casts cost
///    nothing; the `fmr` layer inserts them freely).
/// 2. **Chain fusion** — a `Sapply` (built-in) or `MapplyScalar` with f64
///    output whose producer is an f64 `Sapply`/`MapplyScalar`/chain with
///    no other consumer merges into that producer as one
///    [`InstrKind::FusedChain`]: one strip traversal per chain.
///
/// Operands stored in the surviving instructions keep their original
/// register indices until the final compaction, which renumbers
/// everything (instructions, targets, sinks) densely.
fn peephole(
    instrs: Vec<Instr>,
    target_regs: Vec<usize>,
    sinks: Vec<SinkInstr>,
) -> (Vec<Instr>, Vec<usize>, Vec<SinkInstr>, u64) {
    let n = instrs.len();
    // readers of each original register, including targets and sinks
    let mut uses = vec![0usize; n];
    for ins in &instrs {
        for r in instr_reads(&ins.kind) {
            uses[r] += 1;
        }
    }
    for r in &target_regs {
        uses[*r] += 1;
    }
    for s in &sinks {
        uses[s.src_reg] += 1;
        match &s.kind {
            SinkInstrKind::GroupByRow { labels_reg, .. } => uses[*labels_reg] += 1,
            SinkInstrKind::InnerWideTall { right_reg, .. } => uses[*right_reg] += 1,
            _ => {}
        }
    }

    let mut slots: Vec<Option<Instr>> = instrs.into_iter().map(Some).collect();
    // remap[r]: live slot holding register r's value (identity for live
    // registers; eliminated/fused registers point at their replacement,
    // which by construction is never eliminated later)
    let mut remap: Vec<usize> = (0..n).collect();
    // effective reader count per *live slot* (kept consistent as
    // eliminated registers redirect their readers)
    let mut eff = uses.clone();
    let mut fused_steps = 0u64;

    for j in 0..n {
        let (a_orig, dtype) = {
            let ins = slots[j].as_ref().expect("slot j not yet rewritten");
            let reads = instr_reads(&ins.kind);
            if reads.len() != 1 {
                continue;
            }
            (reads[0], ins.dtype)
        };
        let ar = remap[a_orig];
        enum Rw {
            Alias,
            Fuse(FusedStep),
        }
        let rw = match &slots[j].as_ref().unwrap().kind {
            InstrKind::Cast { to, .. } if slots[ar].as_ref().unwrap().dtype == *to => Rw::Alias,
            InstrKind::Sapply {
                op: UnFn::Builtin(u),
                ..
            } if dtype == DType::F64 => Rw::Fuse(FusedStep::Un(*u)),
            InstrKind::MapplyScalar {
                s, op, scalar_right, ..
            } if dtype == DType::F64 => Rw::Fuse(FusedStep::Scalar {
                // the unfused path casts the scalar to the input dtype
                // (f64 here: chain intermediates are all f64)
                s: s.cast(DType::F64).as_f64(),
                op: *op,
                scalar_right: *scalar_right,
            }),
            _ => continue,
        };
        match rw {
            Rw::Alias => {
                // readers of j now read ar; ar loses the cast itself
                eff[ar] = eff[ar] - 1 + eff[j];
                remap[j] = ar;
                slots[j] = None;
            }
            Rw::Fuse(step) => {
                // fuse only into a single-consumer f64 chain head
                if eff[ar] != 1 || slots_dtype(&slots, ar) != DType::F64 {
                    continue;
                }
                // build the replacement kind from an immutable view first
                let new_kind: Option<InstrKind> = match &slots[ar].as_ref().unwrap().kind {
                    InstrKind::Sapply {
                        a: h,
                        op: UnFn::Builtin(u0),
                    } => Some(InstrKind::FusedChain {
                        a: *h,
                        steps: vec![FusedStep::Un(*u0), step.clone()],
                    }),
                    InstrKind::MapplyScalar {
                        a: h,
                        s: s0,
                        op: op0,
                        scalar_right: sr0,
                    } => {
                        // head input may be non-f64: pre-cast its scalar
                        // through the *input register's* dtype, exactly
                        // like binary_vs/binary_sv would at run time
                        let hdt = slots_dtype(&slots, remap[*h]);
                        Some(InstrKind::FusedChain {
                            a: *h,
                            steps: vec![
                                FusedStep::Scalar {
                                    s: s0.cast(hdt).as_f64(),
                                    op: *op0,
                                    scalar_right: *sr0,
                                },
                                step.clone(),
                            ],
                        })
                    }
                    InstrKind::FusedChain { .. } => None,
                    _ => continue,
                };
                match new_kind {
                    Some(k) => {
                        fused_steps += 2;
                        slots[ar].as_mut().unwrap().kind = k;
                    }
                    None => {
                        if let InstrKind::FusedChain { steps, .. } =
                            &mut slots[ar].as_mut().unwrap().kind
                        {
                            fused_steps += 1;
                            steps.push(step);
                        }
                    }
                }
                eff[ar] = eff[ar] - 1 + eff[j];
                remap[j] = ar;
                slots[j] = None;
            }
        }
    }

    // compact: drop eliminated slots, renumber every register reference
    let mut final_idx = vec![usize::MAX; n];
    let mut out: Vec<Instr> = Vec::with_capacity(n);
    for (i, slot) in slots.iter_mut().enumerate() {
        if let Some(ins) = slot.take() {
            final_idx[i] = out.len();
            out.push(ins);
        }
    }
    let resolve = |r: usize| final_idx[remap[r]];
    for ins in &mut out {
        remap_operands(&mut ins.kind, &resolve);
    }
    let target_regs = target_regs.into_iter().map(&resolve).collect();
    let sinks = sinks
        .into_iter()
        .map(|mut s| {
            s.src_reg = resolve(s.src_reg);
            match &mut s.kind {
                SinkInstrKind::GroupByRow { labels_reg, .. } => *labels_reg = resolve(*labels_reg),
                SinkInstrKind::InnerWideTall { right_reg, .. } => *right_reg = resolve(*right_reg),
                _ => {}
            }
            s
        })
        .collect();
    (out, target_regs, sinks, fused_steps)
}

/// Dtype of the live slot `r` (helper for the borrow-heavy fusion path).
fn slots_dtype(slots: &[Option<Instr>], r: usize) -> DType {
    slots[r].as_ref().expect("remap points at live slots").dtype
}

/// Last-use liveness + in-place planning over the final instruction list.
fn plan_liveness(
    instrs: &[Instr],
    target_regs: &[usize],
    sinks: &[SinkInstr],
    opts: CompileOpts,
    fused_steps: u64,
) -> ExecPlan {
    let n = instrs.len();
    let mut live_end = vec![false; n];
    for r in target_regs {
        live_end[*r] = true;
    }
    for s in sinks {
        live_end[s.src_reg] = true;
        match &s.kind {
            SinkInstrKind::GroupByRow { labels_reg, .. } => live_end[*labels_reg] = true,
            SinkInstrKind::InnerWideTall { right_reg, .. } => live_end[*right_reg] = true,
            _ => {}
        }
    }
    let mut last_use = vec![usize::MAX; n];
    for (i, ins) in instrs.iter().enumerate() {
        for r in instr_reads(&ins.kind) {
            last_use[r] = i;
        }
    }
    let mut dies_at: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (r, &lu) in last_use.iter().enumerate() {
        if !live_end[r] && lu != usize::MAX {
            dies_at[lu].push(r);
        }
    }
    let mut inplace = vec![false; n];
    if opts.inplace_ops {
        for (i, ins) in instrs.iter().enumerate() {
            let cand = match &ins.kind {
                InstrKind::Sapply {
                    a,
                    op: UnFn::Builtin(u),
                } if instrs[*a].dtype == ins.dtype && u.supports_inplace(instrs[*a].dtype) => {
                    Some(*a)
                }
                InstrKind::MapplyScalar { a, op, .. }
                    if instrs[*a].dtype == ins.dtype
                        && op.supports_inplace_broadcast(instrs[*a].dtype) =>
                {
                    Some(*a)
                }
                // same-dtype cast of a dead register is a pure move
                InstrKind::Cast { a, to } if instrs[*a].dtype == *to => Some(*a),
                InstrKind::FusedChain { a, .. } if instrs[*a].dtype == DType::F64 => Some(*a),
                _ => None,
            };
            if let Some(a) = cand {
                if !live_end[a] && last_use[a] == i {
                    inplace[i] = true;
                }
            }
        }
    }
    ExecPlan {
        dies_at,
        inplace,
        fused_steps,
    }
}

fn compile_vkind(kind: &VKind, reg_of: &HashMap<usize, usize>) -> Result<InstrKind> {
    let r = |m: &Matrix| -> usize { reg_of[&m.data_ptr()] };
    Ok(match kind {
        VKind::Fill(s) => InstrKind::Fill(*s),
        VKind::Seq { start, step } => InstrKind::Seq {
            start: *start,
            step: *step,
        },
        VKind::RandU { seed, lo, hi } => InstrKind::RandU {
            seed: *seed,
            lo: *lo,
            hi: *hi,
        },
        VKind::RandN { seed, mean, sd } => InstrKind::RandN {
            seed: *seed,
            mean: *mean,
            sd: *sd,
        },
        VKind::Sapply { a, op } => InstrKind::Sapply {
            a: r(a),
            op: op.clone(),
        },
        VKind::Mapply { a, b, op } => InstrKind::Mapply {
            a: r(a),
            b: r(b),
            op: *op,
        },
        VKind::MapplyScalar {
            a,
            s,
            op,
            scalar_right,
        } => InstrKind::MapplyScalar {
            a: r(a),
            s: *s,
            op: *op,
            scalar_right: *scalar_right,
        },
        VKind::MapplyRow { a, w, op } => InstrKind::MapplyRow {
            a: r(a),
            w: w.buf.clone(),
            op: *op,
        },
        VKind::MapplyCol { a, v, op } => InstrKind::MapplyCol {
            a: r(a),
            v: r(v),
            op: *op,
        },
        VKind::RowAgg { a, op, na } => InstrKind::RowAgg {
            a: r(a),
            op: *op,
            na: *na,
        },
        VKind::RowArgExtreme { a, max } => InstrKind::RowArgExtreme { a: r(a), max: *max },
        VKind::InnerSmall { a, b, f1, f2 } => InstrKind::InnerSmall {
            a: r(a),
            b: b.clone(),
            f1: *f1,
            f2: *f2,
        },
        VKind::Spmm { .. } => {
            return Err(FmError::Unsupported(
                "spmm compiles in the source-registration path".into(),
            ))
        }
        VKind::Cast { a, to } => InstrKind::Cast { a: r(a), to: *to },
        VKind::SelectCol { a, col } => InstrKind::SelectCol {
            a: r(a),
            col: *col as usize,
        },
        VKind::ColBind(ms) => InstrKind::ColBind(ms.iter().map(r).collect()),
    })
}

// ---------------------------------------------------------------------------
// Strip evaluation
// ---------------------------------------------------------------------------

/// Per-partition source data: raw col-major bytes of each source's
/// partition slice covering the pass partition, plus its local row range.
pub struct SourceStrip<'a> {
    /// Partition bytes of the *source's own* partition containing this pass
    /// partition.
    pub bytes: &'a [u8],
    /// Rows in the source partition the bytes describe.
    pub part_rows: usize,
    /// Row offset of the pass partition within the source partition.
    pub local_row0: usize,
}

/// Counter-based SplitMix64: the i-th value of a sequential SplitMix64
/// stream seeded with `seed` (matches python/tests/test_golden.py).
#[inline]
pub fn splitmix64_at(seed: u64, i: u64) -> u64 {
    let mut z = seed.wrapping_add((i.wrapping_add(1)).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// u64 -> f64 in [0,1) via the 53-bit mantissa trick.
#[inline]
pub fn u64_to_unit_f64(z: u64) -> f64 {
    (z >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Take a register's buffer out, leaving an empty placeholder (in-place
/// execution and pool release both go through this).
#[inline]
fn take_reg(regs: &mut [Buf], r: usize) -> Buf {
    std::mem::replace(&mut regs[r], Buf::empty())
}

/// Run-time kernel selection for one strip evaluation (mirrors the
/// `EngineConfig` knobs the same way [`CompileOpts`] mirrors the
/// compile-time ones).
#[derive(Clone, Copy, Debug)]
pub struct EvalOpts {
    /// VUDF mode (Fig 12 ablation): off = one boxed call per element.
    pub vectorized: bool,
    /// Route eligible instructions through the explicit SIMD lane kernels
    /// and register-blocked GEMM microkernels
    /// (`EngineConfig::simd_kernels`). Only meaningful with `vectorized`;
    /// results stay bit-identical to the plain vectorized kernels.
    pub simd: bool,
    /// Allow order-changing lane-parallel full/column reductions in the
    /// sinks (`EngineConfig::simd_reductions`, ≤4-ULP drift).
    pub simd_reductions: bool,
}

impl EvalOpts {
    /// Plain kernels (no explicit SIMD) with the given VUDF mode.
    pub fn plain(vectorized: bool) -> EvalOpts {
        EvalOpts {
            vectorized,
            simd: false,
            simd_reductions: false,
        }
    }

    /// The engine's kernel knobs for a materialization pass.
    pub fn from_config(cfg: &crate::config::EngineConfig) -> EvalOpts {
        EvalOpts {
            vectorized: cfg.vectorized_udf,
            simd: cfg.simd_kernels,
            simd_reductions: cfg.simd_reductions,
        }
    }
}

/// Evaluate the program for one strip.
///
/// * `srcs[i]` — source strip context for `Program::sources[i]`
///   (dense groups reference several entries).
/// * `global_row0` — global row index of the strip's first row (generators).
/// * `rows` — strip height.
/// * `opts` — run-time kernel selection ([`EvalOpts`]): VUDF mode
///   (Fig 12 ablation) and the explicit-SIMD knobs.
/// * `pool` — the worker's strip-buffer recycler; dead registers
///   (per [`ExecPlan::dies_at`]) are released into it as the program
///   runs, and in-place-planned instructions steal their input's buffer
///   outright.
///
/// Returns the register file. Registers that died mid-program hold an
/// empty placeholder; every target- or sink-referenced register is
/// intact. The caller should release the returned buffers back to
/// `pool` once it is done with them.
pub fn eval_strip(
    prog: &Program,
    srcs: &[SourceStrip<'_>],
    global_row0: u64,
    rows: usize,
    opts: EvalOpts,
    pool: &mut StripPool,
) -> Result<Vec<Buf>> {
    let plan = &prog.plan;
    let vectorized = opts.vectorized;
    // explicit SIMD only replaces *vectorized* kernels; the per-element
    // boxed-call ablation mode must keep its per-element cost
    let simd = opts.simd && opts.vectorized;
    let simd_w0 = pool.simd_work();
    let mut regs: Vec<Buf> = Vec::with_capacity(prog.instrs.len());
    for (i, ins) in prog.instrs.iter().enumerate() {
        let ncol = ins.ncol as usize;
        let inplace = plan.inplace[i];
        let out: Buf = match &ins.kind {
            InstrKind::LoadDense(si) => load_strip(&srcs[*si], ins.dtype, ncol, rows, pool)?,
            InstrKind::LoadGroup(sis) => {
                let mut out = pool.acquire(ins.dtype, rows * ncol);
                let mut col_off = 0usize;
                for si in sis {
                    // decode with the *member's own* dtype — a member whose
                    // dtype differs from the promoted group dtype (e.g. an
                    // I32 column bound with F64 columns) has a different
                    // element size, so using the group dtype would both
                    // miscount its columns and misread its bytes
                    let mdt = prog.sources[*si].dtype();
                    let member_ncol = {
                        // member ncol = bytes/(part_rows*esz)
                        let esz = mdt.size();
                        srcs[*si].bytes.len() / (srcs[*si].part_rows * esz)
                    };
                    let m = load_strip(&srcs[*si], mdt, member_ncol, rows, pool)?;
                    // only heterogeneous members pay the cast copy
                    if mdt == ins.dtype {
                        out.copy_from(col_off * rows, &m);
                    } else {
                        out.copy_from(col_off * rows, &m.cast(ins.dtype)?);
                    }
                    pool.release(m);
                    col_off += member_ncol;
                }
                out
            }
            InstrKind::Fill(s) => {
                let mut b = pool.acquire(ins.dtype, rows * ncol);
                b.fill_scalar(*s);
                b
            }
            InstrKind::Seq { start, step } => {
                let mut b = pool.acquire(ins.dtype, rows * ncol);
                for j in 0..ncol {
                    for r in 0..rows {
                        // sequence walks the long dimension; columns repeat
                        let v = start + step * (global_row0 + r as u64) as f64;
                        b.set(j * rows + r, Scalar::F64(v));
                    }
                }
                b
            }
            InstrKind::RandU { seed, lo, hi } => {
                let mut b = pool.acquire(ins.dtype, rows * ncol);
                for j in 0..ncol {
                    for r in 0..rows {
                        let idx = (global_row0 + r as u64) * ins.ncol + j as u64;
                        let u = u64_to_unit_f64(splitmix64_at(*seed, idx));
                        b.set(j * rows + r, Scalar::F64(lo + (hi - lo) * u));
                    }
                }
                b
            }
            InstrKind::RandN { seed, mean, sd } => {
                let mut b = pool.acquire(ins.dtype, rows * ncol);
                for j in 0..ncol {
                    for r in 0..rows {
                        let idx = (global_row0 + r as u64) * ins.ncol + j as u64;
                        let u1 = u64_to_unit_f64(splitmix64_at(*seed, idx * 2)).max(1e-300);
                        let u2 = u64_to_unit_f64(splitmix64_at(*seed, idx * 2 + 1));
                        let z = (-2.0 * u1.ln()).sqrt()
                            * (2.0 * std::f64::consts::PI * u2).cos();
                        b.set(j * rows + r, Scalar::F64(mean + sd * z));
                    }
                }
                b
            }
            InstrKind::Sapply { a, op } => match op {
                UnFn::Builtin(u) => {
                    if inplace {
                        let mut b = take_reg(&mut regs, *a);
                        u.apply_inplace(&mut b, vectorized);
                        pool.count_inplace();
                        b
                    } else if simd {
                        let (r, g) = vudf::unary_lanes(*u, &regs[*a])?;
                        pool.count_simd_lanes_f64(g);
                        pool.count_alloc();
                        r
                    } else {
                        let r = vudf::unary(*u, &regs[*a], vectorized)?;
                        pool.count_alloc();
                        r
                    }
                }
                UnFn::Custom(c) => {
                    let r = c.unary(&regs[*a])?;
                    pool.count_alloc();
                    r
                }
            },
            InstrKind::Mapply { a, b, op } => {
                // insert implicit promotion casts (paper §III-D); a
                // same-dtype operand is borrowed, not copied
                let t = DType::promote(regs[*a].dtype(), regs[*b].dtype());
                let ba = regs[*a].cast_ref(t)?;
                let bb = regs[*b].cast_ref(t)?;
                let r = if simd {
                    let (r, g) = vudf::binary_vv_lanes(*op, &ba, &bb)?;
                    pool.count_simd_lanes_f64(g);
                    r
                } else {
                    vudf::binary_vv(*op, &ba, &bb, vectorized)?
                };
                pool.count_alloc();
                r
            }
            InstrKind::MapplyScalar {
                a,
                s,
                op,
                scalar_right,
            } => {
                if inplace {
                    let mut b = take_reg(&mut regs, *a);
                    op.apply_broadcast_inplace(&mut b, *s, *scalar_right, vectorized);
                    pool.count_inplace();
                    b
                } else if simd {
                    let (r, g) = if *scalar_right {
                        vudf::binary_vs_lanes(*op, &regs[*a], *s)?
                    } else {
                        vudf::binary_sv_lanes(*op, *s, &regs[*a])?
                    };
                    pool.count_simd_lanes_f64(g);
                    pool.count_alloc();
                    r
                } else {
                    let r = if *scalar_right {
                        vudf::binary_vs(*op, &regs[*a], *s, vectorized)?
                    } else {
                        vudf::binary_sv(*op, *s, &regs[*a], vectorized)?
                    };
                    pool.count_alloc();
                    r
                }
            }
            InstrKind::MapplyRow { a, w, op } => {
                let r = if simd {
                    let (r, g) = vudf::binary_rowvec_lanes(*op, &regs[*a], w, rows, ncol)?;
                    pool.count_simd_lanes_f64(g);
                    r
                } else {
                    vudf::binary_rowvec(*op, &regs[*a], w, rows, ncol, vectorized)?
                };
                pool.count_alloc();
                r
            }
            InstrKind::MapplyCol { a, v, op } => {
                let acols = regs[*a].len() / rows;
                let t = DType::promote(regs[*a].dtype(), regs[*v].dtype());
                let ba = regs[*a].cast_ref(t)?;
                let bv = regs[*v].cast_ref(t)?;
                let r = if simd {
                    let (r, g) = vudf::binary_colvec_lanes(*op, &ba, &bv, rows, acols)?;
                    pool.count_simd_lanes_f64(g);
                    r
                } else {
                    vudf::binary_colvec(*op, &ba, &bv, rows, acols, vectorized)?
                };
                pool.count_alloc();
                r
            }
            InstrKind::RowAgg { a, op, na } => row_agg(&regs[*a], rows, *op, *na, opts, pool),
            InstrKind::RowArgExtreme { a, max } => row_arg_extreme(&regs[*a], rows, *max, pool),
            InstrKind::InnerSmall { a, b, f1, f2 } => {
                inner_small(&regs[*a], rows, b, *f1, *f2, simd, pool)?
            }
            InstrKind::Spmm { src, b } => spmm_strip(&srcs[*src], rows, b, pool)?,
            InstrKind::Cast { a, to } => {
                if inplace {
                    // same-dtype cast of a dead register: pure move
                    take_reg(&mut regs, *a)
                } else {
                    let mut b = pool.acquire(*to, regs[*a].len());
                    regs[*a].cast_into(&mut b)?;
                    b
                }
            }
            InstrKind::SelectCol { a, col } => {
                let mut b = pool.acquire(regs[*a].dtype(), rows);
                b.copy_range_from(0, &regs[*a], col * rows, rows);
                b
            }
            InstrKind::ColBind(parts) => {
                let mut out = pool.acquire(ins.dtype, rows * ncol);
                let mut off = 0usize;
                for p in parts {
                    // same-dtype parts are copied straight from the
                    // register, no cast temporary
                    let src = regs[*p].cast_ref(ins.dtype)?;
                    out.copy_from(off, &src);
                    off += src.len();
                }
                out
            }
            InstrKind::FusedChain { a, steps } => {
                if inplace {
                    // in-place chains are planned only on f64 inputs
                    let mut b = take_reg(&mut regs, *a);
                    if simd {
                        let g = chain_lanes_f64_inplace(b.as_f64_mut(), steps);
                        pool.count_simd_lanes_f64(g);
                    } else {
                        run_chain_inplace(&mut b, steps, vectorized);
                    }
                    pool.count_inplace();
                    b
                } else {
                    let mut b = pool.acquire(DType::F64, regs[*a].len());
                    match &regs[*a] {
                        Buf::F64(v) if simd => {
                            let g = chain_lanes_f64(v, b.as_f64_mut(), steps);
                            pool.count_simd_lanes_f64(g);
                        }
                        src => run_chain(src, &mut b, steps, vectorized),
                    }
                    b
                }
            }
        };
        regs.push(out);
        // recycle registers whose last use was this instruction
        // (in-place-consumed inputs are already empty placeholders)
        for r in &plan.dies_at[i] {
            let b = take_reg(&mut regs, *r);
            pool.release(b);
        }
    }
    // a strip counts as SIMD-evaluated when any lane group or GEMM panel
    // ran in it (`Metrics::simd_strips`)
    if pool.simd_work() > simd_w0 {
        pool.count_simd_strip();
    }
    Ok(regs)
}

/// Fold a fused chain over `input` into the f64 buffer `out` (one strip
/// traversal). The input-to-f64 conversion matches what the unfused
/// generic kernels do (`to_f64_vec` semantics); `vectorized = false`
/// routes every step through `black_box` so the Fig 12 element-call
/// ablation keeps paying one opaque call per element per step.
fn run_chain(input: &Buf, out: &mut Buf, steps: &[FusedStep], vectorized: bool) {
    let o = out.as_f64_mut();
    macro_rules! fold {
        ($v:expr, $conv:expr) => {{
            if vectorized {
                for (dst, x) in o.iter_mut().zip($v.iter()) {
                    let mut y = $conv(*x);
                    for st in steps {
                        y = st.eval(y);
                    }
                    *dst = y;
                }
            } else {
                for (dst, x) in o.iter_mut().zip($v.iter()) {
                    let mut y = black_box($conv(*x));
                    for st in steps {
                        y = black_box(st.eval(black_box(y)));
                    }
                    *dst = y;
                }
            }
        }};
    }
    match input {
        Buf::F64(v) => fold!(v, |x: f64| x),
        Buf::F32(v) => fold!(v, |x: f32| x as f64),
        Buf::I64(v) => fold!(v, |x: i64| x as f64),
        Buf::I32(v) => fold!(v, |x: i32| x as f64),
        Buf::Bool(v) => fold!(v, |x: bool| x as u8 as f64),
    }
}

/// [`run_chain`] folding in place on a dead f64 register's buffer.
fn run_chain_inplace(buf: &mut Buf, steps: &[FusedStep], vectorized: bool) {
    let v = buf.as_f64_mut();
    if vectorized {
        for x in v.iter_mut() {
            let mut y = *x;
            for st in steps {
                y = st.eval(y);
            }
            *x = y;
        }
    } else {
        for x in v.iter_mut() {
            let mut y = black_box(*x);
            for st in steps {
                y = black_box(st.eval(black_box(y)));
            }
            *x = y;
        }
    }
}

/// [`run_chain`] through the explicit f64x4 lane kernel
/// (`EngineConfig::simd_kernels`): each lane group holds a `[f64; 4]`
/// working array and applies one step to all four lanes before the next,
/// so the per-step `FusedStep::eval` dispatch amortizes across the group
/// and the step body vectorizes. Per output element the step sequence and
/// arithmetic are identical to the scalar fold — bit-exact (pinned by
/// `tests/simd_parity.rs`). Returns the number of full lane groups.
fn chain_lanes_f64(src: &[f64], out: &mut [f64], steps: &[FusedStep]) -> u64 {
    const L: usize = crate::vudf::F64_LANES;
    let cut = src.len() - src.len() % L;
    let mut groups = 0u64;
    for (o, x) in out[..cut]
        .chunks_exact_mut(L)
        .zip(src[..cut].chunks_exact(L))
    {
        let mut y = [x[0], x[1], x[2], x[3]];
        for st in steps {
            y = [st.eval(y[0]), st.eval(y[1]), st.eval(y[2]), st.eval(y[3])];
        }
        o.copy_from_slice(&y);
        groups += 1;
    }
    for (o, x) in out[cut..].iter_mut().zip(&src[cut..]) {
        let mut y = *x;
        for st in steps {
            y = st.eval(y);
        }
        *o = y;
    }
    groups
}

/// [`chain_lanes_f64`] in place on a dead f64 register's buffer.
fn chain_lanes_f64_inplace(v: &mut [f64], steps: &[FusedStep]) -> u64 {
    const L: usize = crate::vudf::F64_LANES;
    let cut = v.len() - v.len() % L;
    let mut groups = 0u64;
    for x in v[..cut].chunks_exact_mut(L) {
        let mut y = [x[0], x[1], x[2], x[3]];
        for st in steps {
            y = [st.eval(y[0]), st.eval(y[1]), st.eval(y[2]), st.eval(y[3])];
        }
        x.copy_from_slice(&y);
        groups += 1;
    }
    for x in v[cut..].iter_mut() {
        let mut y = *x;
        for st in steps {
            y = st.eval(y);
        }
        *x = y;
    }
    groups
}

/// Strip-load from a col-major source partition: gather `rows` rows of
/// each column starting at the strip's local offset, decoding typed
/// columns straight from the partition bytes into a (pooled) buffer —
/// one pass, no intermediate byte buffer for any dtype (originally f64
/// only; F32/I32/I64 matter for integer label matrices and f32 features
/// — EXPERIMENTS.md §Perf).
fn load_strip(
    src: &SourceStrip<'_>,
    dtype: DType,
    ncol: usize,
    rows: usize,
    pool: &mut StripPool,
) -> Result<Buf> {
    let prows = src.part_rows;
    if src.local_row0 + rows > prows {
        return Err(FmError::Shape(format!(
            "strip [{}..{}) exceeds source partition rows {prows}",
            src.local_row0,
            src.local_row0 + rows
        )));
    }
    let mut out = pool.acquire(dtype, rows * ncol);
    macro_rules! decode {
        ($d:expr, $t:ty, $w:expr) => {{
            for j in 0..ncol {
                let src_off = (j * prows + src.local_row0) * $w;
                let dst = &mut $d[j * rows..(j + 1) * rows];
                for (o, c) in dst
                    .iter_mut()
                    .zip(src.bytes[src_off..src_off + rows * $w].chunks_exact($w))
                {
                    *o = <$t>::from_le_bytes(c.try_into().unwrap());
                }
            }
        }};
    }
    match &mut out {
        Buf::F64(d) => decode!(d, f64, 8),
        Buf::F32(d) => decode!(d, f32, 4),
        Buf::I64(d) => decode!(d, i64, 8),
        Buf::I32(d) => decode!(d, i32, 4),
        Buf::Bool(d) => {
            for j in 0..ncol {
                let src_off = j * prows + src.local_row0;
                for (o, b) in d[j * rows..(j + 1) * rows]
                    .iter_mut()
                    .zip(&src.bytes[src_off..src_off + rows])
                {
                    *o = *b != 0;
                }
            }
        }
    }
    Ok(out)
}

/// Streaming SpMM over one strip: decode the CSR rows
/// `[local_row0, local_row0 + rows)` straight from the sparse source
/// partition's bytes and accumulate `out[r, c] += a[r, j] * b[j, c]` over
/// the row's stored entries (columns ascending).
///
/// Bit-parity contract: for a given output element the additions happen
/// in the same ascending-`j` order as the dense `inner_small` (Mul, Sum)
/// kernel, and entries absent on either side contribute an exact `±0.0`
/// no-op there — so SpMM equals densify-then-`inner.prod` bit for bit
/// (pinned by `rust/tests/properties.rs::prop_spmm_matches_densified`).
fn spmm_strip(
    src: &SourceStrip<'_>,
    rows: usize,
    b: &HostMat,
    pool: &mut StripPool,
) -> Result<Buf> {
    let view = crate::matrix::SparsePartView::parse(src.bytes, src.part_rows)?;
    if src.local_row0 + rows > view.prows {
        return Err(FmError::Shape(format!(
            "spmm strip [{}..{}) exceeds sparse partition rows {}",
            src.local_row0,
            src.local_row0 + rows,
            view.prows
        )));
    }
    let p = b.nrow;
    let q = b.ncol;
    let bv = match &b.buf {
        Buf::F64(v) => v.as_slice(),
        _ => return Err(FmError::DType("spmm right operand must be f64".into())),
    };
    let mut out = pool.acquire(DType::F64, rows * q);
    let o = out.as_f64_mut();
    let mut nnz_seen = 0u64;
    for r in 0..rows {
        let (lo, hi) = view.row_range(src.local_row0 + r);
        nnz_seen += (hi - lo) as u64;
        for e in lo..hi {
            let (j, v) = view.entry(e);
            let jb = j as usize;
            for c in 0..q {
                o[c * rows + r] += v * bv[c * p + jb];
            }
        }
    }
    pool.count_spmm(nnz_seen);
    Ok(out)
}

/// Per-row reduction over a col-major strip -> rows x 1.
///
/// Row reductions accumulate across *columns*, so the rows of one strip
/// are independent outputs: the `opts.simd` lane form processes four rows
/// per group with each row's column-sweep order unchanged — bit-exact.
fn row_agg(a: &Buf, rows: usize, op: AggOp, na: NaMode, opts: EvalOpts, pool: &mut StripPool) -> Buf {
    let ncol = a.len() / rows.max(1);
    let acc_dt = op.acc_dtype(a.dtype());
    if na != NaMode::Off {
        // NA-aware path (`na.rm=`): one general column-sweep fold via the
        // NA-aware scalar kernels — rows are independent, and the per-row
        // fold order matches the NA-oblivious sweep, so NA-free data
        // produces identical results.
        let mut out = pool.acquire(acc_dt, rows);
        for r in 0..rows {
            let mut acc = op.identity_na(acc_dt);
            for j in 0..ncol {
                acc = op.fold_scalar_na(acc, a.get(j * rows + r), na);
            }
            out.set(r, acc);
        }
        return out;
    }
    // fast path: f64 sum/min/max with column-sweep accumulation
    if opts.vectorized && a.dtype() == DType::F64 && acc_dt == DType::F64 {
        if let Buf::F64(v) = a {
            let mut out = pool.acquire(DType::F64, rows);
            let acc = out.as_f64_mut();
            acc.fill(op.identity(DType::F64).as_f64());
            if opts.simd {
                const L: usize = crate::vudf::F64_LANES;
                let cut = rows - rows % L;
                for j in 0..ncol {
                    let col = &v[j * rows..(j + 1) * rows];
                    for (ac, cx) in acc[..cut]
                        .chunks_exact_mut(L)
                        .zip(col[..cut].chunks_exact(L))
                    {
                        match op {
                            AggOp::Sum => {
                                for i in 0..L {
                                    ac[i] += cx[i];
                                }
                            }
                            AggOp::Min => {
                                for i in 0..L {
                                    ac[i] = ac[i].min(cx[i]);
                                }
                            }
                            AggOp::Max => {
                                for i in 0..L {
                                    ac[i] = ac[i].max(cx[i]);
                                }
                            }
                            AggOp::Prod => {
                                for i in 0..L {
                                    ac[i] *= cx[i];
                                }
                            }
                            _ => unreachable!("acc_dtype guarantees numeric op"),
                        }
                    }
                    for r in cut..rows {
                        match op {
                            AggOp::Sum => acc[r] += col[r],
                            AggOp::Min => acc[r] = acc[r].min(col[r]),
                            AggOp::Max => acc[r] = acc[r].max(col[r]),
                            AggOp::Prod => acc[r] *= col[r],
                            _ => unreachable!("acc_dtype guarantees numeric op"),
                        }
                    }
                }
                pool.count_simd_lanes_f64((ncol * (cut / L)) as u64);
                return out;
            }
            for j in 0..ncol {
                let col = &v[j * rows..(j + 1) * rows];
                match op {
                    AggOp::Sum => {
                        for r in 0..rows {
                            acc[r] += col[r];
                        }
                    }
                    AggOp::Min => {
                        for r in 0..rows {
                            acc[r] = acc[r].min(col[r]);
                        }
                    }
                    AggOp::Max => {
                        for r in 0..rows {
                            acc[r] = acc[r].max(col[r]);
                        }
                    }
                    AggOp::Prod => {
                        for r in 0..rows {
                            acc[r] *= col[r];
                        }
                    }
                    _ => unreachable!("acc_dtype guarantees numeric op"),
                }
            }
            return out;
        }
    }
    let mut out = pool.acquire(acc_dt, rows);
    for r in 0..rows {
        let mut acc = op.identity(acc_dt);
        for j in 0..ncol {
            acc = op.fold_scalar(acc, a.get(j * rows + r));
        }
        out.set(r, acc);
    }
    out
}

/// Per-row argmin/argmax (1-based, first extreme wins — R's which.min).
///
/// NaN entries are skipped like R skips NAs: a NaN never wins and never
/// poisons later comparisons (seeding on a NaN first column would make
/// every `<`/`>` test false and freeze the answer at column 1). An all-NaN
/// row yields the **NA index 0** — R's `which.min` on an all-NA vector
/// returns no index (`integer(0)`), and 0 is the out-of-band value a
/// 1-based result column can carry for that; downstream `labels - 1`
/// pipelines turn it into -1, which `fm.groupby.row` drops, matching R's
/// NA-group behaviour.
fn row_arg_extreme(a: &Buf, rows: usize, max: bool, pool: &mut StripPool) -> Buf {
    let ncol = a.len() / rows.max(1);
    let mut out = pool.acquire(DType::I32, rows);
    let o = out.as_i32_mut();
    for r in 0..rows {
        let mut best = f64::NAN;
        let mut bi = 0i32; // 0 = nothing finite seen yet (the NA index)
        for j in 0..ncol {
            let v = a.get(j * rows + r).as_f64();
            if v.is_nan() {
                continue;
            }
            if bi == 0 || (max && v > best) || (!max && v < best) {
                best = v;
                bi = j as i32 + 1; // 1-based like R
            }
        }
        o[r] = bi;
    }
    out
}

/// Generalized inner product of a strip (rows x p) with a small host matrix
/// (p x q): out[r, c] = f2-fold over k of f1(a[r,k], b[k,c]).
///
/// The (Mul, Sum, f64) case is the dense matmul the paper routes to BLAS;
/// here it gets a monomorphic kernel (column-major SAXPY loop) and the
/// XLA-artifact path replaces it at the algorithm level when shapes match.
///
/// With `simd` on, the (Mul, Sum, f64) case runs a register-blocked
/// microkernel instead: an MR=8 accumulator array held in registers
/// sweeps all of `k` before touching the output column, so each output
/// element is loaded/stored once per *panel* instead of once per `k`.
/// Per output element the fold is still ascending-`k` from 0.0 with the
/// same `w != 0.0` skip (which is load-bearing: a stored ±0.0 times an
/// Inf/NaN operand must contribute nothing, exactly like the SpMM
/// densify-parity contract) — bit-exact vs the SAXPY kernel.
fn inner_small(
    a: &Buf,
    rows: usize,
    b: &HostMat,
    f1: BinOp,
    f2: AggOp,
    simd: bool,
    pool: &mut StripPool,
) -> Result<Buf> {
    let p = b.nrow;
    let q = b.ncol;
    if a.len() != rows * p {
        return Err(FmError::Shape(format!(
            "inner.prod: left strip has {} elems, want rows {rows} x p {p}",
            a.len()
        )));
    }
    if f1 == BinOp::Mul && f2 == AggOp::Sum && a.dtype() == DType::F64 {
        if let (Buf::F64(av), Buf::F64(bv)) = (a, &b.buf) {
            let mut outb = pool.acquire(DType::F64, rows * q);
            let out = outb.as_f64_mut();
            if simd {
                const MR: usize = 8;
                let cut = rows - rows % MR;
                let mut panels = 0u64;
                for c in 0..q {
                    let bcol = &bv[c * p..(c + 1) * p];
                    let ocol = &mut out[c * rows..(c + 1) * rows];
                    let mut r0 = 0;
                    while r0 < cut {
                        let mut acc = [0.0f64; MR];
                        for (k, &w) in bcol.iter().enumerate() {
                            if w != 0.0 {
                                let acol = &av[k * rows + r0..k * rows + r0 + MR];
                                for i in 0..MR {
                                    acc[i] += w * acol[i];
                                }
                            }
                        }
                        ocol[r0..r0 + MR].copy_from_slice(&acc);
                        panels += 1;
                        r0 += MR;
                    }
                    // tail rows: the same ascending-k fold, one row at a time
                    for (r, o) in ocol.iter_mut().enumerate().skip(cut) {
                        let mut s = 0.0f64;
                        for (k, &w) in bcol.iter().enumerate() {
                            if w != 0.0 {
                                s += w * av[k * rows + r];
                            }
                        }
                        *o = s;
                    }
                }
                pool.count_gemm_panels(panels);
                return Ok(outb);
            }
            // out[:, c] = sum_k a[:, k] * b[k, c]  (SAXPY over columns)
            for c in 0..q {
                let ocol = &mut out[c * rows..(c + 1) * rows];
                for k in 0..p {
                    let w = bv[c * p + k];
                    if w != 0.0 {
                        let acol = &av[k * rows..(k + 1) * rows];
                        for r in 0..rows {
                            ocol[r] += w * acol[r];
                        }
                    }
                }
            }
            return Ok(outb);
        }
    }
    // generic path through f64
    let acc_dt = f2.acc_dtype(DType::promote(a.dtype(), b.buf.dtype()));
    let mut out = pool.acquire(acc_dt, rows * q);
    let g1 = move |x: f64, y: f64| -> f64 {
        // scalar form of f1 via the vectorized kernel on length-1 buffers
        // is wasteful; use the op's f64 semantic directly
        match f1 {
            BinOp::Add => x + y,
            BinOp::Sub => x - y,
            BinOp::Mul => x * y,
            BinOp::Div => x / y,
            BinOp::Min => x.min(y),
            BinOp::Max => x.max(y),
            BinOp::Eq => (x == y) as u8 as f64,
            BinOp::Ne => (x != y) as u8 as f64,
            _ => f64::NAN,
        }
    };
    for c in 0..q {
        for r in 0..rows {
            let mut acc = f2.identity(acc_dt);
            for k in 0..p {
                let v = g1(a.get(k * rows + r).as_f64(), b.get(k, c).as_f64());
                acc = f2.fold_scalar(acc, Scalar::F64(v));
            }
            out.set(c * rows + r, acc);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Metrics;

    fn test_pool() -> StripPool {
        StripPool::new(true, Arc::new(Metrics::new()))
    }

    #[test]
    fn splitmix_matches_reference_stream() {
        // first values of a sequential SplitMix64 stream with seed 42 --
        // cross-checked against the python implementation in test_golden.py
        let s0 = splitmix64_at(42, 0);
        let s1 = splitmix64_at(42, 1);
        assert_ne!(s0, s1);
        // determinism
        assert_eq!(s0, splitmix64_at(42, 0));
        let u = u64_to_unit_f64(s0);
        assert!((0.0..1.0).contains(&u));
    }

    #[test]
    fn row_agg_and_argmin() {
        let mut p = test_pool();
        // strip 2 rows x 3 cols, col-major: cols [1,5], [2,4], [0,6]
        let a = Buf::from_f64(&[1.0, 5.0, 2.0, 4.0, 0.0, 6.0]);
        let sums = row_agg(&a, 2, AggOp::Sum, NaMode::Off, EvalOpts::plain(true), &mut p);
        assert_eq!(sums.to_f64_vec(), vec![3.0, 15.0]);
        let mins = row_agg(&a, 2, AggOp::Min, NaMode::Off, EvalOpts::plain(true), &mut p);
        assert_eq!(mins.to_f64_vec(), vec![0.0, 4.0]);
        let am = row_arg_extreme(&a, 2, false, &mut p);
        assert_eq!(am.as_i32(), &[3, 2]); // 1-based
    }

    #[test]
    fn row_agg_reuses_released_buffers() {
        let mut p = test_pool();
        let a = Buf::from_f64(&[1.0, 5.0, 2.0, 4.0, 0.0, 6.0]);
        let sums = row_agg(&a, 2, AggOp::Sum, NaMode::Off, EvalOpts::plain(true), &mut p);
        p.release(sums);
        // a recycled buffer must give the same answer as a fresh one
        let again = row_agg(&a, 2, AggOp::Sum, NaMode::Off, EvalOpts::plain(true), &mut p);
        assert_eq!(again.to_f64_vec(), vec![3.0, 15.0]);
        let mins = row_agg(&a, 2, AggOp::Min, NaMode::Off, EvalOpts::plain(true), &mut p);
        assert_eq!(mins.to_f64_vec(), vec![0.0, 4.0]);
    }

    #[test]
    fn row_agg_na_modes() {
        let mut p = test_pool();
        // 2 rows x 3 cols col-major: cols [1,NaN], [2,4], [NaN,6]
        let a = Buf::from_f64(&[1.0, f64::NAN, 2.0, 4.0, f64::NAN, 6.0]);
        let rm = row_agg(&a, 2, AggOp::Sum, NaMode::Remove, EvalOpts::plain(true), &mut p);
        assert_eq!(rm.to_f64_vec(), vec![3.0, 10.0]);
        let pr = row_agg(
            &a,
            2,
            AggOp::Sum,
            NaMode::Propagate,
            EvalOpts::plain(true),
            &mut p,
        );
        assert!(pr.get(0).is_na() && pr.get(1).is_na());
        // NA-free data: NA-aware modes match the legacy kernel bit for bit
        let clean = Buf::from_f64(&[1.0, 5.0, 2.0, 4.0, 0.0, 6.0]);
        for na in [NaMode::Propagate, NaMode::Remove] {
            for op in [AggOp::Sum, AggOp::Min, AggOp::Max, AggOp::Prod] {
                let v = row_agg(&clean, 2, op, na, EvalOpts::plain(true), &mut p);
                let off = row_agg(&clean, 2, op, NaMode::Off, EvalOpts::plain(true), &mut p);
                assert_eq!(v.to_f64_vec(), off.to_f64_vec(), "{op:?}/{na:?}");
            }
        }
    }

    #[test]
    fn row_arg_extreme_skips_nans() {
        let mut p = test_pool();
        // 2 rows x 3 cols col-major: cols [NaN,5], [2,NaN], [0,6]
        let a = Buf::from_f64(&[f64::NAN, 5.0, 2.0, f64::NAN, 0.0, 6.0]);
        let am = row_arg_extreme(&a, 2, false, &mut p);
        assert_eq!(am.as_i32(), &[3, 1], "NaN must not poison which.min");
        let ax = row_arg_extreme(&a, 2, true, &mut p);
        assert_eq!(ax.as_i32(), &[2, 3], "NaN must not poison which.max");
        // an all-NaN row yields the NA index 0 (R: which.min(all-NA)
        // returns no index)
        let b = Buf::from_f64(&[f64::NAN, 1.0, f64::NAN, 0.5]);
        assert_eq!(row_arg_extreme(&b, 2, false, &mut p).as_i32(), &[0, 2]);
    }

    #[test]
    fn inner_small_matmul() {
        let mut p = test_pool();
        // a: 2x2 col-major [[1,2],[3,4]] -> cols [1,3],[2,4]
        let a = Buf::from_f64(&[1.0, 3.0, 2.0, 4.0]);
        let b = HostMat::from_rows_f64(&[vec![1.0, 0.0], vec![0.0, 1.0]]);
        let out = inner_small(&a, 2, &b, BinOp::Mul, AggOp::Sum, false, &mut p).unwrap();
        assert_eq!(out.to_f64_vec(), vec![1.0, 3.0, 2.0, 4.0]); // identity
        // generalized: min-plus "tropical" inner product
        // out[r,c] = min_k(a[r,k] + b[k,c])
        let out = inner_small(&a, 2, &b, BinOp::Add, AggOp::Min, false, &mut p).unwrap();
        assert_eq!(out.to_f64_vec(), vec![2.0, 4.0, 1.0, 3.0]);
    }

    #[test]
    fn inner_small_blocked_matches_saxpy_bitwise() {
        let mut p = test_pool();
        // rows chosen to exercise full MR=8 panels plus a 5-row tail;
        // b carries stored zeros (the w != 0.0 skip) and a negative column
        let rows = 21;
        let kdim = 3;
        let q = 2;
        let av: Vec<f64> = (0..rows * kdim)
            .map(|i| u64_to_unit_f64(splitmix64_at(7, i as u64)) - 0.5)
            .collect();
        let a = Buf::F64(av);
        let b = HostMat::from_rows_f64(&[vec![1.25, -0.5], vec![0.0, 2.0], vec![-3.5, 0.0]]);
        let plain = inner_small(&a, rows, &b, BinOp::Mul, AggOp::Sum, false, &mut p).unwrap();
        let blocked = inner_small(&a, rows, &b, BinOp::Mul, AggOp::Sum, true, &mut p).unwrap();
        assert_eq!(plain, blocked, "register-blocked GEMM must be bit-exact");
        assert_eq!(plain.len(), rows * q);
    }

    #[test]
    fn load_strip_gathers_columns() {
        let mut p = test_pool();
        // source partition: 4 rows x 2 cols col-major = [0,1,2,3, 10,11,12,13]
        let vals: Vec<f64> = vec![0.0, 1.0, 2.0, 3.0, 10.0, 11.0, 12.0, 13.0];
        let bytes = Buf::from_f64(&vals).to_bytes();
        let src = SourceStrip {
            bytes: &bytes,
            part_rows: 4,
            local_row0: 1,
        };
        let b = load_strip(&src, DType::F64, 2, 2, &mut p).unwrap();
        assert_eq!(b.to_f64_vec(), vec![1.0, 2.0, 11.0, 12.0]);
    }

    #[test]
    fn load_strip_typed_fast_paths() {
        let mut p = test_pool();
        // 4 rows x 2 cols of every dtype; strip = rows 1..3
        for dt in [DType::F64, DType::F32, DType::I64, DType::I32, DType::Bool] {
            let full = Buf::from_f64(&[0.0, 1.0, 2.0, 3.0, 10.0, 11.0, 12.0, 13.0])
                .cast(dt)
                .unwrap();
            let bytes = full.to_bytes();
            let src = SourceStrip {
                bytes: &bytes,
                part_rows: 4,
                local_row0: 1,
            };
            let b = load_strip(&src, dt, 2, 2, &mut p).unwrap();
            assert_eq!(b.dtype(), dt);
            let want = Buf::from_f64(&[1.0, 2.0, 11.0, 12.0]).cast(dt).unwrap();
            assert_eq!(b, want, "{dt}");
            p.release(b);
        }
    }

    // -- compile-plan tests ------------------------------------------------

    use crate::dag::VNode;

    fn fillm(nrow: u64, ncol: u64) -> Matrix {
        Matrix::new(MatrixData::Virtual(VNode {
            nrow,
            ncol,
            dtype: DType::F64,
            kind: VKind::Fill(Scalar::F64(2.0)),
        }))
    }

    fn sapply(a: &Matrix, op: UnOp) -> Matrix {
        Matrix::new(MatrixData::Virtual(VNode {
            nrow: a.nrow(),
            ncol: a.ncol(),
            dtype: op.out_dtype(a.dtype()),
            kind: VKind::Sapply {
                a: a.clone(),
                op: UnFn::Builtin(op),
            },
        }))
    }

    fn mapply_s(a: &Matrix, s: Scalar, op: BinOp) -> Matrix {
        Matrix::new(MatrixData::Virtual(VNode {
            nrow: a.nrow(),
            ncol: a.ncol(),
            dtype: op.out_dtype(a.dtype()),
            kind: VKind::MapplyScalar {
                a: a.clone(),
                s,
                op,
                scalar_right: true,
            },
        }))
    }

    #[test]
    fn peephole_fuses_single_consumer_chain() {
        // fill -> sq -> *0.5 -> +1  (three fusable steps onto one head)
        let x = fillm(64, 2);
        let y = mapply_s(
            &mapply_s(&sapply(&x, UnOp::Sq), Scalar::F64(0.5), BinOp::Mul),
            Scalar::F64(1.0),
            BinOp::Add,
        );
        let prog = compile(&[y.clone()], &[]).unwrap();
        // fill + one fused chain
        assert_eq!(prog.instrs.len(), 2);
        match &prog.instrs[1].kind {
            InstrKind::FusedChain { a, steps } => {
                assert_eq!(*a, 0);
                assert_eq!(steps.len(), 3);
            }
            _ => panic!("expected a fused chain"),
        }
        assert_eq!(prog.plan.fused_steps, 3);
        assert_eq!(prog.target_regs, vec![1]);
        // the fill register dies feeding the chain; the chain may run in
        // place on it
        assert_eq!(prog.plan.dies_at[1], vec![0]);
        assert!(prog.plan.inplace[1]);

        // with the peephole off the chain stays three instructions
        let prog = compile_opts(
            &[y],
            &[],
            CompileOpts {
                peephole_fuse: false,
                inplace_ops: true,
            },
        )
        .unwrap();
        assert_eq!(prog.instrs.len(), 4);
        assert_eq!(prog.plan.fused_steps, 0);
        // ... but every step still executes in place on its dead input
        assert!(prog.plan.inplace[1] && prog.plan.inplace[2] && prog.plan.inplace[3]);
    }

    #[test]
    fn peephole_respects_multi_consumer_and_targets() {
        // y = sq(x); z = y * 0.5 — but y is ALSO a target, so the chain
        // must not swallow it
        let x = fillm(64, 2);
        let y = sapply(&x, UnOp::Sq);
        let z = mapply_s(&y, Scalar::F64(0.5), BinOp::Mul);
        let prog = compile(&[y.clone(), z], &[]).unwrap();
        assert_eq!(prog.instrs.len(), 3, "no fusion across a target");
        assert_eq!(prog.plan.fused_steps, 0);
        // y is live at end: nothing may consume it in place
        let y_reg = prog.target_regs[0];
        for (i, ins) in prog.instrs.iter().enumerate() {
            if prog.plan.inplace[i] {
                assert!(!instr_reads(&ins.kind).contains(&y_reg));
            }
        }
    }

    #[test]
    fn identity_cast_is_aliased_away() {
        let x = fillm(32, 1);
        let c = Matrix::new(MatrixData::Virtual(VNode {
            nrow: 32,
            ncol: 1,
            dtype: DType::F64,
            kind: VKind::Cast {
                a: x.clone(),
                to: DType::F64,
            },
        }));
        let prog = compile(&[c], &[]).unwrap();
        assert_eq!(prog.instrs.len(), 1, "same-dtype cast must vanish");
        assert_eq!(prog.target_regs, vec![0]);
    }

    #[test]
    fn eval_strip_honors_plan() {
        // end-to-end: fused/in-place/pooled evaluation must match the
        // unoptimized program on the same strip
        let x = fillm(16, 2);
        let y = mapply_s(&sapply(&x, UnOp::Sq), Scalar::F64(3.0), BinOp::Add);
        let fast = compile(&[y.clone()], &[]).unwrap();
        let slow = compile_opts(
            &[y],
            &[],
            CompileOpts {
                peephole_fuse: false,
                inplace_ops: false,
            },
        )
        .unwrap();
        let mut p = test_pool();
        for (vectorized, simd) in [(true, false), (true, true), (false, false)] {
            let opts = EvalOpts {
                vectorized,
                simd,
                simd_reductions: false,
            };
            let rf = eval_strip(&fast, &[], 0, 16, opts, &mut p).unwrap();
            let rs = eval_strip(&slow, &[], 0, 16, opts, &mut p).unwrap();
            let got = &rf[*fast.target_regs.first().unwrap()];
            let want = &rs[*slow.target_regs.first().unwrap()];
            assert_eq!(got, want);
            assert_eq!(got.to_f64_vec(), vec![7.0; 32]);
            for b in rf {
                p.release(b);
            }
            for b in rs {
                p.release(b);
            }
        }
    }
}
