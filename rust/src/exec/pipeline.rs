//! DAG -> pipeline program compilation and strip evaluation (paper §III-F).
//!
//! A materialization pass compiles the virtual-matrix DAG **once** into a
//! linear [`Program`] — one instruction per unique node, topologically
//! ordered — then executes that program for every CPU-level strip of every
//! I/O-level partition. Registers (one [`Buf`] per node) hold one strip of
//! each node's value; with cache-fuse enabled a strip fits L1/L2, so a
//! node's output is still cache-resident when its consumer runs — the
//! paper's "pass the partition to the subsequent operation instead of
//! materializing the next partition of the same matrix".

use std::collections::HashMap;
use std::sync::Arc;

use crate::dag::{SinkKind, SinkSpec, UnFn, VKind};
use crate::dtype::{DType, Scalar};
use crate::error::{FmError, Result};
use crate::matrix::{HostMat, Matrix, MatrixData};
use crate::vudf::{self, AggOp, BinOp, Buf};

/// One compiled DAG node.
pub struct Instr {
    pub ncol: u64,
    pub dtype: DType,
    pub kind: InstrKind,
}

/// Instruction kinds. Register operands are indices into the program's
/// register file (= instruction order).
pub enum InstrKind {
    /// Strip-load from a materialized dense source (index into the
    /// program's `sources` table).
    LoadDense(usize),
    /// Strip-load from a group: concatenated member columns.
    LoadGroup(Vec<usize>),
    Fill(Scalar),
    Seq { start: f64, step: f64 },
    RandU { seed: u64, lo: f64, hi: f64 },
    RandN { seed: u64, mean: f64, sd: f64 },
    Sapply { a: usize, op: UnFn },
    Mapply { a: usize, b: usize, op: BinOp },
    MapplyScalar { a: usize, s: Scalar, op: BinOp, scalar_right: bool },
    MapplyRow { a: usize, w: Buf, op: BinOp },
    MapplyCol { a: usize, v: usize, op: BinOp },
    RowAgg { a: usize, op: AggOp },
    RowArgExtreme { a: usize, max: bool },
    InnerSmall { a: usize, b: HostMat, f1: BinOp, f2: AggOp },
    Cast { a: usize, to: DType },
    ColBind(Vec<usize>),
    SelectCol { a: usize, col: usize },
}

/// Compiled sink: which register feeds it + terminal aggregation.
pub struct SinkInstr {
    pub src_reg: usize,
    pub ncol: u64,
    pub kind: SinkInstrKind,
}

pub enum SinkInstrKind {
    AggFull(AggOp),
    AggCol(AggOp),
    GroupByRow { labels_reg: usize, k: usize, op: AggOp },
    InnerWideTall { right_reg: usize, f1: BinOp, f2: AggOp },
}

/// A fully compiled materialization pass.
pub struct Program {
    pub instrs: Vec<Instr>,
    /// Distinct dense sources (loaded once per I/O partition).
    pub sources: Vec<Arc<MatrixData>>,
    /// Register index of each requested target matrix.
    pub target_regs: Vec<usize>,
    pub sinks: Vec<SinkInstr>,
    /// Shared long dimension of the DAG.
    pub nrow: u64,
}

/// Compile targets + sinks into a program. All roots must share the long
/// dimension (checked).
pub fn compile(targets: &[Matrix], sinks: &[SinkSpec]) -> Result<Program> {
    let mut roots: Vec<Matrix> = targets.to_vec();
    for s in sinks {
        roots.push(s.source.clone());
        match &s.kind {
            SinkKind::GroupByRow { labels, .. } => roots.push(labels.clone()),
            SinkKind::InnerWideTall { right, .. } => roots.push(right.clone()),
            _ => {}
        }
    }
    if roots.is_empty() {
        return Err(FmError::Shape("nothing to materialize".into()));
    }
    let nrow = crate::dag::validate_long_dim(&roots)?;

    let order = crate::dag::topo_order(&roots);
    let mut reg_of: HashMap<usize, usize> = HashMap::new();
    let mut src_of: HashMap<usize, usize> = HashMap::new();
    let mut instrs = Vec::new();
    let mut sources: Vec<Arc<MatrixData>> = Vec::new();

    let src_idx = |m: &Matrix, sources: &mut Vec<Arc<MatrixData>>,
                       src_of: &mut HashMap<usize, usize>| {
        *src_of.entry(m.data_ptr()).or_insert_with(|| {
            sources.push(Arc::clone(&m.data));
            sources.len() - 1
        })
    };

    for m in &order {
        let reg = instrs.len();
        let kind = match &*m.data {
            MatrixData::Dense(_) => InstrKind::LoadDense(src_idx(m, &mut sources, &mut src_of)),
            MatrixData::Group(g) => {
                let mut idxs = Vec::new();
                for mem in &g.members {
                    let mm = Matrix {
                        data: Arc::clone(mem),
                        transposed: false,
                    };
                    match &**mem {
                        MatrixData::Dense(_) => {
                            idxs.push(src_idx(&mm, &mut sources, &mut src_of))
                        }
                        _ => {
                            return Err(FmError::Unsupported(
                                "group members must be materialized dense matrices".into(),
                            ))
                        }
                    }
                }
                InstrKind::LoadGroup(idxs)
            }
            MatrixData::Virtual(v) => compile_vkind(&v.kind, &reg_of)?,
        };
        instrs.push(Instr {
            ncol: m.data.ncol(),
            dtype: m.data.dtype(),
            kind,
        });
        reg_of.insert(m.data_ptr(), reg);
    }

    let target_regs = targets.iter().map(|t| reg_of[&t.data_ptr()]).collect();
    let sinks = sinks
        .iter()
        .map(|s| {
            let src_reg = reg_of[&s.source.data_ptr()];
            let ncol = s.source.data.ncol();
            let kind = match &s.kind {
                SinkKind::AggFull(op) => SinkInstrKind::AggFull(*op),
                SinkKind::AggCol(op) => SinkInstrKind::AggCol(*op),
                SinkKind::GroupByRow { labels, k, op } => SinkInstrKind::GroupByRow {
                    labels_reg: reg_of[&labels.data_ptr()],
                    k: *k,
                    op: *op,
                },
                SinkKind::InnerWideTall { right, f1, f2 } => SinkInstrKind::InnerWideTall {
                    right_reg: reg_of[&right.data_ptr()],
                    f1: *f1,
                    f2: *f2,
                },
            };
            SinkInstr { src_reg, ncol, kind }
        })
        .collect();

    Ok(Program {
        instrs,
        sources,
        target_regs,
        sinks,
        nrow,
    })
}

fn compile_vkind(kind: &VKind, reg_of: &HashMap<usize, usize>) -> Result<InstrKind> {
    let r = |m: &Matrix| -> usize { reg_of[&m.data_ptr()] };
    Ok(match kind {
        VKind::Fill(s) => InstrKind::Fill(*s),
        VKind::Seq { start, step } => InstrKind::Seq {
            start: *start,
            step: *step,
        },
        VKind::RandU { seed, lo, hi } => InstrKind::RandU {
            seed: *seed,
            lo: *lo,
            hi: *hi,
        },
        VKind::RandN { seed, mean, sd } => InstrKind::RandN {
            seed: *seed,
            mean: *mean,
            sd: *sd,
        },
        VKind::Sapply { a, op } => InstrKind::Sapply {
            a: r(a),
            op: op.clone(),
        },
        VKind::Mapply { a, b, op } => InstrKind::Mapply {
            a: r(a),
            b: r(b),
            op: *op,
        },
        VKind::MapplyScalar {
            a,
            s,
            op,
            scalar_right,
        } => InstrKind::MapplyScalar {
            a: r(a),
            s: *s,
            op: *op,
            scalar_right: *scalar_right,
        },
        VKind::MapplyRow { a, w, op } => InstrKind::MapplyRow {
            a: r(a),
            w: w.buf.clone(),
            op: *op,
        },
        VKind::MapplyCol { a, v, op } => InstrKind::MapplyCol {
            a: r(a),
            v: r(v),
            op: *op,
        },
        VKind::RowAgg { a, op } => InstrKind::RowAgg { a: r(a), op: *op },
        VKind::RowArgExtreme { a, max } => InstrKind::RowArgExtreme { a: r(a), max: *max },
        VKind::InnerSmall { a, b, f1, f2 } => InstrKind::InnerSmall {
            a: r(a),
            b: b.clone(),
            f1: *f1,
            f2: *f2,
        },
        VKind::Cast { a, to } => InstrKind::Cast { a: r(a), to: *to },
        VKind::SelectCol { a, col } => InstrKind::SelectCol {
            a: r(a),
            col: *col as usize,
        },
        VKind::ColBind(ms) => InstrKind::ColBind(ms.iter().map(r).collect()),
    })
}

// ---------------------------------------------------------------------------
// Strip evaluation
// ---------------------------------------------------------------------------

/// Per-partition source data: raw col-major bytes of each source's
/// partition slice covering the pass partition, plus its local row range.
pub struct SourceStrip<'a> {
    /// Partition bytes of the *source's own* partition containing this pass
    /// partition.
    pub bytes: &'a [u8],
    /// Rows in the source partition the bytes describe.
    pub part_rows: usize,
    /// Row offset of the pass partition within the source partition.
    pub local_row0: usize,
}

/// Counter-based SplitMix64: the i-th value of a sequential SplitMix64
/// stream seeded with `seed` (matches python/tests/test_golden.py).
#[inline]
pub fn splitmix64_at(seed: u64, i: u64) -> u64 {
    let mut z = seed.wrapping_add((i.wrapping_add(1)).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// u64 -> f64 in [0,1) via the 53-bit mantissa trick.
#[inline]
pub fn u64_to_unit_f64(z: u64) -> f64 {
    (z >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Evaluate the program for one strip.
///
/// * `srcs[i]` — source strip context for `Program::sources[i]`
///   (dense groups reference several entries).
/// * `global_row0` — global row index of the strip's first row (generators).
/// * `rows` — strip height.
/// * `vectorized` — VUDF mode (Fig 12 ablation).
///
/// Returns the register file (one strip-sized `Buf` per node).
pub fn eval_strip(
    prog: &Program,
    srcs: &[SourceStrip<'_>],
    global_row0: u64,
    rows: usize,
    vectorized: bool,
) -> Result<Vec<Buf>> {
    let mut regs: Vec<Buf> = Vec::with_capacity(prog.instrs.len());
    for ins in &prog.instrs {
        let ncol = ins.ncol as usize;
        let out: Buf = match &ins.kind {
            InstrKind::LoadDense(si) => load_strip(&srcs[*si], ins.dtype, ncol, rows)?,
            InstrKind::LoadGroup(sis) => {
                let mut out = Buf::alloc(ins.dtype, rows * ncol);
                let mut col_off = 0usize;
                for si in sis {
                    // decode with the *member's own* dtype — a member whose
                    // dtype differs from the promoted group dtype (e.g. an
                    // I32 column bound with F64 columns) has a different
                    // element size, so using the group dtype would both
                    // miscount its columns and misread its bytes
                    let mdt = prog.sources[*si].dtype();
                    let member_ncol = {
                        // member ncol = bytes/(part_rows*esz)
                        let esz = mdt.size();
                        srcs[*si].bytes.len() / (srcs[*si].part_rows * esz)
                    };
                    let m = load_strip(&srcs[*si], mdt, member_ncol, rows)?;
                    // only heterogeneous members pay the cast copy
                    let m = if mdt == ins.dtype { m } else { m.cast(ins.dtype)? };
                    out.copy_from(col_off * rows, &m);
                    col_off += member_ncol;
                }
                out
            }
            InstrKind::Fill(s) => Buf::fill(ins.dtype, rows * ncol, *s),
            InstrKind::Seq { start, step } => {
                let mut b = Buf::alloc(ins.dtype, rows * ncol);
                for j in 0..ncol {
                    for r in 0..rows {
                        // sequence walks the long dimension; columns repeat
                        let v = start + step * (global_row0 + r as u64) as f64;
                        b.set(j * rows + r, Scalar::F64(v));
                    }
                }
                b
            }
            InstrKind::RandU { seed, lo, hi } => {
                let mut b = Buf::alloc(ins.dtype, rows * ncol);
                for j in 0..ncol {
                    for r in 0..rows {
                        let idx = (global_row0 + r as u64) * ins.ncol + j as u64;
                        let u = u64_to_unit_f64(splitmix64_at(*seed, idx));
                        b.set(j * rows + r, Scalar::F64(lo + (hi - lo) * u));
                    }
                }
                b
            }
            InstrKind::RandN { seed, mean, sd } => {
                let mut b = Buf::alloc(ins.dtype, rows * ncol);
                for j in 0..ncol {
                    for r in 0..rows {
                        let idx = (global_row0 + r as u64) * ins.ncol + j as u64;
                        let u1 = u64_to_unit_f64(splitmix64_at(*seed, idx * 2)).max(1e-300);
                        let u2 = u64_to_unit_f64(splitmix64_at(*seed, idx * 2 + 1));
                        let z = (-2.0 * u1.ln()).sqrt()
                            * (2.0 * std::f64::consts::PI * u2).cos();
                        b.set(j * rows + r, Scalar::F64(mean + sd * z));
                    }
                }
                b
            }
            InstrKind::Sapply { a, op } => match op {
                UnFn::Builtin(u) => vudf::unary(*u, &regs[*a], vectorized)?,
                UnFn::Custom(c) => c.unary(&regs[*a])?,
            },
            InstrKind::Mapply { a, b, op } => {
                // insert implicit promotion casts (paper §III-D)
                let (ba, bb) = promote_pair(&regs[*a], &regs[*b])?;
                vudf::binary_vv(*op, &ba, &bb, vectorized)?
            }
            InstrKind::MapplyScalar {
                a,
                s,
                op,
                scalar_right,
            } => {
                if *scalar_right {
                    vudf::binary_vs(*op, &regs[*a], *s, vectorized)?
                } else {
                    vudf::binary_sv(*op, *s, &regs[*a], vectorized)?
                }
            }
            InstrKind::MapplyRow { a, w, op } => {
                vudf::binary_rowvec(*op, &regs[*a], w, rows, ncol, vectorized)?
            }
            InstrKind::MapplyCol { a, v, op } => {
                let acols = regs[*a].len() / rows;
                let (ba, bv) = promote_pair(&regs[*a], &regs[*v])?;
                vudf::binary_colvec(*op, &ba, &bv, rows, acols, vectorized)?
            }
            InstrKind::RowAgg { a, op } => row_agg(&regs[*a], rows, *op, vectorized),
            InstrKind::RowArgExtreme { a, max } => row_arg_extreme(&regs[*a], rows, *max),
            InstrKind::InnerSmall { a, b, f1, f2 } => {
                inner_small(&regs[*a], rows, b, *f1, *f2)?
            }
            InstrKind::Cast { a, to } => regs[*a].cast(*to)?,
            InstrKind::SelectCol { a, col } => regs[*a].slice(col * rows, rows),
            InstrKind::ColBind(parts) => {
                let mut out = Buf::alloc(ins.dtype, rows * ncol);
                let mut off = 0usize;
                for p in parts {
                    let src = regs[*p].cast(ins.dtype)?;
                    out.copy_from(off, &src);
                    off += src.len();
                }
                out
            }
        };
        regs.push(out);
    }
    Ok(regs)
}

/// Promote two buffers to their common dtype.
fn promote_pair(a: &Buf, b: &Buf) -> Result<(Buf, Buf)> {
    let t = DType::promote(a.dtype(), b.dtype());
    Ok((a.cast(t)?, b.cast(t)?))
}

/// Strip-load from a col-major source partition: gather `rows` rows of each
/// column starting at the strip's local offset.
fn load_strip(src: &SourceStrip<'_>, dtype: DType, ncol: usize, rows: usize) -> Result<Buf> {
    let esz = dtype.size();
    let prows = src.part_rows;
    if src.local_row0 + rows > prows {
        return Err(FmError::Shape(format!(
            "strip [{}..{}) exceeds source partition rows {prows}",
            src.local_row0,
            src.local_row0 + rows
        )));
    }
    // fast path: decode f64 columns straight from the partition bytes
    // (one pass, no intermediate byte buffer — EXPERIMENTS.md §Perf)
    if dtype == DType::F64 {
        let mut out = Vec::with_capacity(rows * ncol);
        for j in 0..ncol {
            let src_off = (j * prows + src.local_row0) * 8;
            out.extend(
                src.bytes[src_off..src_off + rows * 8]
                    .chunks_exact(8)
                    .map(|c| f64::from_le_bytes(c.try_into().unwrap())),
            );
        }
        return Ok(Buf::F64(out));
    }
    let mut bytes = vec![0u8; rows * ncol * esz];
    for j in 0..ncol {
        let src_off = (j * prows + src.local_row0) * esz;
        let dst_off = j * rows * esz;
        bytes[dst_off..dst_off + rows * esz]
            .copy_from_slice(&src.bytes[src_off..src_off + rows * esz]);
    }
    Buf::from_bytes(dtype, &bytes)
}

/// Per-row reduction over a col-major strip -> rows x 1.
fn row_agg(a: &Buf, rows: usize, op: AggOp, vectorized: bool) -> Buf {
    let ncol = a.len() / rows.max(1);
    let acc_dt = op.acc_dtype(a.dtype());
    // fast path: f64 sum/min/max with column-sweep accumulation
    if vectorized && a.dtype() == DType::F64 && acc_dt == DType::F64 {
        if let Buf::F64(v) = a {
            let mut acc = vec![op.identity(DType::F64).as_f64(); rows];
            for j in 0..ncol {
                let col = &v[j * rows..(j + 1) * rows];
                match op {
                    AggOp::Sum => {
                        for r in 0..rows {
                            acc[r] += col[r];
                        }
                    }
                    AggOp::Min => {
                        for r in 0..rows {
                            acc[r] = acc[r].min(col[r]);
                        }
                    }
                    AggOp::Max => {
                        for r in 0..rows {
                            acc[r] = acc[r].max(col[r]);
                        }
                    }
                    AggOp::Prod => {
                        for r in 0..rows {
                            acc[r] *= col[r];
                        }
                    }
                    _ => unreachable!("acc_dtype guarantees numeric op"),
                }
            }
            return Buf::F64(acc);
        }
    }
    let mut out = Buf::alloc(acc_dt, rows);
    for r in 0..rows {
        let mut acc = op.identity(acc_dt);
        for j in 0..ncol {
            acc = op.fold_scalar(acc, a.get(j * rows + r));
        }
        out.set(r, acc);
    }
    out
}

/// Per-row argmin/argmax (1-based, first extreme wins — R's which.min).
///
/// NaN entries are skipped like R skips NAs: a NaN never wins and never
/// poisons later comparisons (seeding on a NaN first column would make
/// every `<`/`>` test false and freeze the answer at column 1). An all-NaN
/// row falls back to index 1.
fn row_arg_extreme(a: &Buf, rows: usize, max: bool) -> Buf {
    let ncol = a.len() / rows.max(1);
    let mut out = vec![0i32; rows];
    for r in 0..rows {
        let mut best = f64::NAN;
        let mut bi = 0i32; // 0 = nothing finite seen yet
        for j in 0..ncol {
            let v = a.get(j * rows + r).as_f64();
            if v.is_nan() {
                continue;
            }
            if bi == 0 || (max && v > best) || (!max && v < best) {
                best = v;
                bi = j as i32 + 1; // 1-based like R
            }
        }
        out[r] = bi.max(1);
    }
    Buf::I32(out)
}

/// Generalized inner product of a strip (rows x p) with a small host matrix
/// (p x q): out[r, c] = f2-fold over k of f1(a[r,k], b[k,c]).
///
/// The (Mul, Sum, f64) case is the dense matmul the paper routes to BLAS;
/// here it gets a monomorphic kernel (column-major SAXPY loop) and the
/// XLA-artifact path replaces it at the algorithm level when shapes match.
fn inner_small(a: &Buf, rows: usize, b: &HostMat, f1: BinOp, f2: AggOp) -> Result<Buf> {
    let p = b.nrow;
    let q = b.ncol;
    if a.len() != rows * p {
        return Err(FmError::Shape(format!(
            "inner.prod: left strip has {} elems, want rows {rows} x p {p}",
            a.len()
        )));
    }
    if f1 == BinOp::Mul && f2 == AggOp::Sum && a.dtype() == DType::F64 {
        if let (Buf::F64(av), Buf::F64(bv)) = (a, &b.buf) {
            // out[:, c] = sum_k a[:, k] * b[k, c]  (SAXPY over columns)
            let mut out = vec![0.0f64; rows * q];
            for c in 0..q {
                let ocol = &mut out[c * rows..(c + 1) * rows];
                for k in 0..p {
                    let w = bv[c * p + k];
                    if w != 0.0 {
                        let acol = &av[k * rows..(k + 1) * rows];
                        for r in 0..rows {
                            ocol[r] += w * acol[r];
                        }
                    }
                }
            }
            return Ok(Buf::F64(out));
        }
    }
    // generic path through f64
    let acc_dt = f2.acc_dtype(DType::promote(a.dtype(), b.buf.dtype()));
    let mut out = Buf::alloc(acc_dt, rows * q);
    let g1 = move |x: f64, y: f64| -> f64 {
        // scalar form of f1 via the vectorized kernel on length-1 buffers
        // is wasteful; use the op's f64 semantic directly
        match f1 {
            BinOp::Add => x + y,
            BinOp::Sub => x - y,
            BinOp::Mul => x * y,
            BinOp::Div => x / y,
            BinOp::Min => x.min(y),
            BinOp::Max => x.max(y),
            BinOp::Eq => (x == y) as u8 as f64,
            BinOp::Ne => (x != y) as u8 as f64,
            _ => f64::NAN,
        }
    };
    for c in 0..q {
        for r in 0..rows {
            let mut acc = f2.identity(acc_dt);
            for k in 0..p {
                let v = g1(a.get(k * rows + r).as_f64(), b.get(k, c).as_f64());
                acc = f2.fold_scalar(acc, Scalar::F64(v));
            }
            out.set(c * rows + r, acc);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_matches_reference_stream() {
        // first values of a sequential SplitMix64 stream with seed 42 --
        // cross-checked against the python implementation in test_golden.py
        let s0 = splitmix64_at(42, 0);
        let s1 = splitmix64_at(42, 1);
        assert_ne!(s0, s1);
        // determinism
        assert_eq!(s0, splitmix64_at(42, 0));
        let u = u64_to_unit_f64(s0);
        assert!((0.0..1.0).contains(&u));
    }

    #[test]
    fn row_agg_and_argmin() {
        // strip 2 rows x 3 cols, col-major: cols [1,5], [2,4], [0,6]
        let a = Buf::from_f64(&[1.0, 5.0, 2.0, 4.0, 0.0, 6.0]);
        let sums = row_agg(&a, 2, AggOp::Sum, true);
        assert_eq!(sums.to_f64_vec(), vec![3.0, 15.0]);
        let mins = row_agg(&a, 2, AggOp::Min, true);
        assert_eq!(mins.to_f64_vec(), vec![0.0, 4.0]);
        let am = row_arg_extreme(&a, 2, false);
        assert_eq!(am.as_i32(), &[3, 2]); // 1-based
    }

    #[test]
    fn row_arg_extreme_skips_nans() {
        // 2 rows x 3 cols col-major: cols [NaN,5], [2,NaN], [0,6]
        let a = Buf::from_f64(&[f64::NAN, 5.0, 2.0, f64::NAN, 0.0, 6.0]);
        let am = row_arg_extreme(&a, 2, false);
        assert_eq!(am.as_i32(), &[3, 1], "NaN must not poison which.min");
        let ax = row_arg_extreme(&a, 2, true);
        assert_eq!(ax.as_i32(), &[2, 3], "NaN must not poison which.max");
        // an all-NaN row falls back to index 1
        let b = Buf::from_f64(&[f64::NAN, 1.0, f64::NAN, 0.5]);
        assert_eq!(row_arg_extreme(&b, 2, false).as_i32(), &[1, 2]);
    }

    #[test]
    fn inner_small_matmul() {
        // a: 2x2 col-major [[1,2],[3,4]] -> cols [1,3],[2,4]
        let a = Buf::from_f64(&[1.0, 3.0, 2.0, 4.0]);
        let b = HostMat::from_rows_f64(&[vec![1.0, 0.0], vec![0.0, 1.0]]);
        let out = inner_small(&a, 2, &b, BinOp::Mul, AggOp::Sum).unwrap();
        assert_eq!(out.to_f64_vec(), vec![1.0, 3.0, 2.0, 4.0]); // identity
        // generalized: min-plus "tropical" inner product
        // out[r,c] = min_k(a[r,k] + b[k,c])
        let out = inner_small(&a, 2, &b, BinOp::Add, AggOp::Min).unwrap();
        assert_eq!(out.to_f64_vec(), vec![2.0, 4.0, 1.0, 3.0]);
    }

    #[test]
    fn load_strip_gathers_columns() {
        // source partition: 4 rows x 2 cols col-major = [0,1,2,3, 10,11,12,13]
        let vals: Vec<f64> = vec![0.0, 1.0, 2.0, 3.0, 10.0, 11.0, 12.0, 13.0];
        let bytes = Buf::from_f64(&vals).to_bytes();
        let src = SourceStrip {
            bytes: &bytes,
            part_rows: 4,
            local_row0: 1,
        };
        let b = load_strip(&src, DType::F64, 2, 2).unwrap();
        assert_eq!(b.to_f64_vec(), vec![1.0, 2.0, 11.0, 12.0]);
    }
}
