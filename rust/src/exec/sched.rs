//! Locality-aware range scheduling of pass partitions (paper §III-B3/F).
//!
//! The original dispatch handed pass partitions to workers from one global
//! atomic counter. That scatters *neighbouring* partitions across workers,
//! which defeats two locality mechanisms at once: the per-worker source
//! cache (consecutive pass partitions usually share one source I/O
//! partition, but with counter dispatch the sharers land on different
//! workers and each re-copies the same source bytes) and asynchronous
//! read-ahead (with non-deterministic ownership, a prefetch of partition
//! *N+1* races whichever worker claims it and double-reads the file).
//!
//! The [`RangeScheduler`] instead divides the pass into **locality units**
//! — groups of consecutive pass partitions nested inside one source
//! I/O-level partition — and assigns each worker one contiguous range of
//! units up front. A worker that drains its range *steals the upper half*
//! of the largest remaining range (classic work-stealing, bounded skew),
//! preferring victims on its own simulated NUMA node so the
//! `EngineConfig::numa_nodes` knob shapes partition→worker affinity the
//! way SAFS pins I/O threads to the node that owns the flash device.
//! Ownership of the *next* unit is therefore deterministic, which is what
//! makes multi-worker read-ahead safe (see `exec::process_partition`).
//!
//! The scheduler also carries the pass's abort flag: a worker that fails
//! flips it and every other worker stops claiming instead of processing
//! (and writing) the rest of the pass. The flag doubles as the write-back
//! pipeline's abort signal — `exec::run_pass` checks it after the worker
//! scope and *discards* the aborted pass's queued target writes
//! ([`crate::matrix::cache::PartitionCache::discard_writes`]) instead of
//! flushing them, so a doomed pass leaves no partial partitions on disk.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::sync::LockExt;

/// Contiguous-range work scheduler with half-stealing and simulated NUMA
/// affinity. One instance per materialization pass.
pub struct RangeScheduler {
    /// Per-worker remaining claim range `[next, end)` in locality units.
    ranges: Vec<Mutex<(usize, usize)>>,
    /// Simulated NUMA node of each worker (contiguous worker blocks, so
    /// each node owns one contiguous slab of the pass).
    node_of: Vec<usize>,
    /// Pass partitions per locality unit.
    group: usize,
    /// Total pass partitions.
    n_parts: usize,
    /// Total locality units.
    n_units: usize,
    abort: AtomicBool,
    steals: AtomicU64,
    steals_remote: AtomicU64,
}

impl RangeScheduler {
    /// Schedule `n_parts` pass partitions, grouped `group` per locality
    /// unit, over `workers` workers spread across `numa_nodes` simulated
    /// NUMA nodes.
    pub fn new(n_parts: usize, group: usize, workers: usize, numa_nodes: usize) -> RangeScheduler {
        let group = group.max(1);
        let workers = workers.max(1);
        let numa_nodes = numa_nodes.max(1).min(workers);
        let n_units = n_parts.div_ceil(group);
        // contiguous even split of units over workers (first ranges may be
        // one unit longer); workers of one node are contiguous, so each
        // node's initial slab of the matrix is contiguous too
        let ranges = (0..workers)
            .map(|w| Mutex::new((w * n_units / workers, (w + 1) * n_units / workers)))
            .collect();
        let node_of = (0..workers).map(|w| w * numa_nodes / workers).collect();
        RangeScheduler {
            ranges,
            node_of,
            group,
            n_parts,
            n_units,
            abort: AtomicBool::new(false),
            steals: AtomicU64::new(0),
            steals_remote: AtomicU64::new(0),
        }
    }

    /// Simulated NUMA node of worker `w`.
    pub fn node_of(&self, w: usize) -> usize {
        self.node_of[w]
    }

    /// Pass-partition range `[p0, p1)` of locality unit `u`.
    pub fn unit_parts(&self, u: usize) -> (usize, usize) {
        (u * self.group, ((u + 1) * self.group).min(self.n_parts))
    }

    /// Claim the next locality unit for worker `w`: the front of its own
    /// range, or — when the range is dry — the upper half of the largest
    /// remaining range (same-node victims first). `None` when the pass is
    /// complete or aborted.
    pub fn claim_unit(&self, w: usize) -> Option<usize> {
        loop {
            if self.aborted() {
                return None;
            }
            {
                let mut own = self.ranges[w].lock_recover();
                if own.0 < own.1 {
                    let u = own.0;
                    own.0 += 1;
                    return Some(u);
                }
            }
            match self.steal_for(w) {
                StealOutcome::Stole(u) => return Some(u),
                StealOutcome::Empty => return None,
                StealOutcome::Retry => continue,
            }
        }
    }

    /// Peek worker `w`'s next owned unit without claiming it (the
    /// read-ahead hint). The unit may still be stolen before `w` reaches
    /// it — a wasted prefetch, never a correctness problem (single-flight
    /// coalesces any resulting duplicate read).
    pub fn peek_next(&self, w: usize) -> Option<usize> {
        let own = self.ranges[w].lock_recover();
        if own.0 < own.1 {
            Some(own.0)
        } else {
            None
        }
    }

    fn steal_for(&self, w: usize) -> StealOutcome {
        // pass 1: largest same-node victim; pass 2: largest anywhere
        for remote_pass in [false, true] {
            let mut best: Option<(usize, usize)> = None; // (victim, remaining)
            for v in 0..self.ranges.len() {
                if v == w || (!remote_pass && self.node_of[v] != self.node_of[w]) {
                    continue;
                }
                let r = self.ranges[v].lock_recover();
                let remaining = r.1.saturating_sub(r.0);
                if remaining > 0 && best.map(|(_, n)| remaining > n).unwrap_or(true) {
                    best = Some((v, remaining));
                }
            }
            if let Some((victim, _)) = best {
                let stolen = {
                    let mut r = self.ranges[victim].lock_recover();
                    let remaining = r.1.saturating_sub(r.0);
                    if remaining == 0 {
                        // drained between the scan and the lock — rescan
                        return StealOutcome::Retry;
                    }
                    // take the upper half [mid, end); the victim keeps the
                    // lower half it is already streaming through
                    let mid = r.0 + remaining / 2;
                    let stolen = (mid, r.1);
                    r.1 = mid;
                    stolen
                };
                self.steals.fetch_add(1, Ordering::Relaxed);
                if self.node_of[victim] != self.node_of[w] {
                    self.steals_remote.fetch_add(1, Ordering::Relaxed);
                }
                let u = stolen.0;
                let mut own = self.ranges[w].lock_recover();
                *own = (stolen.0 + 1, stolen.1);
                drop(own);
                return StealOutcome::Stole(u);
            }
        }
        StealOutcome::Empty
    }

    /// Signal pass failure: every worker's next claim returns `None`, and
    /// the pass-end barrier discards (rather than flushes) the pass's
    /// queued write-back partitions.
    pub fn abort(&self) {
        self.abort.store(true, Ordering::Relaxed);
    }

    pub fn aborted(&self) -> bool {
        self.abort.load(Ordering::Relaxed)
    }

    /// Ranges stolen so far.
    pub fn steals(&self) -> u64 {
        self.steals.load(Ordering::Relaxed)
    }

    /// Steals that crossed a simulated NUMA node.
    pub fn steals_remote(&self) -> u64 {
        self.steals_remote.load(Ordering::Relaxed)
    }

    /// Total locality units in the pass.
    pub fn n_units(&self) -> usize {
        self.n_units
    }
}

enum StealOutcome {
    Stole(usize),
    Empty,
    Retry,
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn units_cover_partitions_exactly_once() {
        let s = RangeScheduler::new(17, 4, 3, 1);
        assert_eq!(s.n_units(), 5); // ceil(17/4)
        let mut seen = HashSet::new();
        for w in 0..3 {
            while let Some(u) = s.claim_unit(w) {
                let (p0, p1) = s.unit_parts(u);
                for p in p0..p1 {
                    assert!(seen.insert(p), "partition {p} claimed twice");
                }
                // only drain own range here; stealing covered elsewhere
                if s.peek_next(w).is_none() {
                    break;
                }
            }
        }
        // drain leftovers (steals) through worker 0
        while let Some(u) = s.claim_unit(0) {
            let (p0, p1) = s.unit_parts(u);
            for p in p0..p1 {
                assert!(seen.insert(p), "partition {p} claimed twice");
            }
        }
        assert_eq!(seen.len(), 17, "every partition claimed exactly once");
    }

    #[test]
    fn initial_ranges_are_contiguous_per_worker() {
        let s = RangeScheduler::new(12, 1, 3, 1);
        for w in 0..3 {
            let mut last = None;
            while let Some(u) = s.claim_unit(w) {
                if let Some(prev) = last {
                    assert_eq!(u, prev + 1, "worker {w} skipped a unit");
                }
                last = Some(u);
                if s.peek_next(w).is_none() {
                    break;
                }
            }
        }
    }

    #[test]
    fn dry_worker_steals_half_of_largest_range() {
        let s = RangeScheduler::new(8, 1, 2, 1);
        // worker 1 drains its own range [4, 8)
        for _ in 0..4 {
            assert!(s.claim_unit(1).is_some());
        }
        // next claim steals the upper half of worker 0's [0, 4) -> [2, 4)
        let u = s.claim_unit(1).unwrap();
        assert_eq!(u, 2);
        assert_eq!(s.steals(), 1);
        assert_eq!(s.peek_next(1), Some(3));
        // worker 0 still owns its lower half
        assert_eq!(s.peek_next(0), Some(0));
        let mine: Vec<usize> = std::iter::from_fn(|| s.claim_unit(0)).collect();
        assert_eq!(mine, vec![0, 1, 3]); // 0,1 own; 3 stolen back
    }

    #[test]
    fn steals_prefer_same_numa_node() {
        // 4 workers on 2 nodes: node 0 = {0, 1}, node 1 = {2, 3}
        let s = RangeScheduler::new(16, 1, 4, 2);
        assert_eq!((s.node_of(0), s.node_of(1)), (0, 0));
        assert_eq!((s.node_of(2), s.node_of(3)), (1, 1));
        // worker 1 drains [4, 8); its first steal must hit worker 0
        // (same node, 4 units) even though workers 2/3 also hold 4 units
        for _ in 0..4 {
            assert!(s.claim_unit(1).is_some());
        }
        let u = s.claim_unit(1).unwrap();
        assert!(u < 4, "steal went remote (unit {u}) with local work left");
        assert_eq!(s.steals(), 1);
        assert_eq!(s.steals_remote(), 0);
        // drain everything; the tail forces remote steals
        for w in [0usize, 1, 2, 3].iter().cycle().take(64) {
            if s.claim_unit(*w).is_none() && (0..4).all(|w| s.peek_next(w).is_none()) {
                break;
            }
        }
        while s.claim_unit(1).is_some() {}
        assert!(s.steals() >= s.steals_remote());
    }

    #[test]
    fn abort_stops_claims() {
        let s = RangeScheduler::new(8, 1, 2, 1);
        assert!(s.claim_unit(0).is_some());
        s.abort();
        assert!(s.claim_unit(0).is_none());
        assert!(s.claim_unit(1).is_none());
        assert!(s.aborted());
    }

    #[test]
    fn tail_unit_is_short() {
        let s = RangeScheduler::new(10, 4, 1, 1);
        assert_eq!(s.n_units(), 3);
        assert_eq!(s.unit_parts(0), (0, 4));
        assert_eq!(s.unit_parts(2), (8, 10));
    }

    #[test]
    fn more_workers_than_units() {
        let s = RangeScheduler::new(2, 1, 8, 4);
        let mut got = 0;
        for w in 0..8 {
            while s.claim_unit(w).is_some() {
                got += 1;
            }
        }
        assert_eq!(got, 2);
    }
}
