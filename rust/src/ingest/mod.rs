//! Streaming delimited-text ingestion: parallel, out-of-core text →
//! typed EM matrices (FlashR's `fm.load.dense.matrix` /
//! `fm.load.list.vecs`).
//!
//! Two-phase loader over newline-aligned chunks:
//!
//! 1. **Scan** — every chunk is read once, in parallel, to count records,
//!    collect factor vocabularies and NA cells, validate record shape and
//!    record a CRC32 of the raw text. Prefix sums over the per-chunk
//!    counts give every chunk its global row offset (and per-file line
//!    offset, for error reporting).
//! 2. **Parse** — one task per *output partition*: the chunks overlapping
//!    the partition's row range are re-read (verified against the phase-1
//!    CRC; one re-read, then [`FmError::Corrupt`]), parsed into col-major
//!    buffers and written through the ordinary
//!    [`DenseBuilder`](crate::matrix::DenseBuilder) path — ingestion rides
//!    the same §III-B3 memory hierarchy, fault injection and bounded-retry
//!    machinery as every other external matrix.
//!
//! Memory stays bounded by `workers × (chunk + partition)` regardless of
//! input size. Column types follow FlashR's `ele.types` schema codes:
//! `I` integer, `F` float, `H` hashed (feature-hashing trick), `X` factor
//! (categorical; levels collected in the scan phase, sorted, coded 1..k —
//! R's 1-based sorted-levels convention).
//!
//! Input grammar: records are `\n`-terminated (a trailing `\r` is
//! stripped, so CRLF files load), completely blank lines are skipped but
//! still counted for error line numbers, and every record must have
//! exactly `schema.len()` fields — a trailing delimiter therefore reads
//! as one extra (empty) field and is rejected as a ragged row. Fields
//! are ASCII-whitespace-trimmed before NA matching and parsing.

use std::collections::{BTreeSet, HashMap};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::dtype::{DType, Scalar};
use crate::error::{FmError, Result};
use crate::fmr::{Engine, FmMatrix, FmVector};
use crate::matrix::{DenseBuilder, Matrix, Partitioning};
use crate::runtime::manifest::DenseColMeta;
use crate::storage::{crc32, FileStore};
use crate::util::sync::LockExt;
use crate::vudf::Buf;
use crate::StorageKind;

/// Default bucket count for `H` (hashed) columns: 2^20, the order of the
/// hashing-trick width used for the Criteo categorical features.
pub const DEFAULT_HASH_BUCKETS: u32 = 1 << 20;

/// Type of one input column (FlashR's `ele.types` codes).
#[derive(Clone, Debug, PartialEq)]
pub enum ColType {
    /// `I`: decimal integer → `i32` (NA stored as `i32::MIN`).
    Int,
    /// `F`: decimal float → `f64` (NA stored as NaN).
    Float,
    /// `H`: feature-hashed bytes → `i32` code in `1..=buckets`
    /// (FNV-1a 64 of the trimmed field, mod `buckets`, plus 1).
    Hashed { buckets: u32 },
    /// `X`: factor (categorical string) → `i32` code in `1..=k` over the
    /// sorted level set collected in the scan phase.
    Factor,
}

impl ColType {
    /// One-character schema code (`I`/`F`/`H`/`X`).
    pub fn code(&self) -> char {
        match self {
            ColType::Int => 'I',
            ColType::Float => 'F',
            ColType::Hashed { .. } => 'H',
            ColType::Factor => 'X',
        }
    }

    /// Storage dtype of a single column of this type.
    pub fn dtype(&self) -> DType {
        match self {
            ColType::Float => DType::F64,
            ColType::Int | ColType::Hashed { .. } | ColType::Factor => DType::I32,
        }
    }
}

/// Typed column schema of a delimited file: one [`ColType`] per field.
#[derive(Clone, Debug, PartialEq)]
pub struct Schema {
    pub cols: Vec<ColType>,
}

impl Schema {
    /// Parse a code string, e.g. `"IIFXH"` — the compact spelling of
    /// FlashR's `ele.types` vector. `H` columns get
    /// [`DEFAULT_HASH_BUCKETS`]; use [`Schema::of`] for custom buckets.
    pub fn parse(codes: &str) -> Result<Schema> {
        let cols = codes
            .chars()
            .map(|c| match c {
                'I' => Ok(ColType::Int),
                'F' => Ok(ColType::Float),
                'H' => Ok(ColType::Hashed {
                    buckets: DEFAULT_HASH_BUCKETS,
                }),
                'X' => Ok(ColType::Factor),
                other => Err(FmError::Config(format!(
                    "ingest: unknown schema code '{other}' (want I, F, H or X)"
                ))),
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Schema { cols })
    }

    /// Schema from explicit column types.
    pub fn of(cols: Vec<ColType>) -> Schema {
        Schema { cols }
    }

    /// `n` columns of one type (e.g. all-float feature blocks).
    pub fn repeated(col: ColType, n: usize) -> Schema {
        Schema {
            cols: vec![col; n],
        }
    }

    pub fn len(&self) -> usize {
        self.cols.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cols.is_empty()
    }

    /// Storage dtype of the single dense matrix holding every column:
    /// f64 when any `F` column is present, else i32.
    pub fn uniform_dtype(&self) -> DType {
        if self.cols.iter().any(|c| matches!(c, ColType::Float)) {
            DType::F64
        } else {
            DType::I32
        }
    }

    fn validate(&self) -> Result<()> {
        if self.cols.is_empty() {
            return Err(FmError::Config("ingest: empty schema".into()));
        }
        for c in &self.cols {
            if let ColType::Hashed { buckets: 0 } = c {
                return Err(FmError::Config(
                    "ingest: hashed column needs buckets > 0".into(),
                ));
            }
        }
        Ok(())
    }
}

/// Options for [`load_dense_matrix`] / [`load_list_vecs`] — the builder
/// mirror of FlashR's `fm.load.*` keyword arguments.
#[derive(Clone, Debug)]
pub struct LoadOptions {
    pub schema: Schema,
    /// Field delimiter byte (default `,`; Criteo uses `\t`).
    pub delim: u8,
    /// `Some(true)` forces in-memory, `Some(false)` forces external
    /// storage; `None` follows [`EngineConfig::storage`]
    /// (`crate::EngineConfig::storage`).
    pub in_mem: Option<bool>,
    /// Persist the loaded matrix under this name (external storage
    /// only): named backing file(s) plus a `<name>.dense.json` sidecar,
    /// reopenable across runs with `Engine::get_dense_matrix`.
    pub name: Option<String>,
    /// Field spellings that read as NA, compared after ASCII-whitespace
    /// trim (default: the empty field and `NA`).
    pub na_values: Vec<String>,
}

impl LoadOptions {
    pub fn new(schema: Schema) -> LoadOptions {
        LoadOptions {
            schema,
            delim: b',',
            in_mem: None,
            name: None,
            na_values: vec![String::new(), "NA".to_string()],
        }
    }

    pub fn delim(mut self, d: u8) -> Self {
        self.delim = d;
        self
    }

    pub fn in_mem(mut self, v: bool) -> Self {
        self.in_mem = Some(v);
        self
    }

    pub fn name(mut self, n: impl Into<String>) -> Self {
        self.name = Some(n.into());
        self
    }

    pub fn na_values(mut self, vals: &[&str]) -> Self {
        self.na_values = vals.iter().map(|s| s.to_string()).collect();
        self
    }
}

// ---------------------------------------------------------------------------
// field-level parsing

/// FNV-1a 64 over raw bytes (the hashing-trick hash for `H` columns).
fn fnv1a64(b: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &c in b {
        h ^= c as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// ASCII-whitespace trim of a field's bytes.
fn trim(b: &[u8]) -> &[u8] {
    let s = b
        .iter()
        .position(|c| !c.is_ascii_whitespace())
        .unwrap_or(b.len());
    let e = b
        .iter()
        .rposition(|c| !c.is_ascii_whitespace())
        .map(|p| p + 1)
        .unwrap_or(s);
    &b[s..e]
}

/// A parsed cell before it is written at the sink's storage dtype.
enum CellVal {
    I(i32),
    F(f64),
    Na,
}

/// Parse one field. Errors are bare messages; the caller attaches the
/// (file, line, col) location.
fn parse_field(
    raw: &[u8],
    ct: &ColType,
    na: &[&[u8]],
    levels: Option<&HashMap<String, i32>>,
) -> std::result::Result<CellVal, String> {
    let f = trim(raw);
    if na.iter().any(|n| *n == f) {
        return Ok(CellVal::Na);
    }
    match ct {
        ColType::Int => {
            let t = std::str::from_utf8(f)
                .map_err(|_| "invalid UTF-8 in integer field".to_string())?;
            let v: i64 = t
                .parse()
                .map_err(|_| format!("invalid integer '{t}'"))?;
            // i32::MIN is the NA sentinel: an input spelling it must be
            // rejected, not silently read back as NA
            if v <= i32::MIN as i64 || v > i32::MAX as i64 {
                return Err(format!("integer '{t}' out of i32 range"));
            }
            Ok(CellVal::I(v as i32))
        }
        ColType::Float => {
            let t = std::str::from_utf8(f)
                .map_err(|_| "invalid UTF-8 in float field".to_string())?;
            let v: f64 = t.parse().map_err(|_| format!("invalid float '{t}'"))?;
            Ok(CellVal::F(v))
        }
        ColType::Hashed { buckets } => {
            Ok(CellVal::I((fnv1a64(f) % *buckets as u64) as i32 + 1))
        }
        ColType::Factor => {
            let t = std::str::from_utf8(f)
                .map_err(|_| "invalid UTF-8 in factor field".to_string())?;
            match levels.and_then(|m| m.get(t)) {
                Some(code) => Ok(CellVal::I(*code)),
                None => Err(format!("factor level '{t}' not in scanned vocabulary")),
            }
        }
    }
}

/// Widen a parsed cell to the sink's storage dtype (I32 or F64).
fn cell_scalar(v: CellVal, dt: DType) -> Scalar {
    match (v, dt) {
        (CellVal::Na, DType::F64) => Scalar::F64(f64::NAN),
        (CellVal::Na, _) => Scalar::I32(i32::MIN),
        (CellVal::I(x), DType::F64) => Scalar::F64(x as f64),
        (CellVal::I(x), _) => Scalar::I32(x),
        (CellVal::F(x), _) => Scalar::F64(x),
    }
}

// ---------------------------------------------------------------------------
// phase 1: chunk planning + scan

/// One newline-aligned text chunk after the scan phase.
struct ChunkMeta {
    /// Index into the loader's path/store lists.
    file: usize,
    off: u64,
    len: usize,
    /// Data records (non-blank lines) in the chunk.
    rows: u64,
    /// CRC32 of the raw chunk bytes, re-verified in the parse phase.
    crc: u32,
    /// Global first row of the chunk (rows concatenate across files).
    row0: u64,
    /// Physical lines before this chunk *within its file* (0-based).
    line0: u64,
}

/// Physical lines in a chunk: newline count plus an unterminated tail.
fn count_lines(bytes: &[u8]) -> u64 {
    let nl = bytes.iter().filter(|b| **b == b'\n').count() as u64;
    nl + u64::from(bytes.last().map_or(false, |b| *b != b'\n'))
}

/// First record start at or after `nominal`: the byte after the first
/// newline in `[nominal - 1, flen)`. Probes are small reads through the
/// same fault-injected store as the scan itself.
fn next_record_start(store: &FileStore, nominal: u64, flen: u64) -> Result<Option<u64>> {
    const PROBE: usize = 64 << 10;
    let mut p = nominal - 1;
    let mut buf = vec![0u8; PROBE];
    while p < flen {
        let n = PROBE.min((flen - p) as usize);
        store.read_at(p, &mut buf[..n])?;
        if let Some(i) = buf[..n].iter().position(|b| *b == b'\n') {
            return Ok(Some(p + i as u64 + 1));
        }
        p += n as u64;
    }
    Ok(None)
}

/// Newline-aligned chunk table of one file: every chunk starts at byte 0
/// or right after a newline, and only the file's last chunk may end
/// without one.
fn chunk_bounds(store: &FileStore, chunk_bytes: usize) -> Result<Vec<(u64, usize)>> {
    let flen = store.len();
    let mut starts = vec![0u64];
    loop {
        let nominal = *starts.last().unwrap() + chunk_bytes as u64;
        if nominal >= flen {
            break;
        }
        match next_record_start(store, nominal, flen)? {
            Some(s) if s < flen => starts.push(s),
            _ => break,
        }
    }
    Ok(starts
        .iter()
        .enumerate()
        .map(|(i, &s)| {
            let end = starts.get(i + 1).copied().unwrap_or(flen);
            (s, (end - s) as usize)
        })
        .filter(|(_, l)| *l > 0)
        .collect())
}

/// Per-chunk result of the scan phase.
struct ChunkScan {
    rows: u64,
    lines: u64,
    crc: u32,
    na_cells: u64,
    /// One vocabulary per factor column, in schema order.
    vocabs: Vec<BTreeSet<String>>,
    /// First structural error: (0-based line in chunk, 1-based col, msg).
    err: Option<(u64, u64, String)>,
}

/// Scan one chunk: validate record shape, count rows/NA cells, collect
/// factor vocabularies. `vocab_idx[j]` maps schema column j to its slot
/// in `vocabs` (None for non-factor columns).
fn scan_chunk(
    bytes: &[u8],
    o: &LoadOptions,
    na: &[&[u8]],
    vocab_idx: &[Option<usize>],
    n_factors: usize,
) -> ChunkScan {
    let want = o.schema.len();
    let mut s = ChunkScan {
        rows: 0,
        lines: count_lines(bytes),
        crc: crc32(bytes),
        na_cells: 0,
        vocabs: vec![BTreeSet::new(); n_factors],
        err: None,
    };
    let mut line = 0u64;
    let mut start = 0usize;
    while start < bytes.len() {
        let end = bytes[start..]
            .iter()
            .position(|b| *b == b'\n')
            .map(|q| start + q)
            .unwrap_or(bytes.len());
        let mut rec = &bytes[start..end];
        if rec.last() == Some(&b'\r') {
            rec = &rec[..rec.len() - 1];
        }
        if !rec.is_empty() {
            let mut nf = 0usize;
            for (j, field) in rec.split(|b| *b == o.delim).enumerate() {
                nf += 1;
                if j >= want {
                    continue; // counted; rejected below with the full count
                }
                let f = trim(field);
                if na.iter().any(|n| *n == f) {
                    s.na_cells += 1;
                } else if let Some(vi) = vocab_idx[j] {
                    match std::str::from_utf8(f) {
                        Ok(t) => {
                            s.vocabs[vi].insert(t.to_string());
                        }
                        Err(_) => {
                            s.err = Some((
                                line,
                                j as u64 + 1,
                                "invalid UTF-8 in factor field".into(),
                            ));
                            return s;
                        }
                    }
                }
            }
            if nf != want {
                s.err = Some((
                    line,
                    nf as u64,
                    format!("expected {want} fields, found {nf}"),
                ));
                return s;
            }
            s.rows += 1;
        }
        line += 1;
        start = end + 1;
    }
    s
}

/// Everything the parse phase needs from the scan phase.
struct ScanResult {
    stores: Vec<Arc<FileStore>>,
    chunks: Vec<ChunkMeta>,
    nrow: u64,
    /// Per schema column: sorted factor levels (None for non-factors).
    levels: Vec<Option<Arc<Vec<String>>>>,
}

fn ingest_worker_count(eng: &Engine) -> usize {
    let w = if eng.config.ingest_workers == 0 {
        eng.config.threads
    } else {
        eng.config.ingest_workers
    };
    w.max(1)
}

fn scan_phase<P: AsRef<Path>>(
    eng: &Arc<Engine>,
    paths: &[P],
    o: &LoadOptions,
) -> Result<ScanResult> {
    o.schema.validate()?;
    if paths.is_empty() {
        return Err(FmError::Config("ingest: no input files".into()));
    }
    let mut stores = Vec::with_capacity(paths.len());
    let mut raw: Vec<(usize, u64, usize)> = Vec::new();
    for (fi, p) in paths.iter().enumerate() {
        let p = p.as_ref();
        let store = FileStore::open(p, Arc::clone(&eng.ssd), Arc::clone(&eng.metrics))
            .map_err(|e| {
                FmError::Storage(format!("ingest: cannot open {}: {e}", p.display()))
            })?;
        for (off, len) in chunk_bounds(&store, eng.config.ingest_chunk_bytes.max(1))? {
            raw.push((fi, off, len));
        }
        stores.push(Arc::new(store));
    }

    let mut vocab_idx: Vec<Option<usize>> = Vec::with_capacity(o.schema.len());
    let mut n_factors = 0usize;
    for c in &o.schema.cols {
        if matches!(c, ColType::Factor) {
            vocab_idx.push(Some(n_factors));
            n_factors += 1;
        } else {
            vocab_idx.push(None);
        }
    }
    let na: Vec<&[u8]> = o.na_values.iter().map(|s| s.as_bytes()).collect();

    // parallel scan, one claim per chunk (the datasets::from_fn idiom)
    let n_chunks = raw.len();
    let scans: Vec<Mutex<Option<ChunkScan>>> = (0..n_chunks).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    let workers = ingest_worker_count(eng).min(n_chunks.max(1));
    let io_err: Mutex<Option<FmError>> = Mutex::new(None);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n_chunks {
                    break;
                }
                let (fi, off, len) = raw[i];
                let mut bytes = vec![0u8; len];
                if let Err(e) = stores[fi].read_at(off, &mut bytes) {
                    let mut g = io_err.lock_recover();
                    if g.is_none() {
                        *g = Some(e);
                    }
                    break;
                }
                *scans[i].lock_recover() =
                    Some(scan_chunk(&bytes, o, &na, &vocab_idx, n_factors));
            });
        }
    });
    if let Some(e) = io_err.into_inner_recover() {
        return Err(e);
    }
    let scans: Vec<ChunkScan> = scans
        .into_iter()
        .map(|m| m.into_inner_recover().expect("chunk scanned"))
        .collect();

    // first structural error in (file, offset) order — deterministic
    // under any thread schedule; line numbers fixed up via prefix sums
    let mut file_lines = vec![0u64; stores.len()];
    for (i, sc) in scans.iter().enumerate() {
        let fi = raw[i].0;
        if let Some((l, c, m)) = &sc.err {
            return Err(FmError::Parse {
                file: paths[fi].as_ref().display().to_string(),
                line: file_lines[fi] + l + 1,
                col: *c,
                msg: m.clone(),
            });
        }
        file_lines[fi] += sc.lines;
    }

    // prefix sums: global rows (across files), per-file lines; merge vocabs
    let mut chunks = Vec::with_capacity(n_chunks);
    let mut row0 = 0u64;
    let mut line_off = vec![0u64; stores.len()];
    let mut na_cells = 0u64;
    let mut vocabs: Vec<BTreeSet<String>> = vec![BTreeSet::new(); n_factors];
    for (i, sc) in scans.into_iter().enumerate() {
        let (fi, off, len) = raw[i];
        chunks.push(ChunkMeta {
            file: fi,
            off,
            len,
            rows: sc.rows,
            crc: sc.crc,
            row0,
            line0: line_off[fi],
        });
        row0 += sc.rows;
        line_off[fi] += sc.lines;
        na_cells += sc.na_cells;
        for (v, s) in vocabs.iter_mut().zip(sc.vocabs) {
            v.extend(s);
        }
    }
    if row0 == 0 {
        return Err(FmError::Shape("ingest: input contains no data rows".into()));
    }
    eng.metrics
        .ingest_chunks
        .fetch_add(n_chunks as u64, Ordering::Relaxed);
    eng.metrics.ingest_rows.fetch_add(row0, Ordering::Relaxed);
    eng.metrics
        .ingest_na_cells
        .fetch_add(na_cells, Ordering::Relaxed);

    let mut vocabs = vocabs.into_iter();
    let levels = o
        .schema
        .cols
        .iter()
        .map(|c| {
            matches!(c, ColType::Factor)
                .then(|| Arc::new(vocabs.next().expect("factor vocab").into_iter().collect()))
        })
        .collect();
    Ok(ScanResult {
        stores,
        chunks,
        nrow: row0,
        levels,
    })
}

// ---------------------------------------------------------------------------
// phase 2: partition-aligned parse + write

/// Output shape of the parse phase: one p-column matrix builder, or one
/// single-column builder per schema column (sharing one n×1 row grid).
enum SinkSet<'a> {
    One(&'a DenseBuilder),
    PerCol(&'a [DenseBuilder]),
}

fn parse_err<P: AsRef<Path>>(
    paths: &[P],
    fi: usize,
    line: u64,
    col: u64,
    msg: String,
) -> FmError {
    FmError::Parse {
        file: paths[fi].as_ref().display().to_string(),
        line,
        col,
        msg,
    }
}

/// Read a chunk's bytes and verify them against the scan-phase CRC: one
/// re-read on mismatch, then the corruption surfaces. (Text files have
/// no write-time checksum table, so this cross-phase check is what keeps
/// the two phases bit-consistent.)
fn read_chunk_verified(eng: &Engine, store: &FileStore, c: &ChunkMeta) -> Result<Vec<u8>> {
    let mut bytes = vec![0u8; c.len];
    store.read_at(c.off, &mut bytes)?;
    if crc32(&bytes) == c.crc {
        return Ok(bytes);
    }
    eng.metrics
        .checksum_failures
        .fetch_add(1, Ordering::Relaxed);
    store.read_at(c.off, &mut bytes)?;
    if crc32(&bytes) == c.crc {
        return Ok(bytes);
    }
    Err(FmError::Corrupt(format!(
        "ingest: text chunk at bytes {}..{} failed its scan-phase checksum after a re-read",
        c.off,
        c.off + c.len as u64
    )))
}

#[allow(clippy::too_many_arguments)]
fn parse_partition<P: AsRef<Path>>(
    eng: &Engine,
    i: usize,
    paths: &[P],
    o: &LoadOptions,
    scan: &ScanResult,
    grid: &Partitioning,
    sinks: &SinkSet,
    na: &[&[u8]],
    maps: &[Option<HashMap<String, i32>>],
) -> Result<()> {
    let (r0, r1) = grid.part_rows(i);
    let prows = (r1 - r0) as usize;
    let p = o.schema.len();
    let mut bufs: Vec<Buf> = match sinks {
        SinkSet::One(b) => vec![Buf::alloc(b.dtype(), prows * p)],
        SinkSet::PerCol(bs) => bs.iter().map(|b| Buf::alloc(b.dtype(), prows)).collect(),
    };
    let c0 = scan
        .chunks
        .partition_point(|c| c.row0 + c.rows <= r0);
    for c in &scan.chunks[c0..] {
        if c.row0 >= r1 {
            break;
        }
        let bytes = read_chunk_verified(eng, &scan.stores[c.file], c)?;
        let mut grow = c.row0;
        let mut line = c.line0; // physical line within the file, 0-based
        let mut start = 0usize;
        while start < bytes.len() && grow < r1 {
            let end = bytes[start..]
                .iter()
                .position(|b| *b == b'\n')
                .map(|q| start + q)
                .unwrap_or(bytes.len());
            let mut rec = &bytes[start..end];
            if rec.last() == Some(&b'\r') {
                rec = &rec[..rec.len() - 1];
            }
            line += 1;
            start = end + 1;
            if rec.is_empty() {
                continue;
            }
            let row = grow;
            grow += 1;
            if row < r0 {
                continue;
            }
            let ri = (row - r0) as usize;
            let mut fields = rec.split(|b| *b == o.delim);
            for j in 0..p {
                let field = fields.next().ok_or_else(|| {
                    parse_err(paths, c.file, line, j as u64 + 1, format!("expected {p} fields"))
                })?;
                let cv = parse_field(field, &o.schema.cols[j], na, maps[j].as_ref())
                    .map_err(|m| parse_err(paths, c.file, line, j as u64 + 1, m))?;
                match sinks {
                    SinkSet::One(b) => bufs[0].set(j * prows + ri, cell_scalar(cv, b.dtype())),
                    SinkSet::PerCol(bs) => bufs[j].set(ri, cell_scalar(cv, bs[j].dtype())),
                }
            }
        }
    }
    match sinks {
        SinkSet::One(b) => b.write_partition_buf(i, &bufs[0])?,
        SinkSet::PerCol(bs) => {
            for (j, b) in bs.iter().enumerate() {
                b.write_partition_buf(i, &bufs[j])?;
            }
        }
    }
    Ok(())
}

fn parse_phase<P: AsRef<Path>>(
    eng: &Arc<Engine>,
    paths: &[P],
    o: &LoadOptions,
    scan: &ScanResult,
    grid: &Partitioning,
    sinks: &SinkSet,
) -> Result<()> {
    let na: Vec<&[u8]> = o.na_values.iter().map(|s| s.as_bytes()).collect();
    // factor code maps: level -> 1-based rank in the sorted level table
    let maps: Vec<Option<HashMap<String, i32>>> = scan
        .levels
        .iter()
        .map(|ls| {
            ls.as_ref().map(|ls| {
                ls.iter()
                    .enumerate()
                    .map(|(i, l)| (l.clone(), i as i32 + 1))
                    .collect()
            })
        })
        .collect();
    let n_parts = grid.n_parts();
    let next = AtomicUsize::new(0);
    let workers = ingest_worker_count(eng).min(n_parts.max(1));
    // keep the error of the smallest partition index: claims are issued
    // in ascending order, so this is deterministic under any schedule
    let err: Mutex<Option<(usize, FmError)>> = Mutex::new(None);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n_parts {
                    break;
                }
                if let Err(e) = parse_partition(eng, i, paths, o, scan, grid, sinks, &na, &maps)
                {
                    let mut g = err.lock_recover();
                    if g.as_ref().map_or(true, |(pi, _)| i < *pi) {
                        *g = Some((i, e));
                    }
                    break;
                }
            });
        }
    });
    if let Some((_, e)) = err.into_inner_recover() {
        return Err(e);
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// public loaders

fn effective_storage(eng: &Engine, o: &LoadOptions) -> StorageKind {
    match o.in_mem {
        Some(true) => StorageKind::InMem,
        Some(false) => StorageKind::External,
        None => eng.config.storage.clone(),
    }
}

pub(crate) fn make_builder(
    eng: &Arc<Engine>,
    dtype: DType,
    parts: Partitioning,
    storage: &StorageKind,
    name: Option<&str>,
) -> Result<DenseBuilder> {
    match storage {
        StorageKind::InMem => DenseBuilder::new_mem(dtype, parts, &eng.pool),
        StorageKind::External => DenseBuilder::new_ext(
            dtype,
            parts,
            &eng.config.data_dir,
            name,
            eng.config.em_cache_cols as u64,
            Arc::clone(&eng.ssd),
            Arc::clone(&eng.metrics),
            // loaded datasets are the repeatedly-scanned inputs of EM
            // algorithms: cache-resident, like generated ones (§III-B3)
            eng.cache.clone(),
        ),
    }
}

fn col_metas(o: &LoadOptions, scan: &ScanResult) -> Vec<DenseColMeta> {
    o.schema
        .cols
        .iter()
        .zip(&scan.levels)
        .map(|(c, ls)| DenseColMeta {
            code: c.code(),
            levels: ls.as_ref().map(|l| l.as_ref().clone()).unwrap_or_default(),
        })
        .collect()
}

/// Load delimited files into **one dense matrix**, rows concatenated in
/// `paths` order. Storage dtype is [`Schema::uniform_dtype`] (f64 when
/// any `F` column is present, else i32); factor/hashed columns load as
/// their integer codes. With external storage and a
/// [`LoadOptions::name`], the matrix and a sidecar manifest (schema
/// codes + factor levels) persist across runs.
pub fn load_dense_matrix<P: AsRef<Path>>(
    eng: &Arc<Engine>,
    paths: &[P],
    opts: &LoadOptions,
) -> Result<FmMatrix> {
    let scan = scan_phase(eng, paths, opts)?;
    let storage = effective_storage(eng, opts);
    let dtype = opts.schema.uniform_dtype();
    let grid = Partitioning::new(scan.nrow, opts.schema.len() as u64);
    let b = make_builder(eng, dtype, grid.clone(), &storage, opts.name.as_deref())?;
    parse_phase(eng, paths, opts, &scan, &grid, &SinkSet::One(&b))?;
    let data = b.finish();
    if let (StorageKind::External, Some(nm)) = (&storage, opts.name.as_deref()) {
        data.save_named_meta(&eng.config.data_dir, nm, &col_metas(opts, &scan))?;
    }
    Ok(FmMatrix {
        eng: Arc::clone(eng),
        m: Matrix::from_dense(data),
    })
}

/// Load delimited files into **one vector per column** — FlashR's
/// `fm.load.list.vecs`. Every vector shares one n×1 row grid, so the
/// text is parsed once per partition and scattered to all column
/// builders; each column stores at its own dtype ([`ColType::dtype`]).
/// `X` columns come back with their sorted level tables attached
/// ([`FmVector::levels`]). A [`LoadOptions::name`] persists column `j`
/// as `<name>.c<j>` (external storage).
pub fn load_list_vecs<P: AsRef<Path>>(
    eng: &Arc<Engine>,
    paths: &[P],
    opts: &LoadOptions,
) -> Result<Vec<FmVector>> {
    let scan = scan_phase(eng, paths, opts)?;
    let storage = effective_storage(eng, opts);
    let grid = Partitioning::new(scan.nrow, 1);
    let names: Vec<Option<String>> = (0..opts.schema.len())
        .map(|j| opts.name.as_ref().map(|n| format!("{n}.c{j}")))
        .collect();
    let bs: Vec<DenseBuilder> = opts
        .schema
        .cols
        .iter()
        .zip(&names)
        .map(|(c, nm)| make_builder(eng, c.dtype(), grid.clone(), &storage, nm.as_deref()))
        .collect::<Result<_>>()?;
    parse_phase(eng, paths, opts, &scan, &grid, &SinkSet::PerCol(&bs))?;
    let mut out = Vec::with_capacity(bs.len());
    for (j, b) in bs.into_iter().enumerate() {
        let data = b.finish();
        if let (StorageKind::External, Some(nm)) = (&storage, names[j].as_deref()) {
            let cm = DenseColMeta {
                code: opts.schema.cols[j].code(),
                levels: scan.levels[j]
                    .as_ref()
                    .map(|l| l.as_ref().clone())
                    .unwrap_or_default(),
            };
            data.save_named_meta(&eng.config.data_dir, nm, std::slice::from_ref(&cm))?;
        }
        out.push(FmVector {
            v: FmMatrix {
                eng: Arc::clone(eng),
                m: Matrix::from_dense(data),
            },
            levels: scan.levels[j].clone(),
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;
    use crate::testutil::TempDir;

    fn eng() -> Arc<Engine> {
        Engine::new(EngineConfig {
            xla_dispatch: false,
            chunk_bytes: 1 << 22,
            target_part_bytes: 1 << 20,
            ..Default::default()
        })
        .unwrap()
    }

    fn write_file(dir: &TempDir, name: &str, text: &[u8]) -> std::path::PathBuf {
        let p = dir.path().join(name);
        std::fs::write(&p, text).unwrap();
        p
    }

    #[test]
    fn schema_codes_roundtrip() {
        let s = Schema::parse("IFHX").unwrap();
        assert_eq!(
            s.cols,
            vec![
                ColType::Int,
                ColType::Float,
                ColType::Hashed {
                    buckets: DEFAULT_HASH_BUCKETS
                },
                ColType::Factor
            ]
        );
        let codes: String = s.cols.iter().map(|c| c.code()).collect();
        assert_eq!(codes, "IFHX");
        assert_eq!(s.uniform_dtype(), DType::F64);
        assert_eq!(Schema::parse("IIH").unwrap().uniform_dtype(), DType::I32);
        assert!(Schema::parse("IQ").is_err());
        assert!(Schema::parse("").unwrap().validate().is_err());
        assert!(Schema::of(vec![ColType::Hashed { buckets: 0 }])
            .validate()
            .is_err());
    }

    #[test]
    fn field_parse_semantics() {
        let na: Vec<&[u8]> = vec![b"", b"NA"];
        let to_i = |r: std::result::Result<CellVal, String>| match r.unwrap() {
            CellVal::I(v) => v,
            _ => panic!("want int"),
        };
        assert_eq!(to_i(parse_field(b" 42 ", &ColType::Int, &na, None)), 42);
        assert!(matches!(
            parse_field(b"NA", &ColType::Int, &na, None).unwrap(),
            CellVal::Na
        ));
        assert!(matches!(
            parse_field(b"", &ColType::Float, &na, None).unwrap(),
            CellVal::Na
        ));
        // the NA sentinel itself is rejected, not silently read as NA
        assert!(parse_field(b"-2147483648", &ColType::Int, &na, None).is_err());
        assert!(parse_field(b"2147483648", &ColType::Int, &na, None).is_err());
        assert!(parse_field(b"4x", &ColType::Int, &na, None).is_err());
        assert!(parse_field(b"1.5.2", &ColType::Float, &na, None).is_err());
        // hashing is deterministic, bucketed, 1-based
        let h = |b: &[u8]| {
            to_i(parse_field(
                b,
                &ColType::Hashed { buckets: 100 },
                &na,
                None,
            ))
        };
        assert_eq!(h(b"abc"), h(b" abc "));
        assert!(h(b"abc") >= 1 && h(b"abc") <= 100);
        // factor lookup against the scanned vocabulary
        let m: HashMap<String, i32> = [("a".to_string(), 1), ("b".to_string(), 2)]
            .into_iter()
            .collect();
        assert_eq!(to_i(parse_field(b"b", &ColType::Factor, &na, Some(&m))), 2);
        assert!(parse_field(b"zz", &ColType::Factor, &na, Some(&m)).is_err());
    }

    #[test]
    fn chunk_bounds_are_newline_aligned() {
        let tmp = TempDir::new("ingest-bounds");
        let e = eng();
        // 40 rows of "rowNN\n" (6 bytes each)
        let text: String = (0..40).map(|i| format!("row{i:02}\n")).collect();
        let p = write_file(&tmp, "t.csv", text.as_bytes());
        let store =
            FileStore::open(&p, Arc::clone(&e.ssd), Arc::clone(&e.metrics)).unwrap();
        for cb in [1usize, 7, 16, 64, 10_000] {
            let bounds = chunk_bounds(&store, cb).unwrap();
            let total: usize = bounds.iter().map(|(_, l)| l).sum();
            assert_eq!(total, text.len(), "chunk_bytes={cb}");
            for (off, len) in &bounds {
                assert!(*len > 0);
                // every chunk starts at 0 or right after a newline
                if *off > 0 {
                    assert_eq!(text.as_bytes()[*off as usize - 1], b'\n');
                }
                let _ = len;
            }
        }
    }

    #[test]
    fn loads_typed_matrix_with_na_and_crlf() {
        let tmp = TempDir::new("ingest-typed");
        let e = eng();
        let p = write_file(
            &tmp,
            "t.csv",
            b"1,1.5\r\n2,NA\r\n\r\nNA,-3.25\r\n4,0\r\n",
        );
        let before = e.metrics.snapshot();
        let x = load_dense_matrix(
            &e,
            &[&p],
            &LoadOptions::new(Schema::parse("IF").unwrap()),
        )
        .unwrap();
        assert_eq!((x.nrow(), x.ncol()), (4, 2));
        let h = x.to_host().unwrap();
        // any F column promotes the whole matrix to f64; int NA reads NaN
        assert_eq!(h.get(0, 0).as_f64(), 1.0);
        assert_eq!(h.get(0, 1).as_f64(), 1.5);
        assert!(h.get(1, 1).as_f64().is_nan());
        assert!(h.get(2, 0).as_f64().is_nan());
        assert_eq!(h.get(2, 1).as_f64(), -3.25);
        assert_eq!(h.get(3, 0).as_f64(), 4.0);
        let d = e.metrics.snapshot().delta_since(&before);
        assert_eq!(d.ingest_rows, 4);
        assert!(d.ingest_chunks >= 1);
        assert_eq!(d.ingest_na_cells, 2);
    }

    #[test]
    fn int_only_schema_stores_i32_with_sentinel_na() {
        let tmp = TempDir::new("ingest-int");
        let e = eng();
        let p = write_file(&tmp, "t.csv", b"7,1\nNA,2\n-5,3\n");
        let x = load_dense_matrix(
            &e,
            &[&p],
            &LoadOptions::new(Schema::parse("II").unwrap()),
        )
        .unwrap();
        assert_eq!(x.dtype(), DType::I32);
        let h = x.to_host().unwrap();
        assert_eq!(h.get(0, 0), Scalar::I32(7));
        assert_eq!(h.get(1, 0), Scalar::I32(i32::MIN));
        assert_eq!(h.get(2, 0), Scalar::I32(-5));
        assert_eq!(h.get(2, 1), Scalar::I32(3));
    }

    #[test]
    fn list_vecs_factor_levels_sorted_and_coded() {
        let tmp = TempDir::new("ingest-vecs");
        let e = eng();
        let p = write_file(&tmp, "t.tsv", b"1\tcherry\n2\tapple\n3\tNA\n4\tbanana\n5\tapple\n");
        let vecs = load_list_vecs(
            &e,
            &[&p],
            &LoadOptions::new(Schema::parse("IX").unwrap()).delim(b'\t'),
        )
        .unwrap();
        assert_eq!(vecs.len(), 2);
        assert!(vecs[0].levels.is_none());
        let f = &vecs[1];
        assert_eq!(
            f.levels.as_ref().unwrap().as_ref().clone(),
            vec!["apple".to_string(), "banana".to_string(), "cherry".to_string()]
        );
        let h = f.v.to_host().unwrap();
        assert_eq!(h.get(0, 0), Scalar::I32(3)); // cherry
        assert_eq!(h.get(1, 0), Scalar::I32(1)); // apple
        assert_eq!(h.get(2, 0), Scalar::I32(i32::MIN)); // NA
        assert_eq!(h.get(3, 0), Scalar::I32(2)); // banana
        assert_eq!(h.get(4, 0), Scalar::I32(1)); // apple
    }

    #[test]
    fn multi_file_rows_concatenate_in_path_order() {
        let tmp = TempDir::new("ingest-multi");
        let e = eng();
        let a = write_file(&tmp, "a.csv", b"1\n2\n");
        let b = write_file(&tmp, "b.csv", b"3\n");
        let c = write_file(&tmp, "c.csv", b"4\n5\n6\n");
        let x = load_dense_matrix(
            &e,
            &[&a, &b, &c],
            &LoadOptions::new(Schema::parse("I").unwrap()),
        )
        .unwrap();
        let h = x.to_host().unwrap();
        assert_eq!(x.nrow(), 6);
        for r in 0..6 {
            assert_eq!(h.get(r, 0), Scalar::I32(r as i32 + 1));
        }
    }

    #[test]
    fn ragged_and_malformed_rows_carry_location() {
        let tmp = TempDir::new("ingest-err");
        let e = eng();
        let o = LoadOptions::new(Schema::parse("IF").unwrap());

        // ragged row (line 3): 3 fields for a 2-column schema
        let p = write_file(&tmp, "ragged.csv", b"1,1.0\n2,2.0\n3,3.0,9\n4,4.0\n");
        match load_dense_matrix(&e, &[&p], &o) {
            Err(FmError::Parse { file, line, col, .. }) => {
                assert!(file.ends_with("ragged.csv"));
                assert_eq!((line, col), (3, 3));
            }
            other => panic!("want Parse error, got {other:?}"),
        }

        // trailing delimiter reads as an extra empty field
        let p = write_file(&tmp, "trail.csv", b"1,1.0\n2,2.0,\n");
        match load_dense_matrix(&e, &[&p], &o) {
            Err(FmError::Parse { line, col, .. }) => assert_eq!((line, col), (2, 3)),
            other => panic!("want Parse error, got {other:?}"),
        }

        // malformed float (line 2, col 2) surfaces from the parse phase
        let p = write_file(&tmp, "badnum.csv", b"1,1.0\n2,oops\n");
        match load_dense_matrix(&e, &[&p], &o) {
            Err(FmError::Parse { line, col, msg, .. }) => {
                assert_eq!((line, col), (2, 2));
                assert!(msg.contains("oops"));
            }
            other => panic!("want Parse error, got {other:?}"),
        }

        // non-UTF8 bytes in a numeric field
        let p = write_file(&tmp, "bin.csv", b"1,1.0\n2,\xff\xfe\n");
        match load_dense_matrix(&e, &[&p], &o) {
            Err(FmError::Parse { line, col, msg, .. }) => {
                assert_eq!((line, col), (2, 2));
                assert!(msg.contains("UTF-8"));
            }
            other => panic!("want Parse error, got {other:?}"),
        }

        // non-UTF8 bytes in a factor field are caught in the scan phase
        let p = write_file(&tmp, "binx.csv", b"a\n\xff\xfe\n");
        match load_dense_matrix(&e, &[&p], &LoadOptions::new(Schema::parse("X").unwrap())) {
            Err(FmError::Parse { line, col, .. }) => assert_eq!((line, col), (2, 1)),
            other => panic!("want Parse error, got {other:?}"),
        }

        // empty input
        let p = write_file(&tmp, "empty.csv", b"\n\n");
        assert!(matches!(
            load_dense_matrix(&e, &[&p], &o),
            Err(FmError::Shape(_))
        ));
    }

    #[test]
    fn tiny_chunks_match_one_big_chunk_bitwise() {
        let tmp = TempDir::new("ingest-chunks");
        let text: String = (0..500)
            .map(|i| format!("{},{}.25,k{}\n", i, i * 2, i % 7))
            .collect();
        let o = LoadOptions::new(Schema::parse("IFX").unwrap());

        let one = {
            let e = eng();
            let p = write_file(&tmp, "one.csv", text.as_bytes());
            load_dense_matrix(&e, &[&p], &o).unwrap().to_host().unwrap()
        };
        let tiny = {
            let e = Engine::new(EngineConfig {
                xla_dispatch: false,
                chunk_bytes: 1 << 22,
                target_part_bytes: 1 << 20,
                ingest_chunk_bytes: 64, // dozens of chunks
                ingest_workers: 3,
                ..Default::default()
            })
            .unwrap();
            let p = write_file(&tmp, "tiny.csv", text.as_bytes());
            load_dense_matrix(&e, &[&p], &o).unwrap().to_host().unwrap()
        };
        assert_eq!(one, tiny);
    }
}
