//! Engine metrics: I/O bytes, allocation behaviour, memory high-water mark.
//!
//! The paper's Fig 6(b) (memory consumption) and the §IV-D ablations are
//! measured through these counters, so they live in the engine rather than
//! in the bench harness.

use std::sync::atomic::{AtomicU64, Ordering};

/// Monotonic counters + a tracked memory high-water mark.
#[derive(Default, Debug)]
pub struct Metrics {
    /// Bytes read from the external store.
    pub io_read_bytes: AtomicU64,
    /// Bytes written to the external store.
    pub io_write_bytes: AtomicU64,
    /// Read requests issued to the external store.
    pub io_read_reqs: AtomicU64,
    /// Memory chunks served by fresh OS allocation.
    pub chunks_allocated: AtomicU64,
    /// Memory chunks served from the recycle pool.
    pub chunks_recycled: AtomicU64,
    /// Bytes currently held in live chunks (pool outstanding).
    pub mem_in_use: AtomicU64,
    /// High-water mark of `mem_in_use` (the Fig 6(b) number).
    pub mem_peak: AtomicU64,
    /// Partitions whose step was dispatched to an AOT XLA artifact.
    pub xla_dispatches: AtomicU64,
    /// Partitions computed through the native GenOp path.
    pub native_partitions: AtomicU64,
    /// Matrix-cache hits / misses (EM cached matrices).
    pub cache_hits: AtomicU64,
    pub cache_misses: AtomicU64,
    /// Partitions evicted from the matrix cache under capacity pressure.
    pub cache_evictions: AtomicU64,
    /// Capacity evictions where the victim partition belonged to a
    /// *different* tenant than the inserter (multi-tenant fair-share
    /// isolation signal; charged to the victim session's metrics).
    pub cache_cross_evictions: AtomicU64,
    /// Async partition read-aheads queued to the prefetch thread.
    pub prefetch_issued: AtomicU64,
    /// Reads that coalesced onto an in-flight read of the same partition
    /// (the cache's single-flight registry) instead of re-reading the file.
    pub singleflight_coalesced: AtomicU64,
    /// Ranges stolen by pass workers that ran out of their own range.
    pub sched_steals: AtomicU64,
    /// Steals that crossed a simulated NUMA node boundary.
    pub sched_steals_remote: AtomicU64,
    /// Strip-register buffers created fresh by the evaluator (pooled
    /// acquisitions that missed the free list plus kernel-allocated
    /// outputs). The strip-level half of Fig 11's "mem-alloc".
    pub buf_allocs: AtomicU64,
    /// Strip-register buffers served from a worker's strip pool instead
    /// of the allocator (liveness-driven register recycling).
    pub buf_reuses: AtomicU64,
    /// Instructions executed in place on their dead input register's
    /// buffer (no output allocation at all).
    pub inplace_ops: AtomicU64,
    /// Total VUDF steps folded into peephole-fused strip chains, counted
    /// once per compiled pass (a 3-step chain adds 3 per pass).
    pub fused_chain_len: AtomicU64,
    /// Strips evaluated through the streaming SpMM kernel (sparse
    /// row-partitions × small dense right operand).
    pub spmm_strips: AtomicU64,
    /// Sparse entries streamed through SpMM (the workload's nnz per pass
    /// — the sparse analogue of Table IV's I/O accounting).
    pub spmm_nnz: AtomicU64,
    /// Strips whose evaluation ran at least one explicit SIMD lane kernel
    /// or register-blocked GEMM panel (`EngineConfig::simd_kernels`).
    pub simd_strips: AtomicU64,
    /// Full f64x4 lane groups processed by the hand-unrolled elementwise
    /// and fused-chain kernels (tails excluded — 4 elements each).
    pub simd_lanes_f64: AtomicU64,
    /// Register-blocked panels executed by the `inner_prod_small` /
    /// `inner_wide_tall` GEMM microkernels.
    pub gemm_panels: AtomicU64,
    /// Target partitions handed to the asynchronous write-back writer
    /// instead of being written through synchronously (§III-B3 write
    /// path; [`crate::matrix::cache::PartitionCache`]).
    pub wb_enqueued: AtomicU64,
    /// Write-back enqueues that replaced a still-queued write of the same
    /// partition (one coalesced file write instead of two).
    pub wb_coalesced: AtomicU64,
    /// Times a caller blocked on the write-back pipeline: an enqueue that
    /// hit the bounded dirty capacity, or a pass-end flush barrier that
    /// found writes still in flight.
    pub wb_flush_waits: AtomicU64,
    /// Queued write-back partitions discarded by an aborted pass (dirty
    /// data that never reached the disk — by design).
    pub wb_discarded: AtomicU64,
    /// Streaming passes executed (every [`crate::exec::run_pass_opts`]
    /// call, planned or eager). The cross-pass optimizer's headline
    /// number: iterative loops run strictly fewer passes with it on.
    pub passes_run: AtomicU64,
    /// Structurally-equal DAG nodes the planner's hash-consing pass
    /// merged onto one canonical node (each hit is one whole redundant
    /// evaluation eliminated from a pass).
    pub opt_cse_hits: AtomicU64,
    /// Requested targets/sinks the planner pruned as dead because an
    /// identical request in the same batch already produces the result.
    pub opt_sinks_pruned: AtomicU64,
    /// Cost-model decisions to materialize a shared intermediate through
    /// the cache/write-back path (or to substitute an already
    /// materialized copy) instead of recomputing it in the fused pass.
    pub opt_mat_decisions: AtomicU64,
    /// Batches whose optimized pass grouping was served from the
    /// per-engine plan cache (iteration 2..n of a loop).
    pub opt_plan_cache_hits: AtomicU64,
    /// Faults injected by the deterministic [`crate::storage::fault`]
    /// layer (EIO, short reads, torn writes, bit flips, latency spikes).
    /// Zero unless a fault plan is configured.
    pub faults_injected: AtomicU64,
    /// Positioned-I/O attempts retried after a transient error or a
    /// failed write read-back (the bounded retry-with-backoff loop in
    /// [`crate::storage::FileStore`]).
    pub io_retries: AtomicU64,
    /// Partition checksum verifications that failed (each triggers one
    /// re-read before surfacing [`crate::FmError::Corrupt`]).
    pub checksum_failures: AtomicU64,
    /// Newline-aligned text chunks scanned by the delimited-ingestion
    /// loader ([`crate::ingest`], phase 1).
    pub ingest_chunks: AtomicU64,
    /// Data rows parsed into matrices by the ingestion loader.
    pub ingest_rows: AtomicU64,
    /// Cells that matched an NA spelling during ingestion (stored as the
    /// dtype's NA sentinel: NaN for floats, `i32::MIN` for ints).
    pub ingest_na_cells: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_read(&self, bytes: u64) {
        self.io_read_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.io_read_reqs.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_write(&self, bytes: u64) {
        self.io_write_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Track a memory acquisition and maintain the peak.
    pub fn mem_acquire(&self, bytes: u64) {
        let now = self.mem_in_use.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.mem_peak.fetch_max(now, Ordering::Relaxed);
    }

    pub fn mem_release(&self, bytes: u64) {
        self.mem_in_use.fetch_sub(bytes, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            io_read_bytes: self.io_read_bytes.load(Ordering::Relaxed),
            io_write_bytes: self.io_write_bytes.load(Ordering::Relaxed),
            io_read_reqs: self.io_read_reqs.load(Ordering::Relaxed),
            chunks_allocated: self.chunks_allocated.load(Ordering::Relaxed),
            chunks_recycled: self.chunks_recycled.load(Ordering::Relaxed),
            mem_in_use: self.mem_in_use.load(Ordering::Relaxed),
            mem_peak: self.mem_peak.load(Ordering::Relaxed),
            xla_dispatches: self.xla_dispatches.load(Ordering::Relaxed),
            native_partitions: self.native_partitions.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            cache_evictions: self.cache_evictions.load(Ordering::Relaxed),
            cache_cross_evictions: self.cache_cross_evictions.load(Ordering::Relaxed),
            prefetch_issued: self.prefetch_issued.load(Ordering::Relaxed),
            singleflight_coalesced: self.singleflight_coalesced.load(Ordering::Relaxed),
            sched_steals: self.sched_steals.load(Ordering::Relaxed),
            sched_steals_remote: self.sched_steals_remote.load(Ordering::Relaxed),
            buf_allocs: self.buf_allocs.load(Ordering::Relaxed),
            buf_reuses: self.buf_reuses.load(Ordering::Relaxed),
            inplace_ops: self.inplace_ops.load(Ordering::Relaxed),
            fused_chain_len: self.fused_chain_len.load(Ordering::Relaxed),
            spmm_strips: self.spmm_strips.load(Ordering::Relaxed),
            spmm_nnz: self.spmm_nnz.load(Ordering::Relaxed),
            simd_strips: self.simd_strips.load(Ordering::Relaxed),
            simd_lanes_f64: self.simd_lanes_f64.load(Ordering::Relaxed),
            gemm_panels: self.gemm_panels.load(Ordering::Relaxed),
            wb_enqueued: self.wb_enqueued.load(Ordering::Relaxed),
            wb_coalesced: self.wb_coalesced.load(Ordering::Relaxed),
            wb_flush_waits: self.wb_flush_waits.load(Ordering::Relaxed),
            wb_discarded: self.wb_discarded.load(Ordering::Relaxed),
            passes_run: self.passes_run.load(Ordering::Relaxed),
            opt_cse_hits: self.opt_cse_hits.load(Ordering::Relaxed),
            opt_sinks_pruned: self.opt_sinks_pruned.load(Ordering::Relaxed),
            opt_mat_decisions: self.opt_mat_decisions.load(Ordering::Relaxed),
            opt_plan_cache_hits: self.opt_plan_cache_hits.load(Ordering::Relaxed),
            faults_injected: self.faults_injected.load(Ordering::Relaxed),
            io_retries: self.io_retries.load(Ordering::Relaxed),
            checksum_failures: self.checksum_failures.load(Ordering::Relaxed),
            ingest_chunks: self.ingest_chunks.load(Ordering::Relaxed),
            ingest_rows: self.ingest_rows.load(Ordering::Relaxed),
            ingest_na_cells: self.ingest_na_cells.load(Ordering::Relaxed),
        }
    }

    /// Reset every counter (between bench configurations).
    pub fn reset(&self) {
        let s = self;
        for c in [
            &s.io_read_bytes,
            &s.io_write_bytes,
            &s.io_read_reqs,
            &s.chunks_allocated,
            &s.chunks_recycled,
            &s.mem_in_use,
            &s.mem_peak,
            &s.xla_dispatches,
            &s.native_partitions,
            &s.cache_hits,
            &s.cache_misses,
            &s.cache_evictions,
            &s.cache_cross_evictions,
            &s.prefetch_issued,
            &s.singleflight_coalesced,
            &s.sched_steals,
            &s.sched_steals_remote,
            &s.buf_allocs,
            &s.buf_reuses,
            &s.inplace_ops,
            &s.fused_chain_len,
            &s.spmm_strips,
            &s.spmm_nnz,
            &s.simd_strips,
            &s.simd_lanes_f64,
            &s.gemm_panels,
            &s.wb_enqueued,
            &s.wb_coalesced,
            &s.wb_flush_waits,
            &s.wb_discarded,
            &s.passes_run,
            &s.opt_cse_hits,
            &s.opt_sinks_pruned,
            &s.opt_mat_decisions,
            &s.opt_plan_cache_hits,
            &s.faults_injected,
            &s.io_retries,
            &s.checksum_failures,
            &s.ingest_chunks,
            &s.ingest_rows,
            &s.ingest_na_cells,
        ] {
            c.store(0, Ordering::Relaxed);
        }
    }
}

/// Point-in-time copy of all counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct MetricsSnapshot {
    pub io_read_bytes: u64,
    pub io_write_bytes: u64,
    pub io_read_reqs: u64,
    pub chunks_allocated: u64,
    pub chunks_recycled: u64,
    pub mem_in_use: u64,
    pub mem_peak: u64,
    pub xla_dispatches: u64,
    pub native_partitions: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_evictions: u64,
    pub cache_cross_evictions: u64,
    pub prefetch_issued: u64,
    pub singleflight_coalesced: u64,
    pub sched_steals: u64,
    pub sched_steals_remote: u64,
    pub buf_allocs: u64,
    pub buf_reuses: u64,
    pub inplace_ops: u64,
    pub fused_chain_len: u64,
    pub spmm_strips: u64,
    pub spmm_nnz: u64,
    pub simd_strips: u64,
    pub simd_lanes_f64: u64,
    pub gemm_panels: u64,
    pub wb_enqueued: u64,
    pub wb_coalesced: u64,
    pub wb_flush_waits: u64,
    pub wb_discarded: u64,
    pub passes_run: u64,
    pub opt_cse_hits: u64,
    pub opt_sinks_pruned: u64,
    pub opt_mat_decisions: u64,
    pub opt_plan_cache_hits: u64,
    pub faults_injected: u64,
    pub io_retries: u64,
    pub checksum_failures: u64,
    pub ingest_chunks: u64,
    pub ingest_rows: u64,
    pub ingest_na_cells: u64,
}

impl MetricsSnapshot {
    /// Difference vs an earlier snapshot (for per-run accounting).
    pub fn delta_since(&self, earlier: &MetricsSnapshot) -> MetricsSnapshot {
        MetricsSnapshot {
            io_read_bytes: self.io_read_bytes - earlier.io_read_bytes,
            io_write_bytes: self.io_write_bytes - earlier.io_write_bytes,
            io_read_reqs: self.io_read_reqs - earlier.io_read_reqs,
            chunks_allocated: self.chunks_allocated - earlier.chunks_allocated,
            chunks_recycled: self.chunks_recycled - earlier.chunks_recycled,
            mem_in_use: self.mem_in_use,
            mem_peak: self.mem_peak,
            xla_dispatches: self.xla_dispatches - earlier.xla_dispatches,
            native_partitions: self.native_partitions - earlier.native_partitions,
            cache_hits: self.cache_hits - earlier.cache_hits,
            cache_misses: self.cache_misses - earlier.cache_misses,
            cache_evictions: self.cache_evictions - earlier.cache_evictions,
            cache_cross_evictions: self.cache_cross_evictions - earlier.cache_cross_evictions,
            prefetch_issued: self.prefetch_issued - earlier.prefetch_issued,
            singleflight_coalesced: self.singleflight_coalesced - earlier.singleflight_coalesced,
            sched_steals: self.sched_steals - earlier.sched_steals,
            sched_steals_remote: self.sched_steals_remote - earlier.sched_steals_remote,
            buf_allocs: self.buf_allocs - earlier.buf_allocs,
            buf_reuses: self.buf_reuses - earlier.buf_reuses,
            inplace_ops: self.inplace_ops - earlier.inplace_ops,
            fused_chain_len: self.fused_chain_len - earlier.fused_chain_len,
            spmm_strips: self.spmm_strips - earlier.spmm_strips,
            spmm_nnz: self.spmm_nnz - earlier.spmm_nnz,
            simd_strips: self.simd_strips - earlier.simd_strips,
            simd_lanes_f64: self.simd_lanes_f64 - earlier.simd_lanes_f64,
            gemm_panels: self.gemm_panels - earlier.gemm_panels,
            wb_enqueued: self.wb_enqueued - earlier.wb_enqueued,
            wb_coalesced: self.wb_coalesced - earlier.wb_coalesced,
            wb_flush_waits: self.wb_flush_waits - earlier.wb_flush_waits,
            wb_discarded: self.wb_discarded - earlier.wb_discarded,
            passes_run: self.passes_run - earlier.passes_run,
            opt_cse_hits: self.opt_cse_hits - earlier.opt_cse_hits,
            opt_sinks_pruned: self.opt_sinks_pruned - earlier.opt_sinks_pruned,
            opt_mat_decisions: self.opt_mat_decisions - earlier.opt_mat_decisions,
            opt_plan_cache_hits: self.opt_plan_cache_hits - earlier.opt_plan_cache_hits,
            faults_injected: self.faults_injected - earlier.faults_injected,
            io_retries: self.io_retries - earlier.io_retries,
            checksum_failures: self.checksum_failures - earlier.checksum_failures,
            ingest_chunks: self.ingest_chunks - earlier.ingest_chunks,
            ingest_rows: self.ingest_rows - earlier.ingest_rows,
            ingest_na_cells: self.ingest_na_cells - earlier.ingest_na_cells,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_tracks_high_water() {
        let m = Metrics::new();
        m.mem_acquire(100);
        m.mem_acquire(50);
        m.mem_release(120);
        m.mem_acquire(10);
        let s = m.snapshot();
        assert_eq!(s.mem_peak, 150);
        assert_eq!(s.mem_in_use, 40);
    }

    #[test]
    fn reset_zeroes() {
        let m = Metrics::new();
        m.add_read(10);
        m.reset();
        assert_eq!(m.snapshot().io_read_bytes, 0);
    }
}
