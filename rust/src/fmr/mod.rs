//! `fmr` — the R-like user API (paper §III-A, Tables I–III).
//!
//! [`FmMatrix`] mirrors the paper's R interface: constructors
//! (`fm.runif.matrix`, `fm.seq.int`, …), conversions (`fm.conv.R2FM` /
//! `FM2R`), the GenOps, and the reimplemented R-base matrix functions
//! (`rowSums`, `pmin`, `sqrt`, arithmetic operators, `%*%`, `t`, …).
//!
//! Semantics follow the paper:
//! * every operation is **lazy** (returns a virtual matrix) while
//!   `fuse_mem` is on; with it off (the eager / MLlib-like mode) each
//!   operation materializes immediately;
//! * **sinks** (`sum`, `colSums`, `fm.groupby.row`, wide×tall
//!   `fm.inner.prod`) always force a pass — batch them with
//!   [`engine::Engine::materialize_sinks`] / [`engine::Engine::run_pass`]
//!   to share one scan (the paper's `fm.materialize` on several sinks);
//! * matrices are immutable; dropping the last handle returns chunks to
//!   the pool (the paper's GC).

pub mod engine;
pub mod session;

use std::collections::{BTreeSet, HashMap};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex as StdMutex};

use crate::dag::{SinkResult, SinkSpec, UnFn};
use crate::dtype::{DType, Scalar};
use crate::error::{FmError, Result};
use crate::genops::{self, RowAggResult};
use crate::matrix::{DenseBuilder, HostMat, Matrix, MatrixData, Partitioning};
use crate::util::sync::LockExt;
use crate::vudf::{AggOp, BinOp, Buf, NaMode, UnOp};

pub use engine::Engine;
pub use session::Session;

/// A FlashMatrix matrix handle bound to an engine.
#[derive(Clone)]
pub struct FmMatrix {
    pub eng: Arc<Engine>,
    pub m: Matrix,
}

impl FmMatrix {
    fn wrap(eng: &Arc<Engine>, m: Matrix) -> FmMatrix {
        FmMatrix {
            eng: Arc::clone(eng),
            m,
        }
    }

    /// Apply the engine's laziness policy to a freshly recorded node:
    /// under `fuse_mem` the node stays virtual; in the eager mode it is
    /// materialized immediately (one pass per operation — the MLlib-like
    /// behaviour Fig 6/11 compare against). Eager per-op results are
    /// one-shot intermediates, so they are kept out of the write-through
    /// matrix cache (§III-B3 residency decision).
    fn policy(self) -> Result<FmMatrix> {
        if self.eng.config.fuse_mem || !self.m.is_virtual() {
            return Ok(self);
        }
        let transposed = self.m.transposed;
        let mats = self.eng.materialize_intermediate(&[self.m.canonical()])?;
        let mut m = mats.into_iter().next().unwrap();
        m.transposed = transposed;
        Ok(FmMatrix::wrap(&self.eng, m))
    }

    // -- shape / metadata ---------------------------------------------------

    pub fn nrow(&self) -> u64 {
        self.m.nrow()
    }

    pub fn ncol(&self) -> u64 {
        self.m.ncol()
    }

    pub fn dtype(&self) -> DType {
        self.m.dtype()
    }

    pub fn is_virtual(&self) -> bool {
        self.m.is_virtual()
    }

    /// `t(A)` — zero-copy transpose.
    pub fn t(&self) -> FmMatrix {
        FmMatrix::wrap(&self.eng, self.m.t())
    }

    // -- constructors (Table II) --------------------------------------------
    //
    // The canonical constructor surface is [`EngineExt`]:
    // `eng.fill(...)`, `eng.seq_int(...)`, `eng.runif_matrix(...)`.
    // The old free-standing forms below survive as thin deprecated
    // shims (see ARCHITECTURE.md for the old→new mapping).

    /// Deprecated shim — use [`EngineExt::rep_int`]: `eng.rep_int(...)`.
    #[deprecated(note = "use EngineExt: eng.rep_int(value, n)")]
    pub fn rep_int(eng: &Arc<Engine>, value: Scalar, n: u64) -> FmMatrix {
        eng.rep_int(value, n)
    }

    /// Deprecated shim — use [`EngineExt::fill`]: `eng.fill(...)`.
    #[deprecated(note = "use EngineExt: eng.fill(value, nrow, ncol)")]
    pub fn fill(eng: &Arc<Engine>, value: Scalar, nrow: u64, ncol: u64) -> FmMatrix {
        eng.fill(value, nrow, ncol)
    }

    /// Deprecated shim — use [`EngineExt::seq_int`]: `eng.seq_int(...)`.
    #[deprecated(note = "use EngineExt: eng.seq_int(start, by, n)")]
    pub fn seq_int(eng: &Arc<Engine>, start: f64, by: f64, n: u64) -> FmMatrix {
        eng.seq_int(start, by, n)
    }

    /// Deprecated shim — use [`EngineExt::runif_matrix`].
    #[deprecated(note = "use EngineExt: eng.runif_matrix(nrow, ncol, lo, hi, seed)")]
    pub fn runif_matrix(
        eng: &Arc<Engine>,
        nrow: u64,
        ncol: u64,
        lo: f64,
        hi: f64,
        seed: u64,
    ) -> FmMatrix {
        eng.runif_matrix(nrow, ncol, lo, hi, seed)
    }

    /// Deprecated shim — use [`EngineExt::rnorm_matrix`].
    #[deprecated(note = "use EngineExt: eng.rnorm_matrix(nrow, ncol, mean, sd, seed)")]
    pub fn rnorm_matrix(
        eng: &Arc<Engine>,
        nrow: u64,
        ncol: u64,
        mean: f64,
        sd: f64,
        seed: u64,
    ) -> FmMatrix {
        eng.rnorm_matrix(nrow, ncol, mean, sd, seed)
    }

    /// `fm.conv.R2FM` — import a small host matrix as a dense FM matrix.
    /// (Also available engine-anchored as [`EngineExt::from_host`].)
    pub fn from_host(eng: &Arc<Engine>, h: &HostMat) -> Result<FmMatrix> {
        let parts = Partitioning::new(h.nrow as u64, h.ncol as u64);
        let b = DenseBuilder::new_mem(h.buf.dtype(), parts.clone(), &eng.pool)?;
        for i in 0..parts.n_parts() {
            let (r0, r1) = parts.part_rows(i);
            let prows = (r1 - r0) as usize;
            let mut buf = Buf::alloc(h.buf.dtype(), prows * h.ncol);
            for j in 0..h.ncol {
                let col = h.buf.slice(j * h.nrow + r0 as usize, prows);
                buf.copy_from(j * prows, &col);
            }
            b.write_partition_buf(i, &buf)?;
        }
        Ok(FmMatrix::wrap(eng, Matrix::from_dense(b.finish())))
    }

    /// `fm.conv.FM2R` — export to a host matrix (materializes first).
    /// View-aware: a transposed handle exports transposed.
    pub fn to_host(&self) -> Result<HostMat> {
        let dense = self.materialize()?;
        let d = match &*dense.m.data {
            MatrixData::Dense(d) => d,
            _ => return Err(FmError::Shape("materialize returned non-dense".into())),
        };
        let h = HostMat::new(
            d.nrow() as usize,
            d.ncol() as usize,
            d.to_buf()?,
        )?;
        Ok(if self.m.transposed { h.transposed() } else { h })
    }

    /// `fm.materialize` — force materialization (no-op for dense).
    pub fn materialize(&self) -> Result<FmMatrix> {
        if !self.m.is_virtual() {
            return Ok(self.clone());
        }
        let transposed = self.m.transposed;
        let mats = self.eng.materialize(&[self.m.canonical()])?;
        let mut m = mats.into_iter().next().unwrap();
        m.transposed = transposed;
        Ok(FmMatrix::wrap(&self.eng, m))
    }

    // -- GenOps (Table I) ----------------------------------------------------

    /// `fm.sapply(A, f)` with a built-in op.
    pub fn sapply(&self, op: UnOp) -> Result<FmMatrix> {
        FmMatrix::wrap(&self.eng, genops::sapply(&self.m, UnFn::Builtin(op))).policy()
    }

    /// `fm.sapply(A, f)` with a registered custom VUDF.
    pub fn sapply_custom(&self, name: &str) -> Result<FmMatrix> {
        let f = self
            .eng
            .registry
            .lookup(name)
            .ok_or_else(|| FmError::Unsupported(format!("no VUDF named '{name}'")))?;
        FmMatrix::wrap(&self.eng, genops::sapply(&self.m, UnFn::Custom(f))).policy()
    }

    /// `fm.mapply(A, B, f)`.
    pub fn mapply(&self, other: &FmMatrix, op: BinOp) -> Result<FmMatrix> {
        FmMatrix::wrap(&self.eng, genops::mapply(&self.m, &other.m, op)?).policy()
    }

    /// `fm.mapply` with a scalar operand (`A op s` / `s op A`).
    pub fn mapply_scalar(&self, s: Scalar, op: BinOp, scalar_right: bool) -> Result<FmMatrix> {
        FmMatrix::wrap(&self.eng, genops::mapply_scalar(&self.m, s, op, scalar_right)).policy()
    }

    /// `fm.mapply.row(A, w, f)`.
    pub fn mapply_row(&self, w: &HostMat, op: BinOp) -> Result<FmMatrix> {
        FmMatrix::wrap(&self.eng, genops::mapply_row(&self.m, w, op)?).policy()
    }

    /// `fm.mapply.col(A, v, f)`.
    pub fn mapply_col(&self, v: &FmMatrix, op: BinOp) -> Result<FmMatrix> {
        FmMatrix::wrap(&self.eng, genops::mapply_col(&self.m, &v.m, op)?).policy()
    }

    /// `fm.agg(A, f)` — whole-matrix aggregate.
    pub fn agg(&self, op: AggOp) -> Result<Scalar> {
        self.agg_na(op, NaMode::Off)
    }

    /// `fm.agg(A, f, na.rm=)` — NA-aware whole-matrix aggregate.
    /// [`NaMode::Remove`] mirrors R's `na.rm=TRUE` (skip NA cells);
    /// [`NaMode::Propagate`] mirrors `na.rm=FALSE` (any NA poisons the
    /// result). NA is NaN for float dtypes and `i32::MIN`/`i64::MIN`
    /// (R's `NA_integer_`) for integer dtypes.
    pub fn agg_na(&self, op: AggOp, na: NaMode) -> Result<Scalar> {
        let r = self
            .eng
            .materialize_sinks(&[genops::agg_full_na(&self.m, op, na)])?;
        Ok(r.into_iter().next().unwrap().scalar())
    }

    /// Deferred `fm.agg` sink (for batched one-pass materialization).
    pub fn agg_sink(&self, op: AggOp) -> SinkSpec {
        genops::agg_full(&self.m, op)
    }

    /// `fm.agg.row(A, f)` — per-row aggregate (n×1; stays lazy on tall
    /// matrices).
    pub fn agg_row(&self, op: AggOp) -> Result<FmMatrix> {
        self.agg_row_na(op, NaMode::Off)
    }

    /// NA-aware `fm.agg.row` (see [`FmMatrix::agg_na`]).
    pub fn agg_row_na(&self, op: AggOp, na: NaMode) -> Result<FmMatrix> {
        match genops::agg_row_na(&self.m, op, na) {
            RowAggResult::InDag(v) => FmMatrix::wrap(&self.eng, v).policy(),
            RowAggResult::Sink(s) => {
                let r = self.eng.materialize_sinks(&[s])?;
                let h = match r.into_iter().next().unwrap() {
                    SinkResult::Mat(h) => h,
                    _ => unreachable!(),
                };
                FmMatrix::from_host(&self.eng, &HostMat {
                    nrow: h.ncol,
                    ncol: 1,
                    buf: h.buf,
                })
            }
        }
    }

    /// `fm.agg.col(A, f)` — per-column aggregate as a small host matrix.
    pub fn agg_col(&self, op: AggOp) -> Result<HostMat> {
        self.agg_col_na(op, NaMode::Off)
    }

    /// NA-aware `fm.agg.col` (see [`FmMatrix::agg_na`]).
    pub fn agg_col_na(&self, op: AggOp, na: NaMode) -> Result<HostMat> {
        match genops::agg_col_na(&self.m, op, na) {
            RowAggResult::Sink(s) => {
                let r = self.eng.materialize_sinks(&[s])?;
                match r.into_iter().next().unwrap() {
                    SinkResult::Mat(h) => Ok(h),
                    _ => unreachable!(),
                }
            }
            RowAggResult::InDag(v) => {
                // wide view: per-column of the view = per-row in-DAG
                FmMatrix::wrap(&self.eng, v).to_host()
            }
        }
    }

    /// Deferred `fm.agg.col` sink.
    pub fn agg_col_sink(&self, op: AggOp) -> Result<SinkSpec> {
        match genops::agg_col(&self.m, op) {
            RowAggResult::Sink(s) => Ok(s),
            RowAggResult::InDag(_) => Err(FmError::Unsupported(
                "agg.col on a wide view is not a sink; call agg_col".into(),
            )),
        }
    }

    /// `which.min` / `which.max` per row (1-based indices, i32).
    pub fn which_min_row(&self) -> Result<FmMatrix> {
        FmMatrix::wrap(&self.eng, genops::which_extreme_row(&self.m, false)?).policy()
    }

    pub fn which_max_row(&self) -> Result<FmMatrix> {
        FmMatrix::wrap(&self.eng, genops::which_extreme_row(&self.m, true)?).policy()
    }

    /// `fm.groupby.row(A, labels, f)` — labels in `0..k`.
    pub fn groupby_row(&self, labels: &FmMatrix, k: usize, op: AggOp) -> Result<HostMat> {
        let s = genops::groupby_row(&self.m, &labels.m, k, op)?;
        let r = self.eng.materialize_sinks(&[s])?;
        match r.into_iter().next().unwrap() {
            SinkResult::Mat(h) => Ok(h),
            _ => unreachable!(),
        }
    }

    /// Deferred groupby sink.
    pub fn groupby_row_sink(&self, labels: &FmMatrix, k: usize, op: AggOp) -> Result<SinkSpec> {
        genops::groupby_row(&self.m, &labels.m, k, op)
    }

    /// `fm.inner.prod(A, B, f1, f2)` with a small host right operand
    /// (stays lazy: output shares the long dimension).
    pub fn inner_prod_small(&self, b: &HostMat, f1: BinOp, f2: AggOp) -> Result<FmMatrix> {
        FmMatrix::wrap(&self.eng, genops::inner_small(&self.m, b, f1, f2)?).policy()
    }

    /// `fm.inner.prod(t(A), B, f1, f2)` — wide × tall sink (e.g. Gramian).
    pub fn inner_prod_wide_tall(
        &self,
        right: &FmMatrix,
        f1: BinOp,
        f2: AggOp,
    ) -> Result<HostMat> {
        let s = genops::inner_wide_tall(&self.m, &right.m, f1, f2)?;
        let r = self.eng.materialize_sinks(&[s])?;
        match r.into_iter().next().unwrap() {
            SinkResult::Mat(h) => Ok(h),
            _ => unreachable!(),
        }
    }

    /// Deferred wide×tall inner-product sink.
    pub fn inner_prod_wide_tall_sink(
        &self,
        right: &FmMatrix,
        f1: BinOp,
        f2: AggOp,
    ) -> Result<SinkSpec> {
        genops::inner_wide_tall(&self.m, &right.m, f1, f2)
    }

    /// `%*%` — matrix multiplication: tall × small host matrix.
    pub fn matmul_small(&self, b: &HostMat) -> Result<FmMatrix> {
        self.inner_prod_small(b, BinOp::Mul, AggOp::Sum)
    }

    /// `fm.multiply(A, B)` with a sparse left operand: stream the CSR
    /// row-partitions of `A` (n×m) against the small in-memory dense
    /// matrix `B` (m×q) -> tall dense n×q (lazy). The sparse matrix is
    /// scheduled, cached and prefetched like any dense pass source; the
    /// result composes with every other GenOp (the PageRank iteration
    /// fuses SpMM + scale + shift + convergence sink into one pass).
    pub fn spmm(&self, b: HostMat) -> Result<FmMatrix> {
        FmMatrix::wrap(&self.eng, genops::spmm(&self.m, b)?).policy()
    }

    /// Whether this handle wraps a sparse (CSR) matrix.
    pub fn is_sparse(&self) -> bool {
        self.m.is_sparse()
    }

    /// Stored entries of a sparse matrix (`None` for dense/virtual).
    pub fn nnz(&self) -> Option<u64> {
        match &*self.m.data {
            MatrixData::Sparse(s) => Some(s.nnz),
            _ => None,
        }
    }

    /// Total encoded bytes of a sparse matrix's backing (what
    /// `em_cache_bytes` is compared against in the SpMM ablation).
    pub fn sparse_bytes(&self) -> Option<u64> {
        match &*self.m.data {
            MatrixData::Sparse(s) => Some(s.total_bytes()),
            _ => None,
        }
    }

    /// `t(A) %*% B` — the Gramian-shaped product.
    pub fn crossprod(&self, right: &FmMatrix) -> Result<HostMat> {
        self.t().inner_prod_wide_tall(right, BinOp::Mul, AggOp::Sum)
    }

    /// `A[, j]` — select one column (0-based; lazy).
    pub fn col(&self, j: u64) -> Result<FmMatrix> {
        FmMatrix::wrap(&self.eng, genops::select_col(&self.m, j)?).policy()
    }

    /// Lazy element-type cast.
    pub fn cast(&self, to: DType) -> Result<FmMatrix> {
        FmMatrix::wrap(&self.eng, genops::cast(&self.m, to)).policy()
    }

    /// `fm.conv.store(A, in.mem=)` — move a matrix to the given storage
    /// (Table II). `in_mem = true` produces a matrix backed by memory
    /// chunks, `false` an SSD-backed (external-memory) matrix — the same
    /// vocabulary as [`LoadOptions::in_mem`](crate::ingest::LoadOptions).
    /// Streams the matrix once through a copy pass.
    pub fn conv_store(&self, in_mem: bool) -> Result<FmMatrix> {
        let kind = if in_mem {
            crate::StorageKind::InMem
        } else {
            crate::StorageKind::External
        };
        // identity node so dense inputs also stream through the pass
        let id = genops::mapply_scalar(
            &self.m.canonical(),
            Scalar::F64(0.0).cast(self.dtype()),
            BinOp::Add,
            true,
        );
        let (mut mats, _) =
            crate::exec::run_pass_to(&self.eng.ctx(), &[id], &[], Some(kind))?;
        let mut m = mats.remove(0);
        m.transposed = self.m.transposed;
        Ok(FmMatrix::wrap(&self.eng, m))
    }

    /// Deprecated shim — use [`FmMatrix::conv_store`] with the loader's
    /// `in_mem` vocabulary.
    #[deprecated(note = "use conv_store(in_mem: bool)")]
    pub fn conv_store_kind(&self, kind: crate::StorageKind) -> Result<FmMatrix> {
        self.conv_store(kind == crate::StorageKind::InMem)
    }

    /// R's `as.factor` on an integer column (FlashR `fm.as.factor`):
    /// two streaming passes over the n×1 matrix — collect the distinct
    /// non-NA values, sort them into the level table, then recode every
    /// cell to its 1-based level index as `i32`. NA cells stay NA
    /// (`i32::MIN`). The level table keeps the original values as
    /// strings, like R's `levels()`; text columns get their factor codes
    /// at load time instead ([`crate::ingest::ColType::Factor`]).
    ///
    /// The recoded vector lands on the engine's default storage, so an
    /// EM pipeline stays out-of-core through factorization.
    pub fn as_factor(&self) -> Result<FmVector> {
        if self.ncol() != 1 || self.m.transposed {
            return Err(FmError::Shape(format!(
                "as_factor: expected an n x 1 column, got {}x{}",
                self.nrow(),
                self.ncol()
            )));
        }
        if !matches!(self.dtype(), DType::I32 | DType::I64) {
            return Err(FmError::Unsupported(format!(
                "as_factor: integer column required, got {}",
                self.dtype()
            )));
        }
        let mat = if self.m.is_virtual() {
            self.eng
                .materialize_intermediate(&[self.m.canonical()])?
                .into_iter()
                .next()
                .unwrap()
        } else {
            self.m.clone()
        };
        let d = match &*mat.data {
            MatrixData::Dense(d) => d,
            _ => {
                return Err(FmError::Unsupported(
                    "as_factor: materialized dense column required".into(),
                ))
            }
        };
        let n_parts = d.parts.n_parts();
        let threads = self.eng.config.threads.max(1).min(n_parts.max(1));

        // pass 1: distinct non-NA values, merged across partition workers
        let uniq: StdMutex<BTreeSet<i64>> = StdMutex::new(BTreeSet::new());
        let err1: StdMutex<Option<FmError>> = StdMutex::new(None);
        let next1 = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..threads {
                s.spawn(|| loop {
                    let i = next1.fetch_add(1, Ordering::Relaxed);
                    if i >= n_parts || err1.lock_recover().is_some() {
                        return;
                    }
                    match d.partition_buf(i) {
                        Ok(buf) => {
                            let mut local = BTreeSet::new();
                            for r in 0..buf.len() {
                                let v = buf.get(r);
                                if !v.is_na() {
                                    local.insert(v.as_i64());
                                }
                            }
                            uniq.lock_recover().extend(local);
                        }
                        Err(e) => {
                            *err1.lock_recover() = Some(e);
                            return;
                        }
                    }
                });
            }
        });
        if let Some(e) = err1.into_inner_recover() {
            return Err(e);
        }
        let values: Vec<i64> = uniq.into_inner_recover().into_iter().collect();
        if values.len() >= i32::MAX as usize {
            return Err(FmError::Unsupported(format!(
                "as_factor: {} distinct values exceed the i32 code space",
                values.len()
            )));
        }
        let code_of: HashMap<i64, i32> = values
            .iter()
            .enumerate()
            .map(|(k, &v)| (v, k as i32 + 1))
            .collect();

        // pass 2: recode each partition to 1-based level indices
        let b = crate::ingest::make_builder(
            &self.eng,
            DType::I32,
            d.parts.clone(),
            &self.eng.config.storage,
            None,
        )?;
        let err2: StdMutex<Option<FmError>> = StdMutex::new(None);
        let next2 = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..threads {
                s.spawn(|| loop {
                    let i = next2.fetch_add(1, Ordering::Relaxed);
                    if i >= n_parts || err2.lock_recover().is_some() {
                        return;
                    }
                    let step = || -> Result<()> {
                        let src = d.partition_buf(i)?;
                        let mut out = Buf::alloc(DType::I32, src.len());
                        for r in 0..src.len() {
                            let v = src.get(r);
                            let code = if v.is_na() {
                                i32::MIN
                            } else {
                                code_of[&v.as_i64()]
                            };
                            out.set(r, Scalar::I32(code));
                        }
                        b.write_partition_buf(i, &out)
                    };
                    if let Err(e) = step() {
                        *err2.lock_recover() = Some(e);
                        return;
                    }
                });
            }
        });
        if let Some(e) = err2.into_inner_recover() {
            return Err(e);
        }
        Ok(FmVector {
            v: FmMatrix::wrap(&self.eng, Matrix::from_dense(b.finish())),
            levels: Some(Arc::new(values.iter().map(|v| v.to_string()).collect())),
        })
    }

    /// A *group of dense matrices* standing for one wider matrix
    /// (paper §III-B4): members must be materialized tall matrices sharing
    /// nrow. Dtypes may differ (the `fm.cbind.list` factor scenario): the
    /// group reads as the promoted dtype and members are cast on load.
    /// GenOps decompose onto the members automatically.
    pub fn group(eng: &Arc<Engine>, members: &[&FmMatrix]) -> Result<FmMatrix> {
        if members.is_empty() {
            return Err(FmError::Shape("empty group".into()));
        }
        let mut datas = Vec::with_capacity(members.len());
        let first = &members[0].m;
        for m in members {
            match &*m.m.data {
                MatrixData::Dense(d) => {
                    if m.m.transposed || d.nrow() != first.data.nrow() {
                        return Err(FmError::Shape(
                            "group members must be tall with the same nrow".into(),
                        ));
                    }
                }
                _ => {
                    return Err(FmError::Unsupported(
                        "group members must be materialized dense matrices".into(),
                    ))
                }
            }
            datas.push(Arc::clone(&m.m.data));
        }
        Ok(FmMatrix::wrap(
            eng,
            Matrix::new(MatrixData::Group(crate::matrix::GroupData { members: datas })),
        ))
    }

    /// `fm.cbind` — column concatenation (lazy).
    pub fn cbind(eng: &Arc<Engine>, ms: &[&FmMatrix]) -> Result<FmMatrix> {
        let mats: Vec<Matrix> = ms.iter().map(|m| m.m.clone()).collect();
        FmMatrix::wrap(eng, genops::colbind(&mats)?).policy()
    }

    // -- R base reimplementations (Table III) --------------------------------

    pub fn abs(&self) -> Result<FmMatrix> {
        self.sapply(UnOp::Abs)
    }

    pub fn sqrt(&self) -> Result<FmMatrix> {
        self.sapply(UnOp::Sqrt)
    }

    pub fn sq(&self) -> Result<FmMatrix> {
        self.sapply(UnOp::Sq)
    }

    pub fn exp(&self) -> Result<FmMatrix> {
        self.sapply(UnOp::Exp)
    }

    pub fn log(&self) -> Result<FmMatrix> {
        self.sapply(UnOp::Log)
    }

    pub fn neg(&self) -> Result<FmMatrix> {
        self.sapply(UnOp::Neg)
    }

    /// `1 / (1 + exp(-A))` — the logistic function as one pinned GenOp
    /// chain (neg → exp → +1 → 1/x). The logistic-regression golden
    /// fixtures assert bit-level label parity against a python mirror of
    /// exactly this op order, so label generation
    /// ([`crate::datasets::logistic_labels`]) and the IRLS fit
    /// ([`crate::algs::logistic::logistic`]) must share this one
    /// definition.
    pub fn sigmoid(&self) -> Result<FmMatrix> {
        self.neg()?
            .exp()?
            .add_scalar(1.0)?
            .mapply_scalar(Scalar::F64(1.0), BinOp::Div, false)
    }

    pub fn add(&self, o: &FmMatrix) -> Result<FmMatrix> {
        self.mapply(o, BinOp::Add)
    }

    pub fn sub(&self, o: &FmMatrix) -> Result<FmMatrix> {
        self.mapply(o, BinOp::Sub)
    }

    pub fn mul(&self, o: &FmMatrix) -> Result<FmMatrix> {
        self.mapply(o, BinOp::Mul)
    }

    pub fn div(&self, o: &FmMatrix) -> Result<FmMatrix> {
        self.mapply(o, BinOp::Div)
    }

    pub fn pmin(&self, o: &FmMatrix) -> Result<FmMatrix> {
        self.mapply(o, BinOp::Min)
    }

    pub fn pmax(&self, o: &FmMatrix) -> Result<FmMatrix> {
        self.mapply(o, BinOp::Max)
    }

    pub fn add_scalar(&self, s: f64) -> Result<FmMatrix> {
        self.mapply_scalar(Scalar::F64(s), BinOp::Add, true)
    }

    pub fn sub_scalar(&self, s: f64) -> Result<FmMatrix> {
        self.mapply_scalar(Scalar::F64(s), BinOp::Sub, true)
    }

    pub fn mul_scalar(&self, s: f64) -> Result<FmMatrix> {
        self.mapply_scalar(Scalar::F64(s), BinOp::Mul, true)
    }

    pub fn div_scalar(&self, s: f64) -> Result<FmMatrix> {
        self.mapply_scalar(Scalar::F64(s), BinOp::Div, true)
    }

    /// `sum(A)`.
    pub fn sum(&self) -> Result<f64> {
        Ok(self.agg(AggOp::Sum)?.as_f64())
    }

    /// `min(A)` / `max(A)`.
    pub fn min(&self) -> Result<f64> {
        Ok(self.agg(AggOp::Min)?.as_f64())
    }

    pub fn max(&self) -> Result<f64> {
        Ok(self.agg(AggOp::Max)?.as_f64())
    }

    /// `sum(A, na.rm=)` / `min(A, na.rm=)` / `max(A, na.rm=)`.
    /// With `na_rm = false` any NA cell makes the result NaN (R's
    /// propagate semantics); with `na_rm = true` NA cells are skipped
    /// and the R empty-set identities apply (`sum` 0, `min` `Inf`,
    /// `max` `-Inf` when every cell is NA).
    pub fn sum_na(&self, na_rm: bool) -> Result<f64> {
        let s = self.agg_na(AggOp::Sum, NaMode::from_na_rm(na_rm))?;
        Ok(if s.is_na() { f64::NAN } else { s.as_f64() })
    }

    pub fn min_na(&self, na_rm: bool) -> Result<f64> {
        let s = self.agg_na(AggOp::Min, NaMode::from_na_rm(na_rm))?;
        Ok(if s.is_na() { f64::NAN } else { s.as_f64() })
    }

    pub fn max_na(&self, na_rm: bool) -> Result<f64> {
        let s = self.agg_na(AggOp::Max, NaMode::from_na_rm(na_rm))?;
        Ok(if s.is_na() { f64::NAN } else { s.as_f64() })
    }

    /// `mean(A, na.rm=)` — NA-removing mean divides by the count of
    /// non-NA cells, exactly like R. Sum and count are batched as two
    /// sinks over one shared scan.
    pub fn mean(&self, na_rm: bool) -> Result<f64> {
        let na = NaMode::from_na_rm(na_rm);
        let sinks = [
            genops::agg_full_na(&self.m, AggOp::Sum, na),
            genops::agg_full_na(&self.m, AggOp::Count, na),
        ];
        let r = self.eng.materialize_sinks(&sinks)?;
        let mut it = r.into_iter();
        let s = it.next().unwrap().scalar();
        let c = it.next().unwrap().scalar();
        if s.is_na() || c.is_na() {
            return Ok(f64::NAN);
        }
        Ok(s.as_f64() / c.as_f64())
    }

    /// `any(A)` / `all(A)` on a logical matrix.
    pub fn any(&self) -> Result<bool> {
        Ok(self.agg(AggOp::Any)?.as_bool())
    }

    pub fn all(&self) -> Result<bool> {
        Ok(self.agg(AggOp::All)?.as_bool())
    }

    /// `rowSums(A)` — n×1 (lazy on tall matrices).
    pub fn row_sums(&self) -> Result<FmMatrix> {
        self.agg_row(AggOp::Sum)
    }

    /// `rowSums(A, na.rm=)`.
    pub fn row_sums_na(&self, na_rm: bool) -> Result<FmMatrix> {
        self.agg_row_na(AggOp::Sum, NaMode::from_na_rm(na_rm))
    }

    /// `colSums(A)` — 1×p host vector.
    pub fn col_sums(&self) -> Result<HostMat> {
        self.agg_col(AggOp::Sum)
    }

    /// `colSums(A, na.rm=)`.
    pub fn col_sums_na(&self, na_rm: bool) -> Result<HostMat> {
        self.agg_col_na(AggOp::Sum, NaMode::from_na_rm(na_rm))
    }

    /// `colMeans(A)`.
    pub fn col_means(&self) -> Result<HostMat> {
        let mut s = self.col_sums()?;
        let n = self.nrow() as f64;
        for j in 0..s.buf.len() {
            let v = s.buf.get(j).as_f64() / n;
            s.buf.set(j, Scalar::F64(v));
        }
        Ok(s)
    }
}

/// Engine-anchored constructors and loaders — the canonical creation
/// surface. Everything that *creates* data in an engine hangs off the
/// engine handle itself:
///
/// ```
/// use flashmatrix::fmr::{Engine, EngineExt};
/// let eng = Engine::default_engine().unwrap();
/// let x = eng.seq_int(0.0, 1.0, 10);
/// assert_eq!(x.sum().unwrap(), 45.0);
/// ```
///
/// Implemented for `Arc<Engine>` (an [`FmMatrix`] keeps a strong
/// reference to its engine, so constructors need the `Arc`, not a bare
/// `&Engine`). The old free-standing `eng.seq_int(...)`
/// constructor zoo is deprecated in favor of this trait; ARCHITECTURE.md
/// documents the old→new mapping.
/// A column vector with optional factor metadata: the n×1 [`FmMatrix`]
/// plus, for factor columns, the sorted level table mapping codes
/// `1..=k` back to the original strings (R's `levels(f)`). Produced by
/// the list-of-vectors loader ([`EngineExt::load_list_vecs`]) and by
/// [`FmMatrix::as_factor`]; consumed by [`EngineExt::cbind_list`].
#[derive(Clone)]
pub struct FmVector {
    pub v: FmMatrix,
    pub levels: Option<Arc<Vec<String>>>,
}

impl FmVector {
    /// A plain (non-factor) vector.
    pub fn plain(v: FmMatrix) -> FmVector {
        FmVector { v, levels: None }
    }

    /// Number of factor levels (0 for a non-factor vector).
    pub fn n_levels(&self) -> usize {
        self.levels.as_ref().map(|l| l.len()).unwrap_or(0)
    }
}

pub trait EngineExt {
    /// `fm.rep.int(value, n)` — constant n×1 vector.
    fn rep_int(&self, value: Scalar, n: u64) -> FmMatrix;

    /// Constant n×p matrix.
    fn fill(&self, value: Scalar, nrow: u64, ncol: u64) -> FmMatrix;

    /// `fm.seq.int(start, by, n)` — arithmetic sequence, n×1.
    fn seq_int(&self, start: f64, by: f64, n: u64) -> FmMatrix;

    /// `fm.runif.matrix(n, p, min, max)` — deterministic counter-based
    /// uniform matrix (virtual; materializes on demand).
    fn runif_matrix(&self, nrow: u64, ncol: u64, lo: f64, hi: f64, seed: u64) -> FmMatrix;

    /// `fm.rnorm.matrix(n, p, mean, sd)`.
    fn rnorm_matrix(&self, nrow: u64, ncol: u64, mean: f64, sd: f64, seed: u64) -> FmMatrix;

    /// `fm.conv.R2FM` — import a small host matrix.
    fn from_host(&self, h: &HostMat) -> Result<FmMatrix>;

    /// `fm.cbind` — column concatenation (lazy).
    fn cbind(&self, ms: &[&FmMatrix]) -> Result<FmMatrix>;

    /// A group of dense matrices standing for one wider matrix
    /// (see [`FmMatrix::group`] for member requirements).
    fn group(&self, members: &[&FmMatrix]) -> Result<FmMatrix>;

    /// FlashR's `fm.load.dense.matrix` — parse delimited text files into
    /// one typed matrix (see [`crate::ingest`] for the two-phase
    /// out-of-core pipeline and [`crate::ingest::LoadOptions`]).
    fn load_dense_matrix<P: AsRef<std::path::Path>>(
        &self,
        paths: &[P],
        opts: &crate::ingest::LoadOptions,
    ) -> Result<FmMatrix>;

    /// FlashR's `fm.load.list.vecs` — parse delimited text files into
    /// one vector per column, each at its own dtype, with factor level
    /// tables attached.
    fn load_list_vecs<P: AsRef<std::path::Path>>(
        &self,
        paths: &[P],
        opts: &crate::ingest::LoadOptions,
    ) -> Result<Vec<FmVector>>;

    /// FlashR's `fm.cbind.list` — bind loaded column vectors into one
    /// matrix. Mixed dtypes promote like R: any float column promotes
    /// the result to `f64`, else any `i64` widens to `i64`; narrower
    /// columns are cast (lazily) on the way in.
    fn cbind_list(&self, vs: &[FmVector]) -> Result<FmMatrix>;

    /// FlashR's `fm.get.dense.matrix` — reattach a *named* dense dataset
    /// persisted in `data_dir` (its `<name>.dense.json` sidecar carries
    /// dtype, shape and write-time partition checksums).
    fn get_dense_matrix(&self, name: &str) -> Result<FmMatrix>;
}

impl EngineExt for Arc<Engine> {
    fn rep_int(&self, value: Scalar, n: u64) -> FmMatrix {
        self.fill(value, n, 1)
    }

    fn fill(&self, value: Scalar, nrow: u64, ncol: u64) -> FmMatrix {
        FmMatrix::wrap(
            self,
            Matrix::new(MatrixData::Virtual(crate::dag::VNode {
                nrow,
                ncol,
                dtype: value.dtype(),
                kind: crate::dag::VKind::Fill(value),
            })),
        )
    }

    fn seq_int(&self, start: f64, by: f64, n: u64) -> FmMatrix {
        FmMatrix::wrap(
            self,
            Matrix::new(MatrixData::Virtual(crate::dag::VNode {
                nrow: n,
                ncol: 1,
                dtype: DType::F64,
                kind: crate::dag::VKind::Seq { start, step: by },
            })),
        )
    }

    fn runif_matrix(&self, nrow: u64, ncol: u64, lo: f64, hi: f64, seed: u64) -> FmMatrix {
        FmMatrix::wrap(
            self,
            Matrix::new(MatrixData::Virtual(crate::dag::VNode {
                nrow,
                ncol,
                dtype: DType::F64,
                kind: crate::dag::VKind::RandU { seed, lo, hi },
            })),
        )
    }

    fn rnorm_matrix(&self, nrow: u64, ncol: u64, mean: f64, sd: f64, seed: u64) -> FmMatrix {
        FmMatrix::wrap(
            self,
            Matrix::new(MatrixData::Virtual(crate::dag::VNode {
                nrow,
                ncol,
                dtype: DType::F64,
                kind: crate::dag::VKind::RandN { seed, mean, sd },
            })),
        )
    }

    fn from_host(&self, h: &HostMat) -> Result<FmMatrix> {
        FmMatrix::from_host(self, h)
    }

    fn cbind(&self, ms: &[&FmMatrix]) -> Result<FmMatrix> {
        FmMatrix::cbind(self, ms)
    }

    fn group(&self, members: &[&FmMatrix]) -> Result<FmMatrix> {
        FmMatrix::group(self, members)
    }

    fn load_dense_matrix<P: AsRef<std::path::Path>>(
        &self,
        paths: &[P],
        opts: &crate::ingest::LoadOptions,
    ) -> Result<FmMatrix> {
        crate::ingest::load_dense_matrix(self, paths, opts)
    }

    fn load_list_vecs<P: AsRef<std::path::Path>>(
        &self,
        paths: &[P],
        opts: &crate::ingest::LoadOptions,
    ) -> Result<Vec<FmVector>> {
        crate::ingest::load_list_vecs(self, paths, opts)
    }

    fn cbind_list(&self, vs: &[FmVector]) -> Result<FmMatrix> {
        if vs.is_empty() {
            return Err(FmError::Shape("cbind_list: empty vector list".into()));
        }
        let dtypes: Vec<DType> = vs.iter().map(|v| v.v.dtype()).collect();
        let promoted = if dtypes.iter().any(|d| matches!(d, DType::F64 | DType::F32)) {
            DType::F64
        } else if dtypes.iter().any(|d| *d == DType::I64) {
            DType::I64
        } else {
            dtypes[0]
        };
        let cast: Vec<FmMatrix> = vs
            .iter()
            .map(|v| {
                if v.v.dtype() == promoted {
                    Ok(v.v.clone())
                } else {
                    v.v.cast(promoted)
                }
            })
            .collect::<Result<_>>()?;
        let refs: Vec<&FmMatrix> = cast.iter().collect();
        FmMatrix::cbind(self, &refs)
    }

    fn get_dense_matrix(&self, name: &str) -> Result<FmMatrix> {
        let (data, _meta) = crate::matrix::DenseData::open_named(
            &self.config.data_dir,
            name,
            Arc::clone(&self.ssd),
            Arc::clone(&self.metrics),
            self.cache.clone(),
        )?;
        Ok(FmMatrix::wrap(self, Matrix::from_dense(data)))
    }
}

impl std::fmt::Debug for FmMatrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "FmMatrix[{}x{} {} {}{}]",
            self.nrow(),
            self.ncol(),
            self.dtype(),
            if self.is_virtual() {
                "virtual"
            } else if self.is_sparse() {
                "sparse"
            } else {
                "dense"
            },
            if self.m.transposed { " t" } else { "" },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;

    fn eng() -> Arc<Engine> {
        Engine::new(EngineConfig {
            xla_dispatch: false,
            chunk_bytes: 1 << 20,
            target_part_bytes: 1 << 20,
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn fill_sum_and_means() {
        let e = eng();
        let a = e.fill(Scalar::F64(2.0), 1000, 3);
        assert_eq!(a.sum().unwrap(), 6000.0);
        let cm = a.col_means().unwrap();
        assert_eq!(cm.buf.to_f64_vec(), vec![2.0, 2.0, 2.0]);
    }

    #[test]
    fn seq_and_row_sums() {
        let e = eng();
        // seq 0..9 as a column; rowSums of 1 col = itself; sum = 45
        let s = e.seq_int(0.0, 1.0, 10);
        assert_eq!(s.sum().unwrap(), 45.0);
        let h = s.to_host().unwrap();
        assert_eq!(h.get(3, 0).as_f64(), 3.0);
    }

    #[test]
    fn lazy_pipeline_fuses_and_matches_eager() {
        // (|x| + x^2) summed — computed lazily vs eagerly must agree
        let mk = |fuse: bool| {
            let e = Engine::new(EngineConfig {
                xla_dispatch: false,
                fuse_mem: fuse,
                fuse_cache: fuse,
                chunk_bytes: 1 << 20,
                target_part_bytes: 1 << 20,
                ..Default::default()
            })
            .unwrap();
            let x = e.runif_matrix(5000, 4, -1.0, 1.0, 7);
            let expr = x.abs().unwrap().add(&x.sq().unwrap()).unwrap();
            expr.sum().unwrap()
        };
        let lazy = mk(true);
        let eager = mk(false);
        assert!((lazy - eager).abs() < 1e-9, "{lazy} vs {eager}");
    }

    #[test]
    fn transpose_roundtrip_export() {
        let e = eng();
        let h = HostMat::from_rows_f64(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        let a = FmMatrix::from_host(&e, &h).unwrap();
        let ht = a.t().to_host().unwrap();
        assert_eq!(ht.nrow, 2);
        assert_eq!(ht.get(1, 2).as_f64(), 6.0);
    }

    #[test]
    fn crossprod_identity() {
        let e = eng();
        // X = [[1,0],[0,1],[1,1]]; t(X)X = [[2,1],[1,2]]
        let h = HostMat::from_rows_f64(&[vec![1.0, 0.0], vec![0.0, 1.0], vec![1.0, 1.0]]);
        let x = FmMatrix::from_host(&e, &h).unwrap();
        let g = x.crossprod(&x).unwrap();
        assert_eq!(g.to_row_major_f64(), vec![2.0, 1.0, 1.0, 2.0]);
    }

    #[test]
    fn groupby_row_sums_by_label() {
        let e = eng();
        let h = HostMat::from_rows_f64(&[
            vec![1.0, 10.0],
            vec![2.0, 20.0],
            vec![3.0, 30.0],
            vec![4.0, 40.0],
        ]);
        let x = FmMatrix::from_host(&e, &h).unwrap();
        let labels = FmMatrix::from_host(
            &e,
            &HostMat {
                nrow: 4,
                ncol: 1,
                buf: Buf::I32(vec![0, 1, 0, 1]),
            },
        )
        .unwrap();
        let g = x.groupby_row(&labels, 2, AggOp::Sum).unwrap();
        assert_eq!(g.nrow, 2);
        assert_eq!(g.get(0, 0).as_f64(), 4.0); // rows 0+2 col 0
        assert_eq!(g.get(1, 1).as_f64(), 60.0); // rows 1+3 col 1
    }

    #[test]
    fn which_min_row_matches_manual() {
        let e = eng();
        let h = HostMat::from_rows_f64(&[vec![3.0, 1.0, 2.0], vec![0.5, 2.0, 0.1]]);
        let x = FmMatrix::from_host(&e, &h).unwrap();
        let am = x.which_min_row().unwrap().to_host().unwrap();
        assert_eq!(am.get(0, 0).as_i64(), 2); // 1-based
        assert_eq!(am.get(1, 0).as_i64(), 3);
    }

    #[test]
    fn inner_prod_small_matmul() {
        let e = eng();
        let h = HostMat::from_rows_f64(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let x = FmMatrix::from_host(&e, &h).unwrap();
        let b = HostMat::from_rows_f64(&[vec![1.0, 0.0], vec![0.0, 1.0]]);
        let y = x.matmul_small(&b).unwrap().to_host().unwrap();
        assert_eq!(y.to_row_major_f64(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn na_rm_aggregates_match_r() {
        let e = eng();
        let h = HostMat::from_rows_f64(&[
            vec![1.0, f64::NAN],
            vec![2.0, 5.0],
            vec![f64::NAN, 7.0],
        ]);
        let x = FmMatrix::from_host(&e, &h).unwrap();
        // na.rm=TRUE skips NA cells
        assert_eq!(x.sum_na(true).unwrap(), 15.0);
        assert_eq!(x.min_na(true).unwrap(), 1.0);
        assert_eq!(x.max_na(true).unwrap(), 7.0);
        assert_eq!(x.mean(true).unwrap(), 15.0 / 4.0);
        // na.rm=FALSE propagates
        assert!(x.sum_na(false).unwrap().is_nan());
        assert!(x.mean(false).unwrap().is_nan());
        // per-column sums with na.rm
        let cs = x.col_sums_na(true).unwrap();
        assert_eq!(cs.buf.to_f64_vec(), vec![3.0, 12.0]);
        let cs = x.col_sums_na(false).unwrap();
        assert!(cs.buf.get(0).as_f64().is_nan());
        // per-row sums with na.rm (in-DAG path)
        let rs = x.row_sums_na(true).unwrap().to_host().unwrap();
        assert_eq!(rs.get(0, 0).as_f64(), 1.0);
        assert_eq!(rs.get(1, 0).as_f64(), 7.0);
        assert_eq!(rs.get(2, 0).as_f64(), 7.0);
        // NA-free data: na.rm variants agree with the legacy path
        let y = e.fill(Scalar::F64(2.0), 100, 3);
        assert_eq!(y.sum_na(true).unwrap(), y.sum().unwrap());
        assert_eq!(y.sum_na(false).unwrap(), y.sum().unwrap());
        assert_eq!(y.mean(false).unwrap(), 2.0);
    }

    #[test]
    fn mixed_dtype_promotes() {
        let e = eng();
        let a = e.fill(Scalar::I32(3), 100, 2);
        let b = e.fill(Scalar::F64(0.5), 100, 2);
        let c = a.add(&b).unwrap();
        assert_eq!(c.dtype(), DType::F64);
        assert_eq!(c.sum().unwrap(), 700.0);
    }
}
