//! `fmr` — the R-like user API (paper §III-A, Tables I–III).
//!
//! [`FmMatrix`] mirrors the paper's R interface: constructors
//! (`fm.runif.matrix`, `fm.seq.int`, …), conversions (`fm.conv.R2FM` /
//! `FM2R`), the GenOps, and the reimplemented R-base matrix functions
//! (`rowSums`, `pmin`, `sqrt`, arithmetic operators, `%*%`, `t`, …).
//!
//! Semantics follow the paper:
//! * every operation is **lazy** (returns a virtual matrix) while
//!   `fuse_mem` is on; with it off (the eager / MLlib-like mode) each
//!   operation materializes immediately;
//! * **sinks** (`sum`, `colSums`, `fm.groupby.row`, wide×tall
//!   `fm.inner.prod`) always force a pass — batch them with
//!   [`engine::Engine::materialize_sinks`] / [`engine::Engine::run_pass`]
//!   to share one scan (the paper's `fm.materialize` on several sinks);
//! * matrices are immutable; dropping the last handle returns chunks to
//!   the pool (the paper's GC).

pub mod engine;
pub mod session;

use std::sync::Arc;

use crate::dag::{SinkResult, SinkSpec, UnFn};
use crate::dtype::{DType, Scalar};
use crate::error::{FmError, Result};
use crate::genops::{self, RowAggResult};
use crate::matrix::{DenseBuilder, HostMat, Matrix, MatrixData, Partitioning};
use crate::vudf::{AggOp, BinOp, Buf, UnOp};

pub use engine::Engine;
pub use session::Session;

/// A FlashMatrix matrix handle bound to an engine.
#[derive(Clone)]
pub struct FmMatrix {
    pub eng: Arc<Engine>,
    pub m: Matrix,
}

impl FmMatrix {
    fn wrap(eng: &Arc<Engine>, m: Matrix) -> FmMatrix {
        FmMatrix {
            eng: Arc::clone(eng),
            m,
        }
    }

    /// Apply the engine's laziness policy to a freshly recorded node:
    /// under `fuse_mem` the node stays virtual; in the eager mode it is
    /// materialized immediately (one pass per operation — the MLlib-like
    /// behaviour Fig 6/11 compare against). Eager per-op results are
    /// one-shot intermediates, so they are kept out of the write-through
    /// matrix cache (§III-B3 residency decision).
    fn policy(self) -> Result<FmMatrix> {
        if self.eng.config.fuse_mem || !self.m.is_virtual() {
            return Ok(self);
        }
        let transposed = self.m.transposed;
        let mats = self.eng.materialize_intermediate(&[self.m.canonical()])?;
        let mut m = mats.into_iter().next().unwrap();
        m.transposed = transposed;
        Ok(FmMatrix::wrap(&self.eng, m))
    }

    // -- shape / metadata ---------------------------------------------------

    pub fn nrow(&self) -> u64 {
        self.m.nrow()
    }

    pub fn ncol(&self) -> u64 {
        self.m.ncol()
    }

    pub fn dtype(&self) -> DType {
        self.m.dtype()
    }

    pub fn is_virtual(&self) -> bool {
        self.m.is_virtual()
    }

    /// `t(A)` — zero-copy transpose.
    pub fn t(&self) -> FmMatrix {
        FmMatrix::wrap(&self.eng, self.m.t())
    }

    // -- constructors (Table II) --------------------------------------------

    /// `fm.rep.int(value, n)` — constant n×1 vector.
    pub fn rep_int(eng: &Arc<Engine>, value: Scalar, n: u64) -> FmMatrix {
        FmMatrix::wrap(
            eng,
            Matrix::new(MatrixData::Virtual(crate::dag::VNode {
                nrow: n,
                ncol: 1,
                dtype: value.dtype(),
                kind: crate::dag::VKind::Fill(value),
            })),
        )
    }

    /// Constant n×p matrix.
    pub fn fill(eng: &Arc<Engine>, value: Scalar, nrow: u64, ncol: u64) -> FmMatrix {
        FmMatrix::wrap(
            eng,
            Matrix::new(MatrixData::Virtual(crate::dag::VNode {
                nrow,
                ncol,
                dtype: value.dtype(),
                kind: crate::dag::VKind::Fill(value),
            })),
        )
    }

    /// `fm.seq.int(start, by, n)` — arithmetic sequence, n×1.
    pub fn seq_int(eng: &Arc<Engine>, start: f64, by: f64, n: u64) -> FmMatrix {
        FmMatrix::wrap(
            eng,
            Matrix::new(MatrixData::Virtual(crate::dag::VNode {
                nrow: n,
                ncol: 1,
                dtype: DType::F64,
                kind: crate::dag::VKind::Seq { start, step: by },
            })),
        )
    }

    /// `fm.runif.matrix(n, p, min, max)` — deterministic counter-based
    /// uniform matrix (virtual; materializes on demand).
    pub fn runif_matrix(
        eng: &Arc<Engine>,
        nrow: u64,
        ncol: u64,
        lo: f64,
        hi: f64,
        seed: u64,
    ) -> FmMatrix {
        FmMatrix::wrap(
            eng,
            Matrix::new(MatrixData::Virtual(crate::dag::VNode {
                nrow,
                ncol,
                dtype: DType::F64,
                kind: crate::dag::VKind::RandU { seed, lo, hi },
            })),
        )
    }

    /// `fm.rnorm.matrix(n, p, mean, sd)`.
    pub fn rnorm_matrix(
        eng: &Arc<Engine>,
        nrow: u64,
        ncol: u64,
        mean: f64,
        sd: f64,
        seed: u64,
    ) -> FmMatrix {
        FmMatrix::wrap(
            eng,
            Matrix::new(MatrixData::Virtual(crate::dag::VNode {
                nrow,
                ncol,
                dtype: DType::F64,
                kind: crate::dag::VKind::RandN { seed, mean, sd },
            })),
        )
    }

    /// `fm.conv.R2FM` — import a small host matrix as a dense FM matrix.
    pub fn from_host(eng: &Arc<Engine>, h: &HostMat) -> Result<FmMatrix> {
        let parts = Partitioning::new(h.nrow as u64, h.ncol as u64);
        let b = DenseBuilder::new_mem(h.buf.dtype(), parts.clone(), &eng.pool)?;
        for i in 0..parts.n_parts() {
            let (r0, r1) = parts.part_rows(i);
            let prows = (r1 - r0) as usize;
            let mut buf = Buf::alloc(h.buf.dtype(), prows * h.ncol);
            for j in 0..h.ncol {
                let col = h.buf.slice(j * h.nrow + r0 as usize, prows);
                buf.copy_from(j * prows, &col);
            }
            b.write_partition_buf(i, &buf)?;
        }
        Ok(FmMatrix::wrap(eng, Matrix::from_dense(b.finish())))
    }

    /// `fm.conv.FM2R` — export to a host matrix (materializes first).
    /// View-aware: a transposed handle exports transposed.
    pub fn to_host(&self) -> Result<HostMat> {
        let dense = self.materialize()?;
        let d = match &*dense.m.data {
            MatrixData::Dense(d) => d,
            _ => return Err(FmError::Shape("materialize returned non-dense".into())),
        };
        let h = HostMat::new(
            d.nrow() as usize,
            d.ncol() as usize,
            d.to_buf()?,
        )?;
        Ok(if self.m.transposed { h.transposed() } else { h })
    }

    /// `fm.materialize` — force materialization (no-op for dense).
    pub fn materialize(&self) -> Result<FmMatrix> {
        if !self.m.is_virtual() {
            return Ok(self.clone());
        }
        let transposed = self.m.transposed;
        let mats = self.eng.materialize(&[self.m.canonical()])?;
        let mut m = mats.into_iter().next().unwrap();
        m.transposed = transposed;
        Ok(FmMatrix::wrap(&self.eng, m))
    }

    // -- GenOps (Table I) ----------------------------------------------------

    /// `fm.sapply(A, f)` with a built-in op.
    pub fn sapply(&self, op: UnOp) -> Result<FmMatrix> {
        FmMatrix::wrap(&self.eng, genops::sapply(&self.m, UnFn::Builtin(op))).policy()
    }

    /// `fm.sapply(A, f)` with a registered custom VUDF.
    pub fn sapply_custom(&self, name: &str) -> Result<FmMatrix> {
        let f = self
            .eng
            .registry
            .lookup(name)
            .ok_or_else(|| FmError::Unsupported(format!("no VUDF named '{name}'")))?;
        FmMatrix::wrap(&self.eng, genops::sapply(&self.m, UnFn::Custom(f))).policy()
    }

    /// `fm.mapply(A, B, f)`.
    pub fn mapply(&self, other: &FmMatrix, op: BinOp) -> Result<FmMatrix> {
        FmMatrix::wrap(&self.eng, genops::mapply(&self.m, &other.m, op)?).policy()
    }

    /// `fm.mapply` with a scalar operand (`A op s` / `s op A`).
    pub fn mapply_scalar(&self, s: Scalar, op: BinOp, scalar_right: bool) -> Result<FmMatrix> {
        FmMatrix::wrap(&self.eng, genops::mapply_scalar(&self.m, s, op, scalar_right)).policy()
    }

    /// `fm.mapply.row(A, w, f)`.
    pub fn mapply_row(&self, w: &HostMat, op: BinOp) -> Result<FmMatrix> {
        FmMatrix::wrap(&self.eng, genops::mapply_row(&self.m, w, op)?).policy()
    }

    /// `fm.mapply.col(A, v, f)`.
    pub fn mapply_col(&self, v: &FmMatrix, op: BinOp) -> Result<FmMatrix> {
        FmMatrix::wrap(&self.eng, genops::mapply_col(&self.m, &v.m, op)?).policy()
    }

    /// `fm.agg(A, f)` — whole-matrix aggregate.
    pub fn agg(&self, op: AggOp) -> Result<Scalar> {
        let r = self.eng.materialize_sinks(&[genops::agg_full(&self.m, op)])?;
        Ok(r.into_iter().next().unwrap().scalar())
    }

    /// Deferred `fm.agg` sink (for batched one-pass materialization).
    pub fn agg_sink(&self, op: AggOp) -> SinkSpec {
        genops::agg_full(&self.m, op)
    }

    /// `fm.agg.row(A, f)` — per-row aggregate (n×1; stays lazy on tall
    /// matrices).
    pub fn agg_row(&self, op: AggOp) -> Result<FmMatrix> {
        match genops::agg_row(&self.m, op) {
            RowAggResult::InDag(v) => FmMatrix::wrap(&self.eng, v).policy(),
            RowAggResult::Sink(s) => {
                let r = self.eng.materialize_sinks(&[s])?;
                let h = match r.into_iter().next().unwrap() {
                    SinkResult::Mat(h) => h,
                    _ => unreachable!(),
                };
                FmMatrix::from_host(&self.eng, &HostMat {
                    nrow: h.ncol,
                    ncol: 1,
                    buf: h.buf,
                })
            }
        }
    }

    /// `fm.agg.col(A, f)` — per-column aggregate as a small host matrix.
    pub fn agg_col(&self, op: AggOp) -> Result<HostMat> {
        match genops::agg_col(&self.m, op) {
            RowAggResult::Sink(s) => {
                let r = self.eng.materialize_sinks(&[s])?;
                match r.into_iter().next().unwrap() {
                    SinkResult::Mat(h) => Ok(h),
                    _ => unreachable!(),
                }
            }
            RowAggResult::InDag(v) => {
                // wide view: per-column of the view = per-row in-DAG
                FmMatrix::wrap(&self.eng, v).to_host()
            }
        }
    }

    /// Deferred `fm.agg.col` sink.
    pub fn agg_col_sink(&self, op: AggOp) -> Result<SinkSpec> {
        match genops::agg_col(&self.m, op) {
            RowAggResult::Sink(s) => Ok(s),
            RowAggResult::InDag(_) => Err(FmError::Unsupported(
                "agg.col on a wide view is not a sink; call agg_col".into(),
            )),
        }
    }

    /// `which.min` / `which.max` per row (1-based indices, i32).
    pub fn which_min_row(&self) -> Result<FmMatrix> {
        FmMatrix::wrap(&self.eng, genops::which_extreme_row(&self.m, false)?).policy()
    }

    pub fn which_max_row(&self) -> Result<FmMatrix> {
        FmMatrix::wrap(&self.eng, genops::which_extreme_row(&self.m, true)?).policy()
    }

    /// `fm.groupby.row(A, labels, f)` — labels in `0..k`.
    pub fn groupby_row(&self, labels: &FmMatrix, k: usize, op: AggOp) -> Result<HostMat> {
        let s = genops::groupby_row(&self.m, &labels.m, k, op)?;
        let r = self.eng.materialize_sinks(&[s])?;
        match r.into_iter().next().unwrap() {
            SinkResult::Mat(h) => Ok(h),
            _ => unreachable!(),
        }
    }

    /// Deferred groupby sink.
    pub fn groupby_row_sink(&self, labels: &FmMatrix, k: usize, op: AggOp) -> Result<SinkSpec> {
        genops::groupby_row(&self.m, &labels.m, k, op)
    }

    /// `fm.inner.prod(A, B, f1, f2)` with a small host right operand
    /// (stays lazy: output shares the long dimension).
    pub fn inner_prod_small(&self, b: &HostMat, f1: BinOp, f2: AggOp) -> Result<FmMatrix> {
        FmMatrix::wrap(&self.eng, genops::inner_small(&self.m, b, f1, f2)?).policy()
    }

    /// `fm.inner.prod(t(A), B, f1, f2)` — wide × tall sink (e.g. Gramian).
    pub fn inner_prod_wide_tall(
        &self,
        right: &FmMatrix,
        f1: BinOp,
        f2: AggOp,
    ) -> Result<HostMat> {
        let s = genops::inner_wide_tall(&self.m, &right.m, f1, f2)?;
        let r = self.eng.materialize_sinks(&[s])?;
        match r.into_iter().next().unwrap() {
            SinkResult::Mat(h) => Ok(h),
            _ => unreachable!(),
        }
    }

    /// Deferred wide×tall inner-product sink.
    pub fn inner_prod_wide_tall_sink(
        &self,
        right: &FmMatrix,
        f1: BinOp,
        f2: AggOp,
    ) -> Result<SinkSpec> {
        genops::inner_wide_tall(&self.m, &right.m, f1, f2)
    }

    /// `%*%` — matrix multiplication: tall × small host matrix.
    pub fn matmul_small(&self, b: &HostMat) -> Result<FmMatrix> {
        self.inner_prod_small(b, BinOp::Mul, AggOp::Sum)
    }

    /// `fm.multiply(A, B)` with a sparse left operand: stream the CSR
    /// row-partitions of `A` (n×m) against the small in-memory dense
    /// matrix `B` (m×q) -> tall dense n×q (lazy). The sparse matrix is
    /// scheduled, cached and prefetched like any dense pass source; the
    /// result composes with every other GenOp (the PageRank iteration
    /// fuses SpMM + scale + shift + convergence sink into one pass).
    pub fn spmm(&self, b: HostMat) -> Result<FmMatrix> {
        FmMatrix::wrap(&self.eng, genops::spmm(&self.m, b)?).policy()
    }

    /// Whether this handle wraps a sparse (CSR) matrix.
    pub fn is_sparse(&self) -> bool {
        self.m.is_sparse()
    }

    /// Stored entries of a sparse matrix (`None` for dense/virtual).
    pub fn nnz(&self) -> Option<u64> {
        match &*self.m.data {
            MatrixData::Sparse(s) => Some(s.nnz),
            _ => None,
        }
    }

    /// Total encoded bytes of a sparse matrix's backing (what
    /// `em_cache_bytes` is compared against in the SpMM ablation).
    pub fn sparse_bytes(&self) -> Option<u64> {
        match &*self.m.data {
            MatrixData::Sparse(s) => Some(s.total_bytes()),
            _ => None,
        }
    }

    /// `t(A) %*% B` — the Gramian-shaped product.
    pub fn crossprod(&self, right: &FmMatrix) -> Result<HostMat> {
        self.t().inner_prod_wide_tall(right, BinOp::Mul, AggOp::Sum)
    }

    /// `A[, j]` — select one column (0-based; lazy).
    pub fn col(&self, j: u64) -> Result<FmMatrix> {
        FmMatrix::wrap(&self.eng, genops::select_col(&self.m, j)?).policy()
    }

    /// Lazy element-type cast.
    pub fn cast(&self, to: DType) -> Result<FmMatrix> {
        FmMatrix::wrap(&self.eng, genops::cast(&self.m, to)).policy()
    }

    /// `fm.conv.store` — move a matrix to the given storage (Table II).
    /// Streams the matrix once through a copy pass; the result is a dense
    /// matrix backed by memory chunks or an SSD file.
    pub fn conv_store(&self, kind: crate::StorageKind) -> Result<FmMatrix> {
        // identity node so dense inputs also stream through the pass
        let id = genops::mapply_scalar(
            &self.m.canonical(),
            Scalar::F64(0.0).cast(self.dtype()),
            BinOp::Add,
            true,
        );
        let (mut mats, _) =
            crate::exec::run_pass_to(&self.eng.ctx(), &[id], &[], Some(kind))?;
        let mut m = mats.remove(0);
        m.transposed = self.m.transposed;
        Ok(FmMatrix::wrap(&self.eng, m))
    }

    /// A *group of dense matrices* standing for one wider matrix
    /// (paper §III-B4): members must be materialized tall matrices sharing
    /// nrow. Dtypes may differ (the `fm.cbind.list` factor scenario): the
    /// group reads as the promoted dtype and members are cast on load.
    /// GenOps decompose onto the members automatically.
    pub fn group(eng: &Arc<Engine>, members: &[&FmMatrix]) -> Result<FmMatrix> {
        if members.is_empty() {
            return Err(FmError::Shape("empty group".into()));
        }
        let mut datas = Vec::with_capacity(members.len());
        let first = &members[0].m;
        for m in members {
            match &*m.m.data {
                MatrixData::Dense(d) => {
                    if m.m.transposed || d.nrow() != first.data.nrow() {
                        return Err(FmError::Shape(
                            "group members must be tall with the same nrow".into(),
                        ));
                    }
                }
                _ => {
                    return Err(FmError::Unsupported(
                        "group members must be materialized dense matrices".into(),
                    ))
                }
            }
            datas.push(Arc::clone(&m.m.data));
        }
        Ok(FmMatrix::wrap(
            eng,
            Matrix::new(MatrixData::Group(crate::matrix::GroupData { members: datas })),
        ))
    }

    /// `fm.cbind` — column concatenation (lazy).
    pub fn cbind(eng: &Arc<Engine>, ms: &[&FmMatrix]) -> Result<FmMatrix> {
        let mats: Vec<Matrix> = ms.iter().map(|m| m.m.clone()).collect();
        FmMatrix::wrap(eng, genops::colbind(&mats)?).policy()
    }

    // -- R base reimplementations (Table III) --------------------------------

    pub fn abs(&self) -> Result<FmMatrix> {
        self.sapply(UnOp::Abs)
    }

    pub fn sqrt(&self) -> Result<FmMatrix> {
        self.sapply(UnOp::Sqrt)
    }

    pub fn sq(&self) -> Result<FmMatrix> {
        self.sapply(UnOp::Sq)
    }

    pub fn exp(&self) -> Result<FmMatrix> {
        self.sapply(UnOp::Exp)
    }

    pub fn log(&self) -> Result<FmMatrix> {
        self.sapply(UnOp::Log)
    }

    pub fn neg(&self) -> Result<FmMatrix> {
        self.sapply(UnOp::Neg)
    }

    /// `1 / (1 + exp(-A))` — the logistic function as one pinned GenOp
    /// chain (neg → exp → +1 → 1/x). The logistic-regression golden
    /// fixtures assert bit-level label parity against a python mirror of
    /// exactly this op order, so label generation
    /// ([`crate::datasets::logistic_labels`]) and the IRLS fit
    /// ([`crate::algs::logistic::logistic`]) must share this one
    /// definition.
    pub fn sigmoid(&self) -> Result<FmMatrix> {
        self.neg()?
            .exp()?
            .add_scalar(1.0)?
            .mapply_scalar(Scalar::F64(1.0), BinOp::Div, false)
    }

    pub fn add(&self, o: &FmMatrix) -> Result<FmMatrix> {
        self.mapply(o, BinOp::Add)
    }

    pub fn sub(&self, o: &FmMatrix) -> Result<FmMatrix> {
        self.mapply(o, BinOp::Sub)
    }

    pub fn mul(&self, o: &FmMatrix) -> Result<FmMatrix> {
        self.mapply(o, BinOp::Mul)
    }

    pub fn div(&self, o: &FmMatrix) -> Result<FmMatrix> {
        self.mapply(o, BinOp::Div)
    }

    pub fn pmin(&self, o: &FmMatrix) -> Result<FmMatrix> {
        self.mapply(o, BinOp::Min)
    }

    pub fn pmax(&self, o: &FmMatrix) -> Result<FmMatrix> {
        self.mapply(o, BinOp::Max)
    }

    pub fn add_scalar(&self, s: f64) -> Result<FmMatrix> {
        self.mapply_scalar(Scalar::F64(s), BinOp::Add, true)
    }

    pub fn sub_scalar(&self, s: f64) -> Result<FmMatrix> {
        self.mapply_scalar(Scalar::F64(s), BinOp::Sub, true)
    }

    pub fn mul_scalar(&self, s: f64) -> Result<FmMatrix> {
        self.mapply_scalar(Scalar::F64(s), BinOp::Mul, true)
    }

    pub fn div_scalar(&self, s: f64) -> Result<FmMatrix> {
        self.mapply_scalar(Scalar::F64(s), BinOp::Div, true)
    }

    /// `sum(A)`.
    pub fn sum(&self) -> Result<f64> {
        Ok(self.agg(AggOp::Sum)?.as_f64())
    }

    /// `min(A)` / `max(A)`.
    pub fn min(&self) -> Result<f64> {
        Ok(self.agg(AggOp::Min)?.as_f64())
    }

    pub fn max(&self) -> Result<f64> {
        Ok(self.agg(AggOp::Max)?.as_f64())
    }

    /// `any(A)` / `all(A)` on a logical matrix.
    pub fn any(&self) -> Result<bool> {
        Ok(self.agg(AggOp::Any)?.as_bool())
    }

    pub fn all(&self) -> Result<bool> {
        Ok(self.agg(AggOp::All)?.as_bool())
    }

    /// `rowSums(A)` — n×1 (lazy on tall matrices).
    pub fn row_sums(&self) -> Result<FmMatrix> {
        self.agg_row(AggOp::Sum)
    }

    /// `colSums(A)` — 1×p host vector.
    pub fn col_sums(&self) -> Result<HostMat> {
        self.agg_col(AggOp::Sum)
    }

    /// `colMeans(A)`.
    pub fn col_means(&self) -> Result<HostMat> {
        let mut s = self.col_sums()?;
        let n = self.nrow() as f64;
        for j in 0..s.buf.len() {
            let v = s.buf.get(j).as_f64() / n;
            s.buf.set(j, Scalar::F64(v));
        }
        Ok(s)
    }
}

impl std::fmt::Debug for FmMatrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "FmMatrix[{}x{} {} {}{}]",
            self.nrow(),
            self.ncol(),
            self.dtype(),
            if self.is_virtual() {
                "virtual"
            } else if self.is_sparse() {
                "sparse"
            } else {
                "dense"
            },
            if self.m.transposed { " t" } else { "" },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;

    fn eng() -> Arc<Engine> {
        Engine::new(EngineConfig {
            xla_dispatch: false,
            chunk_bytes: 1 << 20,
            target_part_bytes: 1 << 20,
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn fill_sum_and_means() {
        let e = eng();
        let a = FmMatrix::fill(&e, Scalar::F64(2.0), 1000, 3);
        assert_eq!(a.sum().unwrap(), 6000.0);
        let cm = a.col_means().unwrap();
        assert_eq!(cm.buf.to_f64_vec(), vec![2.0, 2.0, 2.0]);
    }

    #[test]
    fn seq_and_row_sums() {
        let e = eng();
        // seq 0..9 as a column; rowSums of 1 col = itself; sum = 45
        let s = FmMatrix::seq_int(&e, 0.0, 1.0, 10);
        assert_eq!(s.sum().unwrap(), 45.0);
        let h = s.to_host().unwrap();
        assert_eq!(h.get(3, 0).as_f64(), 3.0);
    }

    #[test]
    fn lazy_pipeline_fuses_and_matches_eager() {
        // (|x| + x^2) summed — computed lazily vs eagerly must agree
        let mk = |fuse: bool| {
            let e = Engine::new(EngineConfig {
                xla_dispatch: false,
                fuse_mem: fuse,
                fuse_cache: fuse,
                chunk_bytes: 1 << 20,
                target_part_bytes: 1 << 20,
                ..Default::default()
            })
            .unwrap();
            let x = FmMatrix::runif_matrix(&e, 5000, 4, -1.0, 1.0, 7);
            let expr = x.abs().unwrap().add(&x.sq().unwrap()).unwrap();
            expr.sum().unwrap()
        };
        let lazy = mk(true);
        let eager = mk(false);
        assert!((lazy - eager).abs() < 1e-9, "{lazy} vs {eager}");
    }

    #[test]
    fn transpose_roundtrip_export() {
        let e = eng();
        let h = HostMat::from_rows_f64(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        let a = FmMatrix::from_host(&e, &h).unwrap();
        let ht = a.t().to_host().unwrap();
        assert_eq!(ht.nrow, 2);
        assert_eq!(ht.get(1, 2).as_f64(), 6.0);
    }

    #[test]
    fn crossprod_identity() {
        let e = eng();
        // X = [[1,0],[0,1],[1,1]]; t(X)X = [[2,1],[1,2]]
        let h = HostMat::from_rows_f64(&[vec![1.0, 0.0], vec![0.0, 1.0], vec![1.0, 1.0]]);
        let x = FmMatrix::from_host(&e, &h).unwrap();
        let g = x.crossprod(&x).unwrap();
        assert_eq!(g.to_row_major_f64(), vec![2.0, 1.0, 1.0, 2.0]);
    }

    #[test]
    fn groupby_row_sums_by_label() {
        let e = eng();
        let h = HostMat::from_rows_f64(&[
            vec![1.0, 10.0],
            vec![2.0, 20.0],
            vec![3.0, 30.0],
            vec![4.0, 40.0],
        ]);
        let x = FmMatrix::from_host(&e, &h).unwrap();
        let labels = FmMatrix::from_host(
            &e,
            &HostMat {
                nrow: 4,
                ncol: 1,
                buf: Buf::I32(vec![0, 1, 0, 1]),
            },
        )
        .unwrap();
        let g = x.groupby_row(&labels, 2, AggOp::Sum).unwrap();
        assert_eq!(g.nrow, 2);
        assert_eq!(g.get(0, 0).as_f64(), 4.0); // rows 0+2 col 0
        assert_eq!(g.get(1, 1).as_f64(), 60.0); // rows 1+3 col 1
    }

    #[test]
    fn which_min_row_matches_manual() {
        let e = eng();
        let h = HostMat::from_rows_f64(&[vec![3.0, 1.0, 2.0], vec![0.5, 2.0, 0.1]]);
        let x = FmMatrix::from_host(&e, &h).unwrap();
        let am = x.which_min_row().unwrap().to_host().unwrap();
        assert_eq!(am.get(0, 0).as_i64(), 2); // 1-based
        assert_eq!(am.get(1, 0).as_i64(), 3);
    }

    #[test]
    fn inner_prod_small_matmul() {
        let e = eng();
        let h = HostMat::from_rows_f64(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let x = FmMatrix::from_host(&e, &h).unwrap();
        let b = HostMat::from_rows_f64(&[vec![1.0, 0.0], vec![0.0, 1.0]]);
        let y = x.matmul_small(&b).unwrap().to_host().unwrap();
        assert_eq!(y.to_row_major_f64(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn mixed_dtype_promotes() {
        let e = eng();
        let a = FmMatrix::fill(&e, Scalar::I32(3), 100, 2);
        let b = FmMatrix::fill(&e, Scalar::F64(0.5), 100, 2);
        let c = a.add(&b).unwrap();
        assert_eq!(c.dtype(), DType::F64);
        assert_eq!(c.sum().unwrap(), 700.0);
    }
}
