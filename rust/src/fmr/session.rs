//! Multi-tenant sessions: many independent callers sharing one engine's
//! §III-B3 memory hierarchy.
//!
//! A [`Session`] is a tenant of a root [`Engine`]: it carries its own
//! `EngineConfig` (threads, optimizer toggles, laziness policy), its own
//! [`Metrics`], chunk pool and plan cache, but shares the parent's
//! simulated SSD and write-through [`crate::matrix::PartitionCache`].
//! The cache registers the session as a tenant so that
//!
//! * cache-resident matrices the session materializes are charged to its
//!   fair-share budget (`EngineConfig::session_mem_bytes`, or an equal
//!   split of the cache when 0), and one tenant's streaming scan evicts
//!   its own LRU entries before touching another tenant's working set;
//! * its hits/misses/evictions land in its own `Metrics`, so per-tenant
//!   hit rates are observable;
//! * its share of the write-back dirty queue is bounded, so a bursting
//!   tenant blocks on its own quota instead of starving the others.
//!
//! Concurrent passes from different sessions are safe: each pass holds
//! its own prefetch generation ([`crate::matrix::cache::PassGuard`]),
//! and `EngineConfig::max_concurrent_passes` on the root engine bounds
//! how many run at once. Dropping the `Session` unregisters the tenant
//! and releases its cache accounting.

use std::sync::Arc;

use crate::config::EngineConfig;
use crate::error::Result;
use crate::metrics::Metrics;

use super::Engine;

/// One tenant of a shared engine. Cloneable handle; the underlying
/// session engine (and its cache registration) lives until the last
/// clone drops.
#[derive(Clone)]
pub struct Session {
    eng: Arc<Engine>,
}

impl Session {
    /// Open a session against `parent`, sharing its storage and cache.
    /// `config` is this tenant's private configuration; cache-level knobs
    /// are inherited from the parent (see [`Engine::session`]).
    pub fn open(parent: &Arc<Engine>, config: EngineConfig) -> Result<Session> {
        Ok(Session {
            eng: Engine::session(parent, config)?,
        })
    }

    /// The session's engine: pass it anywhere an `Arc<Engine>` goes
    /// (`FmMatrix` constructors, `datasets::*`, `algs::*`).
    pub fn engine(&self) -> &Arc<Engine> {
        &self.eng
    }

    /// Cache tenant id (0 means the parent had no partition cache and
    /// the session runs unaccounted).
    pub fn id(&self) -> u64 {
        self.eng.session_id()
    }

    /// This tenant's private metrics.
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.eng.metrics
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::Scalar;
    use crate::fmr::EngineExt;
    use crate::testutil::{out_of_core_config, TempDir};

    #[test]
    fn sessions_share_cache_with_private_metrics() {
        let dir = TempDir::new("session-shared");
        let root = Engine::new(out_of_core_config(dir.path())).unwrap();
        let s1 = Session::open(&root, out_of_core_config(dir.path())).unwrap();
        let s2 = Session::open(&root, out_of_core_config(dir.path())).unwrap();
        assert_ne!(s1.id(), 0);
        assert_ne!(s1.id(), s2.id());
        assert_eq!(root.cache.as_ref().unwrap().session_count(), 2);

        let a = s1.engine().fill(Scalar::F64(2.0), 40_000, 4);
        let b = s2.engine().fill(Scalar::F64(3.0), 40_000, 4);
        let sa = a.materialize().unwrap().sum().unwrap();
        let sb = b.materialize().unwrap().sum().unwrap();
        assert_eq!(sa, 2.0 * 40_000.0 * 4.0);
        assert_eq!(sb, 3.0 * 40_000.0 * 4.0);

        // each tenant's pass activity lands in its own metrics, not the
        // root's pass counters
        assert!(s1.metrics().snapshot().passes_run > 0);
        assert!(s2.metrics().snapshot().passes_run > 0);

        drop(s1);
        drop(s2);
        assert_eq!(root.cache.as_ref().unwrap().session_count(), 0);
    }

    #[test]
    fn session_results_match_root_results() {
        let dir = TempDir::new("session-parity");
        let root = Engine::new(out_of_core_config(dir.path())).unwrap();
        let via_root = {
            let x = root.runif_matrix(30_000, 4, -1.0, 1.0, 11);
            x.sq().unwrap().sum().unwrap()
        };
        let s = Session::open(&root, out_of_core_config(dir.path())).unwrap();
        let via_session = {
            let x = s.engine().runif_matrix(30_000, 4, -1.0, 1.0, 11);
            x.sq().unwrap().sum().unwrap()
        };
        assert_eq!(via_root.to_bits(), via_session.to_bits());
    }
}
