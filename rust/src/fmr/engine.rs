//! The engine: shared state behind every `FmMatrix`.

use std::path::Path;
use std::sync::{Arc, Mutex, OnceLock};

use crate::config::EngineConfig;
use crate::dag::{SinkResult, SinkSpec};
use crate::error::Result;
use crate::exec::ExecCtx;
use crate::matrix::{Matrix, PartitionCache};
use crate::mem::ChunkPool;
use crate::metrics::Metrics;
use crate::plan::{self, PlanOutput, PlanRequest, Planner};
use crate::runtime::XlaService;
use crate::storage::SsdSim;
use crate::vudf::VudfRegistry;

/// One FlashMatrix engine: configuration, memory pool, storage model,
/// the write-through matrix cache, metrics, the VUDF registry and
/// (lazily) the XLA service.
pub struct Engine {
    pub config: EngineConfig,
    pub pool: ChunkPool,
    pub metrics: Arc<Metrics>,
    pub ssd: Arc<SsdSim>,
    /// Write-through partition cache shared by every EM matrix of this
    /// engine (§III-B3); `None` when `em_cache_bytes == 0`.
    pub cache: Option<Arc<PartitionCache>>,
    pub registry: VudfRegistry,
    xla: OnceLock<Option<XlaService>>,
    /// Serializes whole-DAG materialization passes when needed by tests.
    pub pass_lock: Mutex<()>,
    /// Cross-pass optimizer state (`config.cross_pass_opt`): recurrence
    /// counters, materialize-vs-recompute decisions, the memoized shared
    /// intermediates, and the shape-keyed plan cache. See [`crate::plan`].
    planner: Mutex<Planner>,
    /// Cache tenant id of this engine: 0 for a root engine (the cache's
    /// implicit default tenant), non-zero for a [`Engine::session`]
    /// engine registered with a shared parent cache. Unregistered on drop.
    cache_session: u64,
}

impl Engine {
    /// Build an engine from a validated configuration.
    pub fn new(config: EngineConfig) -> Result<Arc<Engine>> {
        config.validate()?;
        let metrics = Arc::new(Metrics::new());
        let pool = ChunkPool::new(config.chunk_bytes, config.recycle_chunks, Arc::clone(&metrics));
        let ssd = Arc::new(SsdSim::with_policy(
            config.throttle.as_ref(),
            config.fault_injection.clone(),
            config.io_retry_limit,
            config.io_checksums,
        ));
        let cache = if config.em_cache_bytes > 0 {
            Some(PartitionCache::new(
                config.em_cache_bytes,
                config.prefetch_depth,
                // the cache hosts the write-back writer thread; 0 keeps
                // the write path synchronous write-through
                if config.writeback {
                    config.writeback_queue_bytes
                } else {
                    0
                },
                Arc::clone(&metrics),
            ))
        } else {
            None
        };
        if let Some(c) = &cache {
            c.set_max_concurrent_passes(config.max_concurrent_passes);
        }
        Ok(Arc::new(Engine {
            config,
            pool,
            metrics,
            ssd,
            cache,
            registry: VudfRegistry::new(),
            xla: OnceLock::new(),
            pass_lock: Mutex::new(()),
            planner: Mutex::new(Planner::new()),
            cache_session: 0,
        }))
    }

    /// Derive a *session engine* sharing this engine's storage model and
    /// write-through partition cache, but carrying its own configuration,
    /// metrics, chunk pool, plan cache and VUDF registry — one tenant of
    /// the multi-tenant serving surface. The session is registered with
    /// the shared cache (`config.session_mem_bytes` is its fair-share
    /// eviction budget; 0 = an equal split of the cache) and unregistered
    /// when the returned engine drops. Cache-resident matrices the
    /// session materializes are charged to its budget, and its cache
    /// hits/misses land in its own [`Metrics`].
    ///
    /// Cache-level knobs (`em_cache_bytes`, `prefetch_depth`,
    /// `writeback*`, throttle/fault policy) stay the parent's: sessions
    /// share one §III-B3 hierarchy by construction.
    pub fn session(parent: &Arc<Engine>, mut config: EngineConfig) -> Result<Arc<Engine>> {
        // the shared hierarchy is the parent's; keep the session's copy
        // of these knobs truthful so `ctx()` decisions match it
        config.em_cache_bytes = parent.config.em_cache_bytes;
        config.prefetch_depth = parent.config.prefetch_depth;
        config.writeback = parent.config.writeback;
        config.writeback_queue_bytes = parent.config.writeback_queue_bytes;
        config.validate()?;
        let metrics = Arc::new(Metrics::new());
        let pool = ChunkPool::new(config.chunk_bytes, config.recycle_chunks, Arc::clone(&metrics));
        let cache_session = parent
            .cache
            .as_ref()
            .map(|c| c.register_session(Arc::clone(&metrics), config.session_mem_bytes))
            .unwrap_or(0);
        Ok(Arc::new(Engine {
            config,
            pool,
            metrics,
            ssd: Arc::clone(&parent.ssd),
            cache: parent.cache.clone(),
            registry: VudfRegistry::new(),
            xla: OnceLock::new(),
            pass_lock: Mutex::new(()),
            planner: Mutex::new(Planner::new()),
            cache_session,
        }))
    }

    /// Cache tenant id of this engine (0 = root tenant).
    pub fn session_id(&self) -> u64 {
        self.cache_session
    }

    /// Default in-memory engine.
    pub fn default_engine() -> Result<Arc<Engine>> {
        Engine::new(EngineConfig::default())
    }

    /// Execution context for a pass.
    pub fn ctx(&self) -> ExecCtx<'_> {
        ExecCtx {
            config: &self.config,
            pool: &self.pool,
            metrics: &self.metrics,
            ssd: &self.ssd,
            cache: self.cache.clone(),
            session: self.cache_session,
        }
    }

    /// The XLA service, started on first use. Returns `None` when
    /// `xla_dispatch` is off or the artifacts directory is unusable (the
    /// engine then runs fully native, like the paper without BLAS).
    pub fn xla(&self) -> Option<&XlaService> {
        self.xla
            .get_or_init(|| {
                if !self.config.xla_dispatch {
                    return None;
                }
                match XlaService::start(Path::new(&self.config.artifacts_dir)) {
                    Ok(s) => Some(s),
                    Err(e) => {
                        eprintln!(
                            "flashmatrix: XLA dispatch disabled ({e}); running native GenOps only"
                        );
                        None
                    }
                }
            })
            .as_ref()
    }

    /// Materialize several virtual matrices in one fused pass. With
    /// `cross_pass_opt` the batch goes through the [`crate::plan`]
    /// optimizer first (CSE, duplicate pruning, memoized intermediates);
    /// the single-pass contract and all results are unchanged.
    pub fn materialize(&self, targets: &[Matrix]) -> Result<Vec<Matrix>> {
        if !self.config.cross_pass_opt || targets.is_empty() {
            return crate::exec::materialize(&self.ctx(), targets);
        }
        let reqs: Vec<PlanRequest> = targets.iter().map(PlanRequest::target).collect();
        let out = plan::execute_batch(&self.ctx(), &self.planner, &reqs, true)?;
        Ok(out.into_iter().map(PlanOutput::target).collect())
    }

    /// Materialize one-shot intermediates (the eager mode's per-operation
    /// results). They are written through to storage like any matrix but
    /// are **not** admitted to the partition cache: data read exactly once
    /// would only evict reusable partitions (§III-B3 residency policy).
    pub fn materialize_intermediate(&self, targets: &[Matrix]) -> Result<Vec<Matrix>> {
        Ok(crate::exec::run_pass_opts(&self.ctx(), targets, &[], None, false)?.0)
    }

    /// Materialize several sinks in one fused pass (`fm.materialize`).
    /// Optimized like [`Engine::materialize`] when `cross_pass_opt` is on.
    pub fn materialize_sinks(&self, sinks: &[SinkSpec]) -> Result<Vec<SinkResult>> {
        if !self.config.cross_pass_opt || sinks.is_empty() {
            return crate::exec::materialize_sinks(&self.ctx(), sinks);
        }
        let reqs: Vec<PlanRequest> = sinks
            .iter()
            .map(|s| PlanRequest::Sink(clone_sink(s)))
            .collect();
        let out = plan::execute_batch(&self.ctx(), &self.planner, &reqs, true)?;
        Ok(out.into_iter().map(PlanOutput::sink).collect())
    }

    /// Mixed pass: targets + sinks share one scan (§III-F). Optimized
    /// like [`Engine::materialize`] when `cross_pass_opt` is on.
    pub fn run_pass(
        &self,
        targets: &[Matrix],
        sinks: &[SinkSpec],
    ) -> Result<(Vec<Matrix>, Vec<SinkResult>)> {
        if !self.config.cross_pass_opt || (targets.is_empty() && sinks.is_empty()) {
            return crate::exec::run_pass(&self.ctx(), targets, sinks);
        }
        let reqs: Vec<PlanRequest> = targets
            .iter()
            .map(PlanRequest::target)
            .chain(sinks.iter().map(|s| PlanRequest::Sink(clone_sink(s))))
            .collect();
        let out = plan::execute_batch(&self.ctx(), &self.planner, &reqs, true)?;
        let mut ms = Vec::with_capacity(targets.len());
        let mut rs = Vec::with_capacity(sinks.len());
        for o in out {
            match o {
                PlanOutput::Target(m) => ms.push(m),
                PlanOutput::Sink(r) => rs.push(r),
            }
        }
        Ok((ms, rs))
    }

    /// Plan and run a batch of *independent* forced materializations —
    /// one R statement each, typically everything an iterative algorithm
    /// needs per iteration. Unlike [`Engine::run_pass`] the batch is not
    /// promised to be a single pass: with `cross_pass_opt` the planner
    /// fuses requests into as few passes as the bit-identity geometry
    /// guards allow; with it off, each request runs as its own pass
    /// (eager-R semantics), so the optimizer's pass savings are visible
    /// in `passes_run` / `io_read_bytes`.
    pub fn plan_batch(&self, requests: &[PlanRequest]) -> Result<Vec<PlanOutput>> {
        plan::execute_batch(&self.ctx(), &self.planner, requests, false)
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        if self.cache_session != 0 {
            if let Some(c) = &self.cache {
                c.unregister_session(self.cache_session);
            }
        }
    }
}

/// `SinkSpec` is intentionally not `Clone` (sinks are single-use by
/// convention); the planner needs value copies to canonicalize.
fn clone_sink(s: &SinkSpec) -> SinkSpec {
    let parents: Vec<Matrix> = s.kind.parents().into_iter().cloned().collect();
    SinkSpec {
        source: s.source.clone(),
        kind: s.kind.with_parents(&parents),
    }
}
