//! The engine: shared state behind every `FmMatrix`.

use std::path::Path;
use std::sync::{Arc, Mutex, OnceLock};

use crate::config::EngineConfig;
use crate::dag::{SinkResult, SinkSpec};
use crate::error::Result;
use crate::exec::ExecCtx;
use crate::matrix::{Matrix, PartitionCache};
use crate::mem::ChunkPool;
use crate::metrics::Metrics;
use crate::runtime::XlaService;
use crate::storage::SsdSim;
use crate::vudf::VudfRegistry;

/// One FlashMatrix engine: configuration, memory pool, storage model,
/// the write-through matrix cache, metrics, the VUDF registry and
/// (lazily) the XLA service.
pub struct Engine {
    pub config: EngineConfig,
    pub pool: ChunkPool,
    pub metrics: Arc<Metrics>,
    pub ssd: Arc<SsdSim>,
    /// Write-through partition cache shared by every EM matrix of this
    /// engine (§III-B3); `None` when `em_cache_bytes == 0`.
    pub cache: Option<Arc<PartitionCache>>,
    pub registry: VudfRegistry,
    xla: OnceLock<Option<XlaService>>,
    /// Serializes whole-DAG materialization passes when needed by tests.
    pub pass_lock: Mutex<()>,
}

impl Engine {
    /// Build an engine from a validated configuration.
    pub fn new(config: EngineConfig) -> Result<Arc<Engine>> {
        config.validate()?;
        let metrics = Arc::new(Metrics::new());
        let pool = ChunkPool::new(config.chunk_bytes, config.recycle_chunks, Arc::clone(&metrics));
        let ssd = Arc::new(SsdSim::new(config.throttle.as_ref()));
        let cache = if config.em_cache_bytes > 0 {
            Some(PartitionCache::new(
                config.em_cache_bytes,
                config.prefetch_depth,
                // the cache hosts the write-back writer thread; 0 keeps
                // the write path synchronous write-through
                if config.writeback {
                    config.writeback_queue_bytes
                } else {
                    0
                },
                Arc::clone(&metrics),
            ))
        } else {
            None
        };
        Ok(Arc::new(Engine {
            config,
            pool,
            metrics,
            ssd,
            cache,
            registry: VudfRegistry::new(),
            xla: OnceLock::new(),
            pass_lock: Mutex::new(()),
        }))
    }

    /// Default in-memory engine.
    pub fn default_engine() -> Result<Arc<Engine>> {
        Engine::new(EngineConfig::default())
    }

    /// Execution context for a pass.
    pub fn ctx(&self) -> ExecCtx<'_> {
        ExecCtx {
            config: &self.config,
            pool: &self.pool,
            metrics: &self.metrics,
            ssd: &self.ssd,
            cache: self.cache.clone(),
        }
    }

    /// The XLA service, started on first use. Returns `None` when
    /// `xla_dispatch` is off or the artifacts directory is unusable (the
    /// engine then runs fully native, like the paper without BLAS).
    pub fn xla(&self) -> Option<&XlaService> {
        self.xla
            .get_or_init(|| {
                if !self.config.xla_dispatch {
                    return None;
                }
                match XlaService::start(Path::new(&self.config.artifacts_dir)) {
                    Ok(s) => Some(s),
                    Err(e) => {
                        eprintln!(
                            "flashmatrix: XLA dispatch disabled ({e}); running native GenOps only"
                        );
                        None
                    }
                }
            })
            .as_ref()
    }

    /// Materialize several virtual matrices in one fused pass.
    pub fn materialize(&self, targets: &[Matrix]) -> Result<Vec<Matrix>> {
        crate::exec::materialize(&self.ctx(), targets)
    }

    /// Materialize one-shot intermediates (the eager mode's per-operation
    /// results). They are written through to storage like any matrix but
    /// are **not** admitted to the partition cache: data read exactly once
    /// would only evict reusable partitions (§III-B3 residency policy).
    pub fn materialize_intermediate(&self, targets: &[Matrix]) -> Result<Vec<Matrix>> {
        Ok(crate::exec::run_pass_opts(&self.ctx(), targets, &[], None, false)?.0)
    }

    /// Materialize several sinks in one fused pass (`fm.materialize`).
    pub fn materialize_sinks(&self, sinks: &[SinkSpec]) -> Result<Vec<SinkResult>> {
        crate::exec::materialize_sinks(&self.ctx(), sinks)
    }

    /// Mixed pass: targets + sinks share one scan (§III-F).
    pub fn run_pass(
        &self,
        targets: &[Matrix],
        sinks: &[SinkSpec],
    ) -> Result<(Vec<Matrix>, Vec<SinkResult>)> {
        crate::exec::run_pass(&self.ctx(), targets, sinks)
    }
}
