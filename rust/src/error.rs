//! Engine-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (no `thiserror`): the crate is
//! std-only so `cargo build` works without a network or vendored deps.

use std::fmt;

/// Errors surfaced by the FlashMatrix engine.
#[derive(Debug)]
pub enum FmError {
    Shape(String),
    DType(String),
    Unsupported(String),
    Storage(String),
    Runtime(String),
    Config(String),
    Io(std::io::Error),
    Json(String),
    /// Data failed an integrity check (partition checksum mismatch that
    /// survived a re-read, or a structurally invalid CSR block). Unlike
    /// [`FmError::Io`] this is *not* retried: the bytes are wrong, not
    /// merely unavailable.
    Corrupt(String),
    /// Delimited-text ingestion rejected the input. Carries the source
    /// file, the 1-based line within it, the 1-based column (field)
    /// index, and what was wrong — malformed input is a *data* problem
    /// the caller must see precisely located, not an I/O condition.
    Parse {
        file: String,
        line: u64,
        col: u64,
        msg: String,
    },
}

impl fmt::Display for FmError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FmError::Shape(m) => write!(f, "shape mismatch: {m}"),
            FmError::DType(m) => write!(f, "dtype error: {m}"),
            FmError::Unsupported(m) => write!(f, "unsupported operation: {m}"),
            FmError::Storage(m) => write!(f, "storage error: {m}"),
            FmError::Runtime(m) => write!(f, "runtime (XLA) error: {m}"),
            FmError::Config(m) => write!(f, "configuration error: {m}"),
            FmError::Io(e) => write!(f, "{e}"),
            FmError::Json(m) => write!(f, "json error: {m}"),
            FmError::Corrupt(m) => write!(f, "data corruption: {m}"),
            FmError::Parse {
                file,
                line,
                col,
                msg,
            } => write!(f, "parse error: {file}:{line}:{col}: {msg}"),
        }
    }
}

impl std::error::Error for FmError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            FmError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for FmError {
    fn from(e: std::io::Error) -> Self {
        FmError::Io(e)
    }
}

// The `xla` name resolves to the in-tree stub unless the real crate is
// wired in (see src/xla_stub.rs).
use crate::xla_stub as xla;

impl From<xla::Error> for FmError {
    fn from(e: xla::Error) -> Self {
        FmError::Runtime(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, FmError>;

/// Shorthand for shape errors.
pub fn shape_err<T>(msg: impl Into<String>) -> Result<T> {
    Err(FmError::Shape(msg.into()))
}
