//! Engine-wide error type.

use thiserror::Error;

/// Errors surfaced by the FlashMatrix engine.
#[derive(Error, Debug)]
pub enum FmError {
    #[error("shape mismatch: {0}")]
    Shape(String),
    #[error("dtype error: {0}")]
    DType(String),
    #[error("unsupported operation: {0}")]
    Unsupported(String),
    #[error("storage error: {0}")]
    Storage(String),
    #[error("runtime (XLA) error: {0}")]
    Runtime(String),
    #[error("configuration error: {0}")]
    Config(String),
    #[error(transparent)]
    Io(#[from] std::io::Error),
    #[error("json error: {0}")]
    Json(String),
}

impl From<xla::Error> for FmError {
    fn from(e: xla::Error) -> Self {
        FmError::Runtime(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, FmError>;

/// Shorthand for shape errors.
pub fn shape_err<T>(msg: impl Into<String>) -> Result<T> {
    Err(FmError::Shape(msg.into()))
}
