//! Built-in VUDF operations: unary, binary and aggregation kernels.
//!
//! Each op is enum-dispatched once per *vector*, and the per-type inner
//! loops are monomorphic straight-line code the compiler auto-vectorizes —
//! this is the paper's VUDF fast path. `*_scalar_mode` variants route every
//! element through an opaque function pointer (one call per element), the
//! behaviour of R/MLlib that Fig 12's ablation measures.

use std::hint::black_box;

use crate::dtype::{DType, Scalar};
use crate::error::{FmError, Result};

use super::buf::Buf;
use super::BroadcastSide;

/// Unary built-ins (`fm.sapply` operations, Table III).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum UnOp {
    Neg,
    Abs,
    Sqrt,
    /// x^2 — used by L2-norm / variance pipelines.
    Sq,
    Exp,
    Log,
    Floor,
    Ceil,
    Round,
    Sign,
    /// logical negation (Bool output)
    Not,
    /// x != 0 (Bool output) — the nnz test.
    NotZero,
    /// NaN test (Bool output) — R's is.na on doubles.
    IsNa,
}

impl UnOp {
    /// Output dtype for a given input dtype (float ops promote ints).
    pub fn out_dtype(self, input: DType) -> DType {
        match self {
            UnOp::Not | UnOp::NotZero | UnOp::IsNa => DType::Bool,
            UnOp::Sqrt | UnOp::Exp | UnOp::Log => {
                if input == DType::F32 {
                    DType::F32
                } else {
                    DType::F64
                }
            }
            _ => {
                if input == DType::Bool {
                    DType::I32
                } else {
                    input
                }
            }
        }
    }

    fn f64_fn(self) -> fn(f64) -> f64 {
        match self {
            UnOp::Neg => |x| -x,
            UnOp::Abs => f64::abs,
            UnOp::Sqrt => f64::sqrt,
            UnOp::Sq => |x| x * x,
            UnOp::Exp => f64::exp,
            UnOp::Log => f64::ln,
            UnOp::Floor => f64::floor,
            UnOp::Ceil => f64::ceil,
            UnOp::Round => |x| x.round_ties_even(),
            UnOp::Sign => |x| {
                if x > 0.0 {
                    1.0
                } else if x < 0.0 {
                    -1.0
                } else {
                    0.0
                }
            },
            UnOp::Not | UnOp::NotZero => |x| (x != 0.0) as u8 as f64,
            UnOp::IsNa => |x| x.is_nan() as u8 as f64,
        }
    }

    /// Scalar f64 semantic of the op, inlined. The fused-chain hot loop
    /// uses this instead of [`UnOp::f64_fn`]'s function pointer so the
    /// per-element dispatch stays a predictable branch, not an indirect
    /// call. Must agree with `f64_fn` (pinned by `eval_matches_fn` below).
    #[inline(always)]
    pub fn eval_f64(self, x: f64) -> f64 {
        match self {
            UnOp::Neg => -x,
            UnOp::Abs => x.abs(),
            UnOp::Sqrt => x.sqrt(),
            UnOp::Sq => x * x,
            UnOp::Exp => x.exp(),
            UnOp::Log => x.ln(),
            UnOp::Floor => x.floor(),
            UnOp::Ceil => x.ceil(),
            UnOp::Round => x.round_ties_even(),
            UnOp::Sign => {
                if x > 0.0 {
                    1.0
                } else if x < 0.0 {
                    -1.0
                } else {
                    0.0
                }
            }
            UnOp::Not | UnOp::NotZero => (x != 0.0) as u8 as f64,
            UnOp::IsNa => x.is_nan() as u8 as f64,
        }
    }

    /// Ops whose in-place form is bit-identical to [`UnOp::apply`] /
    /// [`UnOp::apply_scalar_mode`]: the output dtype equals the input
    /// dtype (the buffer can be rewritten in place) and the per-element
    /// operation matches the out-of-place kernel exactly. Bool is
    /// excluded — its ops are cheap and rare mid-pipeline.
    pub fn supports_inplace(self, input: DType) -> bool {
        input != DType::Bool && self.out_dtype(input) == input
    }

    /// Apply in place on a dead register's buffer (the liveness-driven
    /// register-reuse fast path). Caller must check
    /// [`UnOp::supports_inplace`]. `vectorized = false` mirrors
    /// `apply_scalar_mode`'s per-element boxed calls so the Fig 12
    /// ablation keeps measuring what it measures.
    pub fn apply_inplace(self, a: &mut Buf, vectorized: bool) {
        debug_assert!(self.supports_inplace(a.dtype()));
        if !vectorized {
            // out dtype == input dtype, so writing through set() takes
            // exactly apply_scalar_mode's conversion path
            let f = black_box(self.f64_fn());
            for i in 0..a.len() {
                let x = black_box(a.get(i).as_f64());
                a.set(i, Scalar::F64(f(x)));
            }
            return;
        }
        let f = self.f64_fn();
        match a {
            // f64: the monomorphic arms of `apply` and its generic path
            // agree with f64_fn, so one loop covers every op
            Buf::F64(v) => {
                for x in v.iter_mut() {
                    *x = f(*x);
                }
            }
            // same-type arms mirror `apply`'s monomorphic kernels; the
            // rest mirror its generic through-f64 path
            Buf::F32(v) => match self {
                UnOp::Neg => {
                    for x in v.iter_mut() {
                        *x = -*x;
                    }
                }
                UnOp::Abs => {
                    for x in v.iter_mut() {
                        *x = x.abs();
                    }
                }
                UnOp::Sq => {
                    for x in v.iter_mut() {
                        *x = *x * *x;
                    }
                }
                _ => {
                    for x in v.iter_mut() {
                        *x = f(*x as f64) as f32;
                    }
                }
            },
            Buf::I64(v) => match self {
                UnOp::Neg => {
                    for x in v.iter_mut() {
                        *x = -*x;
                    }
                }
                UnOp::Abs => {
                    for x in v.iter_mut() {
                        *x = x.abs();
                    }
                }
                UnOp::Sq => {
                    for x in v.iter_mut() {
                        *x = *x * *x;
                    }
                }
                _ => {
                    for x in v.iter_mut() {
                        *x = f(*x as f64) as i64;
                    }
                }
            },
            Buf::I32(v) => match self {
                UnOp::Neg => {
                    for x in v.iter_mut() {
                        *x = -*x;
                    }
                }
                _ => {
                    for x in v.iter_mut() {
                        *x = f(*x as f64) as i32;
                    }
                }
            },
            Buf::Bool(_) => unreachable!("supports_inplace excludes Bool"),
        }
    }

    /// Vectorized apply (uVUDF form).
    pub fn apply(self, a: &Buf) -> Result<Buf> {
        let out_dt = self.out_dtype(a.dtype());
        // Bool outputs and promotions go through a generic f64 path; the
        // hot same-type numeric cases get monomorphic loops.
        match (self, a) {
            (UnOp::Neg, Buf::F64(v)) => Ok(Buf::F64(v.iter().map(|x| -x).collect())),
            (UnOp::Abs, Buf::F64(v)) => Ok(Buf::F64(v.iter().map(|x| x.abs()).collect())),
            (UnOp::Sq, Buf::F64(v)) => Ok(Buf::F64(v.iter().map(|x| x * x).collect())),
            (UnOp::Sqrt, Buf::F64(v)) => Ok(Buf::F64(v.iter().map(|x| x.sqrt()).collect())),
            (UnOp::Exp, Buf::F64(v)) => Ok(Buf::F64(v.iter().map(|x| x.exp()).collect())),
            (UnOp::Log, Buf::F64(v)) => Ok(Buf::F64(v.iter().map(|x| x.ln()).collect())),
            (UnOp::Neg, Buf::F32(v)) => Ok(Buf::F32(v.iter().map(|x| -x).collect())),
            (UnOp::Abs, Buf::F32(v)) => Ok(Buf::F32(v.iter().map(|x| x.abs()).collect())),
            (UnOp::Sq, Buf::F32(v)) => Ok(Buf::F32(v.iter().map(|x| x * x).collect())),
            (UnOp::Neg, Buf::I64(v)) => Ok(Buf::I64(v.iter().map(|x| -x).collect())),
            (UnOp::Abs, Buf::I64(v)) => Ok(Buf::I64(v.iter().map(|x| x.abs()).collect())),
            (UnOp::Sq, Buf::I64(v)) => Ok(Buf::I64(v.iter().map(|x| x * x).collect())),
            (UnOp::Neg, Buf::I32(v)) => Ok(Buf::I32(v.iter().map(|x| -x).collect())),
            (UnOp::NotZero, Buf::F64(v)) => Ok(Buf::Bool(v.iter().map(|x| *x != 0.0).collect())),
            (UnOp::Not, Buf::Bool(v)) => Ok(Buf::Bool(v.iter().map(|x| !x).collect())),
            (UnOp::IsNa, Buf::F64(v)) => Ok(Buf::Bool(v.iter().map(|x| x.is_nan()).collect())),
            _ => {
                // generic path: via f64
                let f = self.f64_fn();
                let tmp: Vec<f64> = a.to_f64_vec().iter().map(|x| f(*x)).collect();
                Buf::F64(tmp).cast(out_dt)
            }
        }
    }

    /// Per-element boxed-call mode (Fig 12 ablation / MLlib-like baseline).
    pub fn apply_scalar_mode(self, a: &Buf) -> Result<Buf> {
        let out_dt = self.out_dtype(a.dtype());
        let f = black_box(self.f64_fn());
        let mut out = Buf::alloc(out_dt, a.len());
        for i in 0..a.len() {
            let x = black_box(a.get(i).as_f64());
            out.set(i, Scalar::F64(f(x)));
        }
        Ok(out)
    }
}

/// Binary built-ins (element-wise R operators, Table III).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Pow,
    Min,
    Max,
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
    And,
    Or,
    /// `ifelse0`: keep left where right (a mask) is zero/false, else 0 —
    /// the paper's missing-value replacement VUDF (Fig 5).
    IfElse0,
}

impl BinOp {
    pub fn is_comparison(self) -> bool {
        matches!(
            self,
            BinOp::Eq | BinOp::Ne | BinOp::Lt | BinOp::Le | BinOp::Gt | BinOp::Ge
        )
    }

    pub fn is_logical(self) -> bool {
        matches!(self, BinOp::And | BinOp::Or)
    }

    /// Output dtype for same-typed operands.
    pub fn out_dtype(self, input: DType) -> DType {
        if self.is_comparison() || self.is_logical() {
            DType::Bool
        } else {
            match self {
                // R: integer division returns double; int pow returns double
                BinOp::Div | BinOp::Pow if !input.is_float() => DType::F64,
                _ => {
                    if input == DType::Bool {
                        DType::I32
                    } else {
                        input
                    }
                }
            }
        }
    }

    fn f64_fn(self) -> fn(f64, f64) -> f64 {
        match self {
            BinOp::Add => |a, b| a + b,
            BinOp::Sub => |a, b| a - b,
            BinOp::Mul => |a, b| a * b,
            BinOp::Div => |a, b| a / b,
            BinOp::Pow => f64::powf,
            BinOp::Min => f64::min,
            BinOp::Max => f64::max,
            BinOp::Eq => |a, b| (a == b) as u8 as f64,
            BinOp::Ne => |a, b| (a != b) as u8 as f64,
            BinOp::Lt => |a, b| (a < b) as u8 as f64,
            BinOp::Le => |a, b| (a <= b) as u8 as f64,
            BinOp::Gt => |a, b| (a > b) as u8 as f64,
            BinOp::Ge => |a, b| (a >= b) as u8 as f64,
            BinOp::And => |a, b| ((a != 0.0) && (b != 0.0)) as u8 as f64,
            BinOp::Or => |a, b| ((a != 0.0) || (b != 0.0)) as u8 as f64,
            BinOp::IfElse0 => |a, b| if b != 0.0 { 0.0 } else { a },
        }
    }

    /// Scalar f64 semantic of the op, inlined (the fused-chain hot loop —
    /// see [`UnOp::eval_f64`]). Pinned to `f64_fn` by `eval_matches_fn`.
    #[inline(always)]
    pub fn eval_f64(self, a: f64, b: f64) -> f64 {
        match self {
            BinOp::Add => a + b,
            BinOp::Sub => a - b,
            BinOp::Mul => a * b,
            BinOp::Div => a / b,
            BinOp::Pow => a.powf(b),
            BinOp::Min => a.min(b),
            BinOp::Max => a.max(b),
            BinOp::Eq => (a == b) as u8 as f64,
            BinOp::Ne => (a != b) as u8 as f64,
            BinOp::Lt => (a < b) as u8 as f64,
            BinOp::Le => (a <= b) as u8 as f64,
            BinOp::Gt => (a > b) as u8 as f64,
            BinOp::Ge => (a >= b) as u8 as f64,
            BinOp::And => ((a != 0.0) && (b != 0.0)) as u8 as f64,
            BinOp::Or => ((a != 0.0) || (b != 0.0)) as u8 as f64,
            BinOp::IfElse0 => {
                if b != 0.0 {
                    0.0
                } else {
                    a
                }
            }
        }
    }

    /// Broadcast (vector ⊕ scalar) forms whose in-place variant is
    /// bit-identical to the out-of-place path: output dtype equals the
    /// vector dtype. Bool is excluded (see [`UnOp::supports_inplace`]).
    pub fn supports_inplace_broadcast(self, input: DType) -> bool {
        input != DType::Bool && self.out_dtype(input) == input
    }

    /// In-place bVUDF2/3: vector ⊕ scalar written back into the vector's
    /// own buffer. Caller must check [`BinOp::supports_inplace_broadcast`].
    /// `s` is cast to the buffer dtype first, exactly like
    /// [`crate::vudf::binary_vs`] / [`crate::vudf::binary_sv`] do;
    /// `vectorized = false` mirrors `apply_broadcast_scalar_mode`.
    pub fn apply_broadcast_inplace(
        self,
        v: &mut Buf,
        s: Scalar,
        scalar_right: bool,
        vectorized: bool,
    ) {
        debug_assert!(self.supports_inplace_broadcast(v.dtype()));
        let sf = s.cast(v.dtype()).as_f64();
        let f = self.f64_fn();
        if !vectorized {
            let f = black_box(f);
            for i in 0..v.len() {
                let x = black_box(v.get(i).as_f64());
                let r = if scalar_right { f(x, sf) } else { f(sf, x) };
                v.set(i, Scalar::F64(r));
            }
            return;
        }
        match v {
            // f64: `apply_broadcast`'s monomorphic arms and its generic
            // path both agree with f64_fn
            Buf::F64(vv) => {
                if scalar_right {
                    for x in vv.iter_mut() {
                        *x = f(*x, sf);
                    }
                } else {
                    for x in vv.iter_mut() {
                        *x = f(sf, *x);
                    }
                }
            }
            // no same-dtype monomorphic arms exist for these in
            // `apply_broadcast`; mirror its generic through-f64 path
            Buf::F32(vv) => {
                if scalar_right {
                    for x in vv.iter_mut() {
                        *x = f(*x as f64, sf) as f32;
                    }
                } else {
                    for x in vv.iter_mut() {
                        *x = f(sf, *x as f64) as f32;
                    }
                }
            }
            Buf::I64(vv) => {
                if scalar_right {
                    for x in vv.iter_mut() {
                        *x = f(*x as f64, sf) as i64;
                    }
                } else {
                    for x in vv.iter_mut() {
                        *x = f(sf, *x as f64) as i64;
                    }
                }
            }
            Buf::I32(vv) => {
                if scalar_right {
                    for x in vv.iter_mut() {
                        *x = f(*x as f64, sf) as i32;
                    }
                } else {
                    for x in vv.iter_mut() {
                        *x = f(sf, *x as f64) as i32;
                    }
                }
            }
            Buf::Bool(_) => unreachable!("supports_inplace_broadcast excludes Bool"),
        }
    }

    /// Vectorized elementwise apply (bVUDF1). Operands share a dtype.
    pub fn apply_vv(self, a: &Buf, b: &Buf) -> Result<Buf> {
        macro_rules! arith {
            ($va:expr, $vb:expr, $ctor:path, $f:expr) => {
                Ok($ctor($va.iter().zip($vb.iter()).map(|(x, y)| $f(*x, *y)).collect()))
            };
        }
        match (self, a, b) {
            (BinOp::Add, Buf::F64(x), Buf::F64(y)) => arith!(x, y, Buf::F64, |a: f64, b| a + b),
            (BinOp::Sub, Buf::F64(x), Buf::F64(y)) => arith!(x, y, Buf::F64, |a: f64, b| a - b),
            (BinOp::Mul, Buf::F64(x), Buf::F64(y)) => arith!(x, y, Buf::F64, |a: f64, b| a * b),
            (BinOp::Div, Buf::F64(x), Buf::F64(y)) => arith!(x, y, Buf::F64, |a: f64, b| a / b),
            (BinOp::Min, Buf::F64(x), Buf::F64(y)) => arith!(x, y, Buf::F64, f64::min),
            (BinOp::Max, Buf::F64(x), Buf::F64(y)) => arith!(x, y, Buf::F64, f64::max),
            (BinOp::Add, Buf::F32(x), Buf::F32(y)) => arith!(x, y, Buf::F32, |a: f32, b| a + b),
            (BinOp::Sub, Buf::F32(x), Buf::F32(y)) => arith!(x, y, Buf::F32, |a: f32, b| a - b),
            (BinOp::Mul, Buf::F32(x), Buf::F32(y)) => arith!(x, y, Buf::F32, |a: f32, b| a * b),
            (BinOp::Add, Buf::I64(x), Buf::I64(y)) => arith!(x, y, Buf::I64, |a: i64, b| a + b),
            (BinOp::Sub, Buf::I64(x), Buf::I64(y)) => arith!(x, y, Buf::I64, |a: i64, b| a - b),
            (BinOp::Mul, Buf::I64(x), Buf::I64(y)) => arith!(x, y, Buf::I64, |a: i64, b| a * b),
            (BinOp::Add, Buf::I32(x), Buf::I32(y)) => arith!(x, y, Buf::I32, |a: i32, b| a + b),
            (BinOp::Lt, Buf::F64(x), Buf::F64(y)) => arith!(x, y, Buf::Bool, |a: f64, b| a < b),
            (BinOp::Le, Buf::F64(x), Buf::F64(y)) => arith!(x, y, Buf::Bool, |a: f64, b| a <= b),
            (BinOp::Eq, Buf::F64(x), Buf::F64(y)) => arith!(x, y, Buf::Bool, |a: f64, b| a == b),
            (BinOp::Eq, Buf::I32(x), Buf::I32(y)) => arith!(x, y, Buf::Bool, |a: i32, b| a == b),
            (BinOp::And, Buf::Bool(x), Buf::Bool(y)) => {
                arith!(x, y, Buf::Bool, |a: bool, b| a && b)
            }
            (BinOp::Or, Buf::Bool(x), Buf::Bool(y)) => {
                arith!(x, y, Buf::Bool, |a: bool, b| a || b)
            }
            (BinOp::IfElse0, Buf::F64(x), Buf::F64(y)) => {
                arith!(x, y, Buf::F64, |a: f64, b: f64| if b != 0.0 { 0.0 } else { a })
            }
            _ => {
                // generic path via f64 with a final cast
                let out_dt = self.out_dtype(DType::promote(a.dtype(), b.dtype()));
                let f = self.f64_fn();
                let xa = a.to_f64_vec();
                let xb = b.to_f64_vec();
                let tmp: Vec<f64> = xa.iter().zip(xb.iter()).map(|(x, y)| f(*x, *y)).collect();
                Buf::F64(tmp).cast(out_dt)
            }
        }
    }

    /// Vectorized broadcast apply: bVUDF2 (`side == ScalarRight`) or
    /// bVUDF3 (`side == ScalarLeft`). `scalar` is a 1-element buffer.
    pub fn apply_broadcast(self, v: &Buf, scalar: &Buf, side: BroadcastSide) -> Result<Buf> {
        if scalar.len() != 1 {
            return Err(FmError::Shape("broadcast operand must be length 1".into()));
        }
        macro_rules! bcast {
            ($vv:expr, $s:expr, $ctor:path, $f:expr) => {{
                let s = $s;
                Ok($ctor(match side {
                    BroadcastSide::ScalarRight => $vv.iter().map(|x| $f(*x, s)).collect(),
                    BroadcastSide::ScalarLeft => $vv.iter().map(|x| $f(s, *x)).collect(),
                }))
            }};
        }
        match (self, v, scalar) {
            (BinOp::Add, Buf::F64(x), Buf::F64(s)) => bcast!(x, s[0], Buf::F64, |a: f64, b| a + b),
            (BinOp::Sub, Buf::F64(x), Buf::F64(s)) => bcast!(x, s[0], Buf::F64, |a: f64, b| a - b),
            (BinOp::Mul, Buf::F64(x), Buf::F64(s)) => bcast!(x, s[0], Buf::F64, |a: f64, b| a * b),
            (BinOp::Div, Buf::F64(x), Buf::F64(s)) => bcast!(x, s[0], Buf::F64, |a: f64, b| a / b),
            (BinOp::Min, Buf::F64(x), Buf::F64(s)) => bcast!(x, s[0], Buf::F64, f64::min),
            (BinOp::Max, Buf::F64(x), Buf::F64(s)) => bcast!(x, s[0], Buf::F64, f64::max),
            (BinOp::Lt, Buf::F64(x), Buf::F64(s)) => bcast!(x, s[0], Buf::Bool, |a: f64, b| a < b),
            (BinOp::Gt, Buf::F64(x), Buf::F64(s)) => bcast!(x, s[0], Buf::Bool, |a: f64, b| a > b),
            (BinOp::Eq, Buf::I32(x), Buf::I32(s)) => bcast!(x, s[0], Buf::Bool, |a: i32, b| a == b),
            _ => {
                let out_dt = self.out_dtype(DType::promote(v.dtype(), scalar.dtype()));
                let f = self.f64_fn();
                let s = scalar.get(0).as_f64();
                let xv = v.to_f64_vec();
                let tmp: Vec<f64> = match side {
                    BroadcastSide::ScalarRight => xv.iter().map(|x| f(*x, s)).collect(),
                    BroadcastSide::ScalarLeft => xv.iter().map(|x| f(s, *x)).collect(),
                };
                Buf::F64(tmp).cast(out_dt)
            }
        }
    }

    /// Per-element boxed-call elementwise mode.
    pub fn apply_vv_scalar_mode(self, a: &Buf, b: &Buf) -> Result<Buf> {
        let out_dt = self.out_dtype(DType::promote(a.dtype(), b.dtype()));
        let f = black_box(self.f64_fn());
        let mut out = Buf::alloc(out_dt, a.len());
        for i in 0..a.len() {
            let x = black_box(a.get(i).as_f64());
            let y = black_box(b.get(i).as_f64());
            out.set(i, Scalar::F64(f(x, y)));
        }
        Ok(out)
    }

    /// Per-element boxed-call broadcast mode.
    pub fn apply_broadcast_scalar_mode(
        self,
        v: &Buf,
        scalar: &Buf,
        side: BroadcastSide,
    ) -> Result<Buf> {
        let out_dt = self.out_dtype(DType::promote(v.dtype(), scalar.dtype()));
        let f = black_box(self.f64_fn());
        let s = scalar.get(0).as_f64();
        let mut out = Buf::alloc(out_dt, v.len());
        for i in 0..v.len() {
            let x = black_box(v.get(i).as_f64());
            let r = match side {
                BroadcastSide::ScalarRight => f(x, s),
                BroadcastSide::ScalarLeft => f(s, x),
            };
            out.set(i, Scalar::F64(r));
        }
        Ok(out)
    }
}

/// Aggregation built-ins (aVUDF pairs: `aggregate` + `combine`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AggOp {
    Sum,
    Prod,
    Min,
    Max,
    /// number of elements (combine = Sum)
    Count,
    Any,
    All,
}

impl AggOp {
    /// Accumulator dtype for a given input dtype.
    pub fn acc_dtype(self, input: DType) -> DType {
        match self {
            AggOp::Count => DType::I64,
            AggOp::Any | AggOp::All => DType::Bool,
            AggOp::Sum | AggOp::Prod => {
                if input == DType::Bool {
                    DType::I64
                } else {
                    input
                }
            }
            AggOp::Min | AggOp::Max => input,
        }
    }

    /// Identity element of the accumulator.
    pub fn identity(self, acc_dt: DType) -> Scalar {
        match self {
            AggOp::Sum | AggOp::Count => Scalar::F64(0.0).cast(acc_dt),
            AggOp::Prod => Scalar::F64(1.0).cast(acc_dt),
            AggOp::Min => match acc_dt {
                DType::F64 => Scalar::F64(f64::INFINITY),
                DType::F32 => Scalar::F32(f32::INFINITY),
                DType::I64 => Scalar::I64(i64::MAX),
                DType::I32 => Scalar::I32(i32::MAX),
                DType::Bool => Scalar::Bool(true),
            },
            AggOp::Max => match acc_dt {
                DType::F64 => Scalar::F64(f64::NEG_INFINITY),
                DType::F32 => Scalar::F32(f32::NEG_INFINITY),
                DType::I64 => Scalar::I64(i64::MIN),
                DType::I32 => Scalar::I32(i32::MIN),
                DType::Bool => Scalar::Bool(false),
            },
            AggOp::Any => Scalar::Bool(false),
            AggOp::All => Scalar::Bool(true),
        }
    }

    /// The `combine` half as a scalar fold (merging partials).
    pub fn fold_scalar(self, acc: Scalar, x: Scalar) -> Scalar {
        let dt = acc.dtype();
        match self {
            AggOp::Sum | AggOp::Count => Scalar::F64(acc.as_f64() + x.as_f64()).cast(dt),
            AggOp::Prod => Scalar::F64(acc.as_f64() * x.as_f64()).cast(dt),
            AggOp::Min => {
                if x.as_f64() < acc.as_f64() {
                    x.cast(dt)
                } else {
                    acc
                }
            }
            AggOp::Max => {
                if x.as_f64() > acc.as_f64() {
                    x.cast(dt)
                } else {
                    acc
                }
            }
            AggOp::Any => Scalar::Bool(acc.as_bool() || x.as_bool()),
            AggOp::All => Scalar::Bool(acc.as_bool() && x.as_bool()),
        }
    }

    /// aVUDF1: reduce a vector to one scalar (in the accumulator dtype).
    pub fn reduce(self, a: &Buf) -> Scalar {
        let acc_dt = self.acc_dtype(a.dtype());
        match (self, a) {
            // hot monomorphic loops: the compiler turns these into SIMD
            // reductions (the paper's manually-flattened reduction vector)
            (AggOp::Sum, Buf::F64(v)) => Scalar::F64(v.iter().sum()),
            (AggOp::Min, Buf::F64(v)) => {
                Scalar::F64(v.iter().copied().fold(f64::INFINITY, f64::min))
            }
            (AggOp::Max, Buf::F64(v)) => {
                Scalar::F64(v.iter().copied().fold(f64::NEG_INFINITY, f64::max))
            }
            (AggOp::Sum, Buf::F32(v)) => Scalar::F32(v.iter().sum()),
            (AggOp::Sum, Buf::I64(v)) => Scalar::I64(v.iter().sum()),
            (AggOp::Sum, Buf::I32(v)) => Scalar::I32(v.iter().sum()),
            (AggOp::Count, _) => Scalar::I64(a.len() as i64),
            (AggOp::Any, Buf::Bool(v)) => Scalar::Bool(v.iter().any(|x| *x)),
            (AggOp::All, Buf::Bool(v)) => Scalar::Bool(v.iter().all(|x| *x)),
            _ => {
                let mut acc = self.identity(acc_dt);
                for i in 0..a.len() {
                    acc = self.fold_scalar(acc, a.get(i));
                }
                acc
            }
        }
    }

    /// aVUDF1 in per-element boxed-call mode.
    pub fn reduce_scalar_mode(self, a: &Buf) -> Scalar {
        let acc_dt = self.acc_dtype(a.dtype());
        let mut acc = self.identity(acc_dt);
        for i in 0..a.len() {
            acc = black_box(self.fold_scalar(black_box(acc), black_box(a.get(i))));
        }
        acc
    }

    /// aVUDF2: elementwise combine of two partial-accumulator vectors.
    pub fn combine(self, acc: &mut Buf, x: &Buf) -> Result<()> {
        if acc.len() != x.len() {
            return Err(FmError::Shape(format!(
                "combine length mismatch: {} vs {}",
                acc.len(),
                x.len()
            )));
        }
        match (self, acc, x) {
            (AggOp::Sum | AggOp::Count, Buf::F64(a), Buf::F64(b)) => {
                for (o, v) in a.iter_mut().zip(b) {
                    *o += v;
                }
            }
            (AggOp::Min, Buf::F64(a), Buf::F64(b)) => {
                for (o, v) in a.iter_mut().zip(b) {
                    *o = o.min(*v);
                }
            }
            (AggOp::Max, Buf::F64(a), Buf::F64(b)) => {
                for (o, v) in a.iter_mut().zip(b) {
                    *o = o.max(*v);
                }
            }
            (AggOp::Sum | AggOp::Count, Buf::I64(a), Buf::I64(b)) => {
                for (o, v) in a.iter_mut().zip(b) {
                    *o += v;
                }
            }
            (op, acc, x) => {
                for i in 0..x.len() {
                    let folded = op.fold_scalar(acc.get(i), x.get(i));
                    acc.set(i, folded);
                }
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// NA-aware reductions (R's `na.rm=` semantics)
// ---------------------------------------------------------------------------

/// How an aggregation treats NA elements (NaN for floats, the most
/// negative value for integers — R's sentinels; see [`Scalar::is_na`]).
///
/// `Off` is the NA-oblivious legacy path and stays bit-identical to the
/// kernels above; `Propagate`/`Remove` are R's `na.rm=FALSE/TRUE`. The
/// which.min/which.max row kernels already pin R's NaN handling; this
/// extends the same discipline to Sum/Prod/Min/Max (`fm.sum(x, na.rm=)`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub enum NaMode {
    /// Legacy kernels, NA-oblivious (exact historical bit patterns).
    #[default]
    Off,
    /// `na.rm=FALSE`: any NA in the input makes the result NA.
    Propagate,
    /// `na.rm=TRUE`: NA elements are skipped.
    Remove,
}

impl NaMode {
    /// R's flag form; `Off` never comes from user code.
    pub fn from_na_rm(na_rm: bool) -> NaMode {
        if na_rm {
            NaMode::Remove
        } else {
            NaMode::Propagate
        }
    }

    /// Stable discriminant for plan hashing.
    pub fn code(self) -> u8 {
        match self {
            NaMode::Off => 0,
            NaMode::Propagate => 1,
            NaMode::Remove => 2,
        }
    }
}

impl AggOp {
    /// Identity element for the NA-aware paths. Identical to
    /// [`identity`](AggOp::identity) except integer `Max`, whose natural
    /// identity (`i32::MIN`/`i64::MIN`) *is* the integer NA sentinel:
    /// the NA-aware fold starts one above it so an untouched accumulator
    /// is not mistaken for a poisoned one. (A data value equal to the
    /// sentinel is NA by definition, so no representable non-NA input is
    /// lost.)
    pub fn identity_na(self, acc_dt: DType) -> Scalar {
        match (self, acc_dt) {
            (AggOp::Max, DType::I32) => Scalar::I32(i32::MIN + 1),
            (AggOp::Max, DType::I64) => Scalar::I64(i64::MIN + 1),
            _ => self.identity(acc_dt),
        }
    }

    /// NA-aware `combine` fold. `x` is checked for NA in *its own* dtype
    /// (before any accumulator cast), so integer sentinels are seen even
    /// when the accumulator is wider.
    pub fn fold_scalar_na(self, acc: Scalar, x: Scalar, na: NaMode) -> Scalar {
        match na {
            NaMode::Off => self.fold_scalar(acc, x),
            NaMode::Remove => {
                if x.is_na() {
                    acc
                } else {
                    self.fold_scalar(acc, x)
                }
            }
            NaMode::Propagate => {
                if acc.is_na() {
                    acc
                } else if x.is_na() {
                    Scalar::na(acc.dtype())
                } else {
                    self.fold_scalar(acc, x)
                }
            }
        }
    }

    /// NA-aware aVUDF1: reduce a vector (in its *input* dtype) to one
    /// accumulator-dtype scalar. Monomorphic f64 fast paths keep the same
    /// left-to-right accumulation order as the scalar reference
    /// ([`reduce_na_scalar_mode`](AggOp::reduce_na_scalar_mode)), so the
    /// two are bit-identical (pinned by a property test).
    pub fn reduce_na(self, a: &Buf, na: NaMode) -> Scalar {
        if na == NaMode::Off {
            return self.reduce(a);
        }
        let acc_dt = self.acc_dtype(a.dtype());
        match (self, a, na) {
            (AggOp::Sum, Buf::F64(v), NaMode::Remove) => {
                let mut s = 0.0;
                for &x in v {
                    if !x.is_nan() {
                        s += x;
                    }
                }
                Scalar::F64(s)
            }
            (AggOp::Sum, Buf::F64(v), NaMode::Propagate) => {
                let mut s = 0.0;
                for &x in v {
                    if x.is_nan() {
                        return Scalar::na(acc_dt);
                    }
                    s += x;
                }
                Scalar::F64(s)
            }
            (AggOp::Min, Buf::F64(v), NaMode::Remove) => {
                let mut m = f64::INFINITY;
                for &x in v {
                    if !x.is_nan() && x < m {
                        m = x;
                    }
                }
                Scalar::F64(m)
            }
            (AggOp::Max, Buf::F64(v), NaMode::Remove) => {
                let mut m = f64::NEG_INFINITY;
                for &x in v {
                    if !x.is_nan() && x > m {
                        m = x;
                    }
                }
                Scalar::F64(m)
            }
            (AggOp::Min, Buf::F64(v), NaMode::Propagate) => {
                let mut m = f64::INFINITY;
                for &x in v {
                    if x.is_nan() {
                        return Scalar::na(acc_dt);
                    }
                    if x < m {
                        m = x;
                    }
                }
                Scalar::F64(m)
            }
            (AggOp::Max, Buf::F64(v), NaMode::Propagate) => {
                let mut m = f64::NEG_INFINITY;
                for &x in v {
                    if x.is_nan() {
                        return Scalar::na(acc_dt);
                    }
                    if x > m {
                        m = x;
                    }
                }
                Scalar::F64(m)
            }
            _ => {
                let mut acc = self.identity_na(acc_dt);
                for i in 0..a.len() {
                    acc = self.fold_scalar_na(acc, a.get(i), na);
                }
                acc
            }
        }
    }

    /// NA-aware aVUDF1 in per-element boxed-call mode — the bit-parity
    /// reference for [`reduce_na`](AggOp::reduce_na).
    pub fn reduce_na_scalar_mode(self, a: &Buf, na: NaMode) -> Scalar {
        if na == NaMode::Off {
            return self.reduce_scalar_mode(a);
        }
        let acc_dt = self.acc_dtype(a.dtype());
        let mut acc = self.identity_na(acc_dt);
        for i in 0..a.len() {
            acc = black_box(self.fold_scalar_na(black_box(acc), black_box(a.get(i)), na));
        }
        acc
    }

    /// NA-aware aVUDF2: elementwise combine of two partial-accumulator
    /// vectors (both already in the accumulator dtype).
    pub fn combine_na(self, acc: &mut Buf, x: &Buf, na: NaMode) -> Result<()> {
        if na == NaMode::Off {
            return self.combine(acc, x);
        }
        if acc.len() != x.len() {
            return Err(FmError::Shape(format!(
                "combine length mismatch: {} vs {}",
                acc.len(),
                x.len()
            )));
        }
        for i in 0..x.len() {
            let folded = self.fold_scalar_na(acc.get(i), x.get(i), na);
            acc.set(i, folded);
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Explicit SIMD lane kernels (`EngineConfig::simd_kernels`)
// ---------------------------------------------------------------------------
//
// Stable-Rust "SIMD": hand-unrolled lane groups — [`F64_LANES`]-wide f64 /
// [`F32_LANES`]-wide f32 local arrays the autovectorizer keeps in vector
// registers, with an explicit scalar tail for the 0..lane-width remainder.
// Every lane kernel evaluates exactly the same scalar function per element
// as the un-unrolled `apply*` paths above, so outputs are **bit-identical**
// (pinned by `tests/simd_parity.rs`); the win is amortized per-element op
// dispatch and full-width loads/stores. The one deliberate exception is
// [`AggOp::reduce_lanes`], which changes the *accumulation order* of a
// reduction and therefore sits behind the separate
// `EngineConfig::simd_reductions` opt-in (documented ≤4-ULP drift on the
// suite's well-conditioned inputs).

/// f64 lane width: 4 doubles = one 256-bit vector register.
pub const F64_LANES: usize = 4;
/// f32 lane width: 8 singles = one 256-bit vector register.
pub const F32_LANES: usize = 8;

/// Unrolled unary map over f64 lanes; returns the number of full lane
/// groups processed (the `Metrics::simd_lanes_f64` contribution).
#[inline]
fn map_lanes_f64(src: &[f64], out: &mut [f64], f: impl Fn(f64) -> f64 + Copy) -> u64 {
    let cut = src.len() - src.len() % F64_LANES;
    let mut groups = 0u64;
    for (o, x) in out[..cut]
        .chunks_exact_mut(F64_LANES)
        .zip(src[..cut].chunks_exact(F64_LANES))
    {
        let y = [f(x[0]), f(x[1]), f(x[2]), f(x[3])];
        o.copy_from_slice(&y);
        groups += 1;
    }
    for (o, x) in out[cut..].iter_mut().zip(&src[cut..]) {
        *o = f(*x);
    }
    groups
}

/// Unrolled unary map over f32 lanes (8-wide).
#[inline]
fn map_lanes_f32(src: &[f32], out: &mut [f32], f: impl Fn(f32) -> f32 + Copy) {
    let cut = src.len() - src.len() % F32_LANES;
    for (o, x) in out[..cut]
        .chunks_exact_mut(F32_LANES)
        .zip(src[..cut].chunks_exact(F32_LANES))
    {
        let y = [
            f(x[0]),
            f(x[1]),
            f(x[2]),
            f(x[3]),
            f(x[4]),
            f(x[5]),
            f(x[6]),
            f(x[7]),
        ];
        o.copy_from_slice(&y);
    }
    for (o, x) in out[cut..].iter_mut().zip(&src[cut..]) {
        *o = f(*x);
    }
}

/// Unrolled binary zip over f64 lanes; returns full lane groups.
#[inline]
fn zip_lanes_f64(a: &[f64], b: &[f64], out: &mut [f64], f: impl Fn(f64, f64) -> f64 + Copy) -> u64 {
    let cut = a.len() - a.len() % F64_LANES;
    let mut groups = 0u64;
    for ((o, x), y) in out[..cut]
        .chunks_exact_mut(F64_LANES)
        .zip(a[..cut].chunks_exact(F64_LANES))
        .zip(b[..cut].chunks_exact(F64_LANES))
    {
        let r = [f(x[0], y[0]), f(x[1], y[1]), f(x[2], y[2]), f(x[3], y[3])];
        o.copy_from_slice(&r);
        groups += 1;
    }
    for ((o, x), y) in out[cut..].iter_mut().zip(&a[cut..]).zip(&b[cut..]) {
        *o = f(*x, *y);
    }
    groups
}

/// Unrolled binary zip over f32 lanes (8-wide).
#[inline]
fn zip_lanes_f32(a: &[f32], b: &[f32], out: &mut [f32], f: impl Fn(f32, f32) -> f32 + Copy) {
    let cut = a.len() - a.len() % F32_LANES;
    for ((o, x), y) in out[..cut]
        .chunks_exact_mut(F32_LANES)
        .zip(a[..cut].chunks_exact(F32_LANES))
        .zip(b[..cut].chunks_exact(F32_LANES))
    {
        let r = [
            f(x[0], y[0]),
            f(x[1], y[1]),
            f(x[2], y[2]),
            f(x[3], y[3]),
            f(x[4], y[4]),
            f(x[5], y[5]),
            f(x[6], y[6]),
            f(x[7], y[7]),
        ];
        o.copy_from_slice(&r);
    }
    for ((o, x), y) in out[cut..].iter_mut().zip(&a[cut..]).zip(&b[cut..]) {
        *o = f(*x, *y);
    }
}

impl UnOp {
    /// Lane-kernel form of [`UnOp::apply`]: `Some((out, f64_lane_groups))`
    /// when a lane kernel covers this op/dtype, `None` to fall back to the
    /// plain vectorized path. Covered: every f64→f64 op (via the inlined
    /// [`UnOp::eval_f64`], which is pinned to `f64_fn`) and every f32→f32
    /// op (native f32 for the monomorphic `apply` arms, through-f64 for
    /// the rest — mirroring `apply`'s generic path bit for bit).
    pub fn apply_lanes(self, a: &Buf) -> Option<(Buf, u64)> {
        match a {
            Buf::F64(v) if self.out_dtype(DType::F64) == DType::F64 => {
                let mut out = vec![0.0f64; v.len()];
                let groups = map_lanes_f64(v, &mut out, |x| self.eval_f64(x));
                Some((Buf::F64(out), groups))
            }
            Buf::F32(v) if self.out_dtype(DType::F32) == DType::F32 => {
                let mut out = vec![0.0f32; v.len()];
                match self {
                    // apply's monomorphic f32 arms compute natively
                    UnOp::Neg => map_lanes_f32(v, &mut out, |x| -x),
                    UnOp::Abs => map_lanes_f32(v, &mut out, |x| x.abs()),
                    UnOp::Sq => map_lanes_f32(v, &mut out, |x| x * x),
                    // the rest mirror apply's generic through-f64 path
                    _ => map_lanes_f32(v, &mut out, |x| self.eval_f64(x as f64) as f32),
                }
                Some((Buf::F32(out), 0))
            }
            _ => None,
        }
    }
}

impl BinOp {
    /// Lane-kernel form of [`BinOp::apply_vv`] (same coverage contract as
    /// [`UnOp::apply_lanes`]; comparison/logical ops produce Bool and stay
    /// on the plain path).
    pub fn apply_vv_lanes(self, a: &Buf, b: &Buf) -> Option<(Buf, u64)> {
        match (a, b) {
            (Buf::F64(x), Buf::F64(y)) if self.out_dtype(DType::F64) == DType::F64 => {
                let mut out = vec![0.0f64; x.len()];
                let groups = zip_lanes_f64(x, y, &mut out, |p, q| self.eval_f64(p, q));
                Some((Buf::F64(out), groups))
            }
            (Buf::F32(x), Buf::F32(y)) if self.out_dtype(DType::F32) == DType::F32 => {
                let mut out = vec![0.0f32; x.len()];
                match self {
                    // apply_vv's monomorphic f32 arms compute natively
                    BinOp::Add => zip_lanes_f32(x, y, &mut out, |p, q| p + q),
                    BinOp::Sub => zip_lanes_f32(x, y, &mut out, |p, q| p - q),
                    BinOp::Mul => zip_lanes_f32(x, y, &mut out, |p, q| p * q),
                    // the rest mirror apply_vv's generic through-f64 path
                    _ => zip_lanes_f32(x, y, &mut out, |p, q| {
                        self.eval_f64(p as f64, q as f64) as f32
                    }),
                }
                Some((Buf::F32(out), 0))
            }
            _ => None,
        }
    }

    /// Lane-kernel form of [`BinOp::apply_broadcast`] for f64 vectors (the
    /// strip evaluator's `MapplyScalar`/`MapplyRow` hot dtype).
    pub fn apply_broadcast_lanes(
        self,
        v: &Buf,
        s: f64,
        side: BroadcastSide,
    ) -> Option<(Buf, u64)> {
        match v {
            Buf::F64(x) if self.out_dtype(DType::F64) == DType::F64 => {
                let mut out = vec![0.0f64; x.len()];
                let groups = match side {
                    BroadcastSide::ScalarRight => {
                        map_lanes_f64(x, &mut out, |p| self.eval_f64(p, s))
                    }
                    BroadcastSide::ScalarLeft => {
                        map_lanes_f64(x, &mut out, |p| self.eval_f64(s, p))
                    }
                };
                Some((Buf::F64(out), groups))
            }
            _ => None,
        }
    }
}

impl AggOp {
    /// Lane-parallel f64 reduction: [`F64_LANES`] independent accumulators
    /// swept over full lane groups, combined left-to-right, then the tail
    /// folded in sequentially. **Order-changing** for `Sum` (deterministic,
    /// but not the sequential fold `reduce` uses — hence the
    /// `EngineConfig::simd_reductions` opt-in and the ≤4-ULP parity bound
    /// in `tests/simd_parity.rs`); `Min`/`Max` are order-insensitive under
    /// IEEE `min`/`max` NaN-skipping, so they stay result-identical.
    pub fn reduce_lanes(self, a: &Buf) -> Option<Scalar> {
        let Buf::F64(v) = a else { return None };
        let cut = v.len() - v.len() % F64_LANES;
        match self {
            AggOp::Sum => {
                let mut acc = [0.0f64; F64_LANES];
                for x in v[..cut].chunks_exact(F64_LANES) {
                    acc[0] += x[0];
                    acc[1] += x[1];
                    acc[2] += x[2];
                    acc[3] += x[3];
                }
                let mut s = ((acc[0] + acc[1]) + acc[2]) + acc[3];
                for x in &v[cut..] {
                    s += x;
                }
                Some(Scalar::F64(s))
            }
            AggOp::Min => {
                let mut acc = [f64::INFINITY; F64_LANES];
                for x in v[..cut].chunks_exact(F64_LANES) {
                    acc[0] = acc[0].min(x[0]);
                    acc[1] = acc[1].min(x[1]);
                    acc[2] = acc[2].min(x[2]);
                    acc[3] = acc[3].min(x[3]);
                }
                let mut s = acc[0].min(acc[1]).min(acc[2]).min(acc[3]);
                for x in &v[cut..] {
                    s = s.min(*x);
                }
                Some(Scalar::F64(s))
            }
            AggOp::Max => {
                let mut acc = [f64::NEG_INFINITY; F64_LANES];
                for x in v[..cut].chunks_exact(F64_LANES) {
                    acc[0] = acc[0].max(x[0]);
                    acc[1] = acc[1].max(x[1]);
                    acc[2] = acc[2].max(x[2]);
                    acc[3] = acc[3].max(x[3]);
                }
                let mut s = acc[0].max(acc[1]).max(acc[2]).max(acc[3]);
                for x in &v[cut..] {
                    s = s.max(*x);
                }
                Some(Scalar::F64(s))
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn out_dtypes() {
        assert_eq!(BinOp::Lt.out_dtype(DType::F64), DType::Bool);
        assert_eq!(BinOp::Div.out_dtype(DType::I64), DType::F64);
        assert_eq!(BinOp::Add.out_dtype(DType::Bool), DType::I32);
        assert_eq!(UnOp::Sqrt.out_dtype(DType::I32), DType::F64);
        assert_eq!(UnOp::NotZero.out_dtype(DType::F64), DType::Bool);
    }

    #[test]
    fn reduce_matches_fold() {
        let v = Buf::from_f64(&[3.0, -1.0, 7.0, 2.0]);
        for op in [AggOp::Sum, AggOp::Prod, AggOp::Min, AggOp::Max] {
            let fast = op.reduce(&v);
            let slow = op.reduce_scalar_mode(&v);
            assert_eq!(fast, slow, "{op:?}");
        }
        assert_eq!(AggOp::Sum.reduce(&v), Scalar::F64(11.0));
        assert_eq!(AggOp::Min.reduce(&v), Scalar::F64(-1.0));
        assert_eq!(AggOp::Count.reduce(&v), Scalar::I64(4));
    }

    #[test]
    fn combine_merges_partials() {
        let mut acc = Buf::from_f64(&[1.0, 5.0]);
        AggOp::Min.combine(&mut acc, &Buf::from_f64(&[3.0, 2.0])).unwrap();
        assert_eq!(acc.to_f64_vec(), vec![1.0, 2.0]);
        let mut acc = Buf::from_f64(&[1.0, 5.0]);
        AggOp::Sum.combine(&mut acc, &Buf::from_f64(&[3.0, 2.0])).unwrap();
        assert_eq!(acc.to_f64_vec(), vec![4.0, 7.0]);
    }

    #[test]
    fn sum_of_bool_counts_trues() {
        let v = Buf::Bool(vec![true, false, true, true]);
        assert_eq!(AggOp::Sum.reduce(&v), Scalar::I64(3));
    }

    #[test]
    fn ifelse0_masks() {
        let a = Buf::from_f64(&[1.0, 2.0, 3.0]);
        let m = Buf::from_f64(&[0.0, 1.0, 0.0]);
        let r = BinOp::IfElse0.apply_vv(&a, &m).unwrap();
        assert_eq!(r.to_f64_vec(), vec![1.0, 0.0, 3.0]);
    }

    const ALL_UN: [UnOp; 13] = [
        UnOp::Neg,
        UnOp::Abs,
        UnOp::Sqrt,
        UnOp::Sq,
        UnOp::Exp,
        UnOp::Log,
        UnOp::Floor,
        UnOp::Ceil,
        UnOp::Round,
        UnOp::Sign,
        UnOp::Not,
        UnOp::NotZero,
        UnOp::IsNa,
    ];

    const ALL_BIN: [BinOp; 16] = [
        BinOp::Add,
        BinOp::Sub,
        BinOp::Mul,
        BinOp::Div,
        BinOp::Pow,
        BinOp::Min,
        BinOp::Max,
        BinOp::Eq,
        BinOp::Ne,
        BinOp::Lt,
        BinOp::Le,
        BinOp::Gt,
        BinOp::Ge,
        BinOp::And,
        BinOp::Or,
        BinOp::IfElse0,
    ];

    #[test]
    fn eval_matches_fn() {
        let xs = [-2.5, -1.0, 0.0, 0.5, 1.5, 3.0, f64::NAN];
        for op in ALL_UN {
            let f = op.f64_fn();
            for &x in &xs {
                let (a, b) = (op.eval_f64(x), f(x));
                assert!(a == b || (a.is_nan() && b.is_nan()), "{op:?}({x})");
            }
        }
        for op in ALL_BIN {
            let f = op.f64_fn();
            for &x in &xs {
                for &y in &xs {
                    let (a, b) = (op.eval_f64(x, y), f(x, y));
                    assert!(a == b || (a.is_nan() && b.is_nan()), "{op:?}({x},{y})");
                }
            }
        }
    }

    #[test]
    fn unary_inplace_matches_apply() {
        let cases = [
            Buf::from_f64(&[-2.5, -1.0, 0.0, 0.5, 9.0]),
            Buf::F32(vec![-2.5, -1.0, 0.0, 0.5, 9.0]),
            Buf::I64(vec![-3, -1, 0, 2, 9]),
            Buf::I32(vec![-3, -1, 0, 2, 9]),
        ];
        for a in &cases {
            for op in ALL_UN {
                if !op.supports_inplace(a.dtype()) {
                    continue;
                }
                for vectorized in [true, false] {
                    let want = if vectorized {
                        op.apply(a).unwrap()
                    } else {
                        op.apply_scalar_mode(a).unwrap()
                    };
                    let mut got = a.clone();
                    op.apply_inplace(&mut got, vectorized);
                    assert_eq!(got, want, "{op:?} {} vec={vectorized}", a.dtype());
                }
            }
        }
    }

    #[test]
    fn broadcast_inplace_matches_apply() {
        use crate::vudf::{binary_sv, binary_vs};
        let cases = [
            Buf::from_f64(&[-2.5, -1.0, 0.0, 0.5, 9.0]),
            Buf::F32(vec![-2.5, -1.0, 0.0, 0.5, 9.0]),
            Buf::I64(vec![-3, -1, 0, 2, 9]),
            Buf::I32(vec![-3, -1, 0, 2, 9]),
        ];
        let s = Scalar::F64(1.5);
        for v in &cases {
            for op in ALL_BIN {
                if !op.supports_inplace_broadcast(v.dtype()) {
                    continue;
                }
                for scalar_right in [true, false] {
                    for vectorized in [true, false] {
                        let want = if scalar_right {
                            binary_vs(op, v, s, vectorized).unwrap()
                        } else {
                            binary_sv(op, s, v, vectorized).unwrap()
                        };
                        let mut got = v.clone();
                        op.apply_broadcast_inplace(&mut got, s, scalar_right, vectorized);
                        assert_eq!(
                            got,
                            want,
                            "{op:?} {} right={scalar_right} vec={vectorized}",
                            v.dtype()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn lane_kernels_match_plain_apply() {
        // lengths straddle every tail remainder of both lane widths
        let vals: Vec<f64> = vec![
            -2.5,
            -1.0,
            0.0,
            -0.0,
            0.5,
            1.5,
            3.0,
            f64::NAN,
            f64::INFINITY,
            f64::NEG_INFINITY,
            9.25,
            -7.0,
            0.125,
            2.0,
            -0.25,
            4.0,
            5.5,
        ];
        for len in 0..vals.len() {
            let a64 = Buf::F64(vals[..len].to_vec());
            let a32 = Buf::F32(vals[..len].iter().map(|x| *x as f32).collect());
            let b64 = Buf::F64(vals[..len].iter().rev().cloned().collect());
            let b32 = Buf::F32(vals[..len].iter().rev().map(|x| *x as f32).collect());
            // bit-level comparison: NaN outputs must match too, which
            // Buf's PartialEq (IEEE NaN != NaN) cannot check
            for op in ALL_UN {
                for a in [&a64, &a32] {
                    if let Some((got, _)) = op.apply_lanes(a) {
                        assert_eq!(
                            got.to_bytes(),
                            op.apply(a).unwrap().to_bytes(),
                            "{op:?} {} len={len}",
                            a.dtype()
                        );
                    }
                }
            }
            for op in ALL_BIN {
                for (a, b) in [(&a64, &b64), (&a32, &b32)] {
                    if let Some((got, _)) = op.apply_vv_lanes(a, b) {
                        assert_eq!(
                            got.to_bytes(),
                            op.apply_vv(a, b).unwrap().to_bytes(),
                            "{op:?} {} len={len}",
                            a.dtype()
                        );
                    }
                }
                for side in [BroadcastSide::ScalarRight, BroadcastSide::ScalarLeft] {
                    if let Some((got, _)) = op.apply_broadcast_lanes(&a64, 1.5, side) {
                        let s = Buf::from_f64(&[1.5]);
                        assert_eq!(
                            got.to_bytes(),
                            op.apply_broadcast(&a64, &s, side).unwrap().to_bytes(),
                            "{op:?} broadcast len={len}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn lane_reduce_min_max_identical_sum_close() {
        let v: Vec<f64> = (0..23).map(|i| 0.5 + (i as f64) * 0.37).collect();
        let b = Buf::F64(v);
        for op in [AggOp::Min, AggOp::Max] {
            // min/max are order-insensitive: lane form is bit-identical
            assert_eq!(op.reduce_lanes(&b).unwrap(), op.reduce(&b), "{op:?}");
        }
        let lanes = AggOp::Sum.reduce_lanes(&b).unwrap().as_f64();
        let seq = AggOp::Sum.reduce(&b).as_f64();
        let ulps = (lanes.to_bits() as i64 - seq.to_bits() as i64).unsigned_abs();
        assert!(ulps <= 4, "lane sum drifted {ulps} ULPs");
        // NaN-skipping min/max survive lanes: IEEE min/max drop NaN the
        // same way in any order (all-NaN degenerates to the identity on
        // BOTH paths — lane and sequential agree bit for bit)
        let nan = Buf::F64(vec![f64::NAN; 7]);
        assert_eq!(AggOp::Min.reduce_lanes(&nan).unwrap(), AggOp::Min.reduce(&nan));
        assert_eq!(AggOp::Max.reduce_lanes(&nan).unwrap(), AggOp::Max.reduce(&nan));
        let mixed = Buf::F64(vec![f64::NAN, 3.0, f64::NAN, -1.0, f64::NAN]);
        assert_eq!(AggOp::Min.reduce_lanes(&mixed).unwrap(), Scalar::F64(-1.0));
        assert_eq!(AggOp::Max.reduce_lanes(&mixed).unwrap(), Scalar::F64(3.0));
    }

    #[test]
    fn identities_are_neutral() {
        let v = Buf::from_f64(&[2.5, -3.0]);
        for op in [AggOp::Sum, AggOp::Prod, AggOp::Min, AggOp::Max] {
            let acc_dt = op.acc_dtype(DType::F64);
            let id = op.identity(acc_dt);
            let r = op.fold_scalar(id, Scalar::F64(2.5));
            assert_eq!(r, Scalar::F64(2.5), "{op:?}");
        }
        let _ = v;
    }

    /// Bitwise scalar comparison that treats two NaNs as equal (NA == NA
    /// for parity purposes; payload bits are canonical on both paths).
    fn scalar_bits_eq(a: Scalar, b: Scalar) -> bool {
        match (a, b) {
            (Scalar::F64(x), Scalar::F64(y)) => x.to_bits() == y.to_bits(),
            (Scalar::F32(x), Scalar::F32(y)) => x.to_bits() == y.to_bits(),
            _ => a == b,
        }
    }

    /// Property: the monomorphic NA-aware reduce is bit-identical to the
    /// boxed-scalar reference fold, for every op × mode × dtype over
    /// deterministic pseudo-random data salted with NA sentinels.
    #[test]
    fn na_reduce_matches_scalar_reference() {
        let mut state = 0x9E3779B97F4A7C15u64;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        for trial in 0..64 {
            let n = 1 + (next() % 97) as usize;
            let mut f64s = Vec::with_capacity(n);
            let mut i32s = Vec::with_capacity(n);
            for _ in 0..n {
                let r = next();
                if r % 5 == 0 && trial % 3 != 0 {
                    f64s.push(f64::NAN);
                    i32s.push(i32::MIN);
                } else {
                    f64s.push(((r % 2001) as f64 - 1000.0) / 8.0);
                    i32s.push((r % 2001) as i32 - 1000);
                }
            }
            for buf in [Buf::F64(f64s.clone()), Buf::I32(i32s.clone())] {
                for op in [AggOp::Sum, AggOp::Prod, AggOp::Min, AggOp::Max] {
                    for na in [NaMode::Off, NaMode::Propagate, NaMode::Remove] {
                        let fast = op.reduce_na(&buf, na);
                        let slow = op.reduce_na_scalar_mode(&buf, na);
                        assert!(
                            scalar_bits_eq(fast, slow),
                            "{op:?}/{na:?}/{:?}: {fast:?} vs {slow:?}",
                            buf.dtype()
                        );
                    }
                }
            }
        }
    }

    /// Pin the R semantics table: na.rm=FALSE propagates, na.rm=TRUE
    /// skips, and all-NA inputs degrade to the identity (R's empty-set
    /// results) for Remove.
    #[test]
    fn na_modes_pin_r_semantics() {
        let v = Buf::F64(vec![1.0, f64::NAN, 2.0]);
        assert!(AggOp::Sum.reduce_na(&v, NaMode::Propagate).is_na());
        assert!(AggOp::Min.reduce_na(&v, NaMode::Propagate).is_na());
        assert!(AggOp::Max.reduce_na(&v, NaMode::Propagate).is_na());
        assert!(AggOp::Prod.reduce_na(&v, NaMode::Propagate).is_na());
        assert_eq!(AggOp::Sum.reduce_na(&v, NaMode::Remove), Scalar::F64(3.0));
        assert_eq!(AggOp::Min.reduce_na(&v, NaMode::Remove), Scalar::F64(1.0));
        assert_eq!(AggOp::Max.reduce_na(&v, NaMode::Remove), Scalar::F64(2.0));
        assert_eq!(AggOp::Prod.reduce_na(&v, NaMode::Remove), Scalar::F64(2.0));
        // all-NA: sum -> 0, min -> Inf, max -> -Inf (like R's empty set)
        let all = Buf::F64(vec![f64::NAN; 4]);
        assert_eq!(AggOp::Sum.reduce_na(&all, NaMode::Remove), Scalar::F64(0.0));
        assert_eq!(
            AggOp::Min.reduce_na(&all, NaMode::Remove),
            Scalar::F64(f64::INFINITY)
        );
        assert_eq!(
            AggOp::Max.reduce_na(&all, NaMode::Remove),
            Scalar::F64(f64::NEG_INFINITY)
        );
        // integer sentinels: i32::MIN is NA_integer_
        let iv = Buf::I32(vec![5, i32::MIN, -3]);
        assert!(AggOp::Sum.reduce_na(&iv, NaMode::Propagate).is_na());
        assert_eq!(AggOp::Sum.reduce_na(&iv, NaMode::Remove), Scalar::I32(2));
        assert_eq!(AggOp::Min.reduce_na(&iv, NaMode::Remove), Scalar::I32(-3));
        assert_eq!(AggOp::Max.reduce_na(&iv, NaMode::Remove), Scalar::I32(5));
        // Off keeps the NA-oblivious legacy kernels byte for byte
        assert_eq!(
            AggOp::Min.reduce_na(&v, NaMode::Off),
            AggOp::Min.reduce(&v)
        );
    }
}
