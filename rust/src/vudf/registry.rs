//! User-extensible VUDF registry (paper §III-D: "FlashMatrix allows
//! programmers to extend the framework by registering new VUDFs").
//!
//! Built-in operations are enum-dispatched for speed; *custom* VUDFs are
//! trait objects registered by name. Like the paper's C/C++ VUDFs, a custom
//! VUDF must supply the vectorized forms it supports; GenOps look the name
//! up at DAG-build time and call the matching form per CPU-partition.

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use crate::dtype::{DType, Scalar};
use crate::error::{FmError, Result};

use super::buf::Buf;

/// A user-registered vectorized function. Implementations provide whichever
/// forms they support; unsupported forms default to an error so the GenOp
/// layer can report a clear message.
pub trait CustomVudf: Send + Sync {
    /// Name used to look the VUDF up from `fmr`.
    fn name(&self) -> &str;

    /// Output dtype given input dtype(s).
    fn out_dtype(&self, input: DType) -> DType;

    /// uVUDF form.
    fn unary(&self, _a: &Buf) -> Result<Buf> {
        Err(FmError::Unsupported(format!(
            "VUDF '{}' has no unary form",
            self.name()
        )))
    }

    /// bVUDF1 form.
    fn binary_vv(&self, _a: &Buf, _b: &Buf) -> Result<Buf> {
        Err(FmError::Unsupported(format!(
            "VUDF '{}' has no binary form",
            self.name()
        )))
    }

    /// aVUDF1 form (aggregate).
    fn aggregate(&self, _a: &Buf) -> Result<Scalar> {
        Err(FmError::Unsupported(format!(
            "VUDF '{}' has no aggregate form",
            self.name()
        )))
    }

    /// aVUDF2 form (combine partials); defaults to aggregate-compatible
    /// error.
    fn combine(&self, _acc: &mut Buf, _x: &Buf) -> Result<()> {
        Err(FmError::Unsupported(format!(
            "VUDF '{}' has no combine form",
            self.name()
        )))
    }
}

/// Thread-safe name -> VUDF map owned by the engine.
#[derive(Default)]
pub struct VudfRegistry {
    map: RwLock<HashMap<String, Arc<dyn CustomVudf>>>,
}

impl VudfRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or replace) a VUDF under its own name.
    pub fn register(&self, v: Arc<dyn CustomVudf>) {
        self.map.write().unwrap().insert(v.name().to_string(), v);
    }

    pub fn lookup(&self, name: &str) -> Option<Arc<dyn CustomVudf>> {
        self.map.read().unwrap().get(name).cloned()
    }

    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<_> = self.map.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Clamp01;
    impl CustomVudf for Clamp01 {
        fn name(&self) -> &str {
            "clamp01"
        }
        fn out_dtype(&self, input: DType) -> DType {
            input
        }
        fn unary(&self, a: &Buf) -> Result<Buf> {
            let v: Vec<f64> = a.to_f64_vec().iter().map(|x| x.clamp(0.0, 1.0)).collect();
            Buf::F64(v).cast(a.dtype())
        }
    }

    #[test]
    fn register_and_call() {
        let reg = VudfRegistry::new();
        reg.register(Arc::new(Clamp01));
        let f = reg.lookup("clamp01").unwrap();
        let out = f.unary(&Buf::from_f64(&[-1.0, 0.5, 2.0])).unwrap();
        assert_eq!(out.to_f64_vec(), vec![0.0, 0.5, 1.0]);
        assert!(f.binary_vv(&out, &out).is_err()); // unsupported form
        assert_eq!(reg.names(), vec!["clamp01"]);
        assert!(reg.lookup("nope").is_none());
    }
}
