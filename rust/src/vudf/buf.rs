//! Typed element buffers — the registers of the fused-pipeline evaluator.
//!
//! A `Buf` holds a contiguous run of elements of one [`DType`]. CPU-level
//! partitions, VUDF inputs/outputs and sink accumulators are all `Buf`s.
//! The variants own `Vec`s so buffers can be recycled across partitions by
//! the evaluator (allocation happens once per pipeline, not per partition).

use std::borrow::Cow;

use crate::dtype::{DType, Element, Scalar};
use crate::error::{FmError, Result};

/// A typed, contiguous buffer of elements.
#[derive(Clone, Debug, PartialEq)]
pub enum Buf {
    Bool(Vec<bool>),
    I32(Vec<i32>),
    I64(Vec<i64>),
    F32(Vec<f32>),
    F64(Vec<f64>),
}

macro_rules! per_variant {
    ($self:expr, $v:ident => $e:expr) => {
        match $self {
            Buf::Bool($v) => $e,
            Buf::I32($v) => $e,
            Buf::I64($v) => $e,
            Buf::F32($v) => $e,
            Buf::F64($v) => $e,
        }
    };
}

impl Buf {
    /// Allocate a zeroed buffer.
    pub fn alloc(dtype: DType, len: usize) -> Buf {
        match dtype {
            DType::Bool => Buf::Bool(vec![false; len]),
            DType::I32 => Buf::I32(vec![0; len]),
            DType::I64 => Buf::I64(vec![0; len]),
            DType::F32 => Buf::F32(vec![0.0; len]),
            DType::F64 => Buf::F64(vec![0.0; len]),
        }
    }

    /// Allocate a buffer filled with `value` (cast to `dtype`).
    pub fn fill(dtype: DType, len: usize, value: Scalar) -> Buf {
        let v = value.cast(dtype);
        match (dtype, v) {
            (DType::Bool, Scalar::Bool(x)) => Buf::Bool(vec![x; len]),
            (DType::I32, Scalar::I32(x)) => Buf::I32(vec![x; len]),
            (DType::I64, Scalar::I64(x)) => Buf::I64(vec![x; len]),
            (DType::F32, Scalar::F32(x)) => Buf::F32(vec![x; len]),
            (DType::F64, Scalar::F64(x)) => Buf::F64(vec![x; len]),
            _ => unreachable!("cast guarantees matching variant"),
        }
    }

    pub fn from_f64(v: &[f64]) -> Buf {
        Buf::F64(v.to_vec())
    }

    /// Zero-length placeholder left behind when a register's buffer is
    /// moved out (in-place execution) or released to the strip pool.
    /// Never allocates.
    pub fn empty() -> Buf {
        Buf::F64(Vec::new())
    }

    /// Clear and resize to `len` zeroed elements, keeping the allocation
    /// (the strip pool's reuse path — equivalent to a fresh
    /// [`Buf::alloc`] of the same dtype).
    pub fn reset(&mut self, len: usize) {
        match self {
            Buf::Bool(v) => {
                v.clear();
                v.resize(len, false);
            }
            Buf::I32(v) => {
                v.clear();
                v.resize(len, 0);
            }
            Buf::I64(v) => {
                v.clear();
                v.resize(len, 0);
            }
            Buf::F32(v) => {
                v.clear();
                v.resize(len, 0.0);
            }
            Buf::F64(v) => {
                v.clear();
                v.resize(len, 0.0);
            }
        }
    }

    /// Overwrite every element with `value` (cast to the buffer dtype) —
    /// the pooled equivalent of [`Buf::fill`].
    pub fn fill_scalar(&mut self, value: Scalar) {
        match self {
            Buf::Bool(v) => v.fill(value.as_bool()),
            Buf::I32(v) => v.fill(value.as_i64() as i32),
            Buf::I64(v) => v.fill(value.as_i64()),
            Buf::F32(v) => v.fill(value.as_f64() as f32),
            Buf::F64(v) => v.fill(value.as_f64()),
        }
    }

    pub fn len(&self) -> usize {
        per_variant!(self, v => v.len())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self {
            Buf::Bool(_) => DType::Bool,
            Buf::I32(_) => DType::I32,
            Buf::I64(_) => DType::I64,
            Buf::F32(_) => DType::F32,
            Buf::F64(_) => DType::F64,
        }
    }

    /// Element at `i` as a scalar.
    pub fn get(&self, i: usize) -> Scalar {
        match self {
            Buf::Bool(v) => Scalar::Bool(v[i]),
            Buf::I32(v) => Scalar::I32(v[i]),
            Buf::I64(v) => Scalar::I64(v[i]),
            Buf::F32(v) => Scalar::F32(v[i]),
            Buf::F64(v) => Scalar::F64(v[i]),
        }
    }

    /// Set element `i` (value is cast to the buffer dtype).
    pub fn set(&mut self, i: usize, value: Scalar) {
        match self {
            Buf::Bool(v) => v[i] = value.as_bool(),
            Buf::I32(v) => v[i] = value.as_i64() as i32,
            Buf::I64(v) => v[i] = value.as_i64(),
            Buf::F32(v) => v[i] = value.as_f64() as f32,
            Buf::F64(v) => v[i] = value.as_f64(),
        }
    }

    /// Copy of the elements in `[off, off+len)` as a new buffer.
    pub fn slice(&self, off: usize, len: usize) -> Buf {
        match self {
            Buf::Bool(v) => Buf::Bool(v[off..off + len].to_vec()),
            Buf::I32(v) => Buf::I32(v[off..off + len].to_vec()),
            Buf::I64(v) => Buf::I64(v[off..off + len].to_vec()),
            Buf::F32(v) => Buf::F32(v[off..off + len].to_vec()),
            Buf::F64(v) => Buf::F64(v[off..off + len].to_vec()),
        }
    }

    /// Copy `src` into this buffer starting at `off`. Dtypes must match.
    pub fn copy_from(&mut self, off: usize, src: &Buf) {
        match (self, src) {
            (Buf::Bool(d), Buf::Bool(s)) => d[off..off + s.len()].copy_from_slice(s),
            (Buf::I32(d), Buf::I32(s)) => d[off..off + s.len()].copy_from_slice(s),
            (Buf::I64(d), Buf::I64(s)) => d[off..off + s.len()].copy_from_slice(s),
            (Buf::F32(d), Buf::F32(s)) => d[off..off + s.len()].copy_from_slice(s),
            (Buf::F64(d), Buf::F64(s)) => d[off..off + s.len()].copy_from_slice(s),
            (d, s) => panic!("copy_from dtype mismatch: {} vs {}", d.dtype(), s.dtype()),
        }
    }

    /// Copy `src[src_off .. src_off + len)` into `self[dst_off ..)`.
    /// Dtypes must match — the no-temporary form of `slice` + `copy_from`.
    pub fn copy_range_from(&mut self, dst_off: usize, src: &Buf, src_off: usize, len: usize) {
        match (self, src) {
            (Buf::Bool(d), Buf::Bool(s)) => {
                d[dst_off..dst_off + len].copy_from_slice(&s[src_off..src_off + len])
            }
            (Buf::I32(d), Buf::I32(s)) => {
                d[dst_off..dst_off + len].copy_from_slice(&s[src_off..src_off + len])
            }
            (Buf::I64(d), Buf::I64(s)) => {
                d[dst_off..dst_off + len].copy_from_slice(&s[src_off..src_off + len])
            }
            (Buf::F32(d), Buf::F32(s)) => {
                d[dst_off..dst_off + len].copy_from_slice(&s[src_off..src_off + len])
            }
            (Buf::F64(d), Buf::F64(s)) => {
                d[dst_off..dst_off + len].copy_from_slice(&s[src_off..src_off + len])
            }
            (d, s) => panic!(
                "copy_range_from dtype mismatch: {} vs {}",
                d.dtype(),
                s.dtype()
            ),
        }
    }

    /// Cast to `to`, returning a new buffer (no-op clone when equal).
    /// Prefer [`Buf::cast_ref`] when a borrow suffices: it skips the
    /// same-dtype copy entirely.
    pub fn cast(&self, to: DType) -> Result<Buf> {
        if self.dtype() == to {
            return Ok(self.clone());
        }
        let mut out = Buf::alloc(to, self.len());
        self.cast_into(&mut out)?;
        Ok(out)
    }

    /// Borrow when the dtype already matches, cast otherwise — the cheap
    /// form for read-only consumers (same-dtype casts cost nothing).
    pub fn cast_ref(&self, to: DType) -> Result<Cow<'_, Buf>> {
        if self.dtype() == to {
            Ok(Cow::Borrowed(self))
        } else {
            Ok(Cow::Owned(self.cast(to)?))
        }
    }

    /// Cast into a pre-allocated buffer (the strip pool's reuse path).
    /// `out` fixes the target dtype and must have the same length;
    /// same-dtype casts degrade to a copy.
    pub fn cast_into(&self, out: &mut Buf) -> Result<()> {
        if out.len() != self.len() {
            return Err(FmError::Shape(format!(
                "cast_into length mismatch: {} vs {}",
                out.len(),
                self.len()
            )));
        }
        if self.dtype() == out.dtype() {
            out.copy_from(0, self);
            return Ok(());
        }
        macro_rules! cast_loop {
            ($src:expr, $conv:expr) => {{
                match &mut *out {
                    Buf::Bool(d) => {
                        for (o, x) in d.iter_mut().zip($src.iter()) {
                            *o = $conv(*x) != 0.0
                        }
                    }
                    Buf::I32(d) => {
                        for (o, x) in d.iter_mut().zip($src.iter()) {
                            *o = $conv(*x) as i32
                        }
                    }
                    Buf::I64(d) => {
                        for (o, x) in d.iter_mut().zip($src.iter()) {
                            *o = $conv(*x) as i64
                        }
                    }
                    Buf::F32(d) => {
                        for (o, x) in d.iter_mut().zip($src.iter()) {
                            *o = $conv(*x) as f32
                        }
                    }
                    Buf::F64(d) => {
                        for (o, x) in d.iter_mut().zip($src.iter()) {
                            *o = $conv(*x)
                        }
                    }
                }
            }};
        }
        match self {
            Buf::Bool(s) => cast_loop!(s, |x: bool| x as u8 as f64),
            Buf::I32(s) => cast_loop!(s, |x: i32| x as f64),
            Buf::I64(s) => cast_loop!(s, |x: i64| x as f64),
            Buf::F32(s) => cast_loop!(s, |x: f32| x as f64),
            Buf::F64(s) => cast_loop!(s, |x: f64| x),
        }
        Ok(())
    }

    /// All elements as f64 (tests, display, scalar-mode kernels).
    pub fn to_f64_vec(&self) -> Vec<f64> {
        per_variant!(self, v => v.iter().map(|x| Element::to_f64(*x)).collect())
    }

    /// Typed slice accessors (panic on dtype mismatch — engine-internal).
    pub fn as_f64(&self) -> &[f64] {
        match self {
            Buf::F64(v) => v,
            other => panic!("expected f64 buffer, got {}", other.dtype()),
        }
    }

    pub fn as_f64_mut(&mut self) -> &mut [f64] {
        match self {
            Buf::F64(v) => v,
            other => panic!("expected f64 buffer, got {}", other.dtype()),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match self {
            Buf::I32(v) => v,
            other => panic!("expected i32 buffer, got {}", other.dtype()),
        }
    }

    pub fn as_i32_mut(&mut self) -> &mut [i32] {
        match self {
            Buf::I32(v) => v,
            other => panic!("expected i32 buffer, got {}", other.dtype()),
        }
    }

    /// Raw little-endian bytes of the buffer (storage serialization).
    /// Hot path: chunked conversion the compiler vectorizes (per-element
    /// flat_map was a measured bottleneck — EXPERIMENTS.md §Perf).
    pub fn to_bytes(&self) -> Vec<u8> {
        macro_rules! num_bytes {
            ($v:expr, $w:expr) => {{
                let mut out = vec![0u8; $v.len() * $w];
                for (chunk, x) in out.chunks_exact_mut($w).zip($v.iter()) {
                    chunk.copy_from_slice(&x.to_le_bytes());
                }
                out
            }};
        }
        match self {
            Buf::Bool(v) => v.iter().map(|&b| b as u8).collect(),
            Buf::I32(v) => num_bytes!(v, 4),
            Buf::I64(v) => num_bytes!(v, 8),
            Buf::F32(v) => num_bytes!(v, 4),
            Buf::F64(v) => num_bytes!(v, 8),
        }
    }

    /// Rebuild a buffer from raw little-endian bytes.
    pub fn from_bytes(dtype: DType, bytes: &[u8]) -> Result<Buf> {
        let esz = dtype.size();
        if bytes.len() % esz != 0 {
            return Err(FmError::Storage(format!(
                "byte length {} not a multiple of element size {esz}",
                bytes.len()
            )));
        }
        macro_rules! num_from {
            ($t:ty, $w:expr) => {
                bytes
                    .chunks_exact($w)
                    .map(|c| <$t>::from_le_bytes(c.try_into().unwrap()))
                    .collect()
            };
        }
        Ok(match dtype {
            DType::Bool => Buf::Bool(bytes.iter().map(|&b| b != 0).collect()),
            DType::I32 => Buf::I32(num_from!(i32, 4)),
            DType::I64 => Buf::I64(num_from!(i64, 8)),
            DType::F32 => Buf::F32(num_from!(f32, 4)),
            DType::F64 => Buf::F64(num_from!(f64, 8)),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_bytes_all_dtypes() {
        for dt in [DType::Bool, DType::I32, DType::I64, DType::F32, DType::F64] {
            let b = Buf::fill(dt, 7, Scalar::F64(1.0));
            let bytes = b.to_bytes();
            assert_eq!(bytes.len(), 7 * dt.size());
            let back = Buf::from_bytes(dt, &bytes).unwrap();
            assert_eq!(back, b);
        }
    }

    #[test]
    fn cast_f64_to_i32_truncates() {
        let b = Buf::from_f64(&[1.9, -2.9, 0.0]);
        let c = b.cast(DType::I32).unwrap();
        assert_eq!(c, Buf::I32(vec![1, -2, 0]));
        let d = b.cast(DType::Bool).unwrap();
        assert_eq!(d, Buf::Bool(vec![true, true, false]));
    }

    #[test]
    fn slice_and_copy() {
        let b = Buf::from_f64(&[0.0, 1.0, 2.0, 3.0]);
        let s = b.slice(1, 2);
        assert_eq!(s.to_f64_vec(), vec![1.0, 2.0]);
        let mut d = Buf::alloc(DType::F64, 4);
        d.copy_from(2, &s);
        assert_eq!(d.to_f64_vec(), vec![0.0, 0.0, 1.0, 2.0]);
    }

    #[test]
    fn bad_byte_length_rejected() {
        assert!(Buf::from_bytes(DType::F64, &[0u8; 7]).is_err());
    }

    #[test]
    fn reset_reuses_capacity_zeroed() {
        let mut b = Buf::from_f64(&[1.0, 2.0, 3.0]);
        b.reset(2);
        assert_eq!(b.to_f64_vec(), vec![0.0, 0.0]);
        b.reset(4);
        assert_eq!(b.to_f64_vec(), vec![0.0; 4]);
        assert!(Buf::empty().is_empty());
    }

    #[test]
    fn fill_scalar_matches_fill() {
        for dt in [DType::Bool, DType::I32, DType::I64, DType::F32, DType::F64] {
            let want = Buf::fill(dt, 5, Scalar::F64(1.0));
            let mut got = Buf::alloc(dt, 5);
            got.fill_scalar(Scalar::F64(1.0));
            assert_eq!(got, want, "{dt}");
        }
    }

    #[test]
    fn cast_ref_borrows_same_dtype() {
        let b = Buf::from_f64(&[1.0, 2.0]);
        let c = b.cast_ref(DType::F64).unwrap();
        assert!(matches!(c, std::borrow::Cow::Borrowed(_)));
        let c = b.cast_ref(DType::I32).unwrap();
        assert_eq!(c.as_i32(), &[1, 2]);
    }

    #[test]
    fn cast_into_matches_cast() {
        let b = Buf::from_f64(&[1.9, -2.9, 0.0]);
        for dt in [DType::Bool, DType::I32, DType::I64, DType::F32, DType::F64] {
            let mut out = Buf::alloc(dt, 3);
            b.cast_into(&mut out).unwrap();
            assert_eq!(out, b.cast(dt).unwrap(), "{dt}");
        }
        let mut short = Buf::alloc(DType::F64, 2);
        assert!(b.cast_into(&mut short).is_err());
    }

    #[test]
    fn copy_range_from_copies_window() {
        let src = Buf::from_f64(&[0.0, 1.0, 2.0, 3.0]);
        let mut dst = Buf::alloc(DType::F64, 4);
        dst.copy_range_from(2, &src, 1, 2);
        assert_eq!(dst.to_f64_vec(), vec![0.0, 0.0, 1.0, 2.0]);
    }
}
