//! Vectorized user-defined functions (VUDFs), paper §III-D.
//!
//! GenOps never call a function per element. They call VUDFs — functions
//! over *vectors* of elements — in one of the paper's forms:
//!
//! * `uVUDF`   — vector -> vector                      ([`unary`])
//! * `bVUDF1`  — vector ⊕ vector -> vector             ([`binary_vv`])
//! * `bVUDF2`  — vector ⊕ scalar -> vector             ([`binary_vs`])
//! * `bVUDF3`  — scalar ⊕ vector -> vector             ([`binary_sv`])
//! * `aVUDF1`  — vector -> scalar (aggregate)          ([`AggOp::reduce`])
//! * `aVUDF2`  — vector ⊗ vector -> vector (combine)   ([`AggOp::combine`])
//!
//! Built-in operations are enum-dispatched so the inner loops monomorphize
//! to straight-line code the compiler auto-vectorizes (the paper's
//! AVX-via-autovectorization strategy). The *scalar mode* used by the
//! Fig 12 ablation and the MLlib-like baseline instead routes every element
//! through a boxed `dyn Fn` — one function call per element, the exact
//! overhead the paper's VUDFs exist to amortize.
//!
//! [`binary_colvec`] / [`binary_rowvec`] are the broadcast forms backing
//! `fm.mapply.col` / `fm.mapply.row`; the GenOp layer picks the form per
//! the input layout exactly as §III-G describes.

pub mod buf;
pub mod ops;
pub mod registry;

pub use buf::Buf;
pub use ops::{AggOp, BinOp, NaMode, UnOp, F32_LANES, F64_LANES};
pub use registry::{CustomVudf, VudfRegistry};

use crate::error::{FmError, Result};

/// Maximum vector length passed to a VUDF in one call (paper: 128; balances
/// call-overhead amortization against L1 residency). The enum-dispatched
/// built-ins process whole CPU-partitions in L1-sized strips of this many
/// elements.
pub const MAX_VUDF_LEN: usize = 128;

/// Apply a unary VUDF over a buffer. `vectorized=false` is the per-element
/// boxed-call ablation mode.
pub fn unary(op: UnOp, a: &Buf, vectorized: bool) -> Result<Buf> {
    if vectorized {
        op.apply(a)
    } else {
        op.apply_scalar_mode(a)
    }
}

/// bVUDF1: elementwise vector ⊕ vector.
pub fn binary_vv(op: BinOp, a: &Buf, b: &Buf, vectorized: bool) -> Result<Buf> {
    if a.len() != b.len() {
        return Err(FmError::Shape(format!(
            "binary_vv length mismatch: {} vs {}",
            a.len(),
            b.len()
        )));
    }
    if a.dtype() != b.dtype() {
        return Err(FmError::DType(format!(
            "binary_vv dtype mismatch: {} vs {} (GenOp layer must insert casts)",
            a.dtype(),
            b.dtype()
        )));
    }
    if vectorized {
        op.apply_vv(a, b)
    } else {
        op.apply_vv_scalar_mode(a, b)
    }
}

/// bVUDF2: vector ⊕ scalar.
pub fn binary_vs(op: BinOp, a: &Buf, s: crate::dtype::Scalar, vectorized: bool) -> Result<Buf> {
    let s = s.cast(a.dtype());
    let b = Buf::fill(a.dtype(), 1, s);
    if vectorized {
        op.apply_broadcast(a, &b, BroadcastSide::ScalarRight)
    } else {
        op.apply_broadcast_scalar_mode(a, &b, BroadcastSide::ScalarRight)
    }
}

/// bVUDF3: scalar ⊕ vector (for non-commutative ops).
pub fn binary_sv(op: BinOp, s: crate::dtype::Scalar, b: &Buf, vectorized: bool) -> Result<Buf> {
    let s = s.cast(b.dtype());
    let a = Buf::fill(b.dtype(), 1, s);
    if vectorized {
        op.apply_broadcast(b, &a, BroadcastSide::ScalarLeft)
    } else {
        op.apply_broadcast_scalar_mode(b, &a, BroadcastSide::ScalarLeft)
    }
}

/// uVUDF through the explicit lane kernels (`EngineConfig::simd_kernels`):
/// hand-unrolled f64x4/f32x8 form when one covers the op/dtype, the plain
/// vectorized path otherwise. Returns the output plus the number of full
/// f64x4 lane groups processed (0 on fallback) for
/// `Metrics::simd_lanes_f64`. Bit-identical to [`unary`] with
/// `vectorized = true` — pinned by `tests/simd_parity.rs`.
pub fn unary_lanes(op: UnOp, a: &Buf) -> Result<(Buf, u64)> {
    match op.apply_lanes(a) {
        Some(r) => Ok(r),
        None => Ok((op.apply(a)?, 0)),
    }
}

/// bVUDF1 through the lane kernels (see [`unary_lanes`] for the contract).
pub fn binary_vv_lanes(op: BinOp, a: &Buf, b: &Buf) -> Result<(Buf, u64)> {
    if a.len() != b.len() {
        return Err(FmError::Shape(format!(
            "binary_vv length mismatch: {} vs {}",
            a.len(),
            b.len()
        )));
    }
    if a.dtype() != b.dtype() {
        return Err(FmError::DType(format!(
            "binary_vv dtype mismatch: {} vs {} (GenOp layer must insert casts)",
            a.dtype(),
            b.dtype()
        )));
    }
    match op.apply_vv_lanes(a, b) {
        Some(r) => Ok(r),
        None => Ok((op.apply_vv(a, b)?, 0)),
    }
}

/// bVUDF2 through the lane kernels (see [`unary_lanes`] for the contract).
pub fn binary_vs_lanes(op: BinOp, a: &Buf, s: crate::dtype::Scalar) -> Result<(Buf, u64)> {
    let s = s.cast(a.dtype());
    if let Some(r) = op.apply_broadcast_lanes(a, s.as_f64(), BroadcastSide::ScalarRight) {
        return Ok(r);
    }
    Ok((binary_vs(op, a, s, true)?, 0))
}

/// bVUDF3 through the lane kernels (see [`unary_lanes`] for the contract).
pub fn binary_sv_lanes(op: BinOp, s: crate::dtype::Scalar, b: &Buf) -> Result<(Buf, u64)> {
    let s = s.cast(b.dtype());
    if let Some(r) = op.apply_broadcast_lanes(b, s.as_f64(), BroadcastSide::ScalarLeft) {
        return Ok(r);
    }
    Ok((binary_sv(op, s, b, true)?, 0))
}

/// Which side of a broadcast binary op is the scalar.
#[derive(Clone, Copy, PartialEq)]
pub enum BroadcastSide {
    ScalarLeft,
    ScalarRight,
}

/// `fm.mapply.col` inner form: `out[i,j] = f(a[i,j], v[i])` over a
/// column-major `rows x cols` strip. For a tall column-major partition this
/// is `cols` bVUDF1 calls on long columns — the form §III-G prescribes.
pub fn binary_colvec(
    op: BinOp,
    a: &Buf,
    v: &Buf,
    rows: usize,
    cols: usize,
    vectorized: bool,
) -> Result<Buf> {
    if a.len() != rows * cols || v.len() != rows {
        return Err(FmError::Shape(format!(
            "binary_colvec: a={} v={} rows={} cols={}",
            a.len(),
            v.len(),
            rows,
            cols
        )));
    }
    let v = v.cast(a.dtype())?;
    let mut out = Buf::alloc(op.out_dtype(a.dtype()), a.len());
    for j in 0..cols {
        let col = a.slice(j * rows, rows);
        let r = binary_vv(op, &col, &v, vectorized)?;
        out.copy_from(j * rows, &r);
    }
    Ok(out)
}

/// `fm.mapply.row` inner form: `out[i,j] = f(a[i,j], w[j])` over a
/// column-major strip: each long column combines with one element of `w`
/// via bVUDF2 (§III-G's form selection for tall column-major input).
pub fn binary_rowvec(
    op: BinOp,
    a: &Buf,
    w: &Buf,
    rows: usize,
    cols: usize,
    vectorized: bool,
) -> Result<Buf> {
    if a.len() != rows * cols || w.len() != cols {
        return Err(FmError::Shape(format!(
            "binary_rowvec: a={} w={} rows={} cols={}",
            a.len(),
            w.len(),
            rows,
            cols
        )));
    }
    let w = w.cast(a.dtype())?;
    let mut out = Buf::alloc(op.out_dtype(a.dtype()), a.len());
    for j in 0..cols {
        let col = a.slice(j * rows, rows);
        let r = binary_vs(op, &col, w.get(j), vectorized)?;
        out.copy_from(j * rows, &r);
    }
    Ok(out)
}

/// [`binary_colvec`] through the lane kernels: each column is one bVUDF1
/// lane call (see [`unary_lanes`] for the contract).
pub fn binary_colvec_lanes(
    op: BinOp,
    a: &Buf,
    v: &Buf,
    rows: usize,
    cols: usize,
) -> Result<(Buf, u64)> {
    if a.len() != rows * cols || v.len() != rows {
        return Err(FmError::Shape(format!(
            "binary_colvec: a={} v={} rows={} cols={}",
            a.len(),
            v.len(),
            rows,
            cols
        )));
    }
    let v = v.cast(a.dtype())?;
    let mut out = Buf::alloc(op.out_dtype(a.dtype()), a.len());
    let mut groups = 0u64;
    for j in 0..cols {
        let col = a.slice(j * rows, rows);
        let (r, g) = binary_vv_lanes(op, &col, &v)?;
        groups += g;
        out.copy_from(j * rows, &r);
    }
    Ok((out, groups))
}

/// [`binary_rowvec`] through the lane kernels: each column is one bVUDF2
/// lane call (see [`unary_lanes`] for the contract).
pub fn binary_rowvec_lanes(
    op: BinOp,
    a: &Buf,
    w: &Buf,
    rows: usize,
    cols: usize,
) -> Result<(Buf, u64)> {
    if a.len() != rows * cols || w.len() != cols {
        return Err(FmError::Shape(format!(
            "binary_rowvec: a={} w={} rows={} cols={}",
            a.len(),
            w.len(),
            rows,
            cols
        )));
    }
    let w = w.cast(a.dtype())?;
    let mut out = Buf::alloc(op.out_dtype(a.dtype()), a.len());
    let mut groups = 0u64;
    for j in 0..cols {
        let col = a.slice(j * rows, rows);
        let (r, g) = binary_vs_lanes(op, &col, w.get(j))?;
        groups += g;
        out.copy_from(j * rows, &r);
    }
    Ok((out, groups))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::{DType, Scalar};

    fn f64buf(v: &[f64]) -> Buf {
        Buf::from_f64(v)
    }

    #[test]
    fn unary_forms() {
        let a = f64buf(&[1.0, -4.0, 9.0]);
        let abs = unary(UnOp::Abs, &a, true).unwrap();
        assert_eq!(abs.to_f64_vec(), vec![1.0, 4.0, 9.0]);
        let abs_s = unary(UnOp::Abs, &a, false).unwrap();
        assert_eq!(abs_s.to_f64_vec(), vec![1.0, 4.0, 9.0]);
        let sq = unary(UnOp::Sq, &a, true).unwrap();
        assert_eq!(sq.to_f64_vec(), vec![1.0, 16.0, 81.0]);
    }

    #[test]
    fn binary_forms_match_each_other() {
        let a = f64buf(&[1.0, 2.0, 3.0]);
        let b = f64buf(&[10.0, 20.0, 30.0]);
        let vv = binary_vv(BinOp::Sub, &a, &b, true).unwrap();
        assert_eq!(vv.to_f64_vec(), vec![-9.0, -18.0, -27.0]);
        // bVUDF2 vs bVUDF3 on a non-commutative op
        let vs = binary_vs(BinOp::Sub, &a, Scalar::F64(1.0), true).unwrap();
        assert_eq!(vs.to_f64_vec(), vec![0.0, 1.0, 2.0]);
        let sv = binary_sv(BinOp::Sub, Scalar::F64(1.0), &a, true).unwrap();
        assert_eq!(sv.to_f64_vec(), vec![0.0, -1.0, -2.0]);
        // scalar mode must agree with vectorized mode
        let vv_s = binary_vv(BinOp::Sub, &a, &b, false).unwrap();
        assert_eq!(vv_s.to_f64_vec(), vv.to_f64_vec());
    }

    #[test]
    fn colvec_and_rowvec_broadcast() {
        // 3x2 col-major: cols [1,2,3] and [4,5,6]
        let a = f64buf(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let v = f64buf(&[10.0, 20.0, 30.0]);
        let out = binary_colvec(BinOp::Add, &a, &v, 3, 2, true).unwrap();
        assert_eq!(out.to_f64_vec(), vec![11.0, 22.0, 33.0, 14.0, 25.0, 36.0]);
        let w = f64buf(&[100.0, 200.0]);
        let out = binary_rowvec(BinOp::Add, &a, &w, 3, 2, true).unwrap();
        assert_eq!(
            out.to_f64_vec(),
            vec![101.0, 102.0, 103.0, 204.0, 205.0, 206.0]
        );
    }

    #[test]
    fn comparison_outputs_bool() {
        let a = f64buf(&[1.0, 5.0]);
        let b = f64buf(&[2.0, 2.0]);
        let lt = binary_vv(BinOp::Lt, &a, &b, true).unwrap();
        assert_eq!(lt.dtype(), DType::Bool);
        assert_eq!(lt.to_f64_vec(), vec![1.0, 0.0]);
    }

    #[test]
    fn length_mismatch_rejected() {
        let a = f64buf(&[1.0]);
        let b = f64buf(&[1.0, 2.0]);
        assert!(binary_vv(BinOp::Add, &a, &b, true).is_err());
    }
}
