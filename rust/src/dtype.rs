//! Element types, promotion rules and scalar values.
//!
//! FlashMatrix matrices are typed containers of primitive elements. Binary
//! GenOps require both operands to share an element type; when they differ
//! the engine inserts a lazy cast on the smaller type (paper §III-D: "If a
//! GenOp gets two matrices with different element types, it first casts the
//! element type of one matrix to match the other").

/// Primitive element types supported by the engine.
///
/// `Bool` is stored as one byte (R's logical); promotion order follows R:
/// Bool < I32 < I64 < F32 < F64.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DType {
    Bool,
    I32,
    I64,
    F32,
    F64,
}

impl DType {
    /// Size of one element in bytes.
    pub fn size(self) -> usize {
        match self {
            DType::Bool => 1,
            DType::I32 | DType::F32 => 4,
            DType::I64 | DType::F64 => 8,
        }
    }

    /// Rank in the promotion lattice.
    fn rank(self) -> u8 {
        match self {
            DType::Bool => 0,
            DType::I32 => 1,
            DType::I64 => 2,
            DType::F32 => 3,
            DType::F64 => 4,
        }
    }

    /// Common type two operands promote to.
    pub fn promote(a: DType, b: DType) -> DType {
        // I64 + F32 promotes to F64 (R promotes integer to double);
        // otherwise the higher rank wins.
        if (a == DType::I64 && b == DType::F32) || (a == DType::F32 && b == DType::I64) {
            return DType::F64;
        }
        if a.rank() >= b.rank() {
            a
        } else {
            b
        }
    }

    pub fn is_float(self) -> bool {
        matches!(self, DType::F32 | DType::F64)
    }

    pub fn is_int(self) -> bool {
        matches!(self, DType::I32 | DType::I64)
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::Bool => "bool",
            DType::I32 => "i32",
            DType::I64 => "i64",
            DType::F32 => "f32",
            DType::F64 => "f64",
        }
    }
}

impl std::fmt::Display for DType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A typed scalar value (the `c` of `fm.agg`, constants in expressions,
/// fill values of constant virtual matrices).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Scalar {
    Bool(bool),
    I32(i32),
    I64(i64),
    F32(f32),
    F64(f64),
}

impl Scalar {
    pub fn dtype(self) -> DType {
        match self {
            Scalar::Bool(_) => DType::Bool,
            Scalar::I32(_) => DType::I32,
            Scalar::I64(_) => DType::I64,
            Scalar::F32(_) => DType::F32,
            Scalar::F64(_) => DType::F64,
        }
    }

    /// Lossy conversion to f64 (for display and float kernels).
    pub fn as_f64(self) -> f64 {
        match self {
            Scalar::Bool(b) => b as u8 as f64,
            Scalar::I32(v) => v as f64,
            Scalar::I64(v) => v as f64,
            Scalar::F32(v) => v as f64,
            Scalar::F64(v) => v,
        }
    }

    /// Lossy conversion to i64.
    pub fn as_i64(self) -> i64 {
        match self {
            Scalar::Bool(b) => b as i64,
            Scalar::I32(v) => v as i64,
            Scalar::I64(v) => v,
            Scalar::F32(v) => v as i64,
            Scalar::F64(v) => v as i64,
        }
    }

    pub fn as_bool(self) -> bool {
        match self {
            Scalar::Bool(b) => b,
            Scalar::I32(v) => v != 0,
            Scalar::I64(v) => v != 0,
            Scalar::F32(v) => v != 0.0,
            Scalar::F64(v) => v != 0.0,
        }
    }

    /// Whether this value is the NA of its dtype (R's missing-value
    /// convention): floats use NaN, integers use the most negative value
    /// (R's `NA_integer_` is `INT_MIN`). `Bool` has no NA representation.
    pub fn is_na(self) -> bool {
        match self {
            Scalar::Bool(_) => false,
            Scalar::I32(v) => v == i32::MIN,
            Scalar::I64(v) => v == i64::MIN,
            Scalar::F32(v) => v.is_nan(),
            Scalar::F64(v) => v.is_nan(),
        }
    }

    /// The canonical NA of a dtype (`Bool` has none and falls back to
    /// `false`, which the NA-aware kernels never produce).
    pub fn na(dt: DType) -> Scalar {
        match dt {
            DType::Bool => Scalar::Bool(false),
            DType::I32 => Scalar::I32(i32::MIN),
            DType::I64 => Scalar::I64(i64::MIN),
            DType::F32 => Scalar::F32(f32::NAN),
            DType::F64 => Scalar::F64(f64::NAN),
        }
    }

    /// Cast to a target dtype (R-style numeric coercion).
    pub fn cast(self, to: DType) -> Scalar {
        match to {
            DType::Bool => Scalar::Bool(self.as_bool()),
            DType::I32 => Scalar::I32(self.as_i64() as i32),
            DType::I64 => Scalar::I64(self.as_i64()),
            DType::F32 => Scalar::F32(self.as_f64() as f32),
            DType::F64 => Scalar::F64(self.as_f64()),
        }
    }
}

impl From<f64> for Scalar {
    fn from(v: f64) -> Self {
        Scalar::F64(v)
    }
}
impl From<f32> for Scalar {
    fn from(v: f32) -> Self {
        Scalar::F32(v)
    }
}
impl From<i64> for Scalar {
    fn from(v: i64) -> Self {
        Scalar::I64(v)
    }
}
impl From<i32> for Scalar {
    fn from(v: i32) -> Self {
        Scalar::I32(v)
    }
}
impl From<bool> for Scalar {
    fn from(v: bool) -> Self {
        Scalar::Bool(v)
    }
}

/// Rust primitive <-> engine dtype binding used by the typed kernels.
pub trait Element: Copy + Send + Sync + 'static + PartialOrd + std::fmt::Debug {
    const DTYPE: DType;
    fn from_f64(v: f64) -> Self;
    fn to_f64(self) -> f64;
    fn zero() -> Self;
    fn one() -> Self;
}

macro_rules! impl_element {
    ($t:ty, $dt:expr, $zero:expr, $one:expr) => {
        impl Element for $t {
            const DTYPE: DType = $dt;
            fn from_f64(v: f64) -> Self {
                v as $t
            }
            fn to_f64(self) -> f64 {
                self as f64
            }
            fn zero() -> Self {
                $zero
            }
            fn one() -> Self {
                $one
            }
        }
    };
}

impl_element!(f64, DType::F64, 0.0, 1.0);
impl_element!(f32, DType::F32, 0.0, 1.0);
impl_element!(i64, DType::I64, 0, 1);
impl_element!(i32, DType::I32, 0, 1);

impl Element for bool {
    const DTYPE: DType = DType::Bool;
    fn from_f64(v: f64) -> Self {
        v != 0.0
    }
    fn to_f64(self) -> f64 {
        self as u8 as f64
    }
    fn zero() -> Self {
        false
    }
    fn one() -> Self {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn promotion_lattice() {
        use DType::*;
        assert_eq!(DType::promote(Bool, I32), I32);
        assert_eq!(DType::promote(I32, I64), I64);
        assert_eq!(DType::promote(I64, F32), F64); // R-style widening
        assert_eq!(DType::promote(F32, F64), F64);
        assert_eq!(DType::promote(F64, Bool), F64);
        for &t in &[Bool, I32, I64, F32, F64] {
            assert_eq!(DType::promote(t, t), t);
            // commutativity
            for &u in &[Bool, I32, I64, F32, F64] {
                assert_eq!(DType::promote(t, u), DType::promote(u, t));
            }
        }
    }

    #[test]
    fn scalar_casts() {
        assert_eq!(Scalar::F64(2.9).cast(DType::I32), Scalar::I32(2));
        assert_eq!(Scalar::I64(0).cast(DType::Bool), Scalar::Bool(false));
        assert_eq!(Scalar::Bool(true).cast(DType::F64), Scalar::F64(1.0));
        assert_eq!(Scalar::F32(1.5).dtype(), DType::F32);
    }

    #[test]
    fn sizes() {
        assert_eq!(DType::Bool.size(), 1);
        assert_eq!(DType::F64.size(), 8);
        assert_eq!(DType::I32.size(), 4);
    }
}
