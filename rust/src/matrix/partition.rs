//! Two-level partitioning of tall-and-skinny matrices (paper §III-B1).
//!
//! Level 1 — **I/O-level partitions**: horizontal row blocks, always a
//! power-of-two number of rows, sized on the order of megabytes. One
//! partition is the unit of I/O (one `pread` per partition), of parallel
//! task dispatch, and of contiguous memory within a chunk.
//!
//! Level 2 — **CPU-level partitions**: row sub-blocks of an I/O partition
//! sized to fit L1/L2 cache; the fused-pipeline evaluator walks them so a
//! partition's intermediates never leave cache (§III-F "cache-fuse").
//!
//! The I/O row-count formula is shared with the AOT compile path
//! (python/compile/model.py::io_rows_for) so artifact input shapes always
//! match full engine partitions. Keep the two in sync.

/// Mirror of `EngineConfig::target_part_bytes` default; the formula's
/// constants are pinned here (and in model.py) so artifact shapes are
/// stable even if the engine config changes at runtime.
pub const TARGET_PART_BYTES: usize = 8 << 20;
pub const MIN_IO_ROWS: u64 = 1024;
pub const MAX_IO_ROWS: u64 = 65536;
/// The formula assumes 8-byte elements regardless of dtype so that a
/// matrix's partitioning never depends on its element type.
pub const FORMULA_ELEM_BYTES: u64 = 8;

/// Rows per I/O-level partition for a `p`-column matrix: the largest power
/// of two with `rows * p * 8 <= 8 MiB`, clamped to `[1024, 65536]`.
pub fn io_rows_for(p: u64) -> u64 {
    let p = p.max(1);
    let rows = (TARGET_PART_BYTES as u64) / (FORMULA_ELEM_BYTES * p);
    let pow2 = if rows == 0 { 1 } else { 1u64 << (63 - rows.leading_zeros()) };
    pow2.clamp(MIN_IO_ROWS, MAX_IO_ROWS)
}

/// Row-range partitioning of an `nrow x ncol` tall matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Partitioning {
    pub nrow: u64,
    pub ncol: u64,
    /// Rows in every full partition (the last may be shorter).
    pub io_rows: u64,
}

impl Partitioning {
    pub fn new(nrow: u64, ncol: u64) -> Partitioning {
        Partitioning {
            nrow,
            ncol,
            io_rows: io_rows_for(ncol),
        }
    }

    /// Partitioning with an explicit I/O row count (tests, conversions).
    pub fn with_io_rows(nrow: u64, ncol: u64, io_rows: u64) -> Partitioning {
        assert!(io_rows > 0);
        Partitioning { nrow, ncol, io_rows }
    }

    /// Number of I/O-level partitions.
    pub fn n_parts(&self) -> usize {
        if self.nrow == 0 {
            0
        } else {
            self.nrow.div_ceil(self.io_rows) as usize
        }
    }

    /// Row range `[start, end)` of partition `i`.
    pub fn part_rows(&self, i: usize) -> (u64, u64) {
        let start = i as u64 * self.io_rows;
        let end = (start + self.io_rows).min(self.nrow);
        assert!(start < self.nrow, "partition {i} out of range");
        (start, end)
    }

    /// Number of rows in partition `i`.
    pub fn rows_in(&self, i: usize) -> u64 {
        let (s, e) = self.part_rows(i);
        e - s
    }

    /// Whether partition `i` is a full (non-tail) partition — only full
    /// partitions are eligible for XLA artifact dispatch.
    pub fn is_full(&self, i: usize) -> bool {
        self.rows_in(i) == self.io_rows
    }

    /// Bytes of one partition for an element size.
    pub fn part_bytes(&self, i: usize, elem: usize) -> usize {
        (self.rows_in(i) * self.ncol) as usize * elem
    }

    /// Byte offset of partition `i` in a densely-packed file/chunk layout.
    pub fn part_offset(&self, i: usize, elem: usize) -> u64 {
        (i as u64 * self.io_rows * self.ncol) * elem as u64
    }

    /// Total backing bytes.
    pub fn total_bytes(&self, elem: usize) -> u64 {
        self.nrow * self.ncol * elem as u64
    }

    /// CPU-level sub-partition row count: the largest row block of `ncol`
    /// columns fitting `cpu_part_bytes` (at 8 B/elem), at least 8 rows.
    pub fn cpu_rows(&self, cpu_part_bytes: usize) -> u64 {
        let per_row = (self.ncol.max(1)) * FORMULA_ELEM_BYTES;
        ((cpu_part_bytes as u64) / per_row)
            .max(8)
            .min(self.io_rows)
            .max(1)
    }

    /// Iterate CPU-level row ranges (local to partition `i`).
    pub fn cpu_ranges(&self, i: usize, cpu_part_bytes: usize) -> Vec<(u64, u64)> {
        let rows = self.rows_in(i);
        let step = self.cpu_rows(cpu_part_bytes);
        let mut out = Vec::new();
        let mut s = 0;
        while s < rows {
            let e = (s + step).min(rows);
            out.push((s, e));
            s = e;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn io_rows_matches_python_pins() {
        // pinned values mirrored in python/tests/test_model.py
        assert_eq!(io_rows_for(8), 65536);
        assert_eq!(io_rows_for(16), 65536);
        assert_eq!(io_rows_for(32), 32768);
        assert_eq!(io_rows_for(64), 16384);
        assert_eq!(io_rows_for(128), 8192);
        assert_eq!(io_rows_for(256), 4096);
        assert_eq!(io_rows_for(512), 2048);
        for p in 1..600 {
            let r = io_rows_for(p);
            assert!(r.is_power_of_two());
            assert!((MIN_IO_ROWS..=MAX_IO_ROWS).contains(&r));
        }
    }

    #[test]
    fn partition_ranges_cover_exactly() {
        let pt = Partitioning::with_io_rows(100_000, 32, 32768);
        assert_eq!(pt.n_parts(), 4);
        let mut covered = 0;
        for i in 0..pt.n_parts() {
            let (s, e) = pt.part_rows(i);
            assert_eq!(s, covered);
            covered = e;
        }
        assert_eq!(covered, 100_000);
        assert!(pt.is_full(0));
        assert!(!pt.is_full(3));
        assert_eq!(pt.rows_in(3), 100_000 - 3 * 32768);
    }

    #[test]
    fn cpu_ranges_cover_partition() {
        let pt = Partitioning::with_io_rows(32768, 32, 32768);
        let ranges = pt.cpu_ranges(0, 64 << 10);
        let mut last = 0;
        for (s, e) in &ranges {
            assert_eq!(*s, last);
            last = *e;
        }
        assert_eq!(last, 32768);
        // 64 KiB / (32 cols * 8B) = 256 rows per CPU partition
        assert_eq!(ranges[0], (0, 256));
    }

    #[test]
    fn empty_matrix() {
        let pt = Partitioning::new(0, 4);
        assert_eq!(pt.n_parts(), 0);
    }

    #[test]
    fn offsets_are_packed() {
        let pt = Partitioning::with_io_rows(5000, 4, 2048);
        assert_eq!(pt.part_offset(0, 8), 0);
        assert_eq!(pt.part_offset(1, 8), 2048 * 4 * 8);
        assert_eq!(pt.part_bytes(2, 8), (5000 - 4096) as usize * 4 * 8);
    }
}
