//! Row-partitioned sparse matrices (CSR per I/O-level partition).
//!
//! FlashR's graph-style workloads (PageRank, label propagation, …) stream
//! an *edge matrix* whose nnz count — not its dense n×n shape — is what
//! has to fit the memory hierarchy. The sparse subsystem reuses the dense
//! infrastructure wholesale:
//!
//! * **Partitioning** — rows are split on the *same* io-row grid as dense
//!   matrices ([`Partitioning`], power-of-two row blocks), so a sparse
//!   source nests inside any pass and is range-scheduled exactly like a
//!   dense source (one `pread` per partition, locality units, read-ahead).
//! * **Byte layout** — each partition is an independent little-endian CSR
//!   block (see [`encode_partition`]): `nnz: u64`, local `row_ptr:
//!   (prows+1) × u64`, `col_idx: nnz × u32`, `values: nnz × f64`.
//!   Partitions are variable-length and densely packed in file order; the
//!   per-partition `(offset, len)` table lives in [`SparseData`] and, for
//!   *named* external matrices, in a sidecar manifest
//!   ([`crate::runtime::manifest::SparseMeta`]) so datasets reopen across
//!   runs.
//! * **Memory hierarchy** — external partitions are admitted to the
//!   engine's write-through [`PartitionCache`] under their own matrix id,
//!   with the same single-flight read-through, prefetch pinning and
//!   drop-time eviction as dense partitions (§III-B3).
//!
//! A sparse matrix is consumed exclusively by the SpMM GenOp
//! ([`crate::genops::spmm`]): the strip evaluator decodes CSR rows
//! straight from the partition bytes and multiplies against a small dense
//! right-hand matrix held in memory — the classic out-of-core PageRank
//! shape (edges on SSD, rank vector in DRAM).

use std::sync::Arc;

use crate::dtype::DType;
use crate::error::{FmError, Result};
use crate::metrics::Metrics;
use crate::storage::{FileStore, SsdSim};

use super::cache::{CacheHandle, PartitionCache};
use super::partition::Partitioning;

/// Bytes of one CSR entry (u32 column + f64 value) — the nnz-proportional
/// part of the layout; the row pointers add `(prows+1) * 8` per partition.
pub const ENTRY_BYTES: usize = 4 + 8;

/// Encode one partition's rows as the CSR byte block. `rows[r]` holds the
/// `(col, value)` pairs of local row `r`; entries are sorted by column and
/// duplicates merged additively (multi-edges accumulate) **in place** —
/// no copy of the entry payload — so the layout is canonical for a given
/// logical matrix and the caller's rows come back normalized.
pub fn encode_partition(rows: &mut [Vec<(u32, f64)>]) -> Vec<u8> {
    for r in rows.iter_mut() {
        r.sort_by_key(|(c, _)| *c); // stable: duplicates keep insert order
        // merge adjacent duplicate columns, accumulating left to right
        // (insertion order — mirrored by the python fixture generator)
        let mut w = 0usize;
        for i in 0..r.len() {
            let (c, v) = r[i];
            if w > 0 && r[w - 1].0 == c {
                r[w - 1].1 += v;
            } else {
                r[w] = (c, v);
                w += 1;
            }
        }
        r.truncate(w);
    }
    let nnz: usize = rows.iter().map(|r| r.len()).sum();
    let mut out = Vec::with_capacity(8 + (rows.len() + 1) * 8 + nnz * ENTRY_BYTES);
    out.extend_from_slice(&(nnz as u64).to_le_bytes());
    let mut acc = 0u64;
    out.extend_from_slice(&acc.to_le_bytes());
    for r in rows.iter() {
        acc += r.len() as u64;
        out.extend_from_slice(&acc.to_le_bytes());
    }
    for r in rows.iter() {
        for (c, _) in r {
            out.extend_from_slice(&c.to_le_bytes());
        }
    }
    for r in rows.iter() {
        for (_, v) in r {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    out
}

/// Zero-copy view over one encoded CSR partition.
pub struct SparsePartView<'a> {
    pub prows: usize,
    pub nnz: usize,
    row_ptr: &'a [u8],
    col_idx: &'a [u8],
    values: &'a [u8],
}

impl<'a> SparsePartView<'a> {
    /// Parse (and bounds-check) a partition of `prows` rows.
    ///
    /// The block may come off disk, so every structural field is treated
    /// as hostile: an oversized `nnz` must not overflow the size
    /// arithmetic, and the `row_ptr` table must be monotone and end at
    /// `nnz` — otherwise [`row_range`](Self::row_range)/
    /// [`entry`](Self::entry) (which trust the view after this gate)
    /// could index out of bounds. A corrupt block surfaces as
    /// [`FmError::Corrupt`], never a panic.
    pub fn parse(bytes: &'a [u8], prows: usize) -> Result<SparsePartView<'a>> {
        if bytes.len() < 8 + (prows + 1) * 8 {
            return Err(FmError::Corrupt(format!(
                "sparse partition too short: {} bytes for {prows} rows",
                bytes.len()
            )));
        }
        let nnz64 = u64::from_le_bytes(bytes[0..8].try_into().unwrap());
        let rp_end = 8 + (prows + 1) * 8;
        let v_end = usize::try_from(nnz64)
            .ok()
            .and_then(|n| n.checked_mul(ENTRY_BYTES))
            .and_then(|b| b.checked_add(rp_end));
        let (nnz, v_end) = match v_end {
            // an absurd nnz (e.g. bit-flipped high byte) overflows here
            // instead of wrapping into a bogus "valid" length
            Some(v) if v == bytes.len() => (nnz64 as usize, v),
            _ => {
                return Err(FmError::Corrupt(format!(
                    "sparse partition: {} bytes inconsistent with header \
                     ({prows} rows, nnz {nnz64})",
                    bytes.len()
                )))
            }
        };
        let ci_end = rp_end + nnz * 4;
        let row_ptr = &bytes[8..rp_end];
        // row_ptr must be monotone within [0, nnz] and exhaust the
        // entries, or the per-row entry ranges would escape the block
        let mut prev = 0u64;
        for r in 0..=prows {
            let p = u64::from_le_bytes(row_ptr[r * 8..r * 8 + 8].try_into().unwrap());
            if p < prev || p > nnz64 {
                return Err(FmError::Corrupt(format!(
                    "sparse partition: row_ptr[{r}] = {p} out of order (prev {prev}, nnz {nnz64})"
                )));
            }
            prev = p;
        }
        if prev != nnz64 {
            return Err(FmError::Corrupt(format!(
                "sparse partition: row_ptr ends at {prev}, want nnz {nnz64}"
            )));
        }
        Ok(SparsePartView {
            prows,
            nnz,
            row_ptr,
            col_idx: &bytes[rp_end..ci_end],
            values: &bytes[ci_end..v_end],
        })
    }

    /// Entry range `[lo, hi)` of local row `r`.
    #[inline]
    pub fn row_range(&self, r: usize) -> (usize, usize) {
        let at = |i: usize| {
            u64::from_le_bytes(self.row_ptr[i * 8..i * 8 + 8].try_into().unwrap()) as usize
        };
        (at(r), at(r + 1))
    }

    /// `(column, value)` of entry `e`.
    #[inline]
    pub fn entry(&self, e: usize) -> (u32, f64) {
        let c = u32::from_le_bytes(self.col_idx[e * 4..e * 4 + 4].try_into().unwrap());
        let v = f64::from_le_bytes(self.values[e * 8..e * 8 + 8].try_into().unwrap());
        (c, v)
    }
}

/// Where a sparse matrix's partition blocks live.
enum SparseBacking {
    /// In-memory: one encoded block per partition.
    Mem(Vec<Arc<Vec<u8>>>),
    /// External file, blocks densely packed in partition order, admitted
    /// to the engine's write-through partition cache like dense
    /// partitions (§III-B3).
    Ext {
        store: Arc<FileStore>,
        metrics: Arc<Metrics>,
        pcache: Option<CacheHandle>,
    },
}

/// A materialized row-partitioned CSR matrix. Immutable after build.
pub struct SparseData {
    pub dtype: DType,
    /// Row grid shared with dense matrices (`ncol` is the logical column
    /// count; it does not drive the byte layout).
    pub parts: Partitioning,
    /// Total stored entries.
    pub nnz: u64,
    /// Byte `(offset, len)` of each partition in the packed layout.
    part_locs: Vec<(u64, usize)>,
    backing: SparseBacking,
}

impl SparseData {
    pub fn nrow(&self) -> u64 {
        self.parts.nrow
    }

    pub fn ncol(&self) -> u64 {
        self.parts.ncol
    }

    /// Total encoded bytes (the matrix's EM footprint — what the cache
    /// ablation compares `em_cache_bytes` against).
    pub fn total_bytes(&self) -> u64 {
        self.part_locs
            .last()
            .map(|(o, l)| o + *l as u64)
            .unwrap_or(0)
    }

    /// Encoded bytes of partition `i`. External matrices go through the
    /// §III-B3 hierarchy: partition-cache hit, single-flight coalesce, or
    /// a leader `pread` that refills the cache — identical to the dense
    /// read path.
    pub fn partition_bytes_shared(&self, i: usize) -> Result<Arc<Vec<u8>>> {
        let (off, len) = self.part_locs[i];
        match &self.backing {
            SparseBacking::Mem(blocks) => Ok(Arc::clone(&blocks[i])),
            SparseBacking::Ext {
                store,
                metrics,
                pcache,
            } => {
                let read = || -> Result<Vec<u8>> {
                    let mut out = vec![0u8; len];
                    store.read_at(off, &mut out)?;
                    Ok(out)
                };
                match pcache {
                    Some(h) => h.cache.get_or_read(h.matrix_id, i, read),
                    None => {
                        metrics
                            .cache_misses
                            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        read().map(Arc::new)
                    }
                }
            }
        }
    }

    /// Queue an async read-ahead of partition `i` (no-op in memory, when
    /// uncached, or out of range) — same contract as the dense
    /// [`super::DenseData::prefetch_partition`].
    pub fn prefetch_partition(&self, i: usize, pass: u64) {
        if i >= self.parts.n_parts() {
            return;
        }
        if let SparseBacking::Ext {
            store,
            pcache: Some(h),
            ..
        } = &self.backing
        {
            let (off, len) = self.part_locs[i];
            PartitionCache::prefetch(&h.cache, store, h.matrix_id, i, off, len, pass);
        }
    }

    /// Cache registration id, if this matrix reads through the engine's
    /// partition cache (multi-tenant owner tagging).
    pub fn cache_matrix_id(&self) -> Option<u64> {
        match &self.backing {
            SparseBacking::Ext {
                pcache: Some(h), ..
            } => Some(h.matrix_id),
            _ => None,
        }
    }

    /// Release read-ahead pins still held for this matrix (pass end).
    pub fn release_prefetch_pins(&self) {
        if let SparseBacking::Ext {
            pcache: Some(h), ..
        } = &self.backing
        {
            h.cache.release_prefetch_pins(h.matrix_id);
        }
    }

    /// Reopen a *named* external sparse matrix from its sidecar manifest
    /// (`<name>.sparse.json` next to the matrix file).
    pub fn open_named(
        dir: &std::path::Path,
        name: &str,
        ssd: Arc<SsdSim>,
        metrics: Arc<Metrics>,
        pcache: Option<Arc<PartitionCache>>,
    ) -> Result<SparseData> {
        let meta = crate::runtime::manifest::SparseMeta::load(&dir.join(format!(
            "{name}.sparse.json"
        )))?;
        let store = Arc::new(FileStore::open(
            &dir.join(name),
            ssd,
            Arc::clone(&metrics),
        )?);
        // re-arm the persisted partition checksums: corruption of the
        // dataset at rest is caught on the first read, not silently
        // folded into results
        store.checksums().seed(
            meta.parts
                .iter()
                .zip(&meta.crcs)
                .filter_map(|((off, len), crc)| crc.map(|c| (*off, *len, c))),
        );
        Ok(SparseData {
            dtype: DType::F64,
            parts: Partitioning::with_io_rows(meta.nrow, meta.ncol, meta.io_rows),
            nnz: meta.nnz,
            part_locs: meta.parts,
            backing: SparseBacking::Ext {
                store,
                metrics,
                pcache: pcache.map(CacheHandle::register),
            },
        })
    }
}

/// Builder: partitions are encoded in row order, then frozen into memory
/// or written through to an external file (+ cache) in one shot — the
/// variable-length layout needs the total size before the fixed-length
/// [`FileStore`] can be created, so encoded blocks are buffered in RAM
/// until `finish_*`. That bounds buildable matrices by DRAM, not by SSD;
/// a streaming builder (growable store + incremental block writes) is
/// the known next step for paper-scale edge sets.
pub struct SparseBuilder {
    parts: Partitioning,
    encoded: Vec<Vec<u8>>,
    nnz: u64,
}

impl SparseBuilder {
    pub fn new(parts: Partitioning) -> SparseBuilder {
        SparseBuilder {
            parts,
            encoded: Vec::new(),
            nnz: 0,
        }
    }

    /// Append the next partition's rows (call once per partition, in
    /// order; `rows.len()` must equal the partition's row count). Rows
    /// are normalized in place by [`encode_partition`].
    pub fn push_partition(&mut self, rows: &mut [Vec<(u32, f64)>]) -> Result<()> {
        let i = self.encoded.len();
        if i >= self.parts.n_parts() {
            return Err(FmError::Shape("sparse builder: too many partitions".into()));
        }
        if rows.len() != self.parts.rows_in(i) as usize {
            return Err(FmError::Shape(format!(
                "sparse partition {i}: {} rows, want {}",
                rows.len(),
                self.parts.rows_in(i)
            )));
        }
        for r in rows.iter() {
            for (c, _) in r {
                if *c as u64 >= self.parts.ncol {
                    return Err(FmError::Shape(format!(
                        "sparse column {c} out of range (ncol = {})",
                        self.parts.ncol
                    )));
                }
            }
        }
        let block = encode_partition(rows);
        self.nnz += u64::from_le_bytes(block[0..8].try_into().unwrap());
        self.encoded.push(block);
        Ok(())
    }

    fn check_complete(&self) -> Result<()> {
        if self.encoded.len() != self.parts.n_parts() {
            return Err(FmError::Shape(format!(
                "sparse builder: {} of {} partitions written",
                self.encoded.len(),
                self.parts.n_parts()
            )));
        }
        Ok(())
    }

    fn locs(&self) -> Vec<(u64, usize)> {
        let mut locs = Vec::with_capacity(self.encoded.len());
        let mut off = 0u64;
        for b in &self.encoded {
            locs.push((off, b.len()));
            off += b.len() as u64;
        }
        locs
    }

    /// Freeze in memory.
    pub fn finish_mem(self) -> Result<SparseData> {
        self.check_complete()?;
        let part_locs = self.locs();
        Ok(SparseData {
            dtype: DType::F64,
            parts: self.parts,
            nnz: self.nnz,
            part_locs,
            backing: SparseBacking::Mem(self.encoded.into_iter().map(Arc::new).collect()),
        })
    }

    /// Write through to an external file (and the partition cache, like
    /// dense write-through). A `name` also writes the sidecar manifest so
    /// the dataset reopens across runs ([`SparseData::open_named`]).
    pub fn finish_ext(
        self,
        dir: &std::path::Path,
        name: Option<&str>,
        ssd: Arc<SsdSim>,
        metrics: Arc<Metrics>,
        pcache: Option<Arc<PartitionCache>>,
    ) -> Result<SparseData> {
        self.check_complete()?;
        let part_locs = self.locs();
        let total: u64 = part_locs.last().map(|(o, l)| o + *l as u64).unwrap_or(0);
        let store = Arc::new(FileStore::create(
            dir,
            name,
            total,
            ssd,
            Arc::clone(&metrics),
        )?);
        let pcache = pcache.map(CacheHandle::register);
        for (i, block) in self.encoded.iter().enumerate() {
            store.write_at(part_locs[i].0, block)?;
            if let Some(h) = &pcache {
                h.cache.insert(h.matrix_id, i, block.clone());
            }
        }
        if let Some(n) = name {
            crate::runtime::manifest::SparseMeta {
                nrow: self.parts.nrow,
                ncol: self.parts.ncol,
                io_rows: self.parts.io_rows,
                nnz: self.nnz,
                parts: part_locs.clone(),
                // persist the partition checksums the writes recorded so
                // a reopened dataset verifies reads across runs
                crcs: store.checksums().export(&part_locs),
            }
            .save(&dir.join(format!("{n}.sparse.json")))?;
        }
        Ok(SparseData {
            dtype: DType::F64,
            parts: self.parts,
            nnz: self.nnz,
            part_locs,
            backing: SparseBacking::Ext {
                store,
                metrics,
                pcache,
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows3() -> Vec<Vec<(u32, f64)>> {
        vec![
            vec![(2, 1.5), (0, -2.0)],        // out of order: encode sorts
            vec![],                            // empty row
            vec![(1, 3.0), (1, 0.5), (3, 1.0)], // duplicate col: merges to 3.5
        ]
    }

    #[test]
    fn encode_parse_roundtrip() {
        let b = encode_partition(&mut rows3());
        let v = SparsePartView::parse(&b, 3).unwrap();
        assert_eq!(v.nnz, 4);
        assert_eq!(v.row_range(0), (0, 2));
        assert_eq!(v.entry(0), (0, -2.0));
        assert_eq!(v.entry(1), (2, 1.5));
        assert_eq!(v.row_range(1), (2, 2));
        assert_eq!(v.row_range(2), (2, 4));
        assert_eq!(v.entry(2), (1, 3.5), "duplicate columns must merge");
        assert_eq!(v.entry(3), (3, 1.0));
    }

    #[test]
    fn parse_rejects_truncated_blocks() {
        let b = encode_partition(&mut rows3());
        assert!(SparsePartView::parse(&b[..b.len() - 1], 3).is_err());
        assert!(SparsePartView::parse(&b, 2).is_err());
        assert!(SparsePartView::parse(&[0u8; 4], 1).is_err());
    }

    #[test]
    fn parse_rejects_corrupt_header_and_row_ptr() {
        // oversized nnz (flipped high byte): must be a typed error, not
        // an arithmetic overflow / huge-slice panic
        let mut b = encode_partition(&mut rows3());
        b[7] = 0xFF;
        let err = SparsePartView::parse(&b, 3).unwrap_err();
        assert!(matches!(err, FmError::Corrupt(_)), "got: {err}");
        // nnz = usize::MAX-ish so nnz * ENTRY_BYTES would wrap
        let mut b = encode_partition(&mut rows3());
        b[0..8].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(matches!(
            SparsePartView::parse(&b, 3).unwrap_err(),
            FmError::Corrupt(_)
        ));
        // non-monotone row_ptr: row 1's pointer rewound below row 0's
        let mut b = encode_partition(&mut rows3());
        b[16..24].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(matches!(
            SparsePartView::parse(&b, 3).unwrap_err(),
            FmError::Corrupt(_)
        ));
        // row_ptr ending short of nnz leaves unreachable entries
        let mut b = encode_partition(&mut rows3());
        let last = 8 + 3 * 8; // row_ptr[3] of 4 pointers
        b[last..last + 8].copy_from_slice(&1u64.to_le_bytes());
        assert!(matches!(
            SparsePartView::parse(&b, 3).unwrap_err(),
            FmError::Corrupt(_)
        ));
    }

    #[test]
    fn reopened_dataset_verifies_persisted_checksums() {
        let tmp = crate::testutil::TempDir::new("sparse-crc");
        let ssd = Arc::new(SsdSim::new(None));
        let metrics = Arc::new(Metrics::new());
        let parts = Partitioning::with_io_rows(4, 3, 2);
        let mut b = SparseBuilder::new(parts);
        b.push_partition(&mut [vec![(0, 1.0), (2, 2.0)], vec![(1, 3.0)]])
            .unwrap();
        b.push_partition(&mut [vec![], vec![(2, -1.0)]]).unwrap();
        let m = b
            .finish_ext(
                tmp.path(),
                Some("crc.mat"),
                Arc::clone(&ssd),
                Arc::clone(&metrics),
                None,
            )
            .unwrap();
        drop(m);
        // flip one payload byte of partition 0 on disk
        {
            use std::os::unix::fs::FileExt;
            let f = std::fs::OpenOptions::new()
                .write(true)
                .open(tmp.path().join("crc.mat"))
                .unwrap();
            f.write_all_at(&[0xAA], 40).unwrap();
        }
        let m2 = SparseData::open_named(
            tmp.path(),
            "crc.mat",
            ssd,
            Arc::clone(&metrics),
            None,
        )
        .unwrap();
        let err = m2.partition_bytes_shared(0).unwrap_err();
        assert!(matches!(err, FmError::Corrupt(_)), "got: {err}");
        assert!(metrics.snapshot().checksum_failures >= 1);
        // the untouched partition still reads fine
        m2.partition_bytes_shared(1).unwrap();
    }

    #[test]
    fn builder_mem_multi_partition() {
        let parts = Partitioning::with_io_rows(5, 4, 2);
        let mut b = SparseBuilder::new(parts);
        b.push_partition(&mut [vec![(0, 1.0)], vec![(3, 2.0)]]).unwrap();
        b.push_partition(&mut [vec![], vec![(1, 4.0), (2, 5.0)]]).unwrap();
        b.push_partition(&mut [vec![(0, 7.0)]]).unwrap(); // tail partition, 1 row
        let m = b.finish_mem().unwrap();
        assert_eq!(m.nnz, 5);
        assert_eq!(m.parts.n_parts(), 3);
        let bytes = m.partition_bytes_shared(1).unwrap();
        let v = SparsePartView::parse(&bytes, 2).unwrap();
        assert_eq!(v.entry(0), (1, 4.0));
    }

    #[test]
    fn builder_validates_shape() {
        let parts = Partitioning::with_io_rows(4, 2, 2);
        let mut b = SparseBuilder::new(parts.clone());
        assert!(b.push_partition(&mut [vec![]]).is_err(), "wrong row count");
        let mut b = SparseBuilder::new(parts.clone());
        assert!(
            b.push_partition(&mut [vec![(5, 1.0)], vec![]]).is_err(),
            "column out of range"
        );
        let b = SparseBuilder::new(parts);
        assert!(b.finish_mem().is_err(), "incomplete builder must not freeze");
    }

    #[test]
    fn ext_write_through_and_reopen() {
        let tmp = crate::testutil::TempDir::new("sparse-ext");
        let ssd = Arc::new(SsdSim::new(None));
        let metrics = Arc::new(Metrics::new());
        let pc = PartitionCache::new(1 << 20, 0, 0, Arc::clone(&metrics));
        let parts = Partitioning::with_io_rows(4, 3, 2);
        let mut b = SparseBuilder::new(parts);
        b.push_partition(&mut [vec![(0, 1.0), (2, 2.0)], vec![(1, 3.0)]])
            .unwrap();
        b.push_partition(&mut [vec![], vec![(2, -1.0)]]).unwrap();
        let m = b
            .finish_ext(
                tmp.path(),
                Some("edges.mat"),
                Arc::clone(&ssd),
                Arc::clone(&metrics),
                Some(Arc::clone(&pc)),
            )
            .unwrap();
        assert_eq!(pc.len(), 2, "write-through must populate the cache");

        // cached read: no file I/O
        let before = metrics.snapshot();
        let bytes = m.partition_bytes_shared(0).unwrap();
        let after = metrics.snapshot();
        assert_eq!(after.cache_hits - before.cache_hits, 1);
        assert_eq!(after.io_read_reqs, before.io_read_reqs);
        let v = SparsePartView::parse(&bytes, 2).unwrap();
        assert_eq!(v.entry(1), (2, 2.0));

        // reopen from the sidecar manifest; file-only read agrees
        let m2 = SparseData::open_named(
            tmp.path(),
            "edges.mat",
            ssd,
            Arc::clone(&metrics),
            None,
        )
        .unwrap();
        assert_eq!(m2.nnz, 4);
        assert_eq!((m2.nrow(), m2.ncol()), (4, 3));
        let b2 = m2.partition_bytes_shared(0).unwrap();
        assert_eq!(&*b2, &*bytes, "file and cache must agree");

        // dropping the matrix evicts its cache entries
        drop(m);
        assert_eq!(pc.len(), 0);
    }
}
