//! Dense-matrix data model (paper §III-B).
//!
//! * [`DenseData`] — physically materialized TAS matrix (memory chunks or
//!   SSD file), always row-partitioned, col-major within a partition.
//! * [`crate::dag::VNode`] — *virtual* matrices: a recorded computation
//!   plus references to parent matrices (§III-B2); materialized lazily.
//! * [`GroupData`] — a group of TAS matrices standing for one wider matrix
//!   (§III-B4); GenOps decompose onto the members.
//! * [`Matrix`] — the engine-internal handle: an `Arc` of the above plus a
//!   `transposed` flag. `t()` flips the flag — no copy — which is how wide
//!   matrices and the row-major layout are represented (§III-B1).
//! * [`HostMat`] — a small host-resident matrix (sink results, centroids,
//!   the "short" operand of inner products).
//! * [`cache`] — the write-through partition cache + async read-ahead that
//!   sit between external-memory matrices and [`crate::storage`]
//!   (§III-B3).

pub mod cache;
pub mod dense;
pub mod partition;
pub mod sparse;

pub use cache::{CacheHandle, PartitionCache};
pub use dense::{Backing, DenseBuilder, DenseData};
pub use partition::{io_rows_for, Partitioning};
pub use sparse::{SparseBuilder, SparseData, SparsePartView};

use std::sync::Arc;

use crate::dtype::{DType, Scalar};
use crate::error::{FmError, Result};
use crate::vudf::Buf;

/// Storage layout tag for the user-visible API (`fm.conv.layout`). The
/// canonical physical form is col-major TAS; a row-major wide matrix is its
/// transposed view.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layout {
    RowMajor,
    ColMajor,
}

/// A group of same-shape TAS matrices side by side (one wider matrix).
pub struct GroupData {
    pub members: Vec<Arc<MatrixData>>,
}

impl GroupData {
    /// Validate: all members dense-or-virtual with equal nrow and equal
    /// partitioning is checked at materialization; here only nrow.
    pub fn nrow(&self) -> u64 {
        self.members.first().map(|m| m.nrow()).unwrap_or(0)
    }

    pub fn ncol(&self) -> u64 {
        self.members.iter().map(|m| m.ncol()).sum()
    }
}

/// The four physical kinds of matrix data.
pub enum MatrixData {
    Dense(DenseData),
    /// Row-partitioned CSR (consumed by the SpMM GenOp only).
    Sparse(SparseData),
    Virtual(crate::dag::VNode),
    Group(GroupData),
}

impl MatrixData {
    /// Rows in canonical (untransposed) orientation — the *long dimension*
    /// all matrices of one DAG share (§III-E).
    pub fn nrow(&self) -> u64 {
        match self {
            MatrixData::Dense(d) => d.nrow(),
            MatrixData::Sparse(s) => s.nrow(),
            MatrixData::Virtual(v) => v.nrow,
            MatrixData::Group(g) => g.nrow(),
        }
    }

    pub fn ncol(&self) -> u64 {
        match self {
            MatrixData::Dense(d) => d.ncol(),
            MatrixData::Sparse(s) => s.ncol(),
            MatrixData::Virtual(v) => v.ncol,
            MatrixData::Group(g) => g.ncol(),
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            MatrixData::Dense(d) => d.dtype,
            MatrixData::Sparse(s) => s.dtype,
            MatrixData::Virtual(v) => v.dtype,
            // a group of mixed-dtype members reads as the promoted dtype
            // (§III-D promotion); members are cast on load
            MatrixData::Group(g) => g
                .members
                .iter()
                .map(|m| m.dtype())
                .reduce(DType::promote)
                .unwrap_or(DType::F64),
        }
    }

    pub fn is_virtual(&self) -> bool {
        matches!(self, MatrixData::Virtual(_))
    }

    pub fn is_sparse(&self) -> bool {
        matches!(self, MatrixData::Sparse(_))
    }
}

/// Engine-internal matrix handle: shared data + transpose view flag.
#[derive(Clone)]
pub struct Matrix {
    pub data: Arc<MatrixData>,
    pub transposed: bool,
}

impl Matrix {
    pub fn new(data: MatrixData) -> Matrix {
        Matrix {
            data: Arc::new(data),
            transposed: false,
        }
    }

    pub fn from_dense(d: DenseData) -> Matrix {
        Matrix::new(MatrixData::Dense(d))
    }

    /// Logical (view) row count.
    pub fn nrow(&self) -> u64 {
        if self.transposed {
            self.data.ncol()
        } else {
            self.data.nrow()
        }
    }

    /// Logical (view) column count.
    pub fn ncol(&self) -> u64 {
        if self.transposed {
            self.data.nrow()
        } else {
            self.data.ncol()
        }
    }

    pub fn dtype(&self) -> DType {
        self.data.dtype()
    }

    /// Zero-copy transpose (paper: layout flip, §III-B1).
    pub fn t(&self) -> Matrix {
        Matrix {
            data: Arc::clone(&self.data),
            transposed: !self.transposed,
        }
    }

    /// The user-visible layout of the view: canonical TAS is col-major, so
    /// its transposed (wide) view reads as row-major.
    pub fn layout(&self) -> Layout {
        if self.transposed {
            Layout::RowMajor
        } else {
            Layout::ColMajor
        }
    }

    pub fn is_virtual(&self) -> bool {
        self.data.is_virtual()
    }

    pub fn is_sparse(&self) -> bool {
        self.data.is_sparse()
    }

    /// Canonical (untransposed) view of the same data.
    pub fn canonical(&self) -> Matrix {
        Matrix {
            data: Arc::clone(&self.data),
            transposed: false,
        }
    }

    /// Pointer identity (DAG node dedup).
    pub fn data_ptr(&self) -> usize {
        Arc::as_ptr(&self.data) as *const () as usize
    }
}

/// A small host-resident col-major matrix. Sink results, inner-product
/// small operands, centroid/parameter matrices.
#[derive(Clone, Debug, PartialEq)]
pub struct HostMat {
    pub nrow: usize,
    pub ncol: usize,
    /// col-major, len = nrow*ncol
    pub buf: Buf,
}

impl HostMat {
    pub fn new(nrow: usize, ncol: usize, buf: Buf) -> Result<HostMat> {
        if buf.len() != nrow * ncol {
            return Err(FmError::Shape(format!(
                "HostMat {nrow}x{ncol} needs {} elements, got {}",
                nrow * ncol,
                buf.len()
            )));
        }
        Ok(HostMat { nrow, ncol, buf })
    }

    pub fn zeros(nrow: usize, ncol: usize, dtype: DType) -> HostMat {
        HostMat {
            nrow,
            ncol,
            buf: Buf::alloc(dtype, nrow * ncol),
        }
    }

    pub fn from_rows_f64(rows: &[Vec<f64>]) -> HostMat {
        let nrow = rows.len();
        let ncol = rows.first().map(|r| r.len()).unwrap_or(0);
        let mut buf = Buf::alloc(DType::F64, nrow * ncol);
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(r.len(), ncol, "ragged rows");
            for (j, v) in r.iter().enumerate() {
                buf.set(j * nrow + i, Scalar::F64(*v));
            }
        }
        HostMat { nrow, ncol, buf }
    }

    pub fn get(&self, r: usize, c: usize) -> Scalar {
        self.buf.get(c * self.nrow + r)
    }

    pub fn set(&mut self, r: usize, c: usize, v: Scalar) {
        self.buf.set(c * self.nrow + r, v);
    }

    /// Column `c` as a buffer copy.
    pub fn col(&self, c: usize) -> Buf {
        self.buf.slice(c * self.nrow, self.nrow)
    }

    /// Transposed copy.
    pub fn transposed(&self) -> HostMat {
        let mut out = HostMat::zeros(self.ncol, self.nrow, self.buf.dtype());
        for r in 0..self.nrow {
            for c in 0..self.ncol {
                out.set(c, r, self.get(r, c));
            }
        }
        out
    }

    /// Row-major f64 vector (XLA literal layout).
    pub fn to_row_major_f64(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.nrow * self.ncol];
        for r in 0..self.nrow {
            for c in 0..self.ncol {
                out[r * self.ncol + c] = self.get(r, c).as_f64();
            }
        }
        out
    }

    /// Build from a row-major f64 slice.
    pub fn from_row_major_f64(nrow: usize, ncol: usize, data: &[f64]) -> HostMat {
        assert_eq!(data.len(), nrow * ncol);
        let mut m = HostMat::zeros(nrow, ncol, DType::F64);
        for r in 0..nrow {
            for c in 0..ncol {
                m.set(r, c, Scalar::F64(data[r * ncol + c]));
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transpose_is_zero_copy_view() {
        let d = HostMat::from_rows_f64(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        assert_eq!(d.get(2, 1).as_f64(), 6.0);
        let t = d.transposed();
        assert_eq!(t.nrow, 2);
        assert_eq!(t.get(1, 2).as_f64(), 6.0);
    }

    #[test]
    fn matrix_view_dims_flip() {
        let v = crate::dag::VNode {
            nrow: 10,
            ncol: 3,
            dtype: DType::F64,
            kind: crate::dag::VKind::Fill(Scalar::F64(0.0)),
        };
        let m = Matrix::new(MatrixData::Virtual(v));
        assert_eq!((m.nrow(), m.ncol()), (10, 3));
        let t = m.t();
        assert_eq!((t.nrow(), t.ncol()), (3, 10));
        assert_eq!(t.layout(), Layout::RowMajor);
        assert_eq!(t.t().layout(), Layout::ColMajor);
        assert_eq!(t.data_ptr(), m.data_ptr());
    }

    #[test]
    fn hostmat_row_major_roundtrip() {
        let m = HostMat::from_row_major_f64(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(m.get(0, 2).as_f64(), 3.0);
        assert_eq!(m.to_row_major_f64(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn hostmat_shape_checked() {
        assert!(HostMat::new(2, 2, Buf::from_f64(&[0.0; 3])).is_err());
    }
}
