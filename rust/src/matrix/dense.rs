//! Physically-materialized dense matrices (paper §III-B).
//!
//! Physical storage is always the **tall-and-skinny canonical form**: rows
//! partitioned into I/O-level partitions, each partition stored
//! contiguously in **column-major** order (the paper's preferred layout for
//! TAS matrices, §III-G). Wide / row-major matrices are *transposed views*
//! over this canonical form ([`crate::matrix::MatrixData`]), which is
//! exactly how the paper avoids data copies on `t()`.
//!
//! Backing is either
//! * [`Backing::Mem`] — partitions packed into recycled fixed-size chunks
//!   from the [`ChunkPool`] (§III-B5), or
//! * [`Backing::Ext`] — a [`FileStore`] on the simulated SSD array, with an
//!   optional write-through *matrix cache* holding the first few columns in
//!   memory (§III-B3).

use std::sync::{Arc, Mutex};

use crate::dtype::DType;
use crate::error::{FmError, Result};
use crate::mem::{Chunk, ChunkPool};
use crate::metrics::Metrics;
use crate::storage::{FileStore, SsdSim};
use crate::vudf::Buf;

use super::partition::Partitioning;

/// Where a dense matrix's bytes live.
pub enum Backing {
    /// In-memory: chunks + per-partition (chunk index, byte offset).
    Mem {
        chunks: Vec<Chunk>,
        /// partition i -> (chunk index, byte offset within chunk)
        slots: Vec<(usize, usize)>,
    },
    /// External-memory file, partitions densely packed in order, plus an
    /// optional first-`cache_cols` column cache (write-through).
    Ext {
        store: Arc<FileStore>,
        cache_cols: u64,
        /// Col-major `nrow x cache_cols` cache, packed per partition in the
        /// same order as the file (only the first cache_cols columns).
        cache: Option<Vec<u8>>,
        metrics: Arc<Metrics>,
    },
}

/// A materialized TAS dense matrix. Immutable after construction
/// (the engine's functional semantics, §III-E).
pub struct DenseData {
    pub dtype: DType,
    pub parts: Partitioning,
    backing: Backing,
}

impl DenseData {
    pub fn nrow(&self) -> u64 {
        self.parts.nrow
    }

    pub fn ncol(&self) -> u64 {
        self.parts.ncol
    }

    /// Bytes of I/O-level partition `i` (col-major within the partition).
    /// In-memory: a copy out of the chunk; external: one `pread` (or a
    /// cache-assisted partial read for cached matrices).
    pub fn partition_bytes(&self, i: usize) -> Result<Vec<u8>> {
        let esz = self.dtype.size();
        let nbytes = self.parts.part_bytes(i, esz);
        match &self.backing {
            Backing::Mem { chunks, slots } => {
                let (ci, off) = slots[i];
                Ok(chunks[ci].bytes()[off..off + nbytes].to_vec())
            }
            Backing::Ext {
                store,
                cache_cols,
                cache,
                metrics,
            } => {
                let prows = self.parts.rows_in(i) as usize;
                let file_off = self.parts.part_offset(i, esz);
                match cache {
                    Some(cached) if *cache_cols > 0 => {
                        // cached columns come from memory; read only the
                        // contiguous tail columns from the file.
                        metrics.cache_hits.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        let cc = (*cache_cols).min(self.parts.ncol) as usize;
                        let cache_part_off =
                            (self.parts.part_offset(i, esz) / self.parts.ncol) * cc as u64;
                        let cached_bytes = cc * prows * esz;
                        let mut out = vec![0u8; nbytes];
                        out[..cached_bytes].copy_from_slice(
                            &cached[cache_part_off as usize..cache_part_off as usize + cached_bytes],
                        );
                        if nbytes > cached_bytes {
                            store.read_at(
                                file_off + cached_bytes as u64,
                                &mut out[cached_bytes..],
                            )?;
                        }
                        Ok(out)
                    }
                    _ => {
                        metrics.cache_misses.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        let mut out = vec![0u8; nbytes];
                        store.read_at(file_off, &mut out)?;
                        Ok(out)
                    }
                }
            }
        }
    }

    /// Partition `i` decoded as a typed buffer (col-major).
    pub fn partition_buf(&self, i: usize) -> Result<Buf> {
        Buf::from_bytes(self.dtype, &self.partition_bytes(i)?)
    }

    /// Whole matrix as one col-major `Buf` (small matrices / tests only).
    pub fn to_buf(&self) -> Result<Buf> {
        let n = (self.parts.nrow * self.parts.ncol) as usize;
        let mut out = Buf::alloc(self.dtype, n);
        let nrow = self.parts.nrow as usize;
        for i in 0..self.parts.n_parts() {
            let (r0, _) = self.parts.part_rows(i);
            let prows = self.parts.rows_in(i) as usize;
            let pb = self.partition_buf(i)?;
            for j in 0..self.parts.ncol as usize {
                let col = pb.slice(j * prows, prows);
                out.copy_from(j * nrow + r0 as usize, &col);
            }
        }
        Ok(out)
    }
}

/// Parallel-writable builder for a [`DenseData`]. Partitions are written
/// independently (each write locks only its target chunk / issues its own
/// positioned write), then the builder freezes into the immutable matrix.
pub struct DenseBuilder {
    dtype: DType,
    parts: Partitioning,
    mode: BuilderMode,
}

enum BuilderMode {
    Mem {
        chunks: Vec<Mutex<Chunk>>,
        slots: Vec<(usize, usize)>,
    },
    Ext {
        store: Arc<FileStore>,
        cache_cols: u64,
        cache: Option<Mutex<Vec<u8>>>,
        metrics: Arc<Metrics>,
    },
}

impl DenseBuilder {
    /// In-memory builder: pack partitions into pool chunks in order.
    pub fn new_mem(dtype: DType, parts: Partitioning, pool: &ChunkPool) -> Result<DenseBuilder> {
        let esz = dtype.size();
        let chunk_bytes = pool.chunk_bytes();
        let mut chunks = Vec::new();
        let mut slots = Vec::with_capacity(parts.n_parts());
        let mut cur_off = chunk_bytes; // force first allocation
        for i in 0..parts.n_parts() {
            let pb = parts.part_bytes(i, esz);
            if pb > chunk_bytes {
                return Err(FmError::Config(format!(
                    "partition ({pb} B) larger than chunk ({chunk_bytes} B)"
                )));
            }
            if cur_off + pb > chunk_bytes {
                chunks.push(Mutex::new(pool.acquire()));
                cur_off = 0;
            }
            slots.push((chunks.len() - 1, cur_off));
            cur_off += pb;
        }
        Ok(DenseBuilder {
            dtype,
            parts,
            mode: BuilderMode::Mem { chunks, slots },
        })
    }

    /// External-memory builder backed by a (possibly throttled) file.
    pub fn new_ext(
        dtype: DType,
        parts: Partitioning,
        dir: &std::path::Path,
        name: Option<&str>,
        cache_cols: u64,
        ssd: Arc<SsdSim>,
        metrics: Arc<Metrics>,
    ) -> Result<DenseBuilder> {
        let store = Arc::new(FileStore::create(
            dir,
            name,
            parts.total_bytes(dtype.size()),
            ssd,
            Arc::clone(&metrics),
        )?);
        let cache = if cache_cols > 0 {
            let cc = cache_cols.min(parts.ncol);
            Some(Mutex::new(vec![
                0u8;
                (parts.nrow * cc) as usize * dtype.size()
            ]))
        } else {
            None
        };
        Ok(DenseBuilder {
            dtype,
            parts,
            mode: BuilderMode::Ext {
                store,
                cache_cols,
                cache,
                metrics,
            },
        })
    }

    pub fn parts(&self) -> &Partitioning {
        &self.parts
    }

    pub fn dtype(&self) -> DType {
        self.dtype
    }

    /// Write partition `i` from col-major bytes. Thread-safe across
    /// distinct partitions. External matrices are write-through: bytes land
    /// on the file *and* (for the cached columns) in the memory cache
    /// (§III-B3).
    pub fn write_partition(&self, i: usize, bytes: &[u8]) -> Result<()> {
        let esz = self.dtype.size();
        let expect = self.parts.part_bytes(i, esz);
        if bytes.len() != expect {
            return Err(FmError::Shape(format!(
                "partition {i} write: got {} bytes, want {expect}",
                bytes.len()
            )));
        }
        match &self.mode {
            BuilderMode::Mem { chunks, slots } => {
                let (ci, off) = slots[i];
                let mut chunk = chunks[ci].lock().unwrap();
                chunk.bytes_mut()[off..off + bytes.len()].copy_from_slice(bytes);
                Ok(())
            }
            BuilderMode::Ext {
                store,
                cache_cols,
                cache,
                ..
            } => {
                store.write_at(self.parts.part_offset(i, esz), bytes)?;
                if let Some(c) = cache {
                    let cc = (*cache_cols).min(self.parts.ncol) as usize;
                    let prows = self.parts.rows_in(i) as usize;
                    let cached_bytes = cc * prows * esz;
                    let cache_off =
                        ((self.parts.part_offset(i, esz) / self.parts.ncol) * cc as u64) as usize;
                    c.lock().unwrap()[cache_off..cache_off + cached_bytes]
                        .copy_from_slice(&bytes[..cached_bytes]);
                }
                Ok(())
            }
        }
    }

    /// Write a typed buffer as partition `i`.
    pub fn write_partition_buf(&self, i: usize, buf: &Buf) -> Result<()> {
        if buf.dtype() != self.dtype {
            return Err(FmError::DType(format!(
                "partition write dtype {} != matrix dtype {}",
                buf.dtype(),
                self.dtype
            )));
        }
        self.write_partition(i, &buf.to_bytes())
    }

    /// Freeze into the immutable matrix.
    pub fn finish(self) -> DenseData {
        let backing = match self.mode {
            BuilderMode::Mem { chunks, slots } => Backing::Mem {
                chunks: chunks.into_iter().map(|m| m.into_inner().unwrap()).collect(),
                slots,
            },
            BuilderMode::Ext {
                store,
                cache_cols,
                cache,
                metrics,
            } => Backing::Ext {
                store,
                cache_cols,
                cache: cache.map(|m| m.into_inner().unwrap()),
                metrics,
            },
        };
        DenseData {
            dtype: self.dtype,
            parts: self.parts,
            backing,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::Scalar;

    fn pool() -> ChunkPool {
        ChunkPool::new(1 << 16, true, Arc::new(Metrics::new()))
    }

    fn seq_matrix(nrow: u64, ncol: u64, io_rows: u64) -> DenseData {
        let parts = Partitioning::with_io_rows(nrow, ncol, io_rows);
        let b = DenseBuilder::new_mem(DType::F64, parts.clone(), &pool()).unwrap();
        for i in 0..parts.n_parts() {
            let (r0, _) = parts.part_rows(i);
            let prows = parts.rows_in(i) as usize;
            let mut buf = Buf::alloc(DType::F64, prows * ncol as usize);
            for j in 0..ncol as usize {
                for r in 0..prows {
                    // value = global_row + 1000*col
                    buf.set(j * prows + r, Scalar::F64((r0 as usize + r) as f64 + 1000.0 * j as f64));
                }
            }
            b.write_partition_buf(i, &buf).unwrap();
        }
        b.finish()
    }

    #[test]
    fn mem_roundtrip_multi_partition() {
        let m = seq_matrix(300, 3, 128);
        assert_eq!(m.parts.n_parts(), 3);
        let full = m.to_buf().unwrap();
        // col-major full matrix: element (r, j) at j*nrow + r
        assert_eq!(full.get(0).as_f64(), 0.0);
        assert_eq!(full.get(299).as_f64(), 299.0);
        assert_eq!(full.get(300).as_f64(), 1000.0);
        assert_eq!(full.get(2 * 300 + 150).as_f64(), 2150.0);
    }

    #[test]
    fn ext_roundtrip_with_cache() {
        let dir = std::env::temp_dir().join(format!("fm-dense-test-{}", std::process::id()));
        let ssd = Arc::new(SsdSim::new(None));
        let metrics = Arc::new(Metrics::new());
        let parts = Partitioning::with_io_rows(256, 4, 128);
        let b = DenseBuilder::new_ext(
            DType::F64,
            parts.clone(),
            &dir,
            None,
            2, // cache first 2 columns
            ssd,
            Arc::clone(&metrics),
        )
        .unwrap();
        for i in 0..parts.n_parts() {
            let prows = parts.rows_in(i) as usize;
            let mut buf = Buf::alloc(DType::F64, prows * 4);
            for e in 0..buf.len() {
                buf.set(e, Scalar::F64((i * 10_000 + e) as f64));
            }
            b.write_partition_buf(i, &buf).unwrap();
        }
        let m = b.finish();
        // partition read must reconstruct cached + uncached columns
        let p1 = m.partition_buf(1).unwrap();
        assert_eq!(p1.get(0).as_f64(), 10_000.0);
        assert_eq!(p1.get(300).as_f64(), 10_300.0);
        assert!(metrics.snapshot().cache_hits > 0);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn oversized_partition_rejected() {
        let parts = Partitioning::with_io_rows(1 << 14, 1024, 1 << 14); // 128 MiB part
        assert!(DenseBuilder::new_mem(DType::F64, parts, &pool()).is_err());
    }

    #[test]
    fn wrong_size_write_rejected() {
        let parts = Partitioning::with_io_rows(100, 2, 64);
        let b = DenseBuilder::new_mem(DType::F64, parts, &pool()).unwrap();
        assert!(b.write_partition(0, &[0u8; 3]).is_err());
    }
}
