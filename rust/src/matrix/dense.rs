//! Physically-materialized dense matrices (paper §III-B).
//!
//! Physical storage is always the **tall-and-skinny canonical form**: rows
//! partitioned into I/O-level partitions, each partition stored
//! contiguously in **column-major** order (the paper's preferred layout for
//! TAS matrices, §III-G). Wide / row-major matrices are *transposed views*
//! over this canonical form ([`crate::matrix::MatrixData`]), which is
//! exactly how the paper avoids data copies on `t()`.
//!
//! Backing is either
//! * [`Backing::Mem`] — partitions packed into recycled fixed-size chunks
//!   from the [`ChunkPool`] (§III-B5), or
//! * [`Backing::Ext`] — a [`FileStore`] on the simulated SSD array, layered
//!   under the write-through memory hierarchy of §III-B3: the engine-wide
//!   **partition cache** ([`crate::matrix::cache::PartitionCache`], keyed by
//!   matrix id + partition index) and an optional first-`cache_cols` column
//!   cache. Reads consult the partition cache before touching the file;
//!   writes go through to both.

use std::sync::{Arc, Mutex};

use crate::dtype::DType;
use crate::error::{FmError, Result};
use crate::mem::{Chunk, ChunkPool};
use crate::metrics::Metrics;
use crate::runtime::manifest::{DenseColMeta, DenseMeta};
use crate::storage::{FileStore, SsdSim, StreamReader};
use crate::util::sync::LockExt;
use crate::vudf::Buf;

use super::cache::{CacheHandle, PartitionCache};
use super::partition::Partitioning;

/// Where a dense matrix's bytes live.
pub enum Backing {
    /// In-memory: chunks + per-partition (chunk index, byte offset).
    Mem {
        chunks: Vec<Chunk>,
        /// partition i -> (chunk index, byte offset within chunk)
        slots: Vec<(usize, usize)>,
    },
    /// External-memory file, partitions densely packed in order, plus an
    /// optional first-`cache_cols` column cache (write-through).
    Ext {
        store: Arc<FileStore>,
        cache_cols: u64,
        /// Col-major `nrow x cache_cols` cache, packed per partition in the
        /// same order as the file (only the first cache_cols columns).
        cache: Option<Vec<u8>>,
        metrics: Arc<Metrics>,
        /// Registration in the engine's write-through partition cache
        /// (§III-B3); `None` for uncached matrices (cache disabled, or a
        /// one-shot intermediate that must not pollute the cache).
        pcache: Option<CacheHandle>,
    },
}

/// A materialized TAS dense matrix. Immutable after construction
/// (the engine's functional semantics, §III-E).
pub struct DenseData {
    pub dtype: DType,
    pub parts: Partitioning,
    backing: Backing,
}

impl DenseData {
    pub fn nrow(&self) -> u64 {
        self.parts.nrow
    }

    pub fn ncol(&self) -> u64 {
        self.parts.ncol
    }

    /// Bytes of I/O-level partition `i` (col-major within the partition).
    /// In-memory: a copy out of the chunk. External: the write-through
    /// partition cache is consulted first (§III-B3); a miss costs one
    /// `pread` (or a column-cache-assisted partial read) and, for cached
    /// matrices, refills the cache.
    pub fn partition_bytes(&self, i: usize) -> Result<Vec<u8>> {
        match Arc::try_unwrap(self.partition_bytes_shared(i)?) {
            Ok(v) => Ok(v),               // sole owner: no extra copy
            Err(a) => Ok(a.as_ref().clone()), // cache keeps its reference
        }
    }

    /// [`partition_bytes`](Self::partition_bytes) behind an `Arc`: cached
    /// EM reads share the cache's buffer without copying — the pass hot
    /// path reads each source partition's bytes zero-copy out of the
    /// §III-B3 hierarchy.
    pub fn partition_bytes_shared(&self, i: usize) -> Result<Arc<Vec<u8>>> {
        let esz = self.dtype.size();
        let nbytes = self.parts.part_bytes(i, esz);
        match &self.backing {
            Backing::Mem { chunks, slots } => {
                let (ci, off) = slots[i];
                Ok(Arc::new(chunks[ci].bytes()[off..off + nbytes].to_vec()))
            }
            Backing::Ext {
                store,
                cache_cols,
                cache,
                metrics,
                pcache,
            } => {
                let prows = self.parts.rows_in(i) as usize;
                let file_off = self.parts.part_offset(i, esz);
                let col_cached = cache.as_ref().filter(|_| *cache_cols > 0);
                let read = || -> Result<Vec<u8>> {
                    match col_cached {
                        Some(cached) => {
                            // cached columns come from memory; read only the
                            // contiguous tail columns from the file.
                            let cc = (*cache_cols).min(self.parts.ncol) as usize;
                            let cache_part_off =
                                (self.parts.part_offset(i, esz) / self.parts.ncol) * cc as u64;
                            let cached_bytes = cc * prows * esz;
                            let mut out = vec![0u8; nbytes];
                            out[..cached_bytes].copy_from_slice(
                                &cached
                                    [cache_part_off as usize..cache_part_off as usize + cached_bytes],
                            );
                            if nbytes > cached_bytes {
                                store.read_at(
                                    file_off + cached_bytes as u64,
                                    &mut out[cached_bytes..],
                                )?;
                            }
                            Ok(out)
                        }
                        None => {
                            let mut out = vec![0u8; nbytes];
                            store.read_at(file_off, &mut out)?;
                            Ok(out)
                        }
                    }
                };
                match pcache {
                    // §III-B3 single-flight read-through: cache hit,
                    // coalesce with an in-flight read (a racing prefetch or
                    // another worker), or read the file as the leader and
                    // refill the cache.
                    Some(h) => h.cache.get_or_read(h.matrix_id, i, read),
                    None => {
                        // uncached matrices keep the column-cache accounting
                        if col_cached.is_some() {
                            metrics
                                .cache_hits
                                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        } else {
                            metrics
                                .cache_misses
                                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        }
                        read().map(Arc::new)
                    }
                }
            }
        }
    }

    /// Hint: asynchronously read partition `i` into the engine's partition
    /// cache so a following [`partition_bytes`](Self::partition_bytes)
    /// hits memory — I/O overlapped with compute (§III-B3). No-op for
    /// in-memory matrices, uncached matrices, out-of-range indices, or
    /// when read-ahead is disabled/backlogged. `pass` is the issuing
    /// pass's id (from [`PartitionCache::begin_pass`]); the prefetched
    /// partition stays pinned only while that pass is active.
    pub fn prefetch_partition(&self, i: usize, pass: u64) {
        if i >= self.parts.n_parts() {
            return;
        }
        if let Backing::Ext {
            store,
            pcache: Some(h),
            ..
        } = &self.backing
        {
            let esz = self.dtype.size();
            PartitionCache::prefetch(
                &h.cache,
                store,
                h.matrix_id,
                i,
                self.parts.part_offset(i, esz),
                self.parts.part_bytes(i, esz),
                pass,
            );
        }
    }

    /// Cache registration id of this matrix, if it reads through the
    /// engine's partition cache (`None` for in-memory / uncached
    /// matrices). Used by the multi-tenant layer to tag cache entries
    /// with their owning session.
    pub fn cache_matrix_id(&self) -> Option<u64> {
        match &self.backing {
            Backing::Ext {
                pcache: Some(h), ..
            } => Some(h.matrix_id),
            _ => None,
        }
    }

    /// Release read-ahead pins this matrix's partitions still hold. An
    /// aborted pass may never send the consumer a prefetched partition
    /// was pinned for (§III-B3); the exec layer calls this on the pass's
    /// sources so orphaned read-aheads stay evictable.
    pub fn release_prefetch_pins(&self) {
        if let Backing::Ext {
            pcache: Some(h), ..
        } = &self.backing
        {
            h.cache.release_prefetch_pins(h.matrix_id);
        }
    }

    /// Residency hint for planner-materialized intermediates
    /// ([`crate::plan`]): pin every partition of this matrix that is
    /// currently resident in the engine's write-through partition cache,
    /// shielding it from LRU eviction until
    /// [`unpin_resident`](Self::unpin_resident) releases it. Returns the
    /// pinned partition indices (pass them back to `unpin_resident`).
    /// No-op (empty) for in-memory or uncached matrices.
    pub fn pin_resident(&self) -> Vec<usize> {
        let mut pinned = Vec::new();
        if let Backing::Ext {
            pcache: Some(h), ..
        } = &self.backing
        {
            for i in 0..self.parts.n_parts() {
                if h.cache.pin(h.matrix_id, i) {
                    pinned.push(i);
                }
            }
        }
        pinned
    }

    /// Release residency pins taken by [`pin_resident`](Self::pin_resident).
    pub fn unpin_resident(&self, pinned: &[usize]) {
        if let Backing::Ext {
            pcache: Some(h), ..
        } = &self.backing
        {
            for &i in pinned {
                h.cache.unpin(h.matrix_id, i);
            }
        }
    }

    /// Partition `i` decoded as a typed buffer (col-major).
    pub fn partition_buf(&self, i: usize) -> Result<Buf> {
        Buf::from_bytes(self.dtype, &self.partition_bytes(i)?)
    }

    /// Whole matrix as one col-major `Buf` (small matrices / tests only).
    ///
    /// External matrices run a double-buffered sequential scan through
    /// [`StreamReader`]: partition `i+1` is in flight while partition `i`
    /// is being assembled (the §III-B3 I/O/compute overlap). Partitions
    /// already resident in the matrix cache are served from memory and
    /// skipped in the stream (write-through keeps both sides identical).
    pub fn to_buf(&self) -> Result<Buf> {
        let n = (self.parts.nrow * self.parts.ncol) as usize;
        let mut out = Buf::alloc(self.dtype, n);
        let nrow = self.parts.nrow as usize;
        let n_parts = self.parts.n_parts();

        let mut streamed: Option<StreamReader> = None;
        let mut resident: Vec<Option<Arc<Vec<u8>>>> = Vec::new();
        if let Backing::Ext {
            store,
            cache_cols,
            cache,
            pcache,
            ..
        } = &self.backing
        {
            // with a column cache in play, partial reads must go through
            // partition_bytes (which serves the cached columns from
            // memory); streaming whole partitions would re-read them
            if cache.is_none() || *cache_cols == 0 {
                let esz = self.dtype.size();
                // peek, not get: absent partitions are served by the
                // stream below, so counting them as cache misses would
                // skew the ablation numbers
                resident = (0..n_parts)
                    .map(|i| pcache.as_ref().and_then(|h| h.cache.peek(h.matrix_id, i)))
                    .collect();
                let ranges: Vec<(u64, usize)> = (0..n_parts)
                    .filter(|&i| resident[i].is_none())
                    .map(|i| (self.parts.part_offset(i, esz), self.parts.part_bytes(i, esz)))
                    .collect();
                streamed = Some(StreamReader::new(Arc::clone(store), ranges, 2));
            }
        }

        for i in 0..n_parts {
            let from_cache = resident.get(i).and_then(|c| c.clone());
            let owned: Vec<u8>;
            let bytes: &[u8] = match (&from_cache, &streamed) {
                (Some(b), _) => b.as_slice(),
                (None, Some(r)) => {
                    owned = r
                        .next()
                        .ok_or_else(|| FmError::Storage("partition stream ended early".into()))??;
                    &owned
                }
                (None, None) => {
                    owned = self.partition_bytes(i)?;
                    &owned
                }
            };
            let (r0, _) = self.parts.part_rows(i);
            let prows = self.parts.rows_in(i) as usize;
            let pb = Buf::from_bytes(self.dtype, bytes)?;
            for j in 0..self.parts.ncol as usize {
                let col = pb.slice(j * prows, prows);
                out.copy_from(j * nrow + r0 as usize, &col);
            }
        }
        Ok(out)
    }

    /// Per-partition `(offset, len)` table of this matrix's packed file
    /// layout, in partition order.
    fn part_table(&self) -> Vec<(u64, usize)> {
        let esz = self.dtype.size();
        (0..self.parts.n_parts())
            .map(|i| (self.parts.part_offset(i, esz), self.parts.part_bytes(i, esz)))
            .collect()
    }

    /// Persist the `<name>.dense.json` sidecar for a *named* external
    /// matrix, so [`open_named`](Self::open_named) can reattach across
    /// engine restarts with the dtype, shape, and write-time partition
    /// CRCs intact. `cols` carries the ingestion column schema
    /// ([`crate::ingest`]); pass `&[]` for schema-less datasets.
    pub fn save_named_meta(
        &self,
        dir: &std::path::Path,
        name: &str,
        cols: &[DenseColMeta],
    ) -> Result<()> {
        let store = match &self.backing {
            Backing::Ext { store, .. } => store,
            Backing::Mem { .. } => {
                return Err(FmError::Unsupported(
                    "save_named_meta: matrix is in-memory, not a named external file".into(),
                ))
            }
        };
        let meta = DenseMeta {
            nrow: self.parts.nrow,
            ncol: self.parts.ncol,
            io_rows: self.parts.io_rows,
            dtype: self.dtype,
            crcs: store.checksums().export(&self.part_table()),
            cols: cols.to_vec(),
        };
        meta.save(&dir.join(format!("{name}.dense.json")))
    }

    /// Reopen a *named* external dense matrix saved in `dir`: load the
    /// `<name>.dense.json` sidecar, open the packed file, verify its
    /// length against the recorded partitioning, and seed the store's
    /// checksum table from the sidecar CRCs so at-rest corruption
    /// surfaces on first read (same contract as
    /// [`SparseData::open_named`](crate::matrix::SparseData::open_named)).
    /// Returns the matrix plus its sidecar (for factor level tables).
    pub fn open_named(
        dir: &std::path::Path,
        name: &str,
        ssd: Arc<SsdSim>,
        metrics: Arc<Metrics>,
        pcache: Option<Arc<PartitionCache>>,
    ) -> Result<(DenseData, DenseMeta)> {
        let meta = DenseMeta::load(&dir.join(format!("{name}.dense.json")))?;
        let parts = Partitioning::with_io_rows(meta.nrow, meta.ncol, meta.io_rows);
        let store = FileStore::open(&dir.join(name), ssd, Arc::clone(&metrics))?;
        let want = parts.total_bytes(meta.dtype.size());
        if store.len() != want {
            return Err(FmError::Corrupt(format!(
                "dense dataset '{name}': file is {} bytes, manifest implies {want}",
                store.len()
            )));
        }
        if meta.crcs.len() != parts.n_parts() {
            return Err(FmError::Corrupt(format!(
                "dense dataset '{name}': {} checksums for {} partitions",
                meta.crcs.len(),
                parts.n_parts()
            )));
        }
        let esz = meta.dtype.size();
        store.checksums().seed((0..parts.n_parts()).filter_map(|i| {
            meta.crcs[i].map(|crc| (parts.part_offset(i, esz), parts.part_bytes(i, esz), crc))
        }));
        Ok((
            DenseData {
                dtype: meta.dtype,
                parts,
                backing: Backing::Ext {
                    store: Arc::new(store),
                    cache_cols: 0,
                    cache: None,
                    metrics,
                    pcache: pcache.map(CacheHandle::register),
                },
            },
            meta,
        ))
    }
}

/// Parallel-writable builder for a [`DenseData`]. Partitions are written
/// independently (each write locks only its target chunk / issues its own
/// positioned write), then the builder freezes into the immutable matrix.
pub struct DenseBuilder {
    dtype: DType,
    parts: Partitioning,
    mode: BuilderMode,
}

/// A builder's registration with the engine cache's asynchronous
/// write-back writer (§III-B3): partition writes are queued to the
/// background thread under `id` instead of stalling the worker on a
/// synchronous `pwrite`. The creating pass must end with
/// [`DenseBuilder::flush_writes`] (success) or
/// [`DenseBuilder::discard_writes`] (abort) before the builder is frozen
/// or dropped — `exec::run_pass` owns that barrier.
struct WbHandle {
    cache: Arc<PartitionCache>,
    id: u64,
}

enum BuilderMode {
    Mem {
        chunks: Vec<Mutex<Chunk>>,
        slots: Vec<(usize, usize)>,
    },
    Ext {
        store: Arc<FileStore>,
        cache_cols: u64,
        cache: Option<Mutex<Vec<u8>>>,
        metrics: Arc<Metrics>,
        pcache: Option<CacheHandle>,
        wb: Option<WbHandle>,
    },
}

impl DenseBuilder {
    /// In-memory builder: pack partitions into pool chunks in order.
    pub fn new_mem(dtype: DType, parts: Partitioning, pool: &ChunkPool) -> Result<DenseBuilder> {
        let esz = dtype.size();
        let chunk_bytes = pool.chunk_bytes();
        let mut chunks = Vec::new();
        let mut slots = Vec::with_capacity(parts.n_parts());
        let mut cur_off = chunk_bytes; // force first allocation
        for i in 0..parts.n_parts() {
            let pb = parts.part_bytes(i, esz);
            if pb > chunk_bytes {
                return Err(FmError::Config(format!(
                    "partition ({pb} B) larger than chunk ({chunk_bytes} B)"
                )));
            }
            if cur_off + pb > chunk_bytes {
                chunks.push(Mutex::new(pool.acquire()));
                cur_off = 0;
            }
            slots.push((chunks.len() - 1, cur_off));
            cur_off += pb;
        }
        Ok(DenseBuilder {
            dtype,
            parts,
            mode: BuilderMode::Mem { chunks, slots },
        })
    }

    /// External-memory builder backed by a (possibly throttled) file.
    /// `pcache` registers the matrix with the engine's write-through
    /// partition cache (§III-B3); pass `None` for one-shot intermediates
    /// that must not pollute the cache (the `fmr` residency decision).
    pub fn new_ext(
        dtype: DType,
        parts: Partitioning,
        dir: &std::path::Path,
        name: Option<&str>,
        cache_cols: u64,
        ssd: Arc<SsdSim>,
        metrics: Arc<Metrics>,
        pcache: Option<Arc<PartitionCache>>,
    ) -> Result<DenseBuilder> {
        let store = Arc::new(FileStore::create(
            dir,
            name,
            parts.total_bytes(dtype.size()),
            ssd,
            Arc::clone(&metrics),
        )?);
        let cache = if cache_cols > 0 {
            let cc = cache_cols.min(parts.ncol);
            Some(Mutex::new(vec![
                0u8;
                (parts.nrow * cc) as usize * dtype.size()
            ]))
        } else {
            None
        };
        Ok(DenseBuilder {
            dtype,
            parts,
            mode: BuilderMode::Ext {
                store,
                cache_cols,
                cache,
                metrics,
                pcache: pcache.map(CacheHandle::register),
                wb: None,
            },
        })
    }

    /// Route this builder's partition writes through `cache`'s
    /// asynchronous write-back writer (§III-B3) instead of synchronous
    /// write-through. No-op for in-memory builders or when the cache has
    /// no writer thread (`writeback` off). The caller owns the pass-end
    /// barrier: [`flush_writes`](Self::flush_writes) before
    /// [`finish`](Self::finish) on success,
    /// [`discard_writes`](Self::discard_writes) on abort.
    pub fn enable_writeback(&mut self, cache: Arc<PartitionCache>) {
        if !cache.writeback_enabled() {
            return;
        }
        if let BuilderMode::Ext { wb, pcache, .. } = &mut self.mode {
            // cache-resident builders share the matrix id with their
            // cache registration; write-back-only builders get a fresh
            // key namespace
            let id = pcache
                .as_ref()
                .map(|h| h.matrix_id)
                .unwrap_or_else(|| cache.alloc_wb_id());
            *wb = Some(WbHandle { cache, id });
        }
    }

    /// Write-back flush barrier: block until every queued write of this
    /// builder landed on the file, surfacing the first write error. The
    /// file is authoritative again when this returns — callers must
    /// flush before [`finish`](Self::finish). No-op without write-back.
    pub fn flush_writes(&self) -> Result<()> {
        if let BuilderMode::Ext { wb: Some(w), .. } = &self.mode {
            w.cache.flush_writes(w.id)?;
        }
        Ok(())
    }

    /// Abort-path discard: drop this builder's queued writes and wait out
    /// an in-flight one, so a doomed pass leaves no partial partitions on
    /// disk and the backing file can be unlinked safely. No-op without
    /// write-back.
    pub fn discard_writes(&self) {
        if let BuilderMode::Ext { wb: Some(w), .. } = &self.mode {
            w.cache.discard_writes(w.id);
        }
    }

    pub fn parts(&self) -> &Partitioning {
        &self.parts
    }

    pub fn dtype(&self) -> DType {
        self.dtype
    }

    /// Cache registration id of the matrix being built, if its partitions
    /// land in the engine's partition cache (`None` for in-memory or
    /// non-resident builders). Lets the exec layer tag the entries with
    /// the submitting session before any partition is written.
    pub fn cache_matrix_id(&self) -> Option<u64> {
        match &self.mode {
            BuilderMode::Ext {
                pcache: Some(h), ..
            } => Some(h.matrix_id),
            _ => None,
        }
    }

    /// Write partition `i` from col-major bytes. Thread-safe across
    /// distinct partitions. External matrices land in the whole memory
    /// hierarchy (§III-B3): the engine's partition cache, the column
    /// cache for the cached columns, and the file — synchronously
    /// (write-through) or, with
    /// [`enable_writeback`](Self::enable_writeback), via the background
    /// writer so the worker moves on immediately (the file then becomes
    /// authoritative at the pass-end [`flush_writes`](Self::flush_writes)
    /// barrier).
    pub fn write_partition(&self, i: usize, bytes: &[u8]) -> Result<()> {
        let esz = self.dtype.size();
        let expect = self.parts.part_bytes(i, esz);
        if bytes.len() != expect {
            return Err(FmError::Shape(format!(
                "partition {i} write: got {} bytes, want {expect}",
                bytes.len()
            )));
        }
        match &self.mode {
            BuilderMode::Mem { chunks, slots } => {
                let (ci, off) = slots[i];
                let mut chunk = chunks[ci].lock_recover();
                chunk.bytes_mut()[off..off + bytes.len()].copy_from_slice(bytes);
                Ok(())
            }
            BuilderMode::Ext {
                store,
                cache_cols,
                cache,
                pcache,
                wb,
                ..
            } => {
                let off = self.parts.part_offset(i, esz);
                let queued = if let Some(w) = wb {
                    // asynchronous write-back (§III-B3): hand the finished
                    // partition to the background writer and move on — the
                    // dirty queue and the partition cache share one buffer
                    let shared = Arc::new(bytes.to_vec());
                    let q = w
                        .cache
                        .enqueue_write(store, w.id, i, off, Arc::clone(&shared));
                    if q {
                        if let Some(h) = pcache {
                            h.cache.insert_shared(h.matrix_id, i, shared);
                        }
                    }
                    q
                } else {
                    false
                };
                if !queued {
                    // synchronous write-through
                    store.write_at(off, bytes)?;
                    if let Some(h) = pcache {
                        h.cache.insert(h.matrix_id, i, bytes.to_vec());
                    }
                }
                if let Some(c) = cache {
                    let cc = (*cache_cols).min(self.parts.ncol) as usize;
                    let prows = self.parts.rows_in(i) as usize;
                    let cached_bytes = cc * prows * esz;
                    let cache_off = ((off / self.parts.ncol) * cc as u64) as usize;
                    c.lock_recover()[cache_off..cache_off + cached_bytes]
                        .copy_from_slice(&bytes[..cached_bytes]);
                }
                Ok(())
            }
        }
    }

    /// Write a typed buffer as partition `i`.
    pub fn write_partition_buf(&self, i: usize, buf: &Buf) -> Result<()> {
        if buf.dtype() != self.dtype {
            return Err(FmError::DType(format!(
                "partition write dtype {} != matrix dtype {}",
                buf.dtype(),
                self.dtype
            )));
        }
        self.write_partition(i, &buf.to_bytes())
    }

    /// Freeze into the immutable matrix.
    pub fn finish(self) -> DenseData {
        let backing = match self.mode {
            BuilderMode::Mem { chunks, slots } => Backing::Mem {
                chunks: chunks.into_iter().map(LockExt::into_inner_recover).collect(),
                slots,
            },
            BuilderMode::Ext {
                store,
                cache_cols,
                cache,
                metrics,
                pcache,
                // the write-back registration ends with the builder; the
                // pass barrier (flush/discard) has already run
                wb: _,
            } => Backing::Ext {
                store,
                cache_cols,
                cache: cache.map(LockExt::into_inner_recover),
                metrics,
                pcache,
            },
        };
        DenseData {
            dtype: self.dtype,
            parts: self.parts,
            backing,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::Scalar;

    fn pool() -> ChunkPool {
        ChunkPool::new(1 << 16, true, Arc::new(Metrics::new()))
    }

    fn seq_matrix(nrow: u64, ncol: u64, io_rows: u64) -> DenseData {
        let parts = Partitioning::with_io_rows(nrow, ncol, io_rows);
        let b = DenseBuilder::new_mem(DType::F64, parts.clone(), &pool()).unwrap();
        for i in 0..parts.n_parts() {
            let (r0, _) = parts.part_rows(i);
            let prows = parts.rows_in(i) as usize;
            let mut buf = Buf::alloc(DType::F64, prows * ncol as usize);
            for j in 0..ncol as usize {
                for r in 0..prows {
                    // value = global_row + 1000*col
                    buf.set(
                        j * prows + r,
                        Scalar::F64((r0 as usize + r) as f64 + 1000.0 * j as f64),
                    );
                }
            }
            b.write_partition_buf(i, &buf).unwrap();
        }
        b.finish()
    }

    #[test]
    fn mem_roundtrip_multi_partition() {
        let m = seq_matrix(300, 3, 128);
        assert_eq!(m.parts.n_parts(), 3);
        let full = m.to_buf().unwrap();
        // col-major full matrix: element (r, j) at j*nrow + r
        assert_eq!(full.get(0).as_f64(), 0.0);
        assert_eq!(full.get(299).as_f64(), 299.0);
        assert_eq!(full.get(300).as_f64(), 1000.0);
        assert_eq!(full.get(2 * 300 + 150).as_f64(), 2150.0);
    }

    #[test]
    fn ext_roundtrip_with_cache() {
        let dir = std::env::temp_dir().join(format!("fm-dense-test-{}", std::process::id()));
        let ssd = Arc::new(SsdSim::new(None));
        let metrics = Arc::new(Metrics::new());
        let parts = Partitioning::with_io_rows(256, 4, 128);
        let b = DenseBuilder::new_ext(
            DType::F64,
            parts.clone(),
            &dir,
            None,
            2, // cache first 2 columns
            ssd,
            Arc::clone(&metrics),
            None,
        )
        .unwrap();
        for i in 0..parts.n_parts() {
            let prows = parts.rows_in(i) as usize;
            let mut buf = Buf::alloc(DType::F64, prows * 4);
            for e in 0..buf.len() {
                buf.set(e, Scalar::F64((i * 10_000 + e) as f64));
            }
            b.write_partition_buf(i, &buf).unwrap();
        }
        let m = b.finish();
        // partition read must reconstruct cached + uncached columns
        let p1 = m.partition_buf(1).unwrap();
        assert_eq!(p1.get(0).as_f64(), 10_000.0);
        assert_eq!(p1.get(300).as_f64(), 10_300.0);
        assert!(metrics.snapshot().cache_hits > 0);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn partition_cache_write_through_consistency() {
        let tmp = crate::testutil::TempDir::new("dense-pcache");
        let dir = tmp.path().to_path_buf();
        let ssd = Arc::new(SsdSim::new(None));
        let metrics = Arc::new(Metrics::new());
        let pc = PartitionCache::new(1 << 20, 0, 0, Arc::clone(&metrics));
        let parts = Partitioning::with_io_rows(256, 2, 128);
        let b = DenseBuilder::new_ext(
            DType::F64,
            parts.clone(),
            &dir,
            None,
            0,
            ssd,
            Arc::clone(&metrics),
            Some(Arc::clone(&pc)),
        )
        .unwrap();
        for i in 0..parts.n_parts() {
            let prows = parts.rows_in(i) as usize;
            let mut buf = Buf::alloc(DType::F64, prows * 2);
            for e in 0..buf.len() {
                buf.set(e, Scalar::F64((i * 1000 + e) as f64));
            }
            b.write_partition_buf(i, &buf).unwrap();
        }
        let m = b.finish();
        assert_eq!(pc.len(), 2, "write-through must populate the cache");

        // a cached read serves from memory: no file I/O
        let before = metrics.snapshot();
        let hit_copy = m.partition_bytes(0).unwrap();
        let after = metrics.snapshot();
        assert_eq!(after.cache_hits - before.cache_hits, 1);
        assert_eq!(
            after.io_read_reqs, before.io_read_reqs,
            "cache hit must not touch the file"
        );

        // force eviction by pressure from another matrix id, then re-read:
        // the file alone must reproduce the same bytes (write-through)
        pc.insert(999, 0, vec![0u8; 700_000]);
        pc.insert(999, 1, vec![0u8; 700_000]);
        let miss_copy = m.partition_bytes(0).unwrap();
        assert_eq!(hit_copy, miss_copy, "file and cache must agree");
        assert!(metrics.snapshot().cache_evictions > 0);

        // the miss refilled the cache; dropping the matrix evicts its keys
        let len_before_drop = pc.len();
        drop(m);
        assert!(pc.len() < len_before_drop, "drop must evict the matrix");
    }

    #[test]
    fn writeback_builder_matches_write_through() {
        let tmp = crate::testutil::TempDir::new("dense-wb");
        let ssd = Arc::new(SsdSim::new(None));
        let metrics = Arc::new(Metrics::new());
        let pc = PartitionCache::new(1 << 20, 0, 1 << 20, Arc::clone(&metrics));
        let parts = Partitioning::with_io_rows(256, 2, 128);
        let mk = |writeback: bool, sub: &str| {
            let mut b = DenseBuilder::new_ext(
                DType::F64,
                parts.clone(),
                &tmp.path().join(sub),
                None,
                0,
                Arc::clone(&ssd),
                Arc::clone(&metrics),
                Some(Arc::clone(&pc)),
            )
            .unwrap();
            if writeback {
                b.enable_writeback(Arc::clone(&pc));
            }
            for i in 0..parts.n_parts() {
                let prows = parts.rows_in(i) as usize;
                let mut buf = Buf::alloc(DType::F64, prows * 2);
                for e in 0..buf.len() {
                    buf.set(e, crate::dtype::Scalar::F64((i * 1000 + e) as f64));
                }
                b.write_partition_buf(i, &buf).unwrap();
            }
            b.flush_writes().unwrap(); // the pass-end barrier
            b.finish()
        };
        let wt = mk(false, "wt");
        let wb = mk(true, "wb");
        assert!(metrics.snapshot().wb_enqueued >= 2);
        // bit-identical through the cache AND through the file alone
        assert_eq!(wt.to_buf().unwrap(), wb.to_buf().unwrap());
        pc.clear();
        assert_eq!(
            wt.partition_bytes(1).unwrap(),
            wb.partition_bytes(1).unwrap(),
            "flushed write-back file must match write-through"
        );
    }

    #[test]
    fn oversized_partition_rejected() {
        let parts = Partitioning::with_io_rows(1 << 14, 1024, 1 << 14); // 128 MiB part
        assert!(DenseBuilder::new_mem(DType::F64, parts, &pool()).is_err());
    }

    #[test]
    fn wrong_size_write_rejected() {
        let parts = Partitioning::with_io_rows(100, 2, 64);
        let b = DenseBuilder::new_mem(DType::F64, parts, &pool()).unwrap();
        assert!(b.write_partition(0, &[0u8; 3]).is_err());
    }
}
